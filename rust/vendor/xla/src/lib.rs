//! Offline stand-in for the internal `xla` PJRT bindings.
//!
//! Mirrors exactly the API subset `yt_stream::runtime` and
//! `yt_stream::compute::hlo` consume — `PjRtClient`, `HloModuleProto`,
//! `XlaComputation`, `PjRtLoadedExecutable`, `PjRtBuffer`, `Literal`,
//! `Error` — but [`PjRtClient::cpu`] fails immediately, so everything
//! downstream degrades to the artifact-unavailable skip/error paths.
//! Replace the path dependency with the real bindings to execute AOT
//! artifacts.

use std::fmt;

/// The stub's only error: PJRT is not actually linked in.
#[derive(Debug)]
pub struct Error(pub String);

impl Error {
    fn stub() -> Error {
        Error("xla stub: PJRT bindings not linked (vendor/xla is an offline stand-in)".into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Element types a [`Literal`] can carry (the subset the stages use).
pub trait ElementType: Copy {}
impl ElementType for u32 {}
impl ElementType for i32 {}
impl ElementType for i64 {}
impl ElementType for u64 {}
impl ElementType for f32 {}
impl ElementType for f64 {}

/// A host-side literal (stub: carries nothing).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: ElementType>(_xs: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn scalar<T: ElementType>(_x: T) -> Literal {
        Literal { _private: () }
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>, Error> {
        Err(Error::stub())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(Error::stub())
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error::stub())
    }
}

/// An XLA computation built from a proto (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A device buffer returned by execution (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::stub())
    }
}

/// A compiled executable (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::stub())
    }
}

/// The PJRT client (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::stub())
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("stub"));
    }
}
