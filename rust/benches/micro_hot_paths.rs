//! Micro-benchmarks of the hot paths identified in EXPERIMENTS.md §Perf:
//! row codec, shuffle hash, compute stages (native + HLO), GetRows round
//! trip, dynamic-table commit, window push/ack — plus the per-row vs
//! batched comparisons backing the PR 6 columnar/group-commit work and
//! the PR 7 consistency-tier pair (state persisted every commit vs only
//! at bounded-error anchors), the PR 8 cold-chunk encode/scan pair, and
//! the PR 10 flight-recorder span-record trio (baseline / disabled /
//! enabled around the same RMW commit).
//!
//! Run with `cargo bench --bench micro_hot_paths`. Output is one line per
//! benchmark (benchkit format); set `BENCHKIT_JSON=/path/BENCH_<pr>.json`
//! to additionally emit the machine-readable document.

use std::sync::Arc;

use yt_stream::compute::native::NativeStage;
use yt_stream::compute::{fnv1a32, ComputeStage};
use yt_stream::row;
use yt_stream::rows::{codec, NameTable, RowsetBuilder, UnversionedRowset};
use yt_stream::util::benchkit::{black_box, Bench};
use yt_stream::util::{Clock, Prng};

fn sample_rowset(rows: usize) -> UnversionedRowset {
    let nt = NameTable::new(&["user", "cluster", "ts"]);
    let mut b = RowsetBuilder::new(nt);
    let mut rng = Prng::seeded(1);
    for i in 0..rows {
        b.push(row![
            format!("user-{}", rng.next_below(500)),
            "hahn",
            i as i64
        ]);
    }
    b.build()
}

fn bench_codec() {
    let rs = sample_rowset(1024);
    let bytes = codec::encode_rowset(&rs);
    let payload = rs.byte_size() as u64;

    Bench::new("codec/encode_rowset_1024")
        .throughput_bytes(payload)
        .run(|| {
            black_box(codec::encode_rowset(&rs));
        });
    Bench::new("codec/decode_rowset_1024")
        .throughput_bytes(payload)
        .run(|| {
            black_box(codec::decode_rowset(&bytes).unwrap());
        });
    // The attachment path: bytes already live in an Arc, decode is fully
    // zero-copy (string cells are views into `shared`).
    let shared: Arc<[u8]> = bytes.clone().into();
    Bench::new("codec/decode_rowset_shared_1024")
        .throughput_bytes(payload)
        .run(|| {
            black_box(codec::decode_rowset_shared(&shared).unwrap());
        });
}

fn bench_hash_and_stages() {
    let users: Vec<String> = (0..1024).map(|i| format!("user-{i}")).collect();
    Bench::new("hash/fnv1a32_1024_keys")
        .throughput_items(1024)
        .run(|| {
            for u in &users {
                black_box(fnv1a32(u));
            }
        });

    let mut rng = Prng::seeded(2);
    let uh: Vec<u32> = (0..4096).map(|_| rng.next_u64() as u32).collect();
    let ch: Vec<u32> = (0..4096).map(|_| rng.next_u64() as u32).collect();
    let hu: Vec<bool> = (0..4096).map(|_| rng.chance(0.15)).collect();
    let native = NativeStage;
    Bench::new("stage/native_map_4096")
        .throughput_items(4096)
        .run(|| {
            black_box(native.map_stage(&uh, &ch, &hu, 10));
        });

    let slots: Vec<u32> = (0..4096).map(|_| rng.next_below(256) as u32).collect();
    let ts: Vec<f32> = (0..4096).map(|_| rng.next_f64() as f32).collect();
    let valid = vec![true; 4096];
    Bench::new("stage/native_reduce_4096x256")
        .throughput_items(4096)
        .run(|| {
            black_box(native.reduce_stage(&slots, &ts, &valid, 256));
        });

    // HLO stages (skipped without artifacts).
    if let Ok(hlo) = yt_stream::compute::hlo::HloStage::load(std::path::Path::new("artifacts")) {
        Bench::new("stage/hlo_map_4096")
            .throughput_items(4096)
            .run(|| {
                black_box(hlo.map_stage(&uh, &ch, &hu, 10));
            });
        Bench::new("stage/hlo_reduce_4096x256")
            .throughput_items(4096)
            .run(|| {
                black_box(hlo.reduce_stage(&slots, &ts, &valid, 256));
            });
    } else {
        eprintln!("note: artifacts missing, skipping hlo stage benches");
    }
}

fn bench_rpc_getrows() {
    use yt_stream::rpc::{Attachment, ReqGetRows, Request, Response, RpcNet, RpcService};

    struct Server {
        attachment: Attachment,
    }
    impl RpcService for Server {
        fn handle(&self, req: Request) -> Result<Response, String> {
            match req {
                // Serve the shared Arc bytes: the clone below is a
                // refcount bump, so the bench measures transport, not
                // memcpy of the attachment.
                Request::GetRows(_) => Ok(Response::GetRows(yt_stream::rpc::RspGetRows {
                    row_count: 1024,
                    last_shuffle_row_index: 1023,
                    attachment: self.attachment.clone(),
                    drained: false,
                })),
                Request::Ping => Ok(Response::Pong),
            }
        }
    }

    let net = RpcNet::new(Clock::realtime(), Prng::seeded(3));
    let attachment: Attachment = codec::encode_rowset(&sample_rowset(1024)).into();
    let bytes = attachment.len() as u64;
    net.register("m0", Arc::new(Server { attachment }));
    Bench::new("rpc/getrows_roundtrip_1024rows")
        .throughput_bytes(bytes)
        .run(|| {
            let rsp = net
                .call(
                    "r0",
                    "m0",
                    Request::GetRows(ReqGetRows {
                        count: 1024,
                        reducer_index: 0,
                        epoch: 0,
                        committed_row_index: -1,
                        mapper_id: "g".into(),
                    }),
                )
                .unwrap();
            black_box(rsp);
        });
}

fn bench_dyntable() {
    use yt_stream::coordinator::processor::ClusterEnv;
    use yt_stream::rows::{ColumnSchema, ColumnType, TableSchema};
    use yt_stream::storage::WriteCategory;

    let env = ClusterEnv::new(Clock::realtime(), 4);
    env.store
        .create_table(
            "t",
            TableSchema::new(vec![
                ColumnSchema::key("k", ColumnType::Int64),
                ColumnSchema::value("v", ColumnType::Str),
            ]),
            WriteCategory::UserOutput,
        )
        .unwrap();
    let mut k = 0i64;
    Bench::new("dyntable/txn_rmw_commit").run(|| {
        k += 1;
        let key = k % 1000;
        let mut txn = env.store.begin();
        let _ = txn
            .lookup("t", &[yt_stream::rows::Value::Int64(key)])
            .unwrap();
        txn.write("t", row![key, "value"]).unwrap();
        txn.commit().unwrap();
    });
}

fn bench_window() {
    use yt_stream::coordinator::bucket::{BucketRow, BucketState};
    use yt_stream::coordinator::window::{WindowEntry, WindowQueue};
    use yt_stream::queue::ContinuationToken;

    Bench::new("window/push_route_ack_trim_64rows")
        .throughput_items(64)
        .run(|| {
            let mut window = WindowQueue::new();
            let mut bucket = BucketState::new();
            let rowset = sample_rowset(64);
            let byte_size = rowset.byte_size();
            let entry_index = window.next_entry_index();
            window.push(WindowEntry {
                entry_index,
                rowset,
                input_begin: 0,
                input_end: 64,
                shuffle_begin: 0,
                shuffle_end: 64,
                continuation_token: ContinuationToken::initial(),
                bucket_ptr_count: 0,
                byte_size,
                read_ts_ms: 0,
                min_event_ts: None,
            });
            for i in 0..64 {
                if bucket.push(BucketRow {
                    shuffle_index: i,
                    entry_index,
                }) {
                    window.get_mut(entry_index).unwrap().bucket_ptr_count += 1;
                }
            }
            let ack = bucket.ack(63);
            if let Some(old) = ack.old_head_entry {
                if ack.new_head_entry != ack.old_head_entry {
                    window.get_mut(old).unwrap().bucket_ptr_count -= 1;
                }
            }
            black_box(window.trim_front());
        });
}

/// Per-row vs batched encode+hash: the same rowset pays either one codec
/// dispatch and hash-state setup per ROW, or one per BATCH.
fn bench_row_batch() {
    use yt_stream::api::partitioning;
    use yt_stream::rows::RowBatch;

    let rs = sample_rowset(1024);
    let payload = rs.byte_size() as u64;

    Bench::new("rows/per_row_encode_hash_1024")
        .throughput_bytes(payload)
        .run(|| {
            for row in rs.rows() {
                black_box(codec::encode_rows(std::slice::from_ref(row)));
                let user = row.get(0).and_then(|v| v.as_str()).unwrap();
                let cluster = row.get(1).and_then(|v| v.as_str()).unwrap();
                black_box(partitioning::composite_key_hash(&[user, cluster]));
            }
        });
    Bench::new("rows/batch_encode_hash_1024")
        .throughput_bytes(payload)
        .run(|| {
            let batch = RowBatch::from_rowset(&rs);
            black_box(batch.encode());
            black_box(batch.key_hash_column(&[0, 1]));
        });
    // Vectorized hash column straight off the row-major set (the mapper
    // fast path when no columnar conversion is wanted).
    Bench::new("rows/hash_column_of_1024")
        .throughput_items(1024)
        .run(|| {
            black_box(RowBatch::key_hash_column_of(&rs, &[0, 1]));
        });
}

/// Grouped vs per-row CAS validation: a commit that must fence N rows
/// pays either N store round trips or one `lookup_many` pass.
fn bench_group_commit() {
    use yt_stream::coordinator::processor::ClusterEnv;
    use yt_stream::rows::{ColumnSchema, ColumnType, TableSchema, Value};
    use yt_stream::storage::WriteCategory;

    let env = ClusterEnv::new(Clock::realtime(), 4);
    env.store
        .create_table(
            "cas",
            TableSchema::new(vec![
                ColumnSchema::key("k", ColumnType::Int64),
                ColumnSchema::value("v", ColumnType::Str),
            ]),
            WriteCategory::ReducerMeta,
        )
        .unwrap();
    for k in 0..10i64 {
        let mut txn = env.store.begin();
        txn.write("cas", row![k, "seed"]).unwrap();
        txn.commit().unwrap();
    }

    let mut n = 0i64;
    Bench::new("dyntable/commit_cas10_per_row").run(|| {
        n += 1;
        let mut txn = env.store.begin();
        for k in 0..10i64 {
            black_box(txn.lookup("cas", &[Value::Int64(k)]).unwrap());
        }
        txn.write("cas", row![n % 10, "w"]).unwrap();
        txn.commit().unwrap();
    });
    let reads: Vec<(&str, Vec<Value>)> =
        (0..10i64).map(|k| ("cas", vec![Value::Int64(k)])).collect();
    Bench::new("dyntable/commit_cas10_grouped").run(|| {
        n += 1;
        let mut txn = env.store.begin();
        black_box(txn.lookup_many(&reads).unwrap());
        txn.write("cas", row![n % 10, "w"]).unwrap();
        txn.commit().unwrap();
    });
}

/// Per-row vs batched spill push: N journal appends vs one.
fn bench_spill_batch() {
    use yt_stream::spill::SpillQueue;
    use yt_stream::storage::{Journal, WriteAccounting, WriteCategory};

    let rs = sample_rowset(256);
    let rows: Vec<_> = rs.rows().to_vec();
    let acc = WriteAccounting::new();
    Bench::new("spill/push_per_row_256")
        .throughput_items(256)
        .run(|| {
            let j = Journal::new("b", WriteCategory::Spill, acc.clone());
            let mut q = SpillQueue::new(j);
            for (i, r) in rows.iter().enumerate() {
                q.push(i as i64, r);
            }
            black_box(q.len());
        });
    let batch: Vec<(i64, Option<i64>, &yt_stream::rows::UnversionedRow)> = rows
        .iter()
        .enumerate()
        .map(|(i, r)| (i as i64, None, r))
        .collect();
    Bench::new("spill/push_batch_256")
        .throughput_items(256)
        .run(|| {
            let j = Journal::new("b", WriteCategory::Spill, acc.clone());
            let mut q = SpillQueue::new(j);
            q.push_batch(&batch);
            black_box(q.len());
        });
}

/// Consistency tiers (PR 7): the reducer's Step-8 state write, persisted
/// on every commit (exactly-once) vs only at anchors (bounded-error,
/// `anchor_every_batches = 8`). Both variants pay the same CAS read —
/// the state row stays in the validation set either way — so the delta
/// is purely the skipped state-row writes the WA frontier banks on.
fn bench_consistency_anchoring() {
    use yt_stream::consistency::{AnchorScheduler, Consistency};
    use yt_stream::coordinator::processor::ClusterEnv;
    use yt_stream::rows::{ColumnSchema, ColumnType, TableSchema, Value};
    use yt_stream::storage::WriteCategory;

    let env = ClusterEnv::new(Clock::realtime(), 4);
    env.store
        .create_table(
            "anchor_state",
            TableSchema::new(vec![
                ColumnSchema::key("k", ColumnType::Int64),
                ColumnSchema::value("v", ColumnType::Str),
            ]),
            WriteCategory::AnchorState,
        )
        .unwrap();
    {
        let mut txn = env.store.begin();
        txn.write("anchor_state", row![0i64, "seed"]).unwrap();
        txn.commit().unwrap();
    }

    let mut run_tier = |name: &str, policy: Consistency| {
        Bench::new(name).throughput_items(64).run(|| {
            // Fresh scheduler per iteration = one reducer incarnation.
            let mut anchors = AnchorScheduler::new(policy);
            for _ in 0..64 {
                let persist = anchors.should_persist(16);
                let mut txn = env.store.begin();
                black_box(txn.lookup("anchor_state", &[Value::Int64(0)]).unwrap());
                if persist {
                    txn.write("anchor_state", row![0i64, "state-blob"]).unwrap();
                }
                txn.commit().unwrap();
                anchors.note_commit(persist, 16);
            }
        });
    };
    run_tier("consistency/persist_every_commit_64", Consistency::ExactlyOnce);
    run_tier(
        "consistency/anchored_every_8_64",
        Consistency::BoundedError {
            divergence_budget: 1 << 20,
            anchor_every_batches: 8,
        },
    );
}

/// Cold tier (PR 8): chunk encode (columnar batch → hex payload + FNV
/// content hash, what compact-on-trim adds to a trim CAS) vs chunk scan
/// (hex decode + hash verify + columnar decode, what one backfill
/// checkpoint replays). Both sides of the compact-once/read-many trade.
fn bench_cold_chunk() {
    use yt_stream::coldtier::{content_hash, hex_decode, hex_encode};
    use yt_stream::rows::RowBatch;

    let rs = sample_rowset(1024);
    let payload = rs.byte_size() as u64;
    let encoded = RowBatch::from_rowset(&rs).encode();
    let hex = hex_encode(&encoded);
    let want = format!("{:016x}", content_hash(&encoded));

    Bench::new("coldtier/chunk_encode_1024")
        .throughput_bytes(payload)
        .run(|| {
            let encoded = RowBatch::from_rowset(&rs).encode();
            black_box(format!("{:016x}", content_hash(&encoded)));
            black_box(hex_encode(&encoded));
        });
    Bench::new("coldtier/chunk_scan_1024")
        .throughput_bytes(payload)
        .run(|| {
            let raw = hex_decode(&hex).unwrap();
            assert_eq!(format!("{:016x}", content_hash(&raw)), want);
            let shared: Arc<[u8]> = raw.into();
            black_box(RowBatch::decode_shared(&shared).unwrap().to_rowset());
        });
}

/// Flight recorder (PR 10): the commit-spine span record, measured
/// around the same RMW commit as `dyntable/txn_rmw_commit`. Three
/// points: no recorder interaction at all (baseline), the disabled
/// recorder (one relaxed atomic load per commit — the ≤5%-of-baseline
/// budget the obs design promises), and the enabled path (span
/// construction + per-worker ring push).
fn bench_obs_span_record() {
    use yt_stream::coordinator::processor::ClusterEnv;
    use yt_stream::obs::{SpanOutcome, TxnSpan, WorkerId};
    use yt_stream::rows::{ColumnSchema, ColumnType, TableSchema};
    use yt_stream::storage::WriteCategory;

    let env = ClusterEnv::new(Clock::realtime(), 4);
    env.store
        .create_table(
            "obs_t",
            TableSchema::new(vec![
                ColumnSchema::key("k", ColumnType::Int64),
                ColumnSchema::value("v", ColumnType::Str),
            ]),
            WriteCategory::UserOutput,
        )
        .unwrap();
    let hub = env.metrics.clone();
    let mut commit_one = |k: i64| {
        let mut txn = env.store.begin();
        let _ = txn
            .lookup("obs_t", &[yt_stream::rows::Value::Int64(k % 1000)])
            .unwrap();
        txn.write("obs_t", row![k % 1000, "value"]).unwrap();
        txn.commit().unwrap()
    };

    let mut k = 0i64;
    Bench::new("obs/txn_commit_baseline").run(|| {
        k += 1;
        black_box(commit_one(k));
    });

    hub.recorder().set_enabled(false);
    Bench::new("obs/txn_commit_span_disabled").run(|| {
        k += 1;
        let res = commit_one(k);
        // The exact call-site shape: one atomic load, everything else
        // (span construction, guid formatting, trace hashing) skipped.
        if hub.recorder().enabled() {
            hub.recorder().record(TxnSpan {
                txn_id: 0,
                trace_id: k as u64,
                worker: WorkerId::reducer(0, "bench"),
                scope: "reduce".to_string(),
                read_set: 1,
                outcome: SpanOutcome::Committed,
                bytes_by_category: res.bytes_by_category,
                start_ms: 0,
                end_ms: 1,
            });
        }
        black_box(res.rows_written);
    });

    hub.recorder().set_enabled(true);
    Bench::new("obs/txn_commit_span_enabled").run(|| {
        k += 1;
        let res = commit_one(k);
        if hub.recorder().enabled() {
            hub.recorder().record(TxnSpan {
                txn_id: 0,
                trace_id: k as u64,
                worker: WorkerId::reducer(0, "bench"),
                scope: "reduce".to_string(),
                read_set: 1,
                outcome: SpanOutcome::Committed,
                bytes_by_category: res.bytes_by_category,
                start_ms: 0,
                end_ms: 1,
            });
        }
        black_box(res.rows_written);
    });
}

fn main() {
    println!("== micro hot paths ==");
    bench_codec();
    bench_hash_and_stages();
    bench_rpc_getrows();
    bench_dyntable();
    bench_window();
    bench_row_batch();
    bench_group_commit();
    bench_spill_batch();
    bench_consistency_anchoring();
    bench_cold_chunk();
    bench_obs_span_record();
    // BENCHKIT_JSON=<path> → machine-readable BENCH_<pr>.json document.
    yt_stream::util::benchkit::write_json_env("rust/micro_hot_paths");
}
