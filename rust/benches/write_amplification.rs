//! The headline bench: write amplification of the streaming processor vs
//! the persisted-shuffle baseline over identical input, at several
//! workload sizes (checking the factor is size-independent for the
//! baseline and *shrinks* with size for ours, since meta-state is
//! per-batch, not per-byte).

use yt_stream::api::{MapperSpec, ReducerSpec};
use yt_stream::baseline::{run_persistent_shuffle, BaselineConfig};
use yt_stream::coordinator::processor::ClusterEnv;
use yt_stream::coordinator::{ComputeMode, InputSpec, StreamingProcessor};
use yt_stream::figures::scenario::{fill_static_input, Scenario, ScenarioCfg};
use yt_stream::metrics::WaReport;
use yt_stream::queue::input_name_table;
use yt_stream::queue::ordered_table::OrderedTable;
use yt_stream::util::yson::Yson;
use yt_stream::util::{Clock, Guid};
use yt_stream::workload::analytics::{
    analytics_mapper_factory, analytics_reducer_factory, ensure_output_table,
};

fn ours(messages: usize) -> WaReport {
    let partitions = 4;
    let clock = Clock::scaled(8);
    let env = ClusterEnv::new(clock.clone(), 7);
    let table = OrderedTable::new("//in/ours", input_name_table(), partitions, env.accounting.clone());
    fill_static_input(&table, &clock, messages, 7);
    let input = InputSpec::Ordered(table);
    let cfg = ScenarioCfg {
        mappers: partitions,
        reducers: 2,
        seed: 7,
        ..ScenarioCfg::default()
    };
    let processor = StreamingProcessor::launch(
        cfg.processor_config(),
        env.clone(),
        input.clone(),
        analytics_mapper_factory(ComputeMode::Native),
        analytics_reducer_factory(ComputeMode::Native),
        Yson::parse("{}").unwrap(),
    )
    .unwrap();
    let scenario = Scenario {
        env,
        input,
        processor,
        producers: None,
        cfg,
    };
    assert!(scenario.wait_drained(60_000), "ours never drained");
    let report = scenario.processor.wa_report("ours");
    scenario.stop();
    report
}

fn baseline(messages: usize) -> WaReport {
    let partitions = 4;
    let clock = Clock::realtime();
    let env = ClusterEnv::new(clock.clone(), 7);
    let client = env.client();
    ensure_output_table(&client).expect("create analytics output table");
    let table =
        OrderedTable::new("//in/base", input_name_table(), partitions, env.accounting.clone());
    fill_static_input(&table, &clock, messages, 7);
    let input = InputSpec::Ordered(table);
    let mf = analytics_mapper_factory(ComputeMode::Native);
    let rf = analytics_reducer_factory(ComputeMode::Native);
    let user_cfg = Yson::parse("{}").unwrap();
    let (_stats, report) = run_persistent_shuffle(
        "baseline",
        &BaselineConfig {
            num_reducers: 2,
            ..BaselineConfig::default()
        },
        &client,
        &input,
        &env.accounting,
        |p| {
            mf(
                &user_cfg,
                &client,
                input_name_table(),
                &MapperSpec {
                    processor_guid: Guid::from_seed(1),
                    state_table: "t".into(),
                    index: p,
                    guid: Guid::from_seed(p as u64),
                    num_reducers: 2,
                },
            )
        },
        |r| {
            rf(
                &user_cfg,
                &client,
                &ReducerSpec {
                    processor_guid: Guid::from_seed(1),
                    state_table: "t".into(),
                    index: r,
                    guid: Guid::from_seed(100 + r as u64),
                    num_mappers: partitions,
                    epoch: 0,
                },
            )
        },
    );
    report
}

fn main() {
    println!("== write amplification: ours vs persisted shuffle ==");
    println!(
        "{:<10} {:>12} {:>12} {:>10} {:>10} {:>8}",
        "messages", "ours_meta_B", "base_payl_B", "ours_WA", "base_WA", "ratio"
    );
    for messages in [100usize, 400, 1000] {
        let o = ours(messages);
        let b = baseline(messages);
        println!(
            "{:<10} {:>12} {:>12} {:>10.4} {:>10.4} {:>8.1}",
            messages * 4,
            o.meta_bytes(),
            b.payload_repersisted_bytes(),
            o.factor(),
            b.factor(),
            if o.factor() > 0.0 { b.factor() / o.factor() } else { f64::INFINITY },
        );
    }
    println!("(paper claim: the streaming design persists only compact meta-state)");
}
