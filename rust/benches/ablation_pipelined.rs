//! Ablation: serial vs §6-pipelined reducer main loop.
//!
//! Same cluster, same producers, same workload; the only difference is
//! `pipelined_reducer`. The pipelined variant overlaps fetch(n+1) with
//! process/commit(n), so its commit cadence should improve whenever the
//! network fetch is a visible fraction of the cycle. Injected RPC latency
//! makes the effect measurable on an in-process transport.

use yt_stream::figures::scenario::{start, ScenarioCfg};
use yt_stream::metrics::hub::names;

fn run_once(label: &str, pipelined: bool, rpc_delay_ms: (u64, u64)) -> (f64, f64) {
    let scenario = start(ScenarioCfg {
        mappers: 6,
        reducers: 2,
        pipelined_reducer: pipelined,
        speedup: 1,
        msgs_per_sec: 1200.0,
        seed: 0xAB1A,
        ..ScenarioCfg::default()
    });
    scenario.env.net.with_faults(|f| f.delay_ms = rpc_delay_ms);

    std::thread::sleep(std::time::Duration::from_secs(2)); // warmup
    let rows0 = scenario.env.metrics.get_counter(names::REDUCER_ROWS);
    let commits0 = scenario.env.metrics.get_counter(names::REDUCER_COMMITS);
    let t0 = std::time::Instant::now();
    std::thread::sleep(std::time::Duration::from_secs(5));
    let dt = t0.elapsed().as_secs_f64();
    let rows = scenario.env.metrics.get_counter(names::REDUCER_ROWS) - rows0;
    let commits = scenario.env.metrics.get_counter(names::REDUCER_COMMITS) - commits0;
    scenario.stop();

    let rows_per_s = rows as f64 / dt;
    let commits_per_s = commits as f64 / dt;
    println!(
        "bench ablation/{label:<24} rows={rows_per_s:>9.0}/s commits={commits_per_s:>7.1}/s"
    );
    (rows_per_s, commits_per_s)
}

fn main() {
    println!("== ablation: serial vs pipelined reducer (§6) ==");
    for (delay, tag) in [((0u64, 0u64), "no_delay"), ((2, 8), "rpc_2-8ms")] {
        let (serial_rows, serial_commits) = run_once(&format!("serial_{tag}"), false, delay);
        let (pipe_rows, pipe_commits) = run_once(&format!("pipelined_{tag}"), true, delay);
        println!(
            "ablation/{tag}: commit-cadence ratio = {:.2} (row throughput ratio = {:.2}; \
             rows are producer-bound, cadence shows the reclaimed fetch time)",
            pipe_commits / serial_commits.max(1.0),
            pipe_rows / serial_rows.max(1.0),
        );
    }
    println!("(§6: overlapping fetch with process+commit reclaims network idle time)");
}
