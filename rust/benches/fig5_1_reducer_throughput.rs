//! End-to-end bench for fig 5.1 / the scale table: sustained reducer
//! ingest throughput of the full streaming processor (simulated cluster,
//! native and — when artifacts exist — HLO compute).
//!
//! Prints per-reducer mean/max MB/s and aggregate rows/s; EXPERIMENTS.md
//! compares the *shape* against the paper's 95 MB/s-per-reducer result.

use yt_stream::coordinator::ComputeMode;
use yt_stream::figures::scenario::{start, ScenarioCfg};
use yt_stream::metrics::hub::names;

fn run_once(label: &str, compute: ComputeMode, mappers: usize, reducers: usize) {
    let scenario = start(ScenarioCfg {
        mappers,
        reducers,
        compute,
        speedup: 1,
        msgs_per_sec: 1500.0,
        seed: 0xF161,
        ..ScenarioCfg::default()
    });
    // Warm up, then measure a steady window.
    std::thread::sleep(std::time::Duration::from_secs(2));
    let t0_rows = scenario.env.metrics.get_counter(names::REDUCER_ROWS);
    let t0_bytes = scenario.env.metrics.get_counter(names::REDUCER_BYTES);
    let t0 = std::time::Instant::now();
    std::thread::sleep(std::time::Duration::from_secs(5));
    let dt = t0.elapsed().as_secs_f64();
    let rows = scenario.env.metrics.get_counter(names::REDUCER_ROWS) - t0_rows;
    let bytes = scenario.env.metrics.get_counter(names::REDUCER_BYTES) - t0_bytes;

    let per_reducer: Vec<f64> = scenario
        .env
        .metrics
        .series_with_prefix("reducer/")
        .iter()
        .filter(|s| s.name().contains("ingest"))
        .filter_map(|s| s.mean_since(2_000))
        .collect();
    let max_thpt = per_reducer.iter().fold(0.0f64, |a, &b| a.max(b));
    let lag: Vec<f64> = scenario
        .env
        .metrics
        .series_with_prefix("mapper/")
        .iter()
        .filter(|s| s.name().ends_with("read_lag_ms"))
        .filter_map(|s| s.mean_since(2_000))
        .collect();
    let mean_lag = lag.iter().sum::<f64>() / lag.len().max(1) as f64;
    scenario.stop();

    println!(
        "bench fig5.1/{label:<28} agg={:.2} MB/s rows={:.0}/s max_per_reducer={:.2} MB/s mean_read_lag={:.0} ms",
        bytes as f64 / dt / 1e6,
        rows as f64 / dt,
        max_thpt / 1e6,
        mean_lag,
    );
}

/// Capacity mode: drain a large pre-filled backlog as fast as possible —
/// measures the pipeline's own ceiling, not the producers'.
fn run_drain(label: &str, compute: ComputeMode, mappers: usize, reducers: usize, messages: usize) {
    use yt_stream::coordinator::processor::ClusterEnv;
    use yt_stream::coordinator::{InputSpec, StreamingProcessor};
    use yt_stream::figures::scenario::fill_static_input;
    use yt_stream::queue::input_name_table;
    use yt_stream::queue::ordered_table::OrderedTable;
    use yt_stream::util::yson::Yson;
    use yt_stream::util::Clock;
    use yt_stream::workload::analytics::{analytics_mapper_factory, analytics_reducer_factory};

    let clock = Clock::realtime();
    let env = ClusterEnv::new(clock.clone(), 0xD12A);
    let table = OrderedTable::new("//in/drain", input_name_table(), mappers, env.accounting.clone());
    fill_static_input(&table, &clock, messages, 0xD12A);
    let input = InputSpec::Ordered(table);
    let mut cfg = ScenarioCfg {
        mappers,
        reducers,
        compute,
        seed: 0xD12A,
        memory_limit_bytes: 64 << 20,
        ..ScenarioCfg::default()
    }
    .processor_config();
    // §Perf iteration 4: bigger reads + fetches cut per-cycle fixed costs
    // (state lookups, RPC fan-out, commit overhead) on the drain path.
    cfg.read_batch_rows = std::env::var("DRAIN_READ_BATCH")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(cfg.read_batch_rows);
    cfg.fetch_count = std::env::var("DRAIN_FETCH_COUNT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(cfg.fetch_count);

    let t0 = std::time::Instant::now();
    let processor = StreamingProcessor::launch(
        cfg,
        env.clone(),
        input.clone(),
        analytics_mapper_factory(compute),
        analytics_reducer_factory(compute),
        Yson::parse("{}").unwrap(),
    )
    .unwrap();
    // Wait until all reducer rows are committed; time the run up to the
    // *last observed progress* so idle stability-polling doesn't bias the
    // capacity number.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(120);
    let mut last = 0;
    let mut stable = 0;
    let mut t_last_progress = std::time::Instant::now();
    while std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(10));
        let r = env.metrics.get_counter(names::REDUCER_ROWS);
        if r != last {
            t_last_progress = std::time::Instant::now();
            stable = 0;
        } else if r > 0 {
            stable += 1;
            if stable > 30 && input.retained_rows() == 0 {
                break;
            }
        }
        last = r;
    }
    let dt = (t_last_progress - t0).as_secs_f64().max(0.001);
    let rows = env.metrics.get_counter(names::REDUCER_ROWS);
    let bytes = env.metrics.get_counter(names::REDUCER_BYTES);
    let in_bytes = env.metrics.get_counter(names::MAPPER_BYTES_READ);
    processor.stop();
    println!(
        "bench fig5.1-drain/{label:<22} input={:.1} MB reduced={rows} rows wall={dt:.2}s \
         ingest_capacity={:.2} MB/s reduce_capacity={:.2} MB/s ({:.0} rows/s)",
        in_bytes as f64 / 1e6,
        in_bytes as f64 / dt / 1e6,
        bytes as f64 / dt / 1e6,
        rows as f64 / dt,
    );
}

fn main() {
    println!("== fig 5.1: reducer throughput (end-to-end) ==");
    run_once("native_8m_2r", ComputeMode::Native, 8, 2);
    run_once("native_8m_4r", ComputeMode::Native, 8, 4);
    let have_artifacts =
        yt_stream::compute::hlo::HloStage::load(std::path::Path::new("artifacts")).is_ok();
    if have_artifacts {
        run_once("hlo_8m_2r", ComputeMode::Hlo, 8, 2);
    } else {
        eprintln!("note: artifacts missing, skipping hlo variant");
    }
    // Capacity: drain a pre-filled backlog (the paper's relevant metric —
    // "the maximum input ingestion speed by reducers").
    run_drain("native_8m_2r", ComputeMode::Native, 8, 2, 24_000);
    run_drain("native_8m_4r", ComputeMode::Native, 8, 4, 24_000);
    if have_artifacts {
        run_drain("hlo_8m_2r", ComputeMode::Hlo, 8, 2, 12_000);
    }
}
