//! Multi-stage streaming topologies (dataflow chaining).
//!
//! The paper's system is a *single* map→shuffle→reduce stage whose
//! reducers commit user output plus meta-state in one transaction. Real
//! deployments compose such stages: Muppet-style chained map/update
//! pipelines are the workhorse shape of streaming MapReduce. This module
//! chains N streaming processors end to end:
//!
//! ```text
//!   source ──stage 0──▶ handoff table ──stage 1──▶ … ──stage N-1──▶ user output
//!   (ordered table)     (ordered table,            (final stage's Reduce
//!                        WriteCategory::InterStage)  writes its own tables)
//! ```
//!
//! * **Handoff** — stage *k*'s reducers emit rows through an
//!   [`sink::EmitReducer`]; the [`sink::SinkReducer`] adapter buffers them
//!   into the reducer's commit transaction via
//!   [`crate::dyntable::Transaction::append_ordered`], so the append rides
//!   the existing row-index meta-state CAS. Exactly-once needs no new
//!   mechanism: a split-brain or conflicting commit aborts, and its
//!   buffered rows never reach the queue. Each stage-*k* reducer owns
//!   tablet *k* of the handoff table, so committed row indexes per tablet
//!   are dense and deterministic.
//! * **Consumption** — stage *k*+1's mappers read the handoff table through
//!   the ordinary [`crate::coordinator::InputSpec::Ordered`] reader; their
//!   `TrimInputRows` cadence advances the table's trim low-water marks, so
//!   intermediate tables stay bounded (trim-after-consume).
//! * **Drain** — a stage is drained only when its upstream is drained AND
//!   its own backlog is empty ([`topology::RunningTopology::wait_drained`]).
//! * **Accounting** — every stage gets its own metrics hub and accounting
//!   scope; [`topology::RunningTopology::wa_report`] renders per-stage WA
//!   factors plus an end-to-end factor whose denominator is only the
//!   original source ingest.
//! * **Elasticity** — [`topology::RunningTopology::reshard_stage`] resizes
//!   one stage's reducer fleet live and re-wires the adjacent stages; the
//!   resident [`topology::TopologyAutoscaler`] runs the fused lag+backlog
//!   policy loop ([`crate::reshard::driver`]) over *every* stage, each
//!   against its own metrics scope — with optional per-stage
//!   [`crate::reshard::DriverConfig`] overrides
//!   ([`topology::TopologyAutoscaler::start_with_stage_configs`]).
//! * **Event time** — an event-timed stage's fleet watermark caps its
//!   downstream consumer's watermark (wired automatically at launch via
//!   `upstream_watermark_table`), so stage k+1 windows on *true* event
//!   time, and
//!   [`topology::RunningTopology::close_event_time_cascade`] walks the
//!   source-close marker down the chain — cascaded drain extended to
//!   "the watermark reached +∞" ([`crate::eventtime`]).

pub mod sink;
pub mod topology;

pub use sink::{EmitReducer, EmitterFactory, FnEmitReducer};
pub use topology::{
    RunningTopology, StageHandle, StageReduce, StageSpec, Topology, TopologyAutoscaler,
    TopologyError,
};
