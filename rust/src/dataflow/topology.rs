//! Declarative topology of chained streaming-MapReduce stages.
//!
//! A [`Topology`] is a list of [`StageSpec`]s. [`Topology::launch`]
//! validates the wiring (schema compatibility between adjacent stages,
//! partition-count wiring: stage *k*+1 runs one mapper per stage-*k*
//! reducer tablet), namespaces every stage's state tables and discovery
//! directory under `//sys/dataflow/<topology>/<stage>/`, creates the
//! inter-stage handoff tables, and launches one supervised
//! [`StreamingProcessor`] fleet per stage against a shared [`ClusterEnv`]
//! — each with its own metrics hub and write-accounting scope so the
//! report can be broken down per stage.

use std::sync::Arc;

use crate::api::{Client, MapperFactory, Reducer, ReducerFactory, ReducerSpec};
use crate::consistency::Consistency;
use crate::controller::Supervisor;
use crate::coordinator::processor::{ClusterEnv, LaunchError};
use crate::coordinator::{InputSpec, ProcessorConfig, StreamingProcessor};
use crate::metrics::hub::names;
use crate::metrics::{MetricsHub, PipelineWaReport, WaReport};
use crate::queue::ordered_table::OrderedTable;
use crate::rows::NameTable;
use crate::storage::WriteCategory;
use crate::util::yson::Yson;

use super::sink::{EmitterFactory, SinkReducer};

/// How a stage's reducers dispose of their results.
pub enum StageReduce {
    /// Intermediate hop: emitted rows are committed into the ordered
    /// handoff table feeding the next stage (exactly once, accounted as
    /// [`WriteCategory::InterStage`]).
    Emit(EmitterFactory),
    /// Final stage: the user's [`Reducer`] writes its own output tables in
    /// the commit transaction (accounted as whatever category those tables
    /// were created with, conventionally `UserOutput`).
    Final(ReducerFactory),
}

/// One stage of a topology.
pub struct StageSpec {
    /// Stage name, unique within the topology (used for state-table
    /// namespacing and the per-stage WA report).
    pub name: String,
    /// Base tunables. `mapper_count`/`reducer_count` define the stage's
    /// shape; state-table paths, discovery dir, `name` and `scope_label`
    /// are overwritten by the topology's namespacing at launch.
    pub config: ProcessorConfig,
    /// Columns this stage's mappers expect from their input stream.
    pub input_columns: Arc<NameTable>,
    /// Columns of the rows handed downstream (required for
    /// [`StageReduce::Emit`] stages; ignored for the final stage).
    pub output_columns: Option<Arc<NameTable>>,
    pub mapper_factory: MapperFactory,
    pub reduce: StageReduce,
    /// The user config node passed to this stage's factories.
    pub user_config: Yson,
}

impl StageSpec {
    /// Convenience constructor for an intermediate (emitting) stage.
    pub fn intermediate(
        name: impl Into<String>,
        config: ProcessorConfig,
        input_columns: Arc<NameTable>,
        output_columns: Arc<NameTable>,
        mapper_factory: MapperFactory,
        emitter_factory: EmitterFactory,
    ) -> StageSpec {
        StageSpec {
            name: name.into(),
            config,
            input_columns,
            output_columns: Some(output_columns),
            mapper_factory,
            reduce: StageReduce::Emit(emitter_factory),
            user_config: Yson::parse("{}").unwrap(),
        }
    }

    /// Convenience constructor for the final stage.
    pub fn final_stage(
        name: impl Into<String>,
        config: ProcessorConfig,
        input_columns: Arc<NameTable>,
        mapper_factory: MapperFactory,
        reducer_factory: ReducerFactory,
    ) -> StageSpec {
        StageSpec {
            name: name.into(),
            config,
            input_columns,
            output_columns: None,
            mapper_factory,
            reduce: StageReduce::Final(reducer_factory),
            user_config: Yson::parse("{}").unwrap(),
        }
    }
}

/// Errors surfaced by topology validation / launch.
#[derive(Debug, thiserror::Error)]
pub enum TopologyError {
    #[error("topology has no stages")]
    Empty,
    #[error("duplicate stage name '{0}'")]
    DuplicateStageName(String),
    #[error("stage '{0}' is intermediate and must use StageReduce::Emit")]
    IntermediateMustEmit(String),
    #[error("stage '{0}': intermediate stage is missing its output columns")]
    MissingOutputSchema(String),
    #[error("stage '{0}' is the final stage and must use StageReduce::Final")]
    FinalMustBeFinal(String),
    #[error(
        "stage '{0}': ordered-table handoff requires exactly-once commits \
         (at_least_once must be off)"
    )]
    ExactlyOnceRequired(String),
    #[error(
        "stage '{0}': at_most_once is sink-only — an intermediate stage feeding an ordered \
         handoff would silently drop rows out of the chain. Use bounded_error (declared, \
         anchored drift) or exactly_once for intermediate stages."
    )]
    AtMostOnceIntermediate(String),
    #[error(
        "stage '{stage}' runs exactly-once but its upstream stage '{upstream}' is approximate: \
         the input itself can drift (bounded replay/loss), so downstream exactly-once cannot \
         promise byte-exact output. Acknowledge this by setting tolerates_upstream_drift on \
         '{stage}', or make '{upstream}' exactly_once."
    )]
    UpstreamDriftUnacknowledged { stage: String, upstream: String },
    #[error(
        "stage '{stage}' windows on event time but its upstream stage '{upstream}' does not \
         track it: rows buffered upstream would be invisible to the watermark, so final-fired \
         windows could silently miss them. Enable event_time on '{upstream}' (its watermark \
         caps '{stage}') or disable it on '{stage}'."
    )]
    EventTimeChainBroken { stage: String, upstream: String },
    #[error("stage '{stage}': mapper_count {mappers} != source partition count {partitions}")]
    SourceWiring {
        stage: String,
        mappers: usize,
        partitions: usize,
    },
    #[error(
        "stage '{stage}': backfill source has {fences} cutover fences for {partitions} \
         partitions — the historical/live split is ill-defined"
    )]
    BackfillFenceWiring {
        stage: String,
        fences: usize,
        partitions: usize,
    },
    #[error(
        "stage '{stage}': cold_tier.base '{base}' is the same cold tier its backfill source \
         reads from — compact-on-trim would re-compact backfilled chunks over the existing \
         chain (discontinuous manifest). Point cold_tier at a different base or disable it."
    )]
    BackfillCompactsItself { stage: String, base: String },
    #[error(
        "stage '{stage}': mapper_count {mappers} != upstream stage '{upstream}' \
         reducer_count {upstream_reducers}"
    )]
    PartitionWiring {
        stage: String,
        mappers: usize,
        upstream: String,
        upstream_reducers: usize,
    },
    #[error("stage '{stage}': expects input columns {expected:?} but upstream provides {found:?}")]
    SchemaMismatch {
        stage: String,
        expected: Vec<String>,
        found: Vec<String>,
    },
    #[error("stage launch failed: {0}")]
    Launch(#[from] LaunchError),
}

/// A declarative chain of stages, built with [`Topology::stage`] and run
/// with [`Topology::launch`].
pub struct Topology {
    pub name: String,
    pub stages: Vec<StageSpec>,
}

impl Topology {
    pub fn new(name: impl Into<String>) -> Topology {
        Topology {
            name: name.into(),
            stages: Vec::new(),
        }
    }

    /// Append a stage (builder style).
    pub fn stage(mut self, spec: StageSpec) -> Topology {
        self.stages.push(spec);
        self
    }

    /// Check the whole chain's wiring against a source without launching
    /// anything.
    pub fn validate(&self, source: &InputSpec) -> Result<(), TopologyError> {
        if self.stages.is_empty() {
            return Err(TopologyError::Empty);
        }
        let mut seen: Vec<&str> = Vec::new();
        for name in self.stages.iter().map(|s| s.name.as_str()) {
            if seen.contains(&name) {
                return Err(TopologyError::DuplicateStageName(name.to_string()));
            }
            seen.push(name);
        }
        let last = self.stages.len() - 1;
        for (k, spec) in self.stages.iter().enumerate() {
            match (&spec.reduce, k == last) {
                (StageReduce::Final(_), false) => {
                    return Err(TopologyError::IntermediateMustEmit(spec.name.clone()))
                }
                (StageReduce::Emit(_), true) => {
                    return Err(TopologyError::FinalMustBeFinal(spec.name.clone()))
                }
                (StageReduce::Emit(_), false) => {
                    if spec.output_columns.is_none() {
                        return Err(TopologyError::MissingOutputSchema(spec.name.clone()));
                    }
                    if spec.config.at_least_once {
                        return Err(TopologyError::ExactlyOnceRequired(spec.name.clone()));
                    }
                }
                (StageReduce::Final(_), true) => {}
            }

            // Consistency-tier wiring (see [`crate::consistency`]):
            // at-most-once may only terminate a chain, and an
            // exactly-once stage anywhere downstream of an approximate
            // one inherits its drift — that demotion must be explicit.
            if matches!(spec.config.consistency, Consistency::AtMostOnce) && k != last {
                return Err(TopologyError::AtMostOnceIntermediate(spec.name.clone()));
            }
            if k > 0
                && spec.config.consistency.is_exactly_once()
                && !spec.config.tolerates_upstream_drift
            {
                if let Some(up) = self.stages[..k]
                    .iter()
                    .rev()
                    .find(|s| s.config.consistency.is_approximate())
                {
                    return Err(TopologyError::UpstreamDriftUnacknowledged {
                        stage: spec.name.clone(),
                        upstream: up.name.clone(),
                    });
                }
            }

            // Partition wiring + schema compatibility against the upstream.
            let (upstream_columns, upstream_partitions): (Arc<NameTable>, usize) = if k == 0 {
                (source.name_table(), source.partition_count())
            } else {
                let up = &self.stages[k - 1];
                (
                    up.output_columns.clone().expect("checked above"),
                    up.config.reducer_count,
                )
            };
            if spec.config.mapper_count != upstream_partitions {
                if k == 0 {
                    return Err(TopologyError::SourceWiring {
                        stage: spec.name.clone(),
                        mappers: spec.config.mapper_count,
                        partitions: upstream_partitions,
                    });
                }
                return Err(TopologyError::PartitionWiring {
                    stage: spec.name.clone(),
                    mappers: spec.config.mapper_count,
                    upstream: self.stages[k - 1].name.clone(),
                    upstream_reducers: upstream_partitions,
                });
            }
            // Unified-backfill wiring: the cutover fences must tile every
            // source partition, and the consuming stage must not compact
            // its own backfill input back into the tier it reads.
            if k == 0 {
                if let InputSpec::BoundedRange(c) = source {
                    if c.fences().len() != c.partition_count() {
                        return Err(TopologyError::BackfillFenceWiring {
                            stage: spec.name.clone(),
                            fences: c.fences().len(),
                            partitions: c.partition_count(),
                        });
                    }
                    if let Some(cold) = &spec.config.cold_tier {
                        if cold.base == c.cold().base() {
                            return Err(TopologyError::BackfillCompactsItself {
                                stage: spec.name.clone(),
                                base: cold.base.clone(),
                            });
                        }
                    }
                }
            }
            if spec.input_columns.names() != upstream_columns.names() {
                return Err(TopologyError::SchemaMismatch {
                    stage: spec.name.clone(),
                    expected: spec.input_columns.names().to_vec(),
                    found: upstream_columns.names().to_vec(),
                });
            }
            // Event-time safety: a stage windowing on event time must be
            // able to trust its watermark. For stage 0 that is the
            // source's ordering contract (the user's assumption, like any
            // stream system); for a later stage it is the upstream fleet
            // watermark cap — which only exists if the upstream stage
            // tracks event time too. Without it the stage would window on
            // its own ingest frontier while rows sit buffered upstream.
            if k > 0
                && spec.config.event_time.is_some()
                && self.stages[k - 1].config.event_time.is_none()
            {
                return Err(TopologyError::EventTimeChainBroken {
                    stage: spec.name.clone(),
                    upstream: self.stages[k - 1].name.clone(),
                });
            }
        }
        Ok(())
    }

    /// Validate, create the handoff tables, and launch one supervised
    /// processor fleet per stage. On a mid-chain launch failure the
    /// already-launched stages are stopped before the error is returned.
    pub fn launch(
        self,
        env: &ClusterEnv,
        source: InputSpec,
    ) -> Result<RunningTopology, TopologyError> {
        self.validate(&source)?;
        let Topology {
            name: topo_name,
            stages: specs,
        } = self;

        let mut stages: Vec<StageHandle> = Vec::new();
        let mut input = source.clone();
        // Mapper state table of the nearest upstream event-timed stage:
        // wired into the next event-timed stage as its watermark cap, so
        // stage k+1 windows on *true* event time — rows still buffered in
        // stage k (and their future emissions into the handoff) can never
        // be overtaken. Requires the emit contract documented on
        // [`crate::dataflow::EmitReducer`]: an emitted row's event time is
        // never below the minimum event time of the batch it came from.
        let mut upstream_watermark: Option<String> = None;
        for spec in specs {
            let scope = format!("{}/{}", topo_name, spec.name);
            let base = format!("//sys/dataflow/{}/{}", topo_name, spec.name);
            let mut cfg = spec.config.clone();
            cfg.name = scope.clone();
            cfg.scope_label = Some(scope.clone());
            cfg.mapper_state_table = format!("{base}/mapper_state");
            cfg.reducer_state_table = format!("{base}/reducer_state");
            cfg.reshard_plan_table = format!("{base}/reshard_plan");
            cfg.discovery_dir = format!("{base}/discovery");
            cfg.upstream_watermark_table = match (&cfg.event_time, &upstream_watermark) {
                (Some(_), Some(up)) => Some(up.clone()),
                _ => None,
            };
            // A stage without event time breaks the chain: its buffering
            // is invisible to watermarks, so nothing downstream of it may
            // trust an older stage's value. (Validation already rejects
            // an event-timed stage behind such a break; this reset is
            // defense in depth.)
            upstream_watermark = cfg
                .event_time
                .is_some()
                .then(|| cfg.mapper_state_table.clone());

            // Each stage gets its own hub so per-stage ingest/commit
            // counters stay separable; storage substrates stay shared.
            let mut stage_env = env.clone();
            stage_env.metrics = MetricsHub::new();

            let (reducer_factory, handoff): (ReducerFactory, Option<Arc<OrderedTable>>) =
                match spec.reduce {
                    StageReduce::Final(rf) => (rf, None),
                    StageReduce::Emit(emitter) => {
                        let out_nt = spec.output_columns.clone().expect("validated");
                        let handoff = OrderedTable::new_scoped(
                            &format!("{base}/handoff"),
                            out_nt,
                            cfg.reducer_count,
                            env.accounting.clone(),
                            WriteCategory::InterStage,
                            Some(scope.clone()),
                        );
                        let sink = handoff.clone();
                        let rf: ReducerFactory = Arc::new(
                            move |user_cfg: &Yson, client: &Client, rspec: &ReducerSpec| {
                                Box::new(SinkReducer {
                                    inner: emitter(user_cfg, client, rspec),
                                    handoff: sink.clone(),
                                    tablet: rspec.index,
                                    client: client.clone(),
                                }) as Box<dyn Reducer>
                            },
                        );
                        (rf, Some(handoff))
                    }
                };

            let processor = match StreamingProcessor::launch(
                cfg,
                stage_env,
                input.clone(),
                spec.mapper_factory.clone(),
                reducer_factory,
                spec.user_config.clone(),
            ) {
                Ok(p) => p,
                Err(e) => {
                    for s in stages {
                        s.processor.stop();
                    }
                    return Err(TopologyError::Launch(e));
                }
            };

            if let Some(h) = &handoff {
                input = InputSpec::Ordered(h.clone());
            }
            stages.push(StageHandle {
                name: spec.name,
                scope,
                processor,
                handoff,
            });
        }

        Ok(RunningTopology {
            name: topo_name,
            env: env.clone(),
            source,
            stages,
        })
    }
}

/// A running stage within a [`RunningTopology`].
pub struct StageHandle {
    pub name: String,
    /// Write-accounting scope label (`<topology>/<stage>`).
    scope: String,
    pub processor: StreamingProcessor,
    /// The ordered table this stage feeds (None for the final stage).
    pub handoff: Option<Arc<OrderedTable>>,
}

impl StageHandle {
    pub fn scope(&self) -> &str {
        &self.scope
    }

    pub fn supervisor(&self) -> &Arc<Supervisor> {
        self.processor.supervisor()
    }

    /// This stage's private metrics hub.
    pub fn metrics(&self) -> &Arc<MetricsHub> {
        &self.processor.env.metrics
    }

    /// Rows still retained in this stage's input (its backlog).
    pub fn backlog_rows(&self) -> usize {
        self.processor.input.retained_rows()
    }

    /// Rows this stage's reducers have committed so far.
    pub fn reduced_rows(&self) -> u64 {
        self.metrics().get_counter(names::REDUCER_ROWS)
    }
}

/// A launched topology: the user-facing handle over the whole chain.
pub struct RunningTopology {
    pub name: String,
    env: ClusterEnv,
    source: InputSpec,
    stages: Vec<StageHandle>,
}

impl RunningTopology {
    pub fn stage_count(&self) -> usize {
        self.stages.len()
    }

    pub fn stage(&self, index: usize) -> &StageHandle {
        &self.stages[index]
    }

    pub fn stages(&self) -> &[StageHandle] {
        &self.stages
    }

    pub fn env(&self) -> &ClusterEnv {
        &self.env
    }

    pub fn source(&self) -> &InputSpec {
        &self.source
    }

    /// End-to-end drain predicate for one stage: a stage is drained only
    /// when its upstream is drained AND its own backlog is empty. (Backlog
    /// emptiness is trim-driven, so it implies every retained input row's
    /// effects were committed downstream of it.)
    pub fn stage_drained(&self, index: usize) -> bool {
        self.stages[..=index]
            .iter()
            .all(|s| s.backlog_rows() == 0)
    }

    /// Is the whole chain drained right now? (Instantaneous check; use
    /// [`RunningTopology::wait_drained`] for a stable verdict.)
    pub fn drained(&self) -> bool {
        self.stage_drained(self.stages.len() - 1)
    }

    /// Rows committed by the final stage's reducers.
    pub fn final_reduced_rows(&self) -> u64 {
        self.stages.last().expect("validated non-empty").reduced_rows()
    }

    /// Total supervised worker slots across every stage's fleet.
    pub fn worker_count(&self) -> usize {
        self.stages
            .iter()
            .map(|s| s.supervisor().slot_count())
            .sum()
    }

    /// Rows currently retained across all inter-stage handoff tables
    /// (bounded-ness metric for trim-after-consume).
    pub fn handoff_retained_rows(&self) -> usize {
        self.stages
            .iter()
            .filter_map(|s| s.handoff.as_ref())
            .map(|h| h.retained_rows())
            .sum()
    }

    /// Wait (wall-clock bounded) until every stage is drained — observed
    /// on two consecutive polls with a stable final-stage commit count, so
    /// a topology whose final stage legitimately commits zero rows still
    /// reports drained. Producers into the source must already be stopped,
    /// else this can only time out.
    pub fn wait_drained(&self, wall_timeout_ms: u64) -> bool {
        let deadline =
            std::time::Instant::now() + std::time::Duration::from_millis(wall_timeout_ms);
        // Some(count) = previous poll saw a drained chain with this many
        // final-stage rows committed.
        let mut prev_drained_at: Option<u64> = None;
        while std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(50));
            let drained = self.drained();
            let reduced = self.final_reduced_rows();
            if drained && prev_drained_at == Some(reduced) {
                return true;
            }
            prev_drained_at = drained.then_some(reduced);
        }
        false
    }

    /// Walk the event-time source-close marker down the chain: close
    /// stage 0, wait until its fleet watermark reaches `close_ts_ms` and
    /// its backlog (and handoff, if any) drained, then close stage 1, and
    /// so on — extending cascaded drain to "the watermark reached +∞"
    /// ([`crate::eventtime::EVENT_TIME_CLOSED`] is the conventional
    /// value). A stage's close is only written once everything that could
    /// still append to its input has flushed, preserving the close
    /// contract (marker after the final append). Stages without event
    /// time only contribute their drain condition. Returns `true` when
    /// every event-timed stage's watermark reached the close timestamp
    /// within the wall-clock budget. Producers into the source must
    /// already be stopped.
    pub fn close_event_time_cascade(&self, close_ts_ms: i64, wall_timeout_ms: u64) -> bool {
        let deadline =
            std::time::Instant::now() + std::time::Duration::from_millis(wall_timeout_ms);
        for (k, stage) in self.stages.iter().enumerate() {
            // Everything upstream of stage k (including its own input
            // backlog and the handoff feeding it) must be flushed before
            // its close marker may be written.
            loop {
                let upstream_flushed = k == 0 || self.stage_drained(k - 1);
                let input_flushed = stage.backlog_rows() == 0;
                let upstream_watermark_done = k == 0
                    || self.stages[k - 1]
                        .processor
                        .cfg
                        .event_time
                        .is_none()
                    || self.stages[k - 1]
                        .processor
                        .fleet_watermark()
                        .is_some_and(|w| w >= close_ts_ms);
                if upstream_flushed && input_flushed && upstream_watermark_done {
                    break;
                }
                if std::time::Instant::now() >= deadline {
                    return false;
                }
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            if stage.processor.cfg.event_time.is_some() {
                if stage.processor.close_event_time(close_ts_ms).is_err() {
                    return false;
                }
                // Wait for this stage's own fleet to reach the close mark
                // before descending further.
                loop {
                    if stage
                        .processor
                        .fleet_watermark()
                        .is_some_and(|w| w >= close_ts_ms)
                    {
                        break;
                    }
                    if std::time::Instant::now() >= deadline {
                        return false;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
            }
        }
        true
    }

    /// Reshard stage `k`'s reducer fleet to `new_count` while the whole
    /// chain keeps running, re-wiring the adjacent partition mapping:
    /// an emitting stage's handoff table grows to one tablet per new
    /// reducer *before* the new fleet serves, and the downstream stage's
    /// mapper fleet re-specs against the new tablet count (grown
    /// immediately; on a shrink the surplus mappers idle until their
    /// tablets drain — see
    /// [`RunningTopology::retire_quiet_downstream_mappers`]).
    pub fn reshard_stage(
        &self,
        stage_index: usize,
        new_count: usize,
        wall_timeout_ms: u64,
    ) -> Result<crate::reshard::ReshardStats, crate::reshard::ReshardError> {
        let stage = &self.stages[stage_index];
        if let Some(h) = &stage.handoff {
            h.ensure_tablets(new_count);
        }
        let stats = stage.processor.reshard(new_count, wall_timeout_ms)?;
        if stage.handoff.is_some() && stage_index + 1 < self.stages.len() {
            self.stages[stage_index + 1]
                .processor
                .grow_mappers(new_count);
        }
        Ok(stats)
    }

    /// Resume stage `k`'s interrupted migration (crashed driver / timed-out
    /// [`RunningTopology::reshard_stage`]): re-grow the handoff to the
    /// in-flight target (idempotent — the interrupted driver may have died
    /// before the re-wiring), resume the migration, then re-wire the
    /// downstream mapper fleet against the now-stable count.
    pub fn resume_stage(
        &self,
        stage_index: usize,
        wall_timeout_ms: u64,
    ) -> Result<crate::reshard::ReshardStats, crate::reshard::ReshardError> {
        use crate::reshard::PlanPhase;

        let stage = &self.stages[stage_index];
        if let (Some(h), Some(plan)) = (&stage.handoff, stage.processor.current_plan()) {
            if plan.phase == PlanPhase::Migrating {
                h.ensure_tablets(plan.next_partitions);
            }
        }
        let stats = stage.processor.resume_reshard(wall_timeout_ms)?;
        if stage.handoff.is_some() && stage_index + 1 < self.stages.len() {
            self.stages[stage_index + 1]
                .processor
                .grow_mappers(stats.to_partitions);
        }
        Ok(stats)
    }

    /// After a shrink of stage `k`, retire downstream mapper slots whose
    /// handoff tablet went quiet (no longer written) and fully drained.
    /// Returns how many were retired this call; safe to poll. A tablet is
    /// only "quiet" once the stage's plan is **stable** — while a
    /// migration is still in flight the draining old fleet can still
    /// append, and a transiently-empty tablet must not cost its consumer.
    /// (After finalize, appends to tablets at or past the stable count
    /// can never land: the retired fleet's commits are fenced.)
    pub fn retire_quiet_downstream_mappers(&self, stage_index: usize) -> usize {
        use crate::reshard::PlanPhase;

        let Some(h) = &self.stages[stage_index].handoff else {
            return 0;
        };
        if stage_index + 1 >= self.stages.len() {
            return 0;
        }
        let Some(plan) = self.stages[stage_index].processor.current_plan() else {
            return 0;
        };
        if plan.phase != PlanPhase::Stable {
            return 0;
        }
        let live = plan.partitions;
        let down = &self.stages[stage_index + 1].processor;
        let mut retired = 0;
        for t in live..h.tablet_count().min(down.mapper_count()) {
            if down.supervisor().is_active(crate::controller::Role::Mapper, t)
                && h.first_index(t) == h.end_index(t)
            {
                down.retire_mapper(t);
                retired += 1;
            }
        }
        retired
    }

    /// Per-stage plus end-to-end write-amplification report. Per-stage
    /// denominators are each stage's own ingest; the end-to-end denominator
    /// is only the original source ingest (stage 0's mapper bytes).
    pub fn wa_report(&self) -> PipelineWaReport {
        let source_ingest = self.stages[0].processor.ingested_bytes();
        let total = WaReport::new(
            format!("{} (end-to-end)", self.name),
            source_ingest,
            self.env.accounting.snapshot(),
        );
        let stages = self
            .stages
            .iter()
            .map(|s| {
                WaReport::new(
                    s.scope.clone(),
                    s.processor.ingested_bytes(),
                    self.env.accounting.scope_snapshot(&s.scope),
                )
            })
            .collect();
        PipelineWaReport { stages, total }
    }

    /// Stop every stage's fleet without consuming the handle — what
    /// `Arc`-shared owners (a [`TopologyAutoscaler`] caller) use; query
    /// the env afterwards via [`RunningTopology::env`].
    pub fn shutdown(&self) {
        for s in &self.stages {
            s.processor.shutdown();
        }
    }

    /// Stop every stage's fleet; returns the shared env for post-mortem
    /// queries.
    pub fn stop(self) -> ClusterEnv {
        self.shutdown();
        self.env
    }
}

/// The resident *topology-wide* autoscale loop: one fused lag+backlog
/// policy instance per stage, each reading that stage's private metrics
/// hub and input backlog, all proposals executed through the same
/// stage-re-wiring path as [`RunningTopology::reshard_stage`] — an
/// intermediate stage's handoff table grows before its new fleet serves,
/// and the downstream mapper fleet re-specs after the migration
/// finalizes. After every sweep the loop also retires downstream mapper
/// slots whose handoff tablet went quiet (post-shrink hygiene), so a
/// shrunk chain converges to its minimal fleet without operator help.
///
/// Crash-resumable like the single-stage driver: any stage whose plan row
/// was left `Migrating` is resumed (with its re-wiring) before new
/// proposals, so starting the autoscaler doubles as topology-wide reshard
/// recovery.
pub struct TopologyAutoscaler {
    inner: crate::reshard::driver::LoopHandle,
}

impl TopologyAutoscaler {
    /// Spawn the loop over every stage of `topo`. One shared
    /// [`crate::reshard::DriverConfig`] applies to all stages.
    pub fn start(
        topo: Arc<RunningTopology>,
        cfg: crate::reshard::DriverConfig,
    ) -> TopologyAutoscaler {
        Self::start_with_stage_configs(topo, cfg, Vec::new())
    }

    /// Like [`TopologyAutoscaler::start`], but with optional per-stage
    /// [`crate::reshard::DriverConfig`] overrides: `overrides[k]`, when
    /// `Some`, replaces the shared config for stage `k` — heterogeneous
    /// chains can run different watermarks/floors per stage (a wide
    /// sessionize stage and a narrow aggregate stage rarely want the same
    /// thresholds). Missing or `None` entries fall back to the shared
    /// config; extra entries are ignored. The sweep cadence stays the
    /// shared config's `tick_period_ms` (one loop drives every stage).
    pub fn start_with_stage_configs(
        topo: Arc<RunningTopology>,
        shared: crate::reshard::DriverConfig,
        overrides: Vec<Option<crate::reshard::DriverConfig>>,
    ) -> TopologyAutoscaler {
        TopologyAutoscaler {
            inner: crate::reshard::driver::LoopHandle::spawn("topology-autoscaler", move |stop| {
                let cfgs = resolve_stage_configs(topo.stage_count(), &shared, overrides);
                run_topology_autoscaler(&topo, &shared, &cfgs, stop)
            }),
        }
    }

    /// Signal the loop to exit and join it. Stages left `Migrating` are
    /// resumed by the next start (or manual [`RunningTopology::resume_stage`]).
    pub fn stop(&self) {
        self.inner.stop();
    }
}

/// Resolve the effective per-stage driver configs: override when given,
/// shared otherwise. Extra override entries are ignored.
fn resolve_stage_configs(
    stage_count: usize,
    shared: &crate::reshard::DriverConfig,
    mut overrides: Vec<Option<crate::reshard::DriverConfig>>,
) -> Vec<crate::reshard::DriverConfig> {
    overrides.resize(stage_count, None);
    overrides
        .into_iter()
        .map(|o| o.unwrap_or_else(|| shared.clone()))
        .collect()
}

fn run_topology_autoscaler(
    topo: &Arc<RunningTopology>,
    shared: &crate::reshard::DriverConfig,
    cfgs: &[crate::reshard::DriverConfig],
    stop: &std::sync::atomic::AtomicBool,
) {
    use crate::reshard::driver::{drive_stage_tick, DriverDeps};
    use crate::reshard::Autoscaler;

    let clock = topo.env.clock.clone();
    let mut scalers: Vec<Autoscaler> = cfgs
        .iter()
        .map(|c| Autoscaler::new(c.autoscaler.clone()))
        .collect();
    // Per-stage deps, built once: the ctx factory snapshots live mapper
    // counts per use, and the hooks encode the stage coupling.
    let deps: Vec<DriverDeps> = (0..topo.stages.len())
        .map(|k| {
            let stage = &topo.stages[k];
            let pre_begin = stage.handoff.clone().map(|h| {
                Arc::new(move |n: usize| h.ensure_tablets(n)) as Arc<dyn Fn(usize) + Send + Sync>
            });
            let post_stable = (stage.handoff.is_some() && k + 1 < topo.stages.len()).then(|| {
                let topo = topo.clone();
                Arc::new(move |n: usize| topo.stages[k + 1].processor.grow_mappers(n))
                    as Arc<dyn Fn(usize) + Send + Sync>
            });
            DriverDeps {
                clock: clock.clone(),
                store: topo.env.store.clone(),
                plan_table: stage.processor.cfg.reshard_plan_table.clone(),
                metrics: stage.metrics().clone(),
                input: stage.processor.input.clone(),
                ctx: stage.processor.reshard_ctx_factory(),
                pre_begin,
                post_stable,
            }
        })
        .collect();

    while !stop.load(std::sync::atomic::Ordering::SeqCst) {
        for (k, stage_deps) in deps.iter().enumerate() {
            if stop.load(std::sync::atomic::Ordering::SeqCst) {
                return;
            }
            drive_stage_tick(&cfgs[k], stage_deps, &mut scalers[k], stop);
            // Post-shrink hygiene: downstream mapper slots whose handoff
            // tablet drained for good are retired (their state row gets
            // the CAS'd `retired` flag, unblocking later reducer reshards
            // of the downstream stage).
            topo.retire_quiet_downstream_mappers(k);
        }
        clock.sleep_ms(shared.tick_period_ms);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{FnMapper, FnReducer, PartitionedRowset};
    use crate::dataflow::sink::FnEmitReducer;
    use crate::queue::input_name_table;
    use crate::rows::UnversionedRowset;
    use crate::storage::WriteAccounting;

    fn noop_mapper_factory() -> MapperFactory {
        Arc::new(
            |_cfg: &Yson,
             _client: &Client,
             _nt: Arc<NameTable>,
             _spec: &crate::api::MapperSpec| {
                Box::new(FnMapper(|rows: UnversionedRowset| {
                    let n = rows.len();
                    PartitionedRowset::new(rows, vec![0; n])
                })) as Box<dyn crate::api::Mapper>
            },
        )
    }

    fn noop_emitter_factory() -> EmitterFactory {
        Arc::new(|_cfg: &Yson, _client: &Client, _spec: &ReducerSpec| {
            Box::new(FnEmitReducer(
                |_rows: UnversionedRowset| -> Vec<crate::rows::UnversionedRow> { Vec::new() },
            )) as Box<dyn crate::dataflow::EmitReducer>
        })
    }

    fn noop_reducer_factory() -> ReducerFactory {
        Arc::new(|_cfg: &Yson, _client: &Client, _spec: &ReducerSpec| {
            Box::new(FnReducer(
                |_rows: UnversionedRowset| -> Option<crate::dyntable::Transaction> { None },
            )) as Box<dyn Reducer>
        })
    }

    fn source(partitions: usize) -> InputSpec {
        InputSpec::Ordered(OrderedTable::new(
            "//input/topo_test",
            input_name_table(),
            partitions,
            WriteAccounting::new(),
        ))
    }

    fn cfg(mappers: usize, reducers: usize) -> ProcessorConfig {
        ProcessorConfig {
            mapper_count: mappers,
            reducer_count: reducers,
            ..ProcessorConfig::default()
        }
    }

    fn two_stage(s1: ProcessorConfig, s2: ProcessorConfig) -> Topology {
        Topology::new("t")
            .stage(StageSpec::intermediate(
                "first",
                s1,
                input_name_table(),
                input_name_table(),
                noop_mapper_factory(),
                noop_emitter_factory(),
            ))
            .stage(StageSpec::final_stage(
                "second",
                s2,
                input_name_table(),
                noop_mapper_factory(),
                noop_reducer_factory(),
            ))
    }

    #[test]
    fn empty_topology_rejected() {
        assert!(matches!(
            Topology::new("t").validate(&source(1)),
            Err(TopologyError::Empty)
        ));
    }

    #[test]
    fn valid_two_stage_wiring_passes() {
        // stage1: 4 mappers over 4 source partitions, 2 reducers;
        // stage2: 2 mappers over the 2 handoff tablets.
        two_stage(cfg(4, 2), cfg(2, 1)).validate(&source(4)).unwrap();
    }

    #[test]
    fn source_wiring_mismatch_rejected() {
        assert!(matches!(
            two_stage(cfg(3, 2), cfg(2, 1)).validate(&source(4)),
            Err(TopologyError::SourceWiring { mappers: 3, partitions: 4, .. })
        ));
    }

    #[test]
    fn partition_wiring_mismatch_rejected() {
        assert!(matches!(
            two_stage(cfg(4, 2), cfg(3, 1)).validate(&source(4)),
            Err(TopologyError::PartitionWiring {
                mappers: 3,
                upstream_reducers: 2,
                ..
            })
        ));
    }

    #[test]
    fn schema_mismatch_rejected() {
        let t = Topology::new("t")
            .stage(StageSpec::intermediate(
                "first",
                cfg(2, 2),
                input_name_table(),
                crate::rows::NameTable::new(&["session", "count"]),
                noop_mapper_factory(),
                noop_emitter_factory(),
            ))
            .stage(StageSpec::final_stage(
                "second",
                cfg(2, 1),
                input_name_table(), // wrong: upstream hands (session, count)
                noop_mapper_factory(),
                noop_reducer_factory(),
            ));
        assert!(matches!(
            t.validate(&source(2)),
            Err(TopologyError::SchemaMismatch { .. })
        ));
    }

    #[test]
    fn final_stage_must_be_final() {
        let t = Topology::new("t").stage(StageSpec::intermediate(
            "only",
            cfg(2, 2),
            input_name_table(),
            input_name_table(),
            noop_mapper_factory(),
            noop_emitter_factory(),
        ));
        assert!(matches!(
            t.validate(&source(2)),
            Err(TopologyError::FinalMustBeFinal(_))
        ));
    }

    #[test]
    fn intermediate_stage_must_emit() {
        let t = Topology::new("t")
            .stage(StageSpec::final_stage(
                "first",
                cfg(2, 2),
                input_name_table(),
                noop_mapper_factory(),
                noop_reducer_factory(),
            ))
            .stage(StageSpec::final_stage(
                "second",
                cfg(2, 1),
                input_name_table(),
                noop_mapper_factory(),
                noop_reducer_factory(),
            ));
        assert!(matches!(
            t.validate(&source(2)),
            Err(TopologyError::IntermediateMustEmit(_))
        ));
    }

    #[test]
    fn duplicate_stage_names_rejected() {
        let t = Topology::new("t")
            .stage(StageSpec::intermediate(
                "same",
                cfg(2, 2),
                input_name_table(),
                input_name_table(),
                noop_mapper_factory(),
                noop_emitter_factory(),
            ))
            .stage(StageSpec::final_stage(
                "same",
                cfg(2, 1),
                input_name_table(),
                noop_mapper_factory(),
                noop_reducer_factory(),
            ));
        assert!(matches!(
            t.validate(&source(2)),
            Err(TopologyError::DuplicateStageName(_))
        ));
    }

    #[test]
    fn per_stage_driver_configs_resolve_with_fallback() {
        use crate::reshard::DriverConfig;

        let shared = DriverConfig {
            tick_period_ms: 500,
            ..DriverConfig::default()
        };
        let special = DriverConfig {
            tick_period_ms: 50,
            signal_window_ms: 123,
            ..DriverConfig::default()
        };
        // No overrides: every stage runs the shared config.
        let all = resolve_stage_configs(3, &shared, Vec::new());
        assert_eq!(all.len(), 3);
        assert!(all.iter().all(|c| c.tick_period_ms == 500));
        // Sparse overrides: stage 1 gets its own, the rest fall back;
        // extra entries are ignored.
        let mixed = resolve_stage_configs(
            2,
            &shared,
            vec![None, Some(special.clone()), Some(special.clone())],
        );
        assert_eq!(mixed.len(), 2);
        assert_eq!(mixed[0].tick_period_ms, 500);
        assert_eq!(mixed[1].tick_period_ms, 50);
        assert_eq!(mixed[1].signal_window_ms, 123);
    }

    #[test]
    fn upstream_watermark_wiring_follows_event_time_stages() {
        use crate::coordinator::EventTimeConfig;

        // stage1 event-timed, stage2 event-timed: stage2 must be capped
        // by stage1's (namespaced) mapper state table.
        let mut s1 = cfg(4, 2);
        s1.event_time = Some(EventTimeConfig { column: "ts".into() });
        let mut s2 = cfg(2, 1);
        s2.event_time = Some(EventTimeConfig { column: "ts".into() });
        let env = crate::coordinator::processor::ClusterEnv::new(
            crate::util::Clock::realtime(),
            3,
        );
        let running = two_stage(s1, s2)
            .launch(&env, source(4))
            .expect("launch");
        assert_eq!(
            running.stage(0).processor.cfg.upstream_watermark_table,
            None,
            "source stage has no upstream"
        );
        assert_eq!(
            running.stage(1).processor.cfg.upstream_watermark_table.as_deref(),
            Some("//sys/dataflow/t/first/mapper_state"),
        );
        running.stop();

        // A non-event-timed upstream breaks the chain — and validation
        // rejects the wiring outright: the downstream stage would window
        // on an unsafe frontier-derived watermark while rows sit buffered
        // upstream, invisible to it.
        let s1 = cfg(4, 2);
        let mut s2 = cfg(2, 1);
        s2.event_time = Some(EventTimeConfig { column: "ts".into() });
        assert!(matches!(
            two_stage(s1, s2).validate(&source(4)),
            Err(TopologyError::EventTimeChainBroken { .. })
        ));
    }

    #[test]
    fn at_least_once_emit_stage_rejected() {
        let mut s1 = cfg(2, 2);
        s1.at_least_once = true;
        assert!(matches!(
            two_stage(s1, cfg(2, 1)).validate(&source(2)),
            Err(TopologyError::ExactlyOnceRequired(_))
        ));
    }

    #[test]
    fn at_most_once_intermediate_stage_rejected_sink_allowed() {
        let mut s1 = cfg(2, 2);
        s1.consistency = Consistency::AtMostOnce;
        let mut s2 = cfg(2, 1);
        s2.tolerates_upstream_drift = true;
        assert!(matches!(
            two_stage(s1, s2).validate(&source(2)),
            Err(TopologyError::AtMostOnceIntermediate(_))
        ));
        // As the terminal sink (with the upstream exactly-once) it is fine.
        let mut sink = cfg(2, 1);
        sink.consistency = Consistency::AtMostOnce;
        two_stage(cfg(2, 2), sink).validate(&source(2)).unwrap();
    }

    #[test]
    fn exactly_once_below_approximate_must_acknowledge_drift() {
        let mut s1 = cfg(2, 2);
        s1.consistency = Consistency::bounded_error(64);
        assert!(matches!(
            two_stage(s1, cfg(2, 1)).validate(&source(2)),
            Err(TopologyError::UpstreamDriftUnacknowledged { .. })
        ));
        // The same chain passes once the demotion is explicit.
        let mut s1 = cfg(2, 2);
        s1.consistency = Consistency::bounded_error(64);
        let mut s2 = cfg(2, 1);
        s2.tolerates_upstream_drift = true;
        two_stage(s1, s2).validate(&source(2)).unwrap();
        // An approximate downstream needs no acknowledgement — it never
        // promised byte-exactness in the first place.
        let mut s1 = cfg(2, 2);
        s1.consistency = Consistency::bounded_error(64);
        let mut s2 = cfg(2, 1);
        s2.consistency = Consistency::bounded_error(64);
        two_stage(s1, s2).validate(&source(2)).unwrap();
    }
}
