//! The ordered-table sink: how an intermediate stage's reducers hand rows
//! to the next stage exactly once.
//!
//! User code for an intermediate stage implements [`EmitReducer`] — a pure
//! transform from one combined shuffle batch to the rows the downstream
//! stage should see. The [`SinkReducer`] adapter turns that into the
//! coordinator's [`Reducer`] contract: it opens the commit transaction,
//! buffers the emitted rows onto this reducer's own tablet of the handoff
//! table with [`Transaction::append_ordered`], and returns the transaction
//! for the reducer main procedure to finish (split-brain CAS, meta-state
//! write, atomic commit — §4.4.2 steps 6–8). The append is applied iff the
//! meta-state CAS wins, which is exactly the existing row-index dedup: a
//! batch of shuffle rows is turned into downstream rows at most once.

use std::sync::Arc;

use crate::api::{Client, Reducer, ReducerSpec};
use crate::dyntable::Transaction;
use crate::queue::ordered_table::OrderedTable;
use crate::rows::{UnversionedRow, UnversionedRowset};
use crate::util::yson::Yson;

/// User code of an intermediate dataflow stage: transform one combined
/// batch of shuffled rows into the rows handed to the next stage.
///
/// **Must be deterministic** for a given input rowset (like
/// [`crate::api::Mapper`]): under split-brain races the commit CAS picks
/// one twin's emission, and correctness of the pipeline's *contents*
/// relies on any twin emitting equivalent rows for the same batch.
///
/// **Event-time contract** (only when the downstream stage windows on
/// event time): an emitted row's event-time column must be **no lower
/// than the minimum event time of the batch it was derived from**. The
/// upstream fleet watermark then bounds every future handoff append, and
/// [`crate::coordinator::ProcessorConfig::upstream_watermark_table`]
/// makes the downstream stage's watermark safe. Aggregating emitters
/// satisfy this naturally (a session's `first_ts` *is* a batch minimum).
pub trait EmitReducer: Send {
    fn emit(&mut self, rows: UnversionedRowset) -> Vec<UnversionedRow>;
}

/// `CreateReducer` analogue for intermediate stages.
pub type EmitterFactory =
    Arc<dyn Fn(&Yson, &Client, &ReducerSpec) -> Box<dyn EmitReducer> + Send + Sync>;

/// Adapter: build an [`EmitReducer`] from a plain function (tests,
/// examples).
pub struct FnEmitReducer<F>(pub F);

impl<F: FnMut(UnversionedRowset) -> Vec<UnversionedRow> + Send> EmitReducer for FnEmitReducer<F> {
    fn emit(&mut self, rows: UnversionedRowset) -> Vec<UnversionedRow> {
        (self.0)(rows)
    }
}

/// The coordinator-facing wrapper around an intermediate stage's
/// [`EmitReducer`]: reducer *k* appends into tablet *k* of the handoff
/// table, inside the exactly-once commit transaction.
pub(crate) struct SinkReducer {
    pub inner: Box<dyn EmitReducer>,
    pub handoff: Arc<OrderedTable>,
    pub tablet: usize,
    pub client: Client,
}

impl Reducer for SinkReducer {
    fn reduce(&mut self, rows: UnversionedRowset) -> Option<Transaction> {
        if rows.is_empty() {
            return None;
        }
        let out = self.inner.emit(rows);
        // Always hand back a transaction, even for an empty emission: the
        // reducer main procedure still advances the meta-state (the batch
        // was consumed, it just produced nothing downstream).
        let mut txn = self.client.begin();
        if !out.is_empty() {
            let width = self.handoff.name_table().len();
            for r in &out {
                assert_eq!(
                    r.len(),
                    width,
                    "stage emitted a row of arity {} into handoff table '{}' (schema arity {})",
                    r.len(),
                    self.handoff.name(),
                    width
                );
            }
            txn.append_ordered(self.handoff.clone(), self.tablet, out)
                .expect("append_ordered on an open transaction");
        }
        Some(txn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::processor::ClusterEnv;
    use crate::queue::input_name_table;
    use crate::row;
    use crate::rows::RowsetBuilder;
    use crate::storage::WriteCategory;
    use crate::util::Clock;

    fn rig() -> (ClusterEnv, Arc<OrderedTable>) {
        let env = ClusterEnv::new(Clock::realtime(), 7);
        let handoff = OrderedTable::new_with_category(
            "//dataflow/test/handoff",
            input_name_table(),
            2,
            env.accounting.clone(),
            WriteCategory::InterStage,
        );
        (env, handoff)
    }

    fn batch(payloads: &[&str]) -> UnversionedRowset {
        let mut b = RowsetBuilder::new(input_name_table());
        for p in payloads {
            b.push(row![*p, 0i64]);
        }
        b.build()
    }

    #[test]
    fn sink_appends_land_only_on_commit() {
        let (env, handoff) = rig();
        let mut r = SinkReducer {
            inner: Box::new(FnEmitReducer(|rows: UnversionedRowset| {
                rows.rows().to_vec()
            })),
            handoff: handoff.clone(),
            tablet: 1,
            client: env.client(),
        };
        let txn = r.reduce(batch(&["a", "b"])).expect("txn");
        assert_eq!(handoff.end_index(1), 0, "nothing lands before commit");
        txn.commit().unwrap();
        assert_eq!(handoff.end_index(1), 2);
        assert_eq!(handoff.end_index(0), 0, "reducer owns its own tablet");
    }

    #[test]
    fn sink_aborted_txn_emits_nothing() {
        let (env, handoff) = rig();
        let mut r = SinkReducer {
            inner: Box::new(FnEmitReducer(|rows: UnversionedRowset| {
                rows.rows().to_vec()
            })),
            handoff: handoff.clone(),
            tablet: 0,
            client: env.client(),
        };
        let txn = r.reduce(batch(&["a"])).expect("txn");
        txn.abort();
        assert_eq!(handoff.end_index(0), 0);
    }

    #[test]
    fn sink_empty_emission_still_returns_txn() {
        let (env, handoff) = rig();
        let mut r = SinkReducer {
            inner: Box::new(FnEmitReducer(
                |_rows: UnversionedRowset| -> Vec<UnversionedRow> { Vec::new() },
            )),
            handoff,
            tablet: 0,
            client: env.client(),
        };
        // The meta-state must still be able to advance on a filtered-out
        // batch, so a transaction comes back.
        assert!(r.reduce(batch(&["x"])).is_some());
        assert!(r.reduce(UnversionedRowset::empty(input_name_table())).is_none());
    }
}
