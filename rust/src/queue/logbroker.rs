//! LogBroker-style topics (§4.2).
//!
//! "Reading from a LogBroker topic. It is internally divided into
//! partitions. These partitions have their own offsets, which increase
//! monotonically, but are **not guaranteed to be sequential**. Thus, it is
//! necessary to use the continuationToken argument to specify the next
//! offset to read from."
//!
//! The gappy-offset behaviour is reproduced by advancing the offset by a
//! deterministic pseudo-random stride on every append, which forces the
//! mapper to exercise the token-driven addressing path (the `…Index`
//! arguments only label rows in the mapper's own numbering).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use super::{ContinuationToken, PartitionReader, QueueError, ReadBatch};
use crate::rows::{codec, NameTable, UnversionedRow, UnversionedRowset};
use crate::storage::{Journal, WriteAccounting, WriteCategory};
use crate::util::prng::splitmix64;
use crate::util;

#[derive(Debug)]
struct LbPartition {
    /// (offset, row), offsets strictly increasing but gappy.
    entries: VecDeque<(u64, UnversionedRow)>,
    next_offset: u64,
    /// Seed stream for the offset gaps (deterministic per partition).
    gap_state: u64,
    unavailable: bool,
}

/// A LogBroker topic: partitions with gappy monotonic offsets.
#[derive(Debug)]
pub struct LbTopic {
    name_table: Arc<NameTable>,
    partitions: Vec<Mutex<LbPartition>>,
    journal: Arc<Journal>,
}

const TOKEN_PREFIX: &str = "lb:";

/// Seed for the deterministic offset-gap stream.
const GAP_SEED: u64 = 0x10B2_0CE2_5EED_0001;

fn encode_token(offset: u64) -> ContinuationToken {
    ContinuationToken(format!("{TOKEN_PREFIX}{offset}"))
}

fn decode_token(token: &ContinuationToken) -> Result<u64, QueueError> {
    if token.is_initial() {
        return Ok(0);
    }
    token
        .0
        .strip_prefix(TOKEN_PREFIX)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| QueueError::BadToken(token.0.clone()))
}

impl LbTopic {
    pub fn new(
        name: &str,
        name_table: Arc<NameTable>,
        partition_count: usize,
        accounting: Arc<WriteAccounting>,
    ) -> Arc<LbTopic> {
        Arc::new(LbTopic {
            name_table,
            partitions: (0..partition_count)
                .map(|p| {
                    Mutex::new(LbPartition {
                        entries: VecDeque::new(),
                        next_offset: 0,
                        gap_state: GAP_SEED ^ p as u64,
                        unavailable: false,
                    })
                })
                .collect(),
            journal: Journal::new(name, WriteCategory::SourceIngest, accounting),
        })
    }

    pub fn partition_count(&self) -> usize {
        self.partitions.len()
    }

    pub fn name_table(&self) -> Arc<NameTable> {
        self.name_table.clone()
    }

    /// Producer append. Each row lands at a gappy offset.
    pub fn append(&self, partition: usize, rows: Vec<UnversionedRow>) -> Result<(), QueueError> {
        let encoded = codec::encode_rows(&rows);
        let mut p = util::lock(&self.partitions[partition]);
        if p.unavailable {
            return Err(QueueError::Unavailable(partition));
        }
        self.journal.append(encoded);
        for row in rows {
            let offset = p.next_offset;
            p.entries.push_back((offset, row));
            // Monotonic, non-sequential: stride in 1..=4.
            let stride = 1 + (splitmix64(&mut p.gap_state) % 4);
            p.next_offset += stride;
        }
        Ok(())
    }

    pub fn retained_rows(&self) -> usize {
        self.partitions
            .iter()
            .map(|p| util::lock(&p).entries.len())
            .sum()
    }

    pub fn set_unavailable(&self, partition: usize, unavailable: bool) {
        util::lock(&self.partitions[partition]).unavailable = unavailable;
    }

    /// Offset one past the newest entry (for lag probes).
    pub fn head_offset(&self, partition: usize) -> u64 {
        util::lock(&self.partitions[partition]).next_offset
    }

    pub fn reader(self: &Arc<Self>, partition: usize) -> LbReader {
        LbReader {
            topic: self.clone(),
            partition,
        }
    }
}

/// [`PartitionReader`] over one LogBroker partition; all addressing flows
/// through the continuation token.
pub struct LbReader {
    topic: Arc<LbTopic>,
    partition: usize,
}

impl PartitionReader for LbReader {
    fn read(
        &mut self,
        begin_row_index: i64,
        end_row_index: i64,
        token: &ContinuationToken,
    ) -> Result<ReadBatch, QueueError> {
        let from_offset = decode_token(token)?;
        let want = (end_row_index - begin_row_index).max(0) as usize;
        let p = util::lock(&self.topic.partitions[self.partition]);
        if p.unavailable {
            return Err(QueueError::Unavailable(self.partition));
        }
        // Offsets below the first retained entry but above 0 mean the data
        // was trimmed under us — only an error if the token points below
        // the retained range AND entries exist that started later.
        if let Some(&(first_off, _)) = p.entries.front() {
            if from_offset < first_off && from_offset > 0 {
                // Tokens always point at (last offset + 1); a token strictly
                // below the retained front that isn't initial is stale only
                // if it addresses a trimmed entry. Conservatively accept and
                // start from the front (LogBroker semantics: read from the
                // earliest available).
            }
        }
        let mut rows = Vec::new();
        let mut last_offset = None;
        for (off, row) in p.entries.iter() {
            if *off < from_offset {
                continue;
            }
            if rows.len() >= want {
                break;
            }
            rows.push(row.clone());
            last_offset = Some(*off);
        }
        let next_token = match last_offset {
            Some(off) => encode_token(off + 1),
            None => token.clone(),
        };
        Ok(ReadBatch {
            rowset: UnversionedRowset::new(self.topic.name_table(), rows),
            next_token,
        })
    }

    fn trim(&mut self, _row_index: i64, token: &ContinuationToken) -> Result<(), QueueError> {
        let below = decode_token(token)?;
        let mut p = util::lock(&self.topic.partitions[self.partition]);
        if p.unavailable {
            return Err(QueueError::Unavailable(self.partition));
        }
        while p.entries.front().is_some_and(|(off, _)| *off < below) {
            p.entries.pop_front();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queue::input_name_table;
    use crate::row;

    fn topic() -> Arc<LbTopic> {
        LbTopic::new("lb", input_name_table(), 2, WriteAccounting::new())
    }

    fn rows(n: usize, base: i64) -> Vec<UnversionedRow> {
        (0..n).map(|i| row![format!("m{}", base + i as i64), base + i as i64]).collect()
    }

    #[test]
    fn offsets_are_gappy_but_reads_sequential() {
        let t = topic();
        t.append(0, rows(20, 0)).unwrap();
        let mut r = t.reader(0);
        let mut token = ContinuationToken::initial();
        let mut all = Vec::new();
        let mut idx = 0i64;
        loop {
            let b = r.read(idx, idx + 7, &token).unwrap();
            if b.rowset.is_empty() {
                break;
            }
            idx += b.rowset.len() as i64;
            token = b.next_token;
            all.extend(
                b.rowset
                    .rows()
                    .iter()
                    .map(|row| row.get(0).unwrap().as_str().unwrap().to_string()),
            );
        }
        assert_eq!(all.len(), 20);
        assert_eq!(all[0], "m0");
        assert_eq!(all[19], "m19");
        // Offsets in the partition must exceed the row count (gappy).
        assert!(t.head_offset(0) > 20);
    }

    #[test]
    fn reads_deterministic_for_same_token() {
        let t = topic();
        t.append(0, rows(10, 0)).unwrap();
        let mut r1 = t.reader(0);
        let mut r2 = t.reader(0);
        let tok = ContinuationToken::initial();
        let a = r1.read(0, 5, &tok).unwrap();
        let b = r2.read(0, 5, &tok).unwrap();
        assert_eq!(a.rowset, b.rowset);
        assert_eq!(a.next_token, b.next_token);
    }

    #[test]
    fn trim_via_token() {
        let t = topic();
        t.append(0, rows(10, 0)).unwrap();
        let mut r = t.reader(0);
        let b = r.read(0, 4, &ContinuationToken::initial()).unwrap();
        assert_eq!(b.rowset.len(), 4);
        r.trim(4, &b.next_token).unwrap();
        r.trim(4, &b.next_token).unwrap(); // idempotent
        assert_eq!(t.retained_rows(), 6);
        // Continue reading from the token: untouched rows.
        let b2 = r.read(4, 10, &b.next_token).unwrap();
        assert_eq!(b2.rowset.len(), 6);
        assert_eq!(b2.rowset.cell(0, "payload").unwrap().as_str(), Some("m4"));
    }

    #[test]
    fn empty_read_returns_same_token() {
        let t = topic();
        let mut r = t.reader(1);
        let tok = ContinuationToken::initial();
        let b = r.read(0, 5, &tok).unwrap();
        assert!(b.rowset.is_empty());
        assert_eq!(b.next_token, tok);
    }

    #[test]
    fn bad_token_rejected() {
        let t = topic();
        let mut r = t.reader(0);
        let bad = ContinuationToken("bogus".into());
        assert!(matches!(r.read(0, 1, &bad), Err(QueueError::BadToken(_))));
    }

    #[test]
    fn unavailability() {
        let t = topic();
        t.append(0, rows(1, 0)).unwrap();
        t.set_unavailable(0, true);
        let mut r = t.reader(0);
        assert!(matches!(
            r.read(0, 1, &ContinuationToken::initial()),
            Err(QueueError::Unavailable(0))
        ));
        t.set_unavailable(0, false);
        assert_eq!(r.read(0, 1, &ContinuationToken::initial()).unwrap().rowset.len(), 1);
    }

    #[test]
    fn partitions_have_distinct_gap_patterns() {
        let t = topic();
        t.append(0, rows(10, 0)).unwrap();
        t.append(1, rows(10, 0)).unwrap();
        assert_ne!(t.head_offset(0), t.head_offset(1));
    }
}
