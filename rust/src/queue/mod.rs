//! The input model (§4.2): Kafka-like partitioned queues.
//!
//! "The input is given as a stream of rows consisting of multiple
//! partitions. … Producers can append rows to the end of these queues and
//! consumers can read the partitions at their own pace."
//!
//! A viable input source implements [`PartitionReader`] — exactly the two
//! methods the paper specifies:
//!
//! * `read(begin_row_index, end_row_index, continuation_token)` → next
//!   batch plus a token for the following position; rows get sequential
//!   indexes starting at `begin_row_index` in the mapper's input numbering,
//!   so the method **must** return rows in deterministic order.
//! * `trim(row_index, continuation_token)` — mark earlier entries
//!   committed and safe to delete; idempotent, may be applied lazily.
//!
//! Two sources are provided, mirroring the paper's:
//! [`ordered_table::OrderedTable`] (absolute tablet indexes; the `…Index`
//! arguments do the addressing) and [`logbroker::LbTopic`] (monotonic but
//! *non-sequential* offsets; addressing must go through the token).

pub mod ordered_table;
pub mod logbroker;

use crate::rows::{NameTable, UnversionedRowset};
use std::sync::Arc;

/// Opaque serializable position in an input partition. Stored verbatim in
/// the mapper's persistent state (§4.3.2 `continuation_token` column).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ContinuationToken(pub String);

impl ContinuationToken {
    /// The "start of stream" token every mapper begins from.
    pub fn initial() -> Self {
        ContinuationToken(String::new())
    }

    pub fn is_initial(&self) -> bool {
        self.0.is_empty()
    }
}

/// A batch returned by [`PartitionReader::read`].
#[derive(Debug, Clone)]
pub struct ReadBatch {
    /// The rows, in deterministic order.
    pub rowset: UnversionedRowset,
    /// Token pointing at the next position in the stream.
    pub next_token: ContinuationToken,
}

#[derive(Debug, thiserror::Error)]
pub enum QueueError {
    #[error("partition {partition}: rows before index {first_available} were trimmed (requested {requested})")]
    Trimmed {
        partition: usize,
        requested: i64,
        first_available: i64,
    },
    #[error("partition {0} unavailable (injected fault)")]
    Unavailable(usize),
    #[error("bad continuation token: {0:?}")]
    BadToken(String),
}

/// The paper's `IPartitionReader` (§4.2). One instance per (mapper,
/// partition); drives all interaction with the input stream.
pub trait PartitionReader: Send {
    /// Read up to `end_row_index - begin_row_index` rows from the position
    /// identified by `token`.
    fn read(
        &mut self,
        begin_row_index: i64,
        end_row_index: i64,
        token: &ContinuationToken,
    ) -> Result<ReadBatch, QueueError>;

    /// Mark rows before `row_index` / `token` as committed; idempotent and
    /// allowed to be asynchronous.
    fn trim(&mut self, row_index: i64, token: &ContinuationToken) -> Result<(), QueueError>;
}

/// Schema shared by both input sources: an opaque message payload plus the
/// producer-side write timestamp (drives the read-lag metric of fig. 5.2).
pub fn input_name_table() -> Arc<NameTable> {
    NameTable::new(&["payload", "write_ts_ms"])
}

/// Column index of the payload in [`input_name_table`]-shaped rows.
pub const INPUT_COL_PAYLOAD: usize = 0;
/// Column index of the producer write timestamp.
pub const INPUT_COL_WRITE_TS: usize = 1;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_token_empty() {
        let t = ContinuationToken::initial();
        assert!(t.is_initial());
        assert!(!ContinuationToken("x".into()).is_initial());
    }

    #[test]
    fn input_schema_columns() {
        let nt = input_name_table();
        assert_eq!(nt.id("payload"), Some(INPUT_COL_PAYLOAD));
        assert_eq!(nt.id("write_ts_ms"), Some(INPUT_COL_WRITE_TS));
    }
}
