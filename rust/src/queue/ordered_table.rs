//! Ordered dynamic tables (§4.2, chapter 3).
//!
//! "Reading from an ordered dynamic table. It is internally divided into
//! queue-like partitions called tablets. Each tablet is indexed from zero
//! in an absolute fashion and can be read from and trimmed using these
//! indexes." — so the reader addresses rows purely by the `…Index`
//! arguments and the continuation token is a pass-through.
//!
//! Appends are journal-accounted as [`WriteCategory::SourceIngest`]: the
//! input store is durable, but its writes are the WA *denominator*, not
//! processor overhead.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex, RwLock};

use super::{ContinuationToken, PartitionReader, QueueError, ReadBatch};
use crate::rows::{codec, NameTable, UnversionedRow, UnversionedRowset};
use crate::storage::{Journal, WriteAccounting, WriteCategory};
use crate::util;

/// One queue-like partition of an ordered table.
#[derive(Debug)]
struct Tablet {
    /// Absolute index of the first retained row.
    first_index: i64,
    rows: VecDeque<UnversionedRow>,
    /// Injected fault: reads/writes fail while true (partition outage).
    unavailable: bool,
}

fn fresh_tablet() -> Arc<Mutex<Tablet>> {
    Arc::new(Mutex::new(Tablet {
        first_index: 0,
        rows: VecDeque::new(),
        unavailable: false,
    }))
}

/// An ordered dynamic table: a vector of independently trimmable tablets.
/// The tablet count can *grow* at runtime ([`OrderedTable::ensure_tablets`])
/// — elastic resharding re-partitions a dataflow handoff table in place;
/// existing tablet indexes and their contents are never disturbed.
#[derive(Debug)]
pub struct OrderedTable {
    name_table: Arc<NameTable>,
    tablets: RwLock<Vec<Arc<Mutex<Tablet>>>>,
    journal: Arc<Journal>,
}

impl OrderedTable {
    pub fn new(
        name: &str,
        name_table: Arc<NameTable>,
        tablet_count: usize,
        accounting: Arc<WriteAccounting>,
    ) -> Arc<OrderedTable> {
        Self::new_with_category(name, name_table, tablet_count, accounting, WriteCategory::SourceIngest)
    }

    /// Like [`OrderedTable::new`] but with an explicit write-accounting
    /// category (the §6 order log is *meta-state*, not source ingest).
    pub fn new_with_category(
        name: &str,
        name_table: Arc<NameTable>,
        tablet_count: usize,
        accounting: Arc<WriteAccounting>,
        category: WriteCategory,
    ) -> Arc<OrderedTable> {
        Self::new_scoped(name, name_table, tablet_count, accounting, category, None)
    }

    /// Full-control constructor: explicit category *and* accounting scope
    /// (a dataflow inter-stage handoff table attributes its bytes to the
    /// producing stage).
    pub fn new_scoped(
        name: &str,
        name_table: Arc<NameTable>,
        tablet_count: usize,
        accounting: Arc<WriteAccounting>,
        category: WriteCategory,
        scope: Option<String>,
    ) -> Arc<OrderedTable> {
        Arc::new(OrderedTable {
            name_table,
            tablets: RwLock::new((0..tablet_count).map(|_| fresh_tablet()).collect()),
            journal: Journal::new_scoped(name, category, accounting, scope),
        })
    }

    pub fn tablet_count(&self) -> usize {
        util::rlock(&self.tablets).len()
    }

    /// Grow to at least `count` tablets (no-op when already that large;
    /// shrinking is never done in place — a reshard that reduces the
    /// partition count simply stops writing the tail tablets).
    pub fn ensure_tablets(&self, count: usize) {
        let mut tablets = util::wlock(&self.tablets);
        while tablets.len() < count {
            tablets.push(fresh_tablet());
        }
    }

    /// The tablet handle (panics on out-of-range, like the old indexing).
    fn tablet(&self, index: usize) -> Arc<Mutex<Tablet>> {
        util::rlock(&self.tablets)[index].clone()
    }

    /// Table name (the journal's name).
    pub fn name(&self) -> &str {
        self.journal.name()
    }

    pub fn name_table(&self) -> Arc<NameTable> {
        self.name_table.clone()
    }

    /// Write-accounting category of this table's journal (what an
    /// append's bytes are recorded as).
    pub fn category(&self) -> WriteCategory {
        self.journal.category()
    }

    /// Producer append; returns the absolute index of the first appended
    /// row. Durable: bytes are journal-accounted.
    pub fn append(&self, tablet: usize, rows: Vec<UnversionedRow>) -> Result<i64, QueueError> {
        let encoded = codec::encode_rows(&rows);
        let tablet_ref = self.tablet(tablet);
        let mut t = util::lock(&tablet_ref);
        if t.unavailable {
            return Err(QueueError::Unavailable(tablet));
        }
        self.journal.append(encoded);
        let first = t.first_index + t.rows.len() as i64;
        t.rows.extend(rows);
        Ok(first)
    }

    /// Transactional append path, called by [`crate::dyntable`] while it
    /// holds the store-wide commit lock, *after* availability was validated
    /// (an outage injected mid-commit must not tear the commit, so this
    /// path ignores the flag). Rows must not keep pinning the decoded
    /// attachment buffer they came from; instead of detaching each row
    /// (a per-cell copy), the batch is detached **once**: the journal
    /// record we encode anyway is exactly sized to the batch, so the
    /// retained rows are zero-copy views into that one shared buffer.
    /// Returns the absolute index of the first appended row.
    pub(crate) fn append_committed(&self, tablet: usize, rows: Vec<UnversionedRow>) -> i64 {
        let encoded: Arc<[u8]> = codec::encode_rows(&rows).into();
        let retained =
            // protolint: allow(panic, "round-trip of bytes this same statement encoded; a failure is a codec bug, not data drift, and the commit lock is held — no partial protocol state escapes")
            codec::decode_rows_shared(&encoded).expect("own encode must decode");
        let tablet_ref = self.tablet(tablet);
        let mut t = util::lock(&tablet_ref);
        self.journal.append(encoded);
        let first = t.first_index + t.rows.len() as i64;
        t.rows.extend(retained);
        first
    }

    /// Is the tablet currently serving requests? (False during an injected
    /// partition outage.)
    pub fn is_available(&self, tablet: usize) -> bool {
        !util::lock(&self.tablet(tablet)).unavailable
    }

    /// Absolute index one past the last appended row.
    pub fn end_index(&self, tablet: usize) -> i64 {
        let tablet_ref = self.tablet(tablet);
        let t = util::lock(&tablet_ref);
        t.first_index + t.rows.len() as i64
    }

    /// Absolute index of the first retained (untrimmed) row.
    pub fn first_index(&self, tablet: usize) -> i64 {
        util::lock(&self.tablet(tablet)).first_index
    }

    /// Rows currently retained across all tablets (for backlog metrics).
    pub fn retained_rows(&self) -> usize {
        let tablets: Vec<_> = util::rlock(&self.tablets).clone();
        tablets.iter().map(|tablet| util::lock(tablet).rows.len()).sum()
    }

    /// Per-tablet trim low-water marks: the first retained absolute index
    /// of every tablet. For a dataflow handoff table these are advanced by
    /// the downstream stage's mappers (their `TrimInputRows` persists the
    /// continuation state, then trims), so the marks trail the downstream
    /// consumers' committed positions and bound the table's memory.
    pub fn low_water_marks(&self) -> Vec<i64> {
        let tablets: Vec<_> = util::rlock(&self.tablets).clone();
        tablets
            .iter()
            .map(|tablet| util::lock(tablet).first_index)
            .collect()
    }

    /// Inject or clear a partition outage (used by §5.2-style drills:
    /// "failures of individual partitions").
    pub fn set_unavailable(&self, tablet: usize, unavailable: bool) {
        util::lock(&self.tablet(tablet)).unavailable = unavailable;
    }

    /// Public indexed read over one tablet (used by the §6 order log).
    pub fn read_tablet(
        &self,
        tablet: usize,
        begin: i64,
        end: i64,
    ) -> Result<Vec<UnversionedRow>, QueueError> {
        self.read(tablet, begin, end)
    }

    /// Public idempotent trim of one tablet.
    pub fn trim_tablet(&self, tablet: usize, row_index: i64) -> Result<(), QueueError> {
        self.trim(tablet, row_index)
    }

    fn read(&self, tablet: usize, begin: i64, end: i64) -> Result<Vec<UnversionedRow>, QueueError> {
        let tablet_ref = self.tablet(tablet);
        let t = util::lock(&tablet_ref);
        if t.unavailable {
            return Err(QueueError::Unavailable(tablet));
        }
        if begin < t.first_index {
            return Err(QueueError::Trimmed {
                partition: tablet,
                requested: begin,
                first_available: t.first_index,
            });
        }
        let avail_end = t.first_index + t.rows.len() as i64;
        let end = end.min(avail_end);
        if begin >= end {
            return Ok(Vec::new());
        }
        let lo = (begin - t.first_index) as usize;
        let hi = (end - t.first_index) as usize;
        Ok(t.rows.range(lo..hi).cloned().collect())
    }

    fn trim(&self, tablet: usize, row_index: i64) -> Result<(), QueueError> {
        let tablet_ref = self.tablet(tablet);
        let mut t = util::lock(&tablet_ref);
        if t.unavailable {
            return Err(QueueError::Unavailable(tablet));
        }
        // Idempotent: indexes at or below first_index are no-ops.
        while t.first_index < row_index && !t.rows.is_empty() {
            t.rows.pop_front();
            t.first_index += 1;
        }
        Ok(())
    }

    /// Reader over a single tablet.
    pub fn reader(self: &Arc<Self>, tablet: usize) -> OrderedTableReader {
        OrderedTableReader {
            table: self.clone(),
            tablet,
        }
    }
}

/// [`PartitionReader`] over one tablet: pure index addressing, token is a
/// pass-through (always returned as-is).
pub struct OrderedTableReader {
    table: Arc<OrderedTable>,
    tablet: usize,
}

impl PartitionReader for OrderedTableReader {
    fn read(
        &mut self,
        begin_row_index: i64,
        end_row_index: i64,
        token: &ContinuationToken,
    ) -> Result<ReadBatch, QueueError> {
        let rows = self.table.read(self.tablet, begin_row_index, end_row_index)?;
        Ok(ReadBatch {
            rowset: UnversionedRowset::new(self.table.name_table(), rows),
            next_token: token.clone(),
        })
    }

    fn trim(&mut self, row_index: i64, _token: &ContinuationToken) -> Result<(), QueueError> {
        self.table.trim(self.tablet, row_index)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queue::input_name_table;
    use crate::row;

    fn table(tablets: usize) -> Arc<OrderedTable> {
        OrderedTable::new("input", input_name_table(), tablets, WriteAccounting::new())
    }

    fn rows(n: usize, base: i64) -> Vec<UnversionedRow> {
        (0..n).map(|i| row![format!("msg{}", base + i as i64), base + i as i64]).collect()
    }

    #[test]
    fn append_then_read() {
        let t = table(2);
        assert_eq!(t.append(0, rows(3, 0)).unwrap(), 0);
        assert_eq!(t.append(0, rows(2, 3)).unwrap(), 3);
        assert_eq!(t.end_index(0), 5);
        assert_eq!(t.end_index(1), 0);

        let mut r = t.reader(0);
        let batch = r.read(1, 4, &ContinuationToken::initial()).unwrap();
        assert_eq!(batch.rowset.len(), 3);
        assert_eq!(batch.rowset.cell(0, "payload").unwrap().as_str(), Some("msg1"));
    }

    #[test]
    fn read_past_end_truncates() {
        let t = table(1);
        t.append(0, rows(2, 0)).unwrap();
        let mut r = t.reader(0);
        let b = r.read(0, 100, &ContinuationToken::initial()).unwrap();
        assert_eq!(b.rowset.len(), 2);
        let empty = r.read(2, 100, &ContinuationToken::initial()).unwrap();
        assert!(empty.rowset.is_empty());
    }

    #[test]
    fn trim_is_idempotent_and_guards_reads() {
        let t = table(1);
        t.append(0, rows(10, 0)).unwrap();
        let mut r = t.reader(0);
        r.trim(4, &ContinuationToken::initial()).unwrap();
        r.trim(4, &ContinuationToken::initial()).unwrap();
        r.trim(2, &ContinuationToken::initial()).unwrap(); // lower: no-op
        assert_eq!(t.first_index(0), 4);
        assert_eq!(t.retained_rows(), 6);
        // Reading trimmed rows errors.
        let err = r.read(0, 5, &ContinuationToken::initial());
        assert!(matches!(err, Err(QueueError::Trimmed { first_available: 4, .. })));
        // Reading retained rows still fine.
        assert_eq!(r.read(4, 8, &ContinuationToken::initial()).unwrap().rowset.len(), 4);
    }

    #[test]
    fn trim_past_end_clamps() {
        let t = table(1);
        t.append(0, rows(3, 0)).unwrap();
        t.trim(0, 100).unwrap();
        assert_eq!(t.first_index(0), 3);
        assert_eq!(t.retained_rows(), 0);
        // Appends continue the absolute numbering.
        assert_eq!(t.append(0, rows(1, 3)).unwrap(), 3);
    }

    #[test]
    fn appends_are_accounted_as_source_ingest() {
        let acc = WriteAccounting::new();
        let t = OrderedTable::new("in", input_name_table(), 1, acc.clone());
        t.append(0, rows(5, 0)).unwrap();
        assert!(acc.bytes(WriteCategory::SourceIngest) > 0);
        assert_eq!(acc.bytes(WriteCategory::MapperMeta), 0);
    }

    #[test]
    fn unavailability_fails_ops() {
        let t = table(1);
        t.append(0, rows(1, 0)).unwrap();
        t.set_unavailable(0, true);
        let mut r = t.reader(0);
        assert!(matches!(
            r.read(0, 1, &ContinuationToken::initial()),
            Err(QueueError::Unavailable(0))
        ));
        assert!(t.append(0, rows(1, 1)).is_err());
        t.set_unavailable(0, false);
        assert_eq!(r.read(0, 1, &ContinuationToken::initial()).unwrap().rowset.len(), 1);
    }

    #[test]
    fn committed_append_ignores_outage_and_numbers_rows() {
        let t = table(1);
        t.append(0, rows(2, 0)).unwrap();
        t.set_unavailable(0, true);
        assert!(!t.is_available(0));
        // The transactional path lands even mid-outage (availability was
        // validated before the commit point).
        assert_eq!(t.append_committed(0, rows(3, 2)), 2);
        t.set_unavailable(0, false);
        assert_eq!(t.end_index(0), 5);
        let mut r = t.reader(0);
        assert_eq!(r.read(0, 5, &ContinuationToken::initial()).unwrap().rowset.len(), 5);
    }

    #[test]
    fn committed_append_detaches_into_journal_record() {
        let t = table(1);
        t.append_committed(0, vec![row!["shared-payload", 7i64]]);
        let rec = t.journal.read(0).unwrap();
        let mut r = t.reader(0);
        let b = r.read(0, 1, &ContinuationToken::initial()).unwrap();
        match b.rowset.rows()[0].get(0).unwrap() {
            crate::rows::Value::Str(s) => {
                let p = s.payload_ptr() as usize;
                let start = rec.as_ptr() as usize;
                assert!(
                    p >= start && p < start + rec.len(),
                    "retained cell must be a view into the journal record"
                );
            }
            other => panic!("unexpected cell {other:?}"),
        }
    }

    #[test]
    fn scoped_table_attributes_interstage_bytes() {
        let acc = WriteAccounting::new();
        let t = OrderedTable::new_scoped(
            "//dataflow/handoff",
            input_name_table(),
            1,
            acc.clone(),
            WriteCategory::InterStage,
            Some("topo/sessionize".into()),
        );
        t.append(0, rows(4, 0)).unwrap();
        assert!(acc.bytes(WriteCategory::InterStage) > 0);
        assert_eq!(
            acc.scope_snapshot("topo/sessionize").bytes_of(WriteCategory::InterStage),
            acc.bytes(WriteCategory::InterStage)
        );
        assert_eq!(t.name(), "//dataflow/handoff");
    }

    #[test]
    fn low_water_marks_follow_trims() {
        let t = table(2);
        t.append(0, rows(6, 0)).unwrap();
        t.append(1, rows(3, 0)).unwrap();
        assert_eq!(t.low_water_marks(), vec![0, 0]);
        t.trim(0, 4).unwrap();
        assert_eq!(t.low_water_marks(), vec![4, 0]);
    }

    #[test]
    fn ensure_tablets_grows_without_disturbing_existing() {
        let t = table(2);
        t.append(0, rows(3, 0)).unwrap();
        t.ensure_tablets(5);
        assert_eq!(t.tablet_count(), 5);
        assert_eq!(t.end_index(0), 3, "existing tablets untouched");
        assert_eq!(t.end_index(4), 0);
        t.append(4, rows(2, 0)).unwrap();
        assert_eq!(t.end_index(4), 2);
        // Shrink requests are no-ops.
        t.ensure_tablets(1);
        assert_eq!(t.tablet_count(), 5);
        assert_eq!(t.low_water_marks().len(), 5);
    }

    #[test]
    fn tablets_independent() {
        let t = table(3);
        t.append(0, rows(5, 0)).unwrap();
        t.append(2, rows(7, 0)).unwrap();
        t.trim(0, 5).unwrap();
        assert_eq!(t.first_index(0), 5);
        assert_eq!(t.first_index(2), 0);
        assert_eq!(t.retained_rows(), 7);
    }
}
