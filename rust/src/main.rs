//! `yt-stream` CLI — launcher and evaluation harness.
//!
//! ```text
//! yt-stream figure <id> [--seconds N] [--compute native|hlo] [--seed N] [--auto]
//!     regenerate a paper figure/table: 5.1 5.2 5.3 5.4 5.5 wa scale spill chain reshard window consistency backfill
//!     (--auto: hands-off `figure reshard` — the resident autoscale driver
//!      performs the resizes, no manual reshard() calls)
//! yt-stream run [--config path.yson] [--seconds N]
//!     run the log-analytics streaming processor and print live stats
//! yt-stream fsck [--corrupt]
//!     build a deterministic cold-tier store and verify every chunk hash +
//!     segment-chain continuity (--corrupt: inject a flipped payload byte
//!     and prove fsck detects it — exits non-zero)
//! yt-stream obs [--seconds N] [--worker SUB] [--scope SUB] [--outcome NAME] [--json]
//!     run a short drilled demo (a twinned reducer losing CAS races), then
//!     dump the commit-spine flight recorder: a filtered span timeline by
//!     default, the versioned obs JSON document with --json
//! yt-stream selfcheck
//!     verify the PJRT runtime + AOT artifacts load and agree with native
//! ```

use yt_stream::coordinator::{ComputeMode, ProcessorConfig};
use yt_stream::figures::{run_figure, FigureOpts};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("figure") => {
            let id = args.get(1).cloned().unwrap_or_else(|| {
                eprintln!("usage: yt-stream figure <id>");
                std::process::exit(2);
            });
            let mut opts = FigureOpts::default();
            parse_common(&args[2..], &mut opts);
            run_figure(&id, &opts);
        }
        Some("run") => {
            let mut opts = FigureOpts::default();
            let mut config_path = None;
            let mut it = args[1..].iter();
            while let Some(a) = it.next() {
                if a == "--config" {
                    config_path = it.next().cloned();
                }
            }
            parse_common(&args[1..], &mut opts);
            run_demo(config_path.as_deref(), &opts);
        }
        Some("fsck") => fsck_demo(args.iter().any(|a| a == "--corrupt")),
        Some("obs") => obs_demo(&args[1..]),
        Some("selfcheck") => selfcheck(),
        _ => {
            eprintln!(
                "yt-stream — streaming MapReduce with low write amplification\n\
                 usage:\n  yt-stream figure <5.1|5.2|5.3|5.4|5.5|wa|scale|spill|chain|reshard|window|consistency|backfill> [--seconds N] [--compute native|hlo] [--seed N] [--auto]\n\
                 \x20 yt-stream run [--config path.yson] [--seconds N] [--compute native|hlo]\n\
                 \x20 yt-stream fsck [--corrupt]\n\
                 \x20 yt-stream obs [--seconds N] [--worker SUB] [--scope SUB] [--outcome NAME] [--json]\n\
                 \x20 yt-stream selfcheck"
            );
            std::process::exit(2);
        }
    }
}

fn parse_common(rest: &[String], opts: &mut FigureOpts) {
    let mut it = rest.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--seconds" => {
                opts.sim_seconds = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(opts.sim_seconds)
            }
            "--seed" => {
                opts.seed = it.next().and_then(|v| v.parse().ok()).unwrap_or(opts.seed)
            }
            "--compute" => {
                opts.compute = match it.next().map(String::as_str) {
                    Some("hlo") => ComputeMode::Hlo,
                    _ => ComputeMode::Native,
                }
            }
            "--auto" => opts.auto = true,
            "--config" => {
                let _ = it.next();
            }
            other => {
                eprintln!("unknown flag '{other}'");
                std::process::exit(2);
            }
        }
    }
}

/// `run`: launch the §5.2 analytics processor and print periodic stats.
fn run_demo(config_path: Option<&str>, opts: &FigureOpts) {
    use yt_stream::figures::{Scenario, ScenarioCfg};
    use yt_stream::metrics::hub::names;

    let mut cfg = ScenarioCfg {
        compute: opts.compute,
        seed: opts.seed,
        speedup: 1,
        ..ScenarioCfg::default()
    };
    if let Some(path) = config_path {
        let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
            eprintln!("cannot read {path}: {e}");
            std::process::exit(2);
        });
        let pc = ProcessorConfig::parse(&text).unwrap_or_else(|e| {
            eprintln!("bad config {path}: {e}");
            std::process::exit(2);
        });
        cfg.mappers = pc.mapper_count;
        cfg.reducers = pc.reducer_count;
        cfg.memory_limit_bytes = pc.memory_limit_bytes;
        cfg.spill_enabled = pc.spill.enabled;
        cfg.pipelined_reducer = pc.pipelined_reducer;
        cfg.compute = pc.compute;
    }
    println!(
        "launching log-analytics processor: {} mappers, {} reducers, compute={:?}",
        cfg.mappers, cfg.reducers, cfg.compute
    );
    let scenario: Scenario = yt_stream::figures::scenario::start(cfg);
    let t0 = std::time::Instant::now();
    while t0.elapsed().as_secs() < opts.sim_seconds.max(5) {
        std::thread::sleep(std::time::Duration::from_secs(1));
        let m = &scenario.env.metrics;
        println!(
            "t={:>4}s rows_read={:>9} rows_reduced={:>9} commits={:>6} split_brains={} backlog={}",
            t0.elapsed().as_secs(),
            m.get_counter(names::MAPPER_ROWS_READ),
            m.get_counter(names::REDUCER_ROWS),
            m.get_counter(names::REDUCER_COMMITS),
            m.get_counter(names::MAPPER_SPLIT_BRAIN) + m.get_counter(names::REDUCER_SPLIT_BRAIN),
            scenario.input.retained_rows(),
        );
    }
    let report = scenario.processor.wa_report("yt-stream");
    println!("{report}");
    scenario.stop();
}

/// `fsck`: build a small deterministic cold tier in a fresh store and run
/// the manifest checker over it — chunk hashes, row counts, and segment
/// chain continuity. `--corrupt` flips one payload byte first, which must
/// make the check fail with a non-zero exit; the bench smoke test asserts
/// both outcomes.
fn fsck_demo(corrupt: bool) {
    use yt_stream::coldtier::{
        fsck, hex_decode, hex_encode, ColdStore, KIND_HISTORY, KIND_SEGMENT,
    };
    use yt_stream::dyntable::DynTableStore;
    use yt_stream::queue::input_name_table;
    use yt_stream::rows::{RowsetBuilder, Value};
    use yt_stream::storage::WriteAccounting;

    let store = DynTableStore::new(WriteAccounting::new());
    let cold = ColdStore::new(store.clone(), "//sys/cold/fsck");
    cold.ensure_tables(None).unwrap();

    // Two partitions, each tiled by two contiguous segment chunks — the
    // shape compact-on-trim produces.
    for p in 0..2usize {
        for (begin, end) in [(0i64, 8i64), (8, 20)] {
            let mut b = RowsetBuilder::new(input_name_table());
            for i in begin..end {
                b.push(yt_stream::row![format!("p{p} row {i}"), 10_000 + i]);
            }
            let mut txn = store.begin();
            cold.compact_into(&mut txn, p, KIND_SEGMENT, begin, begin, &b.build(), Some(1), None)
                .unwrap();
            txn.commit().unwrap();
        }
    }
    // One fired-window history chunk (chunk_id = fire watermark).
    let mut b = RowsetBuilder::new(input_name_table());
    b.push(yt_stream::row!["window 0 history", 10_000i64]);
    let mut txn = store.begin();
    cold.compact_into(&mut txn, 0, KIND_HISTORY, 250_000, 0, &b.build(), Some(1), None)
        .unwrap();
    txn.commit().unwrap();

    if corrupt {
        let key = [Value::Int64(0), Value::from(KIND_SEGMENT), Value::Int64(0)];
        let row = store.lookup(&cold.payload_table(), &key).unwrap().unwrap();
        let mut raw = hex_decode(row.get(3).unwrap().as_str().unwrap()).unwrap();
        raw[0] ^= 0xff;
        let mut txn = store.begin();
        txn.write(
            &cold.payload_table(),
            yt_stream::row![0i64, KIND_SEGMENT, 0i64, hex_encode(&raw)],
        )
        .unwrap();
        txn.commit().unwrap();
        println!("injected corruption: flipped first payload byte of chunk 0/{KIND_SEGMENT}/0");
    }

    match fsck(&store, cold.base()) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(1);
        }
    }
}

/// `obs`: exercise the commit spine under a twin drill, then dump the
/// flight recorder. The demo twins reducer 0 mid-run so the rings hold
/// losing spans (conflicted/abdicated) next to the committed ones; the
/// query flags are substring filters over worker address and scope plus
/// an exact outcome name, the same filters `forensics::spans_matching`
/// gives the drill-forensics path.
fn obs_demo(rest: &[String]) {
    use yt_stream::controller::Role;
    use yt_stream::figures::scenario::start;
    use yt_stream::figures::ScenarioCfg;
    use yt_stream::obs::{forensics, ObsExport};

    let mut opts = FigureOpts::default();
    let (mut worker, mut scope, mut outcome) = (None, None, None);
    let mut json = false;
    let mut it = rest.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--seconds" => {
                opts.sim_seconds = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(opts.sim_seconds)
            }
            "--seed" => {
                opts.seed = it.next().and_then(|v| v.parse().ok()).unwrap_or(opts.seed)
            }
            "--compute" => {
                opts.compute = match it.next().map(String::as_str) {
                    Some("hlo") => ComputeMode::Hlo,
                    _ => ComputeMode::Native,
                }
            }
            "--worker" => worker = it.next().cloned(),
            "--scope" => scope = it.next().cloned(),
            "--outcome" => outcome = it.next().cloned(),
            "--json" => json = true,
            other => {
                eprintln!("unknown flag '{other}'");
                std::process::exit(2);
            }
        }
    }

    let scenario = start(ScenarioCfg {
        compute: opts.compute,
        seed: opts.seed,
        speedup: 20,
        ..ScenarioCfg::default()
    });
    scenario.run_for_sim_ms(4_000);
    // Twin a reducer: the twin loses CAS races, so the rings record
    // conflicted/abdicated spans alongside the winner's commits.
    scenario.processor.supervisor().duplicate(Role::Reducer, 0);
    scenario.run_for_sim_ms(opts.sim_seconds.max(1) * 1_000);
    let report = scenario.processor.wa_report("obs-demo");
    let env = scenario.stop();

    if json {
        let mut obs = ObsExport::new("demo", env.metrics.clone());
        obs.add_report(&report);
        print!("{}", obs.to_json());
        return;
    }

    let rec = env.metrics.recorder();
    let spans = forensics::spans_matching(
        rec,
        worker.as_deref(),
        scope.as_deref(),
        outcome.as_deref(),
    );
    for s in &spans {
        println!("{}", forensics::format_span(s));
    }
    println!(
        "{} span(s) shown ({} recorded, {} dropped ring-wide)",
        spans.len(),
        rec.recorded_total(),
        rec.dropped_total(),
    );
}

/// `selfcheck`: PJRT + artifacts sanity (the AOT bridge smoke test).
fn selfcheck() {
    use yt_stream::compute::{hlo::HloStage, native::NativeStage, ComputeStage};

    let rt = match yt_stream::runtime::PjRtRuntime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("PJRT unavailable: {e}");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", rt.platform());

    let dir = std::path::Path::new("artifacts");
    let stage = match HloStage::load(dir) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("artifact load failed: {e}\nhint: run `make artifacts`");
            std::process::exit(1);
        }
    };
    let native = NativeStage;

    // Cross-check a few batches.
    let uh: Vec<u32> = (0..2000u32).map(|i| i.wrapping_mul(2654435761)).collect();
    let ch: Vec<u32> = (0..2000u32).map(|i| i.wrapping_mul(40503)).collect();
    let hu: Vec<bool> = (0..2000).map(|i| i % 7 == 0).collect();
    let a = stage.map_stage(&uh, &ch, &hu, 10);
    let b = native.map_stage(&uh, &ch, &hu, 10);
    assert_eq!(a, b, "map stage mismatch (hlo vs native)");

    let slots: Vec<u32> = (0..2000u32).map(|i| i % 97).collect();
    let ts: Vec<f32> = (0..2000).map(|i| (i % 1000) as f32).collect();
    let valid: Vec<bool> = (0..2000).map(|i| i % 3 != 0).collect();
    let x = stage.reduce_stage(&slots, &ts, &valid, 97);
    let y = native.reduce_stage(&slots, &ts, &valid, 97);
    assert_eq!(x, y, "reduce stage mismatch (hlo vs native)");

    println!("selfcheck OK: hlo == native on map + reduce stages");
}
