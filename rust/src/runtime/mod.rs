//! PJRT runtime: load and execute the AOT artifacts from the L3 hot path.
//!
//! The bridge follows `/opt/xla-example/load_hlo`: python lowers the L2 JAX
//! stages (which call the L1 Pallas kernels) to **HLO text** once at build
//! time (`make artifacts` → `python/compile/aot.py`); this module parses
//! the text with `HloModuleProto::from_text_file`, compiles it on the PJRT
//! CPU client and executes it with concrete batches. Python never runs at
//! request time.
//!
//! HLO *text* (not serialized protos) is the interchange format: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see `/opt/xla-example/README.md`).
//!
//! ## Feature gating
//!
//! The `xla` crate (PJRT bindings) is an **optional** dependency behind
//! the off-by-default `pjrt` cargo feature, so the crate builds offline
//! with the pure-rust [`crate::compute::native`] stage as the default
//! compute path. Without the feature, [`PjRtRuntime::cpu`] returns
//! [`RuntimeError::PjrtDisabled`] and every PJRT consumer (selfcheck,
//! `ComputeMode::Hlo`, the hlo benches/tests) degrades to a clean skip or
//! error. The shapes/constants and [`pad_to`] stay available either way —
//! they define the artifact contract with `python/compile`.
//!
//! ## Fixed artifact shapes
//!
//! AOT compilation freezes shapes. The contract with `python/compile`:
//!
//! * `mapper_stage.hlo.txt`:
//!   `(user_hash u32[B], cluster_hash u32[B], num_reducers u32[]) → (reducer u32[B],)`
//! * `reducer_stage.hlo.txt`:
//!   `(slots i32[B], ts f32[B], valid f32[B]) → (counts f32[G], max_ts f32[G])`
//!
//! with `B = 1024`, `G = 256` ([`BATCH`], [`GROUPS`]). The rust callers pad
//! and chunk arbitrary batch sizes to fit (see `compute::hlo`).

use std::path::PathBuf;
use crate::util;

/// Rows per compiled batch (must match `python/compile/aot.py`).
pub const BATCH: usize = 1024;
/// Group slots per compiled aggregation (must match `aot.py`).
pub const GROUPS: usize = 256;

#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    #[error("artifact '{0}' not found — run `make artifacts` first")]
    MissingArtifact(PathBuf),
    #[error("xla: {0}")]
    Xla(String),
    #[error("PJRT support not compiled in — rebuild with `--features pjrt`")]
    PjrtDisabled,
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// Pad a slice to `n` with a fill value (artifact shapes are fixed).
pub fn pad_to<T: Copy>(xs: &[T], n: usize, fill: T) -> Vec<T> {
    assert!(xs.len() <= n, "chunk longer than batch");
    let mut v = Vec::with_capacity(n);
    v.extend_from_slice(xs);
    v.resize(n, fill);
    v
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::path::Path;
    use std::sync::Mutex;

    use super::RuntimeError;

    /// A compiled, loaded stage ready for execution.
    ///
    /// # Safety / threading
    ///
    /// The `xla` crate's wrappers hold raw pointers and are not `Send`. The
    /// PJRT CPU client is internally synchronized for execution, but we stay
    /// conservative: every [`LoadedStage`] serializes `run` behind a `Mutex`
    /// and the `unsafe impl Send/Sync` below is justified by that exclusive
    /// access (no concurrent mutation of the underlying executable).
    pub struct LoadedStage {
        name: String,
        exe: Mutex<xla::PjRtLoadedExecutable>,
    }

    unsafe impl Send for LoadedStage {}
    unsafe impl Sync for LoadedStage {}

    impl LoadedStage {
        /// Execute with the given argument literals; returns the un-tupled
        /// results (artifacts are lowered with `return_tuple=True`).
        pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>, RuntimeError> {
            let exe = util::lock(&self.exe);
            let result = exe.execute::<xla::Literal>(args)?;
            let literal = result[0][0].to_literal_sync()?;
            Ok(literal.to_tuple()?)
        }

        pub fn name(&self) -> &str {
            &self.name
        }
    }

    /// The PJRT CPU client plus artifact loading.
    pub struct PjRtRuntime {
        client: xla::PjRtClient,
    }

    unsafe impl Send for PjRtRuntime {}
    unsafe impl Sync for PjRtRuntime {}

    impl PjRtRuntime {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<PjRtRuntime, RuntimeError> {
            Ok(PjRtRuntime {
                client: xla::PjRtClient::cpu()?,
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text artifact and compile it for this client.
        pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedStage, RuntimeError> {
            if !path.exists() {
                return Err(RuntimeError::MissingArtifact(path.to_path_buf()));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().expect("artifact path must be utf-8"),
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            Ok(LoadedStage {
                name: path
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_default(),
                exe: Mutex::new(exe),
            })
        }

        /// Load both stage artifacts from a directory.
        pub fn load_stage_artifacts(
            &self,
            dir: &Path,
        ) -> Result<(LoadedStage, LoadedStage), RuntimeError> {
            let mapper = self.load_hlo_text(&dir.join("mapper_stage.hlo.txt"))?;
            let reducer = self.load_hlo_text(&dir.join("reducer_stage.hlo.txt"))?;
            Ok((mapper, reducer))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod pjrt_impl {
    use std::path::Path;

    use super::RuntimeError;

    /// Offline stand-in: the crate was built without the `pjrt` feature,
    /// so there is nothing to load or run. Exists so the CLI/bench/test
    /// surfaces that *mention* PJRT still compile and degrade to a clean
    /// error / skip.
    pub struct LoadedStage {
        never: std::convert::Infallible,
    }

    impl LoadedStage {
        pub fn name(&self) -> &str {
            match self.never {}
        }
    }

    /// Offline stand-in for the PJRT CPU client; every constructor fails
    /// with [`RuntimeError::PjrtDisabled`].
    pub struct PjRtRuntime {
        _private: (),
    }

    impl PjRtRuntime {
        pub fn cpu() -> Result<PjRtRuntime, RuntimeError> {
            Err(RuntimeError::PjrtDisabled)
        }

        pub fn platform(&self) -> String {
            "disabled".into()
        }

        pub fn load_hlo_text(&self, _path: &Path) -> Result<LoadedStage, RuntimeError> {
            Err(RuntimeError::PjrtDisabled)
        }

        pub fn load_stage_artifacts(
            &self,
            _dir: &Path,
        ) -> Result<(LoadedStage, LoadedStage), RuntimeError> {
            Err(RuntimeError::PjrtDisabled)
        }
    }
}

pub use pjrt_impl::{LoadedStage, PjRtRuntime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_to_extends_and_preserves() {
        let p = pad_to(&[1u32, 2, 3], 6, 0);
        assert_eq!(p, vec![1, 2, 3, 0, 0, 0]);
        let q = pad_to(&[1u32], 1, 9);
        assert_eq!(q, vec![1]);
    }

    #[test]
    #[should_panic(expected = "chunk longer")]
    fn pad_to_rejects_overflow() {
        pad_to(&[1u32, 2], 1, 0);
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        let rt = match PjRtRuntime::cpu() {
            Ok(rt) => rt,
            Err(_) => return, // PJRT unavailable (feature off / no plugin)
        };
        match rt.load_hlo_text(std::path::Path::new("/nonexistent/stage.hlo.txt")) {
            Err(RuntimeError::MissingArtifact(_)) => {}
            Err(e) => panic!("unexpected error: {e}"),
            Ok(_) => panic!("loading a nonexistent artifact must fail"),
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn disabled_runtime_reports_clean_error() {
        match PjRtRuntime::cpu() {
            Err(RuntimeError::PjrtDisabled) => {}
            Err(e) => panic!("expected PjrtDisabled, got {e}"),
            Ok(_) => panic!("cpu() must fail when built without the pjrt feature"),
        }
    }
}
