//! Per-stage consistency tiers: the WA-vs-accuracy frontier (AF-Stream's
//! approximate fault tolerance, per-stage like StreamShield).
//!
//! Exactly-once is the *most expensive* tier: every reducer commit writes
//! the meta-state row, so state-write WA scales with O(commits). Many
//! production stages (counters, sampled analytics, monitoring sinks)
//! tolerate bounded inaccuracy — for them this module trades durability
//! writes for a *declared, measured* divergence budget:
//!
//! * [`Consistency::ExactlyOnce`] — today's behavior, the default and the
//!   baseline every approximate mode is judged against. State persists on
//!   every commit; recovery replays nothing twice and loses nothing.
//! * [`Consistency::BoundedError`] — persist the reducer/window state only
//!   at *anchors*: the first commit of every incarnation, then every
//!   `anchor_every_batches` batches or whenever the rows committed since
//!   the last anchor would exceed `divergence_budget`. A crash recovers
//!   from the last anchor and replays the unanchored window — the output
//!   drifts by at most `divergence_budget` rows per failure event. The
//!   anchor write is the *same* meta-state row riding the *same* commit
//!   CAS as exactly-once, so split-brain safety is untouched; a twin that
//!   observes an anchor it didn't write abdicates (exits) rather than
//!   resync, which bounds twin-induced drift to ~two anchor windows.
//! * [`Consistency::AtMostOnce`] — no steady-state persistence at all:
//!   commit marks advance in memory only, acknowledged to mappers through
//!   the normal fetch protocol. Each incarnation *discards* its first
//!   non-empty fetch round (the predecessor's in-flight window), so rows
//!   are processed at most once. For counter/sampling sinks; topology
//!   validation restricts it to final stages.
//!
//! State tables of approximate-tier stages are created under
//! [`WriteCategory::AnchorState`] so `WriteAccounting` reports the anchor
//! write volume as its own frontier line next to exactly-once's
//! `reducer_meta`.

use crate::storage::WriteCategory;
use crate::util::yson::Yson;

/// Default rows-of-drift budget for `BoundedError` when the config names
/// the mode but no budget.
pub const DEFAULT_DIVERGENCE_BUDGET: u64 = 512;
/// Default anchor cadence (batches) for `BoundedError`.
pub const DEFAULT_ANCHOR_EVERY_BATCHES: u32 = 32;

/// Per-stage fault-tolerance policy (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Consistency {
    /// Persist state on every commit (the seed behavior; baseline).
    ExactlyOnce,
    /// Persist state only at anchors; accept ≤ `divergence_budget` rows of
    /// replay/loss drift per failure event.
    BoundedError {
        /// Max rows committed-but-unanchored at any moment (the per-event
        /// drift bound).
        divergence_budget: u64,
        /// Anchor at least every this many committed batches even when
        /// the row budget isn't pressing (bounds recovery *latency*).
        anchor_every_batches: u32,
    },
    /// Never persist steady-state; drop the in-flight window on failure.
    AtMostOnce,
}

impl Default for Consistency {
    fn default() -> Consistency {
        Consistency::ExactlyOnce
    }
}

impl Consistency {
    pub fn is_exactly_once(&self) -> bool {
        matches!(self, Consistency::ExactlyOnce)
    }

    /// Any tier that may skip state persists (and therefore drift).
    pub fn is_approximate(&self) -> bool {
        !self.is_exactly_once()
    }

    pub fn bounded_error(divergence_budget: u64) -> Consistency {
        Consistency::BoundedError {
            divergence_budget,
            anchor_every_batches: DEFAULT_ANCHOR_EVERY_BATCHES,
        }
    }

    /// Stable label for scope lines, figures and logs.
    pub fn label(&self) -> &'static str {
        match self {
            Consistency::ExactlyOnce => "exactly_once",
            Consistency::BoundedError { .. } => "bounded_error",
            Consistency::AtMostOnce => "at_most_once",
        }
    }

    /// Which accounting category this stage's reducer/window state rows
    /// land in: exactly-once keeps the seed's `reducer_meta`; approximate
    /// tiers write (rarer, anchor/lifecycle-only) `anchor_state` rows so
    /// the frontier is visible as two separate WA lines.
    pub fn state_write_category(&self) -> WriteCategory {
        if self.is_exactly_once() {
            WriteCategory::ReducerMeta
        } else {
            WriteCategory::AnchorState
        }
    }

    /// Parse the `consistency = {mode = ...}` config sub-map. Unknown or
    /// absent mode falls back to exactly-once (never silently approximate).
    pub fn from_yson(y: &Yson) -> Consistency {
        match y.get_str_or("mode", "exactly_once") {
            "bounded_error" => Consistency::BoundedError {
                divergence_budget: y
                    .get_i64_or("divergence_budget", DEFAULT_DIVERGENCE_BUDGET as i64)
                    .max(1) as u64,
                anchor_every_batches: y
                    .get_i64_or("anchor_every_batches", DEFAULT_ANCHOR_EVERY_BATCHES as i64)
                    .max(1) as u32,
            },
            "at_most_once" => Consistency::AtMostOnce,
            _ => Consistency::ExactlyOnce,
        }
    }
}

/// Decides, commit by commit, whether this commit must carry the state
/// write (an *anchor*). Owned by one reducer incarnation; its counters
/// are exactly the incarnation's *exposure* — rows and batches committed
/// since durable state last advanced.
///
/// Invariant (the divergence bound): after any `note_commit`,
/// `exposure_rows() <= divergence_budget` for `BoundedError` — a crash at
/// any instant replays/loses at most the budget.
#[derive(Debug)]
pub struct AnchorScheduler {
    policy: Consistency,
    rows_since_anchor: u64,
    batches_since_anchor: u32,
    committed_once: bool,
}

impl AnchorScheduler {
    pub fn new(policy: Consistency) -> AnchorScheduler {
        AnchorScheduler {
            policy,
            rows_since_anchor: 0,
            batches_since_anchor: 0,
            committed_once: false,
        }
    }

    /// Must the commit about to carry `batch_rows` rows persist state?
    pub fn should_persist(&self, batch_rows: u64) -> bool {
        match self.policy {
            Consistency::ExactlyOnce => true,
            Consistency::AtMostOnce => false,
            Consistency::BoundedError {
                divergence_budget,
                anchor_every_batches,
            } => {
                // First commit of the incarnation always anchors: it caps
                // replay-after-crash at one window and lets a twin's rival
                // incarnation detect us via the state CAS immediately.
                !self.committed_once
                    || self.rows_since_anchor + batch_rows > divergence_budget
                    || self.batches_since_anchor + 1 >= anchor_every_batches
            }
        }
    }

    /// Record a successful commit (`persisted` = it carried the state
    /// write).
    pub fn note_commit(&mut self, persisted: bool, batch_rows: u64) {
        self.committed_once = true;
        if persisted {
            self.rows_since_anchor = 0;
            self.batches_since_anchor = 0;
        } else {
            self.rows_since_anchor += batch_rows;
            self.batches_since_anchor += 1;
        }
    }

    /// Rows committed since durable state last advanced (what a crash
    /// right now would drift by).
    pub fn exposure_rows(&self) -> u64 {
        self.rows_since_anchor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_exactly_once() {
        assert_eq!(Consistency::default(), Consistency::ExactlyOnce);
        assert!(Consistency::default().is_exactly_once());
        assert!(!Consistency::default().is_approximate());
    }

    #[test]
    fn labels_and_categories() {
        assert_eq!(Consistency::ExactlyOnce.label(), "exactly_once");
        assert_eq!(Consistency::bounded_error(10).label(), "bounded_error");
        assert_eq!(Consistency::AtMostOnce.label(), "at_most_once");
        assert_eq!(
            Consistency::ExactlyOnce.state_write_category(),
            WriteCategory::ReducerMeta
        );
        assert_eq!(
            Consistency::bounded_error(10).state_write_category(),
            WriteCategory::AnchorState
        );
        assert_eq!(
            Consistency::AtMostOnce.state_write_category(),
            WriteCategory::AnchorState
        );
    }

    #[test]
    fn parse_modes() {
        let y = Yson::parse("{mode = bounded_error; divergence_budget = 64; anchor_every_batches = 4}").unwrap();
        assert_eq!(
            Consistency::from_yson(&y),
            Consistency::BoundedError {
                divergence_budget: 64,
                anchor_every_batches: 4
            }
        );
        let y = Yson::parse("{mode = at_most_once}").unwrap();
        assert_eq!(Consistency::from_yson(&y), Consistency::AtMostOnce);
        let y = Yson::parse("{mode = garbage}").unwrap();
        assert_eq!(Consistency::from_yson(&y), Consistency::ExactlyOnce);
        let y = Yson::parse("{}").unwrap();
        assert_eq!(Consistency::from_yson(&y), Consistency::ExactlyOnce);
    }

    #[test]
    fn parse_defaults_fill_in() {
        let y = Yson::parse("{mode = bounded_error}").unwrap();
        assert_eq!(
            Consistency::from_yson(&y),
            Consistency::BoundedError {
                divergence_budget: DEFAULT_DIVERGENCE_BUDGET,
                anchor_every_batches: DEFAULT_ANCHOR_EVERY_BATCHES,
            }
        );
    }

    #[test]
    fn exactly_once_always_persists() {
        let mut s = AnchorScheduler::new(Consistency::ExactlyOnce);
        for _ in 0..100 {
            assert!(s.should_persist(1_000_000));
            s.note_commit(true, 1_000_000);
            assert_eq!(s.exposure_rows(), 0);
        }
    }

    #[test]
    fn at_most_once_never_persists() {
        let mut s = AnchorScheduler::new(Consistency::AtMostOnce);
        for _ in 0..100 {
            assert!(!s.should_persist(1));
            s.note_commit(false, 1);
        }
    }

    #[test]
    fn first_commit_of_incarnation_anchors() {
        let s = AnchorScheduler::new(Consistency::bounded_error(1_000_000));
        assert!(s.should_persist(1), "fresh incarnation must anchor first");
    }

    #[test]
    fn bounded_error_exposure_never_exceeds_budget() {
        let budget = 100u64;
        let mut s = AnchorScheduler::new(Consistency::BoundedError {
            divergence_budget: budget,
            anchor_every_batches: u32::MAX,
        });
        // Deterministic pseudo-random batch sizes.
        let mut x = 0x2545F4914F6CDD1Du64;
        for _ in 0..10_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let batch = x % 60 + 1;
            let persist = s.should_persist(batch);
            s.note_commit(persist, batch);
            assert!(
                s.exposure_rows() <= budget,
                "exposure {} > budget {budget}",
                s.exposure_rows()
            );
        }
    }

    #[test]
    fn batch_cadence_forces_anchor() {
        let mut s = AnchorScheduler::new(Consistency::BoundedError {
            divergence_budget: u64::MAX / 2,
            anchor_every_batches: 4,
        });
        // First commit anchors.
        assert!(s.should_persist(1));
        s.note_commit(true, 1);
        // Then three skipped commits, the fourth anchors.
        for i in 0..3 {
            assert!(!s.should_persist(1), "commit {i} inside cadence");
            s.note_commit(false, 1);
        }
        assert!(s.should_persist(1), "cadence reached");
        s.note_commit(true, 1);
        assert_eq!(s.exposure_rows(), 0);
    }
}
