//! A minimal slab allocator: values live in stable, reusable slots
//! addressed by `usize` keys.
//!
//! The mapper's in-memory window churns entries at batch rate — every
//! push allocates and every trim frees, forever, on the hottest path the
//! paper's design keeps off the disk. A [`Slab`] turns that churn into
//! slot reuse: removed slots go on an internal free list and the next
//! insert reclaims one, so a steady-state window reaches a fixed pool of
//! slots and stops exercising the allocator entirely. Keys are stable for
//! a value's whole residency (nothing is shifted on removal), which lets
//! FIFO order live in a slim index queue beside the pool.

/// Growable slot pool with free-list reuse. Not a map: keys are assigned
/// by [`Slab::insert`] and only valid until the matching
/// [`Slab::remove`].
#[derive(Debug)]
pub struct Slab<T> {
    slots: Vec<Option<T>>,
    free: Vec<usize>,
    len: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Slab {
            slots: Vec::new(),
            free: Vec::new(),
            len: 0,
        }
    }
}

impl<T> Slab<T> {
    pub fn new() -> Slab<T> {
        Slab::default()
    }

    /// Number of occupied slots.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total slots ever allocated (occupied + free-listed).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Store `value`, reusing a freed slot when one exists. Returns the
    /// slot key.
    pub fn insert(&mut self, value: T) -> usize {
        self.len += 1;
        match self.free.pop() {
            Some(key) => {
                debug_assert!(self.slots[key].is_none(), "free list pointed at a full slot");
                self.slots[key] = Some(value);
                key
            }
            None => {
                self.slots.push(Some(value));
                self.slots.len() - 1
            }
        }
    }

    /// Take the value out of a slot, putting the slot on the free list.
    /// `None` if the slot is vacant (or the key out of range).
    pub fn remove(&mut self, key: usize) -> Option<T> {
        let value = self.slots.get_mut(key)?.take()?;
        self.free.push(key);
        self.len -= 1;
        Some(value)
    }

    pub fn get(&self, key: usize) -> Option<&T> {
        self.slots.get(key)?.as_ref()
    }

    pub fn get_mut(&mut self, key: usize) -> Option<&mut T> {
        self.slots.get_mut(key)?.as_mut()
    }

    /// Drop every value but keep the allocated slot pool for reuse.
    pub fn clear(&mut self) {
        self.free.clear();
        for (key, slot) in self.slots.iter_mut().enumerate() {
            if slot.take().is_some() {
                self.len -= 1;
            }
            self.free.push(key);
        }
        debug_assert_eq!(self.len, 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut s = Slab::new();
        let a = s.insert("a");
        let b = s.insert("b");
        assert_ne!(a, b);
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a), Some(&"a"));
        assert_eq!(s.remove(a), Some("a"));
        assert_eq!(s.get(a), None);
        assert_eq!(s.remove(a), None, "double remove is a no-op");
        assert_eq!(s.len(), 1);
        *s.get_mut(b).unwrap() = "b2";
        assert_eq!(s.get(b), Some(&"b2"));
    }

    #[test]
    fn freed_slots_are_reused_and_capacity_plateaus() {
        let mut s = Slab::new();
        let keys: Vec<usize> = (0..8).map(|i| s.insert(i)).collect();
        assert_eq!(s.capacity(), 8);
        // FIFO-ish churn, like the mapper window: free the front, push a
        // new value — the pool must not grow.
        for round in 0..100 {
            let victim = keys[round % keys.len()];
            s.remove(victim);
            let reused = s.insert(round);
            assert_eq!(reused, victim, "the freed slot is reclaimed");
        }
        assert_eq!(s.capacity(), 8, "steady state allocates nothing");
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn keys_stay_stable_across_other_removals() {
        let mut s = Slab::new();
        let a = s.insert(10);
        let b = s.insert(20);
        let c = s.insert(30);
        s.remove(b);
        assert_eq!(s.get(a), Some(&10), "unrelated removal does not move values");
        assert_eq!(s.get(c), Some(&30));
    }

    #[test]
    fn clear_retains_pool() {
        let mut s = Slab::new();
        for i in 0..5 {
            s.insert(i);
        }
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.capacity(), 5);
        s.insert(99);
        assert_eq!(s.capacity(), 5, "cleared slots are reused");
    }

    #[test]
    fn out_of_range_key_is_none() {
        let mut s: Slab<i32> = Slab::new();
        assert_eq!(s.get(3), None);
        assert_eq!(s.remove(3), None);
        assert_eq!(s.get_mut(3), None);
    }
}
