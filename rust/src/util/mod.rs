//! Small self-contained utilities: deterministic PRNG, simulated/scaled
//! clock, GUIDs, a YSON-subset parser (the paper's configuration format,
//! §4.5), a micro-benchmark harness and a mini property-testing loop.
//!
//! Everything here is dependency-free by design: the build environment is
//! offline, so the crate hand-rolls what it would otherwise take from
//! `rand`, `serde`, `criterion` and `proptest`.

pub mod prng;
pub mod clock;
pub mod guid;
pub mod yson;
pub mod benchkit;
pub mod miniprop;
pub mod slab;
pub mod sync;

pub use clock::Clock;
pub use guid::Guid;
pub use prng::Prng;
pub use sync::{cond_wait_timeout, lock, rlock, wlock};
pub use yson::Yson;
