//! Lock acquisition helpers — the crate-wide mutex-poisoning policy.
//!
//! Every guard in the crate is taken through these helpers instead of
//! scattered `.lock().unwrap()` calls (enforced by `tools/protolint`
//! rule R1's `lock_unwrap` sub-rule). The policy they centralize:
//!
//! **Poisoned locks are recovered, not propagated.** A poisoned mutex
//! means some holder panicked; under this system's fault model a
//! panicking worker is indistinguishable from a killed one, and the
//! protocol is explicitly designed to survive killed workers — any
//! cross-worker invariant a dead holder might have violated is
//! revalidated by commit-time CAS before it can reach persistent state
//! (DESIGN.md §"Exactly-once commit protocol"). Propagating the poison
//! instead would turn one dead worker into a cascade of dead workers
//! sharing the process, which is strictly worse than the fault being
//! modeled. Local in-memory state guarded by a poisoned lock is either
//! rebuilt from persistent state on the next fetch (mapper/reducer
//! state caches) or monotonic counters whose partial update is benign
//! (metrics, accounting).

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Duration;

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock<T: ?Sized>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Read-lock an `RwLock`, recovering the guard if a writer panicked.
pub fn rlock<T: ?Sized>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// Write-lock an `RwLock`, recovering the guard if a holder panicked.
pub fn wlock<T: ?Sized>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

/// `Condvar::wait_timeout` with the same poison-recovery policy as
/// [`lock`]: a panicked notifier does not take the waiter down with it.
pub fn cond_wait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, timeout) {
        Ok((g, _)) => g,
        Err(poisoned) => poisoned.into_inner().0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex, RwLock};

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock(&m), 7);
        *lock(&m) += 1;
        assert_eq!(*lock(&m), 8);
    }

    #[test]
    fn rwlock_helpers_recover_from_poison() {
        let l = Arc::new(RwLock::new(3u32));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(l.is_poisoned());
        assert_eq!(*rlock(&l), 3);
        *wlock(&l) += 1;
        assert_eq!(*rlock(&l), 4);
    }

    #[test]
    fn cond_wait_timeout_times_out() {
        let m = Mutex::new(false);
        let cv = Condvar::new();
        let g = lock(&m);
        let g = cond_wait_timeout(&cv, g, Duration::from_millis(1));
        assert!(!*g);
    }
}
