//! Micro-benchmark harness (criterion is unavailable offline, so `cargo
//! bench` targets use this instead; they are plain `harness = false`
//! binaries).
//!
//! Methodology: warm up, then run timed batches until both a minimum
//! duration and a minimum iteration count are reached; report mean / p50 /
//! p99 per-iteration time and derived throughput. Output is stable
//! one-line-per-benchmark text that the EXPERIMENTS.md tables are built
//! from.

use std::sync::Mutex;
use std::time::{Duration, Instant};
use crate::util;

/// Every report produced by this process (fed by [`Bench::run`]), so a
/// bench binary can emit one machine-readable document at exit — see
/// [`write_json_env`].
static COLLECTED: Mutex<Vec<BenchReport>> = Mutex::new(Vec::new());

/// One benchmark definition.
pub struct Bench {
    name: String,
    warmup: Duration,
    min_time: Duration,
    min_iters: u64,
    /// Optional bytes processed per iteration (enables MB/s reporting).
    bytes_per_iter: Option<u64>,
    /// Optional logical items per iteration (enables Mitems/s reporting).
    items_per_iter: Option<u64>,
}

/// Result of a completed benchmark run.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub mb_per_s: Option<f64>,
    pub mitems_per_s: Option<f64>,
}

/// Millisecond duration from an env var (smoke runs shrink the budget:
/// `BENCHKIT_WARMUP_MS` / `BENCHKIT_MIN_TIME_MS`, see
/// `scripts/bench_smoke.sh`).
fn env_ms(name: &str, default_ms: u64) -> Duration {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .map(Duration::from_millis)
        .unwrap_or(Duration::from_millis(default_ms))
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Bench {
            name: name.into(),
            warmup: env_ms("BENCHKIT_WARMUP_MS", 200),
            min_time: env_ms("BENCHKIT_MIN_TIME_MS", 800),
            min_iters: 10,
            bytes_per_iter: None,
            items_per_iter: None,
        }
    }

    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    pub fn min_time(mut self, d: Duration) -> Self {
        self.min_time = d;
        self
    }

    pub fn min_iters(mut self, n: u64) -> Self {
        self.min_iters = n;
        self
    }

    pub fn throughput_bytes(mut self, bytes: u64) -> Self {
        self.bytes_per_iter = Some(bytes);
        self
    }

    pub fn throughput_items(mut self, items: u64) -> Self {
        self.items_per_iter = Some(items);
        self
    }

    /// Run the benchmark, print and return the report.
    pub fn run<F: FnMut()>(self, mut f: F) -> BenchReport {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Timed samples.
        let mut samples_ns: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.min_time || (samples_ns.len() as u64) < self.min_iters {
            let s = Instant::now();
            f();
            samples_ns.push(s.elapsed().as_nanos() as f64);
            if samples_ns.len() > 5_000_000 {
                break; // safety valve for ~ns-scale bodies
            }
        }
        let report = summarize(
            &self.name,
            &mut samples_ns,
            self.bytes_per_iter,
            self.items_per_iter,
        );
        println!("{}", format_report(&report));
        util::lock(&COLLECTED).push(report.clone());
        report
    }
}

/// Snapshot of every report collected by this process so far.
pub fn collected() -> Vec<BenchReport> {
    util::lock(&COLLECTED).clone()
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "null".to_string()
    }
}

fn json_opt(x: Option<f64>) -> String {
    x.map_or_else(|| "null".to_string(), json_num)
}

/// Render reports as the machine-readable `BENCH_<pr>.json` document
/// (hand-rolled — serde is unavailable offline). `harness` records what
/// produced the numbers so downstream tooling never mistakes a model or
/// smoke run for full measurements.
pub fn reports_to_json(harness: &str, reports: &[BenchReport]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"yt-stream-bench-v1\",\n");
    s.push_str(&format!("  \"harness\": \"{}\",\n", json_escape(harness)));
    s.push_str("  \"benches\": [\n");
    for (i, r) in reports.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {}, \"p50_ns\": {}, \
             \"p99_ns\": {}, \"mb_per_s\": {}, \"mitems_per_s\": {}}}{}\n",
            json_escape(&r.name),
            r.iters,
            json_num(r.mean_ns),
            json_num(r.p50_ns),
            json_num(r.p99_ns),
            json_opt(r.mb_per_s),
            json_opt(r.mitems_per_s),
            if i + 1 < reports.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// If `BENCHKIT_JSON` names a path, write everything this process has
/// collected there — the `scripts/bench_smoke.sh` contract for emitting
/// `BENCH_<pr>.json` at the repo root. Returns the path written, if any.
pub fn write_json_env(harness: &str) -> Option<std::path::PathBuf> {
    let path = std::path::PathBuf::from(std::env::var_os("BENCHKIT_JSON")?);
    let json = reports_to_json(harness, &collected());
    match std::fs::write(&path, json) {
        Ok(()) => {
            println!("benchkit: wrote {}", path.display());
            Some(path)
        }
        Err(e) => {
            eprintln!("benchkit: failed to write {}: {e}", path.display());
            None
        }
    }
}

fn summarize(
    name: &str,
    samples_ns: &mut [f64],
    bytes_per_iter: Option<u64>,
    items_per_iter: Option<u64>,
) -> BenchReport {
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let iters = samples_ns.len() as u64;
    let mean_ns = samples_ns.iter().sum::<f64>() / iters as f64;
    let p = |q: f64| samples_ns[((iters as f64 - 1.0) * q) as usize];
    let mb_per_s = bytes_per_iter.map(|b| b as f64 / (mean_ns / 1e9) / 1e6);
    let mitems_per_s = items_per_iter.map(|n| n as f64 / (mean_ns / 1e9) / 1e6);
    BenchReport {
        name: name.to_string(),
        iters,
        mean_ns,
        p50_ns: p(0.5),
        p99_ns: p(0.99),
        mb_per_s,
        mitems_per_s,
    }
}

/// Human-readable duration.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

fn format_report(r: &BenchReport) -> String {
    let mut line = format!(
        "bench {:<44} iters={:<8} mean={:<10} p50={:<10} p99={:<10}",
        r.name,
        r.iters,
        fmt_ns(r.mean_ns),
        fmt_ns(r.p50_ns),
        fmt_ns(r.p99_ns),
    );
    if let Some(mb) = r.mb_per_s {
        line.push_str(&format!(" thpt={mb:.1} MB/s"));
    }
    if let Some(mi) = r.mitems_per_s {
        line.push_str(&format!(" rate={mi:.2} Mitems/s"));
    }
    line
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let r = Bench::new("noop")
            .warmup(Duration::from_millis(1))
            .min_time(Duration::from_millis(5))
            .min_iters(10)
            .run(|| {
                black_box(1 + 1);
            });
        assert!(r.iters >= 10);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p99_ns >= r.p50_ns);
    }

    #[test]
    fn throughput_computed() {
        let r = Bench::new("sleepy")
            .warmup(Duration::from_millis(1))
            .min_time(Duration::from_millis(5))
            .min_iters(5)
            .throughput_bytes(1_000_000)
            .run(|| std::thread::sleep(Duration::from_micros(100)));
        let mb = r.mb_per_s.unwrap();
        // 1 MB per ~100us → ~10 GB/s nominal; just check it's sane & positive.
        assert!(mb > 0.0);
    }

    #[test]
    fn reports_are_collected_and_serialized() {
        let r = Bench::new("json\"bench")
            .warmup(Duration::from_millis(1))
            .min_time(Duration::from_millis(2))
            .min_iters(3)
            .throughput_items(10)
            .run(|| {
                black_box(2 + 2);
            });
        assert!(
            collected().iter().any(|c| c.name == "json\"bench"),
            "run() must feed the process-wide collector"
        );
        let json = reports_to_json("unit-test", &[r.clone()]);
        assert!(json.contains("\"schema\": \"yt-stream-bench-v1\""));
        assert!(json.contains("\"harness\": \"unit-test\""));
        assert!(json.contains("json\\\"bench"), "names are escaped");
        assert!(json.contains("\"mb_per_s\": null"), "absent metrics are null");
        assert!(json.contains(&format!("\"iters\": {}", r.iters)));
        assert_eq!(
            json.matches('{').count(),
            json.matches('}').count(),
            "braces balance"
        );
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(1500.0).ends_with("us"));
        assert!(fmt_ns(2.5e6).ends_with("ms"));
        assert!(fmt_ns(3.0e9).ends_with(" s"));
    }
}
