//! Scaled wall clock.
//!
//! The paper's failure drills run for tens of minutes on a production-like
//! cluster (§5.2: 10-minute worker pauses, 15-minute buffer drains). The
//! reproduction runs the same *schedules* time-scaled (default 60×), so a
//! "10 minute" outage takes 10 seconds of wall time while every recorded
//! timestamp is reported in *simulated* time — figure axes stay comparable
//! to the paper's.
//!
//! All workers share one [`Clock`]; sleeps divide by the speed factor,
//! `now_ms()` multiplies elapsed wall time by it.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Shared scaled clock. Cheap to clone (Arc inside).
#[derive(Clone, Debug)]
pub struct Clock {
    inner: Arc<ClockInner>,
}

#[derive(Debug)]
struct ClockInner {
    start: Instant,
    /// Simulated milliseconds per wall millisecond.
    speedup: u64,
    /// Monotonic counter mixed into GUIDs and used by tests to order events
    /// that can land on the same millisecond.
    ticks: AtomicU64,
}

impl Clock {
    /// Real-time clock (speedup = 1).
    pub fn realtime() -> Self {
        Self::scaled(1)
    }

    /// Clock running `speedup`× faster than wall time.
    pub fn scaled(speedup: u64) -> Self {
        assert!(speedup >= 1);
        Clock {
            inner: Arc::new(ClockInner {
                start: Instant::now(),
                speedup,
                ticks: AtomicU64::new(0),
            }),
        }
    }

    /// Milliseconds of *simulated* time since clock creation.
    #[inline]
    pub fn now_ms(&self) -> u64 {
        self.inner.start.elapsed().as_millis() as u64 * self.inner.speedup
    }

    /// Microseconds of simulated time (for latency metrics).
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.inner.start.elapsed().as_micros() as u64 * self.inner.speedup
    }

    /// Sleep for `sim_ms` of simulated time (i.e. `sim_ms / speedup` wall).
    pub fn sleep_ms(&self, sim_ms: u64) {
        let wall = Duration::from_micros(sim_ms * 1000 / self.inner.speedup);
        std::thread::sleep(wall);
    }

    /// The configured speed factor.
    pub fn speedup(&self) -> u64 {
        self.inner.speedup
    }

    /// Strictly monotonic tick; no two calls observe the same value.
    pub fn tick(&self) -> u64 {
        self.inner.ticks.fetch_add(1, Ordering::Relaxed)
    }
}

/// A stopwatch over a [`Clock`], reporting simulated elapsed time.
pub struct Stopwatch {
    clock: Clock,
    start_us: u64,
}

impl Stopwatch {
    pub fn start(clock: &Clock) -> Self {
        Stopwatch {
            clock: clock.clone(),
            start_us: clock.now_us(),
        }
    }

    pub fn elapsed_ms(&self) -> u64 {
        (self.clock.now_us() - self.start_us) / 1000
    }

    pub fn elapsed_us(&self) -> u64 {
        self.clock.now_us() - self.start_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn now_advances() {
        let c = Clock::realtime();
        let a = c.now_us();
        std::thread::sleep(Duration::from_millis(2));
        assert!(c.now_us() > a);
    }

    #[test]
    fn scaled_clock_runs_faster() {
        let c = Clock::scaled(100);
        std::thread::sleep(Duration::from_millis(10));
        // 10ms wall ≈ 1000ms simulated.
        let now = c.now_ms();
        assert!(now >= 500, "scaled clock too slow: {now}");
    }

    #[test]
    fn sleep_scales_down() {
        let c = Clock::scaled(1000);
        let wall = Instant::now();
        c.sleep_ms(1000); // 1 simulated second = 1ms wall
        assert!(wall.elapsed() < Duration::from_millis(300));
    }

    #[test]
    fn ticks_strictly_monotonic() {
        let c = Clock::realtime();
        let a = c.tick();
        let b = c.tick();
        assert!(b > a);
    }

    #[test]
    fn stopwatch_measures() {
        let c = Clock::scaled(10);
        let sw = Stopwatch::start(&c);
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed_ms() >= 20); // 5ms wall * 10 = 50 sim ms, allow slack
    }
}
