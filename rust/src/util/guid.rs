//! 128-bit GUIDs in YT's canonical `a-b-c-d` hex format.
//!
//! Workers identify themselves by GUID in discovery and in `GetRows`
//! requests (§4.3.4: `mapper_id` discards requests that were routed via
//! stale discovery data, which is the split-brain defence).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

use super::prng::splitmix64;

static COUNTER: AtomicU64 = AtomicU64::new(1);

/// A 128-bit globally-unique id, formatted YT-style as four dash-separated
/// hex quarters (e.g. `3f19-8a2b-90c1-7de4`).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Guid {
    pub hi: u64,
    pub lo: u64,
}

impl Guid {
    /// Generate a fresh GUID. Mixes a process-global counter with the
    /// current time so GUIDs are unique across restarts of simulated
    /// workers within one process (the only uniqueness domain we need).
    pub fn generate() -> Guid {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let mut s = n
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(std::time::UNIX_EPOCH.elapsed().map(|d| d.as_nanos() as u64).unwrap_or(0));
        let hi = splitmix64(&mut s);
        let lo = splitmix64(&mut s);
        Guid { hi, lo }
    }

    /// Deterministic GUID from a seed (used by property tests).
    pub fn from_seed(seed: u64) -> Guid {
        let mut s = seed;
        Guid {
            hi: splitmix64(&mut s),
            lo: splitmix64(&mut s),
        }
    }

    pub const ZERO: Guid = Guid { hi: 0, lo: 0 };

    /// Parse the `a-b-c-d` hex format produced by `Display`.
    pub fn parse(s: &str) -> Option<Guid> {
        let parts: Vec<&str> = s.split('-').collect();
        if parts.len() != 4 {
            return None;
        }
        let q: Vec<u64> = parts
            .iter()
            .map(|p| u64::from_str_radix(p, 16))
            .collect::<Result<_, _>>()
            .ok()?;
        Some(Guid {
            hi: (q[0] << 32) | q[1],
            lo: (q[2] << 32) | q[3],
        })
    }
}

impl fmt::Display for Guid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{:x}-{:x}-{:x}-{:x}",
            self.hi >> 32,
            self.hi & 0xFFFF_FFFF,
            self.lo >> 32,
            self.lo & 0xFFFF_FFFF
        )
    }
}

impl fmt::Debug for Guid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn generated_guids_unique() {
        let mut seen = HashSet::new();
        for _ in 0..10_000 {
            assert!(seen.insert(Guid::generate()));
        }
    }

    #[test]
    fn display_roundtrip() {
        for seed in 0..100 {
            let g = Guid::from_seed(seed);
            let s = g.to_string();
            assert_eq!(Guid::parse(&s), Some(g), "roundtrip failed for {s}");
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert_eq!(Guid::parse(""), None);
        assert_eq!(Guid::parse("1-2-3"), None);
        assert_eq!(Guid::parse("x-y-z-w"), None);
        assert_eq!(Guid::parse("1-2-3-4-5"), None);
    }

    #[test]
    fn from_seed_deterministic() {
        assert_eq!(Guid::from_seed(7), Guid::from_seed(7));
        assert_ne!(Guid::from_seed(7), Guid::from_seed(8));
    }

    #[test]
    fn zero_formats() {
        assert_eq!(Guid::ZERO.to_string(), "0-0-0-0");
    }
}
