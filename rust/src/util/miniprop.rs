//! Minimal property-based testing loop (proptest is unavailable offline).
//!
//! A property is a function from a seeded [`Prng`] to `Result<(), String>`.
//! The runner executes it across many derived seeds; on failure it re-runs
//! with the same seed to confirm determinism and reports the seed so the
//! case can be replayed with `MINIPROP_SEED=<n>`.
//!
//! This intentionally has no shrinking: generators are written to produce
//! *small* cases by construction (sizes drawn from small ranges), which in
//! practice keeps counterexamples readable.

use super::prng::Prng;

/// Configuration for a property run.
pub struct Config {
    pub cases: u32,
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let base_seed = std::env::var("MINIPROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Config {
            cases: 64,
            base_seed,
        }
    }
}

/// Run `prop` for `cfg.cases` seeds; panic with the failing seed on error.
pub fn check_with(cfg: Config, name: &str, mut prop: impl FnMut(&mut Prng) -> Result<(), String>) {
    for case in 0..cfg.cases {
        let seed = cfg.base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Prng::seeded(seed);
        if let Err(msg) = prop(&mut rng) {
            // Confirm determinism before reporting.
            let mut rng2 = Prng::seeded(seed);
            let second = prop(&mut rng2);
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}; \
                 deterministic replay: {}):\n  {msg}\n\
                 replay with: MINIPROP_SEED={} (case index {case})",
                if second.is_err() { "yes" } else { "NO — flaky!" },
                cfg.base_seed,
            );
        }
    }
}

/// Run with default config.
pub fn check(name: &str, prop: impl FnMut(&mut Prng) -> Result<(), String>) {
    check_with(Config::default(), name, prop)
}

/// Assertion helpers that return `Result<(), String>` for use in properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// `prop_assert_eq!(a, b)` — equality with both values in the message;
/// optional trailing format args add context.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (av, bv) = (&$a, &$b);
        if av != bv {
            return Err(format!("expected {:?} == {:?}", av, bv));
        }
    }};
    ($a:expr, $b:expr, $($fmt:tt)+) => {{
        let (av, bv) = (&$a, &$b);
        if av != bv {
            return Err(format!(
                "expected {:?} == {:?} ({})",
                av,
                bv,
                format!($($fmt)+)
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("addition commutes", |rng| {
            let a = rng.gen_range(0, 1000) as i64;
            let b = rng.gen_range(0, 1000) as i64;
            prop_assert_eq!(a + b, b + a);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_seed() {
        check_with(
            Config {
                cases: 3,
                base_seed: 1,
            },
            "always fails",
            |_rng| Err("nope".to_string()),
        );
    }

    #[test]
    fn seeds_vary_across_cases() {
        let mut values = Vec::new();
        check_with(
            Config {
                cases: 8,
                base_seed: 42,
            },
            "collect",
            |rng| {
                values.push(rng.next_u64());
                Ok(())
            },
        );
        let mut dedup = values.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), values.len(), "cases reused a seed");
    }
}
