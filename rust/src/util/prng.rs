//! Deterministic pseudo-random number generation.
//!
//! The whole simulation (workload generation, fault injection, property
//! tests) must be reproducible from a single seed, so every randomized
//! component takes an explicit [`Prng`] instead of sampling ambient
//! entropy. The generator is xoshiro256++ seeded through splitmix64 —
//! the standard, well-tested construction.

/// splitmix64 step; used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ deterministic PRNG.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Derive an independent child generator; used to hand each worker its
    /// own stream so thread scheduling cannot perturb the others.
    pub fn fork(&mut self) -> Prng {
        Prng::seeded(self.next_u64())
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`; Lemire's multiply-shift with rejection.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "gen_range: lo > hi");
        lo + self.next_below(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.next_below(xs.len() as u64) as usize]
    }

    /// Random ASCII-lowercase identifier of length `n`.
    pub fn ident(&mut self, n: usize) -> String {
        (0..n)
            .map(|_| (b'a' + self.next_below(26) as u8) as char)
            .collect()
    }
}

/// Zipf-distributed sampler over `{0, 1, .., n-1}` with exponent `s`.
///
/// The paper's evaluation stresses that log-message keys are heavily
/// skewed ("root and a few other system users appearing in overwhelmingly
/// more messages than regular users", §5.2); the workload generator uses
/// this to reproduce that skew. Implemented by inverse-CDF over the
/// precomputed harmonic weights — O(log n) per sample.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Sample a rank in `[0, n)`; rank 0 is the most frequent.
    pub fn sample(&self, rng: &mut Prng) -> usize {
        let u = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Prng::seeded(42);
        let mut b = Prng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::seeded(1);
        let mut b = Prng::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn next_below_in_bounds() {
        let mut r = Prng::seeded(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_range_inclusive() {
        let mut r = Prng::seeded(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.gen_range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Prng::seeded(11);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn chance_extremes() {
        let mut r = Prng::seeded(12);
        for _ in 0..100 {
            assert!(!r.chance(0.0));
            assert!(r.chance(1.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::seeded(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let z = Zipf::new(1000, 1.2);
        let mut r = Prng::seeded(99);
        let mut counts = vec![0u32; 1000];
        for _ in 0..20_000 {
            counts[z.sample(&mut r)] += 1;
        }
        // rank 0 should dominate rank 100 by a wide margin
        assert!(counts[0] > 10 * counts[100].max(1));
        assert!(counts.iter().sum::<u32>() == 20_000);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Prng::seeded(5);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
