//! YSON — YT's JSON-like configuration format (§4.5).
//!
//! The paper configures streaming processors "using YT's own JSON-like
//! format, called YSON". This module implements the text-mode subset used
//! for configuration:
//!
//! * maps: `{key = value; key2 = value2}`
//! * lists: `[a; b; c]`
//! * strings: bare identifiers (`foo_bar`, `//path/to/table`) or
//!   double-quoted with escapes (`"hello\nworld"`)
//! * integers (`42`, `-7`), doubles (`3.14`, `1e-3`)
//! * booleans: `%true` / `%false`
//! * entity (null): `#`
//! * attribute maps prefixed to a value: `<compression = lz4> {...}`
//!
//! Plus a writer producing canonical pretty text that re-parses to the same
//! value (round-trip property-tested).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed YSON value. Maps are ordered (BTreeMap) so the writer emits
/// deterministic output.
#[derive(Debug, Clone, PartialEq)]
pub enum Yson {
    Entity,
    Bool(bool),
    Int(i64),
    Uint(u64),
    Double(f64),
    Str(String),
    List(Vec<Yson>),
    Map(BTreeMap<String, Yson>),
    /// A value with an attached attribute map: `<attrs> value`.
    Attributed(BTreeMap<String, Yson>, Box<Yson>),
}

/// Parse or schema-access error.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum YsonError {
    #[error("yson parse error at byte {0}: {1}")]
    Parse(usize, String),
    #[error("yson: missing key '{0}'")]
    MissingKey(String),
    #[error("yson: expected {0}, found {1}")]
    WrongType(&'static str, &'static str),
}

impl Yson {
    pub fn parse(text: &str) -> Result<Yson, YsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(YsonError::Parse(p.i, "trailing input".into()));
        }
        Ok(v)
    }

    fn type_name(&self) -> &'static str {
        match self {
            Yson::Entity => "entity",
            Yson::Bool(_) => "bool",
            Yson::Int(_) => "int",
            Yson::Uint(_) => "uint",
            Yson::Double(_) => "double",
            Yson::Str(_) => "string",
            Yson::List(_) => "list",
            Yson::Map(_) => "map",
            Yson::Attributed(..) => "attributed",
        }
    }

    /// Strip the attribute wrapper, if any.
    pub fn unwrap_attrs(&self) -> &Yson {
        match self {
            Yson::Attributed(_, inner) => inner.unwrap_attrs(),
            other => other,
        }
    }

    /// The attribute map, if this value carries one.
    pub fn attrs(&self) -> Option<&BTreeMap<String, Yson>> {
        match self {
            Yson::Attributed(a, _) => Some(a),
            _ => None,
        }
    }

    pub fn as_map(&self) -> Result<&BTreeMap<String, Yson>, YsonError> {
        match self.unwrap_attrs() {
            Yson::Map(m) => Ok(m),
            other => Err(YsonError::WrongType("map", other.type_name())),
        }
    }

    pub fn as_list(&self) -> Result<&[Yson], YsonError> {
        match self.unwrap_attrs() {
            Yson::List(l) => Ok(l),
            other => Err(YsonError::WrongType("list", other.type_name())),
        }
    }

    pub fn as_str(&self) -> Result<&str, YsonError> {
        match self.unwrap_attrs() {
            Yson::Str(s) => Ok(s),
            other => Err(YsonError::WrongType("string", other.type_name())),
        }
    }

    pub fn as_i64(&self) -> Result<i64, YsonError> {
        match self.unwrap_attrs() {
            Yson::Int(v) => Ok(*v),
            Yson::Uint(v) => Ok(*v as i64),
            other => Err(YsonError::WrongType("int", other.type_name())),
        }
    }

    pub fn as_u64(&self) -> Result<u64, YsonError> {
        match self.unwrap_attrs() {
            Yson::Uint(v) => Ok(*v),
            Yson::Int(v) if *v >= 0 => Ok(*v as u64),
            other => Err(YsonError::WrongType("uint", other.type_name())),
        }
    }

    pub fn as_f64(&self) -> Result<f64, YsonError> {
        match self.unwrap_attrs() {
            Yson::Double(v) => Ok(*v),
            Yson::Int(v) => Ok(*v as f64),
            Yson::Uint(v) => Ok(*v as f64),
            other => Err(YsonError::WrongType("double", other.type_name())),
        }
    }

    pub fn as_bool(&self) -> Result<bool, YsonError> {
        match self.unwrap_attrs() {
            Yson::Bool(v) => Ok(*v),
            other => Err(YsonError::WrongType("bool", other.type_name())),
        }
    }

    /// Fetch a required map key.
    pub fn get(&self, key: &str) -> Result<&Yson, YsonError> {
        self.as_map()?
            .get(key)
            .ok_or_else(|| YsonError::MissingKey(key.to_string()))
    }

    /// Fetch an optional map key.
    pub fn get_opt(&self, key: &str) -> Option<&Yson> {
        self.as_map().ok().and_then(|m| m.get(key))
    }

    /// `get(key)` with a default when absent: integers.
    pub fn get_i64_or(&self, key: &str, default: i64) -> i64 {
        self.get_opt(key).and_then(|v| v.as_i64().ok()).unwrap_or(default)
    }

    pub fn get_u64_or(&self, key: &str, default: u64) -> u64 {
        self.get_opt(key).and_then(|v| v.as_u64().ok()).unwrap_or(default)
    }

    pub fn get_f64_or(&self, key: &str, default: f64) -> f64 {
        self.get_opt(key).and_then(|v| v.as_f64().ok()).unwrap_or(default)
    }

    pub fn get_bool_or(&self, key: &str, default: bool) -> bool {
        self.get_opt(key).and_then(|v| v.as_bool().ok()).unwrap_or(default)
    }

    pub fn get_str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get_opt(key).and_then(|v| v.as_str().ok()).unwrap_or(default)
    }

    /// Convenience constructors for building config programmatically.
    pub fn map(pairs: Vec<(&str, Yson)>) -> Yson {
        Yson::Map(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: &str) -> Yson {
        Yson::Str(s.to_string())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, YsonError> {
        Err(YsonError::Parse(self.i, msg.into()))
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(c) = self.peek() {
            match c {
                b' ' | b'\t' | b'\n' | b'\r' => self.i += 1,
                b'#' if false => {}
                _ => break,
            }
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), YsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Yson, YsonError> {
        self.skip_ws();
        // Attribute prefix.
        if self.peek() == Some(b'<') {
            self.i += 1;
            let attrs = self.map_body(b'>')?;
            self.skip_ws();
            let inner = self.value()?;
            return Ok(Yson::Attributed(attrs, Box::new(inner)));
        }
        match self.peek() {
            None => self.err("unexpected end of input"),
            Some(b'{') => {
                self.i += 1;
                Ok(Yson::Map(self.map_body(b'}')?))
            }
            Some(b'[') => {
                self.i += 1;
                self.list_body()
            }
            Some(b'"') => Ok(Yson::Str(self.quoted_string()?)),
            Some(b'%') => self.percent_literal(),
            Some(b'#') => {
                self.i += 1;
                Ok(Yson::Entity)
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) if is_ident_start(c) => Ok(Yson::Str(self.bare_ident())),
            Some(c) => self.err(format!("unexpected byte '{}'", c as char)),
        }
    }

    fn map_body(&mut self, close: u8) -> Result<BTreeMap<String, Yson>, YsonError> {
        let mut m = BTreeMap::new();
        loop {
            self.skip_ws();
            if self.peek() == Some(close) {
                self.i += 1;
                return Ok(m);
            }
            let key = match self.peek() {
                Some(b'"') => self.quoted_string()?,
                Some(c) if is_ident_start(c) => self.bare_ident(),
                _ => return self.err("expected map key"),
            };
            self.skip_ws();
            self.expect(b'=')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            if self.peek() == Some(b';') {
                self.i += 1;
            }
        }
    }

    fn list_body(&mut self) -> Result<Yson, YsonError> {
        let mut l = Vec::new();
        loop {
            self.skip_ws();
            if self.peek() == Some(b']') {
                self.i += 1;
                return Ok(Yson::List(l));
            }
            l.push(self.value()?);
            self.skip_ws();
            if self.peek() == Some(b';') {
                self.i += 1;
            }
        }
    }

    fn quoted_string(&mut self) -> Result<String, YsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or(YsonError::Parse(self.i, "bad escape".into()))?;
                    self.i += 1;
                    out.push(match c {
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        b'\\' => '\\',
                        b'"' => '"',
                        b'0' => '\0',
                        other => return self.err(format!("bad escape '\\{}'", other as char)),
                    });
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = &self.b[self.i..];
                    let ch_len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| YsonError::Parse(self.i, "invalid utf-8".into()))?;
                    out.push_str(chunk);
                    self.i += ch_len;
                }
            }
        }
    }

    fn percent_literal(&mut self) -> Result<Yson, YsonError> {
        self.expect(b'%')?;
        let word = self.bare_ident();
        match word.as_str() {
            "true" => Ok(Yson::Bool(true)),
            "false" => Ok(Yson::Bool(false)),
            other => self.err(format!("unknown %-literal '{other}'")),
        }
    }

    fn bare_ident(&mut self) -> String {
        let start = self.i;
        while let Some(c) = self.peek() {
            if is_ident_continue(c) {
                self.i += 1;
            } else {
                break;
            }
        }
        String::from_utf8_lossy(&self.b[start..self.i]).into_owned()
    }

    fn number(&mut self) -> Result<Yson, YsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.i += 1,
                b'.' | b'e' | b'E' | b'+' => {
                    is_float = true;
                    self.i += 1;
                }
                b'-' if is_float => self.i += 1, // exponent sign
                b'u' => {
                    // uint suffix: `42u`
                    let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
                    self.i += 1;
                    return text
                        .parse::<u64>()
                        .map(Yson::Uint)
                        .map_err(|e| YsonError::Parse(start, e.to_string()));
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if is_float {
            text.parse::<f64>()
                .map(Yson::Double)
                .map_err(|e| YsonError::Parse(start, e.to_string()))
        } else {
            text.parse::<i64>()
                .map(Yson::Int)
                .map_err(|e| YsonError::Parse(start, e.to_string()))
        }
    }
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_' || c == b'/'
}

fn is_ident_continue(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_' || c == b'/' || c == b'.' || c == b':'
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Yson {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write_value(f, self, 0)
    }
}

fn needs_quoting(s: &str) -> bool {
    s.is_empty()
        || !s.bytes().next().map(is_ident_start).unwrap_or(false)
        || !s.bytes().all(is_ident_continue)
}

fn write_string(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    if needs_quoting(s) {
        write!(f, "\"")?;
        for c in s.chars() {
            match c {
                '\n' => write!(f, "\\n")?,
                '\t' => write!(f, "\\t")?,
                '\r' => write!(f, "\\r")?,
                '\\' => write!(f, "\\\\")?,
                '"' => write!(f, "\\\"")?,
                c => write!(f, "{c}")?,
            }
        }
        write!(f, "\"")
    } else {
        write!(f, "{s}")
    }
}

fn write_map(
    f: &mut fmt::Formatter<'_>,
    m: &BTreeMap<String, Yson>,
    open: char,
    close: char,
    indent: usize,
) -> fmt::Result {
    if m.is_empty() {
        return write!(f, "{open}{close}");
    }
    writeln!(f, "{open}")?;
    for (k, v) in m {
        write!(f, "{:indent$}", "", indent = (indent + 1) * 4)?;
        write_string(f, k)?;
        write!(f, " = ")?;
        write_value(f, v, indent + 1)?;
        writeln!(f, ";")?;
    }
    write!(f, "{:indent$}{close}", "", indent = indent * 4)
}

fn write_value(f: &mut fmt::Formatter<'_>, v: &Yson, indent: usize) -> fmt::Result {
    match v {
        Yson::Entity => write!(f, "#"),
        Yson::Bool(true) => write!(f, "%true"),
        Yson::Bool(false) => write!(f, "%false"),
        Yson::Int(n) => write!(f, "{n}"),
        Yson::Uint(n) => write!(f, "{n}u"),
        Yson::Double(x) => {
            if x.fract() == 0.0 && x.is_finite() && x.abs() < 1e15 {
                write!(f, "{x:.1}")
            } else {
                write!(f, "{x}")
            }
        }
        Yson::Str(s) => write_string(f, s),
        Yson::List(l) => {
            if l.is_empty() {
                return write!(f, "[]");
            }
            writeln!(f, "[")?;
            for item in l {
                write!(f, "{:indent$}", "", indent = (indent + 1) * 4)?;
                write_value(f, item, indent + 1)?;
                writeln!(f, ";")?;
            }
            write!(f, "{:indent$}]", "", indent = indent * 4)
        }
        Yson::Map(m) => write_map(f, m, '{', '}', indent),
        Yson::Attributed(attrs, inner) => {
            write_map(f, attrs, '<', '>', indent)?;
            write!(f, " ")?;
            write_value(f, inner, indent)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Yson::parse("42").unwrap(), Yson::Int(42));
        assert_eq!(Yson::parse("-17").unwrap(), Yson::Int(-17));
        assert_eq!(Yson::parse("42u").unwrap(), Yson::Uint(42));
        assert_eq!(Yson::parse("3.5").unwrap(), Yson::Double(3.5));
        assert_eq!(Yson::parse("1e-3").unwrap(), Yson::Double(1e-3));
        assert_eq!(Yson::parse("%true").unwrap(), Yson::Bool(true));
        assert_eq!(Yson::parse("%false").unwrap(), Yson::Bool(false));
        assert_eq!(Yson::parse("#").unwrap(), Yson::Entity);
        assert_eq!(Yson::parse("hello_world").unwrap(), Yson::Str("hello_world".into()));
        assert_eq!(
            Yson::parse("\"with spaces\\n\"").unwrap(),
            Yson::Str("with spaces\n".into())
        );
    }

    #[test]
    fn parses_paths_as_bare_strings() {
        assert_eq!(
            Yson::parse("//sys/state/mappers").unwrap(),
            Yson::Str("//sys/state/mappers".into())
        );
    }

    #[test]
    fn parses_nested_config() {
        let text = r#"
        {
            processor = {
                mapper_count = 4;
                reducer_count = 2;
                memory_limit = 8589934592;
                backoff_ms = 100;
                state_table = "//sys/state";
                spill = %false;
                thresholds = [0.5; 0.9; 1.0];
            };
        }
        "#;
        let v = Yson::parse(text).unwrap();
        let p = v.get("processor").unwrap();
        assert_eq!(p.get("mapper_count").unwrap().as_i64().unwrap(), 4);
        assert_eq!(p.get("state_table").unwrap().as_str().unwrap(), "//sys/state");
        assert!(!p.get("spill").unwrap().as_bool().unwrap());
        assert_eq!(p.get("thresholds").unwrap().as_list().unwrap().len(), 3);
    }

    #[test]
    fn parses_attributes() {
        let v = Yson::parse("<compression = lz4; replication = 3> {a = 1}").unwrap();
        let attrs = v.attrs().unwrap();
        assert_eq!(attrs["compression"], Yson::Str("lz4".into()));
        assert_eq!(attrs["replication"], Yson::Int(3));
        assert_eq!(v.get("a").unwrap().as_i64().unwrap(), 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Yson::parse("").is_err());
        assert!(Yson::parse("{a = }").is_err());
        assert!(Yson::parse("{a = 1} trailing").is_err());
        assert!(Yson::parse("\"unterminated").is_err());
        assert!(Yson::parse("%maybe").is_err());
    }

    #[test]
    fn trailing_semicolons_optional() {
        let a = Yson::parse("{a=1;b=2;}").unwrap();
        let b = Yson::parse("{a=1;b=2}").unwrap();
        assert_eq!(a, b);
        let c = Yson::parse("[1;2;3;]").unwrap();
        let d = Yson::parse("[1;2;3]").unwrap();
        assert_eq!(c, d);
    }

    #[test]
    fn defaults_helpers() {
        let v = Yson::parse("{a = 5}").unwrap();
        assert_eq!(v.get_i64_or("a", 0), 5);
        assert_eq!(v.get_i64_or("b", 7), 7);
        assert_eq!(v.get_str_or("c", "dflt"), "dflt");
        assert!(v.get_bool_or("d", true));
    }

    #[test]
    fn writer_roundtrip() {
        let texts = [
            "{a = 1; b = [x; y; \"z w\"]; c = {d = %true; e = 2.5; f = #}}",
            "[]",
            "{}",
            "<attr = 7> [1; 2u; -3]",
            "{path = //home/user/table; msg = \"line1\\nline2\"}",
        ];
        for t in texts {
            let v = Yson::parse(t).unwrap();
            let printed = v.to_string();
            let reparsed = Yson::parse(&printed)
                .unwrap_or_else(|e| panic!("re-parse of {printed:?} failed: {e}"));
            assert_eq!(v, reparsed, "roundtrip mismatch for {t}");
        }
    }

    #[test]
    fn wrong_type_errors() {
        let v = Yson::parse("{a = 1}").unwrap();
        assert!(matches!(v.get("a").unwrap().as_str(), Err(YsonError::WrongType(..))));
        assert!(matches!(v.get("zzz"), Err(YsonError::MissingKey(_))));
        assert!(matches!(Yson::Int(-1).as_u64(), Err(YsonError::WrongType(..))));
        assert_eq!(Yson::Int(3).as_f64().unwrap(), 3.0);
    }
}
