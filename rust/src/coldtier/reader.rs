//! Unified backfill input: a bounded historical range served from cold
//! chunks, then a seamless cutover to live tailing at a fenced row index.
//!
//! [`ColdInput`] pairs a [`ColdStore`] with the live ordered table it was
//! compacted from, plus one **cutover fence** per partition: rows below
//! the fence are served from cold chunks (manifest scan → chunk read,
//! verified against the content hash), rows at or above it from the live
//! table. The fence is chosen at launch (typically the live table's low
//! water mark — everything below it has been trimmed into cold), so the
//! two ranges tile the stream with no gap and no overlap.
//!
//! [`ColdReader`] is an ordinary [`PartitionReader`], so the mapper's
//! ingestion loop, event-time tracking, and checkpointed
//! `input_unread_row_index` work unchanged over history: a read never
//! crosses a chunk boundary or the fence, which makes the mapper's
//! persisted cursor a **per-chunk checkpoint** — a rerun after a kill
//! re-reads at most one chunk. `trim` is a total no-op: a backfill
//! consumer does not own the source, so it can neither delete live rows
//! that other consumers still need nor (by construction) the immutable
//! chunks themselves.
//!
//! Watermarks during backfill need no special path: cold rows carry the
//! same payloads they had live, so the mapper re-derives event times row
//! by row as chunks drain — the chunk manifest's event-time range is the
//! planner/audit view of the same information.

use std::sync::Arc;

use crate::metrics::hub::{names, MetricsHub};
use crate::queue::ordered_table::{OrderedTable, OrderedTableReader};
use crate::queue::{ContinuationToken, PartitionReader, QueueError, ReadBatch};
use crate::rows::{NameTable, UnversionedRowset};

use super::store::{ChunkError, ChunkMeta, ColdStore};

/// A bounded historical range over cold chunks that cuts over to live
/// tailing at `fences[partition]`. Wrapped in
/// [`crate::coordinator::InputSpec::BoundedRange`].
#[derive(Debug)]
pub struct ColdInput {
    cold: Arc<ColdStore>,
    live: Arc<OrderedTable>,
    fences: Vec<i64>,
    metrics: Option<Arc<MetricsHub>>,
}

impl ColdInput {
    pub fn new(
        cold: Arc<ColdStore>,
        live: Arc<OrderedTable>,
        fences: Vec<i64>,
        metrics: Option<Arc<MetricsHub>>,
    ) -> Arc<ColdInput> {
        Arc::new(ColdInput {
            cold,
            live,
            fences,
            metrics,
        })
    }

    /// Fence each partition at the live table's current low water mark:
    /// exactly the rows already trimmed (and therefore compacted into
    /// cold) are backfilled; everything still retained is tailed live.
    pub fn at_low_water_marks(
        cold: Arc<ColdStore>,
        live: Arc<OrderedTable>,
        metrics: Option<Arc<MetricsHub>>,
    ) -> Arc<ColdInput> {
        let fences = live.low_water_marks();
        ColdInput::new(cold, live, fences, metrics)
    }

    pub fn cold(&self) -> &Arc<ColdStore> {
        &self.cold
    }

    pub fn live(&self) -> &Arc<OrderedTable> {
        &self.live
    }

    pub fn partition_count(&self) -> usize {
        self.live.tablet_count()
    }

    pub fn name_table(&self) -> Arc<NameTable> {
        self.live.name_table()
    }

    pub fn retained_rows(&self) -> usize {
        self.live.retained_rows()
    }

    pub fn fences(&self) -> &[i64] {
        &self.fences
    }

    pub fn fence(&self, partition: usize) -> i64 {
        self.fences.get(partition).copied().unwrap_or(0)
    }

    pub fn reader(self: &Arc<Self>, partition: usize) -> ColdReader {
        ColdReader {
            input: self.clone(),
            partition,
            live: self.live.reader(partition),
            cached: None,
        }
    }
}

/// [`PartitionReader`] over one partition of a [`ColdInput`].
pub struct ColdReader {
    input: Arc<ColdInput>,
    partition: usize,
    live: OrderedTableReader,
    /// Last decoded chunk `(chunk_id, rows)` — consecutive reads inside
    /// one chunk decode it once, so the chunk-bytes-moved metric counts
    /// each chunk fetch exactly once per visit.
    cached: Option<(i64, UnversionedRowset)>,
}

impl ColdReader {
    fn fetch_chunk(&mut self, meta: &ChunkMeta) -> Result<(), QueueError> {
        if matches!(&self.cached, Some((id, _)) if *id == meta.chunk_id) {
            return Ok(());
        }
        let rows = self.input.cold.read_chunk(meta).map_err(|e| match e {
            ChunkError::Store(_) => QueueError::Unavailable(self.partition),
            other => QueueError::BadToken(format!(
                "cold chunk {}/{} unreadable: {other}",
                self.partition, meta.chunk_id
            )),
        })?;
        if let Some(m) = &self.input.metrics {
            m.add(names::COLD_CHUNK_BYTES_READ, meta.bytes as u64);
        }
        self.cached = Some((meta.chunk_id, rows));
        Ok(())
    }
}

impl PartitionReader for ColdReader {
    fn read(
        &mut self,
        begin_row_index: i64,
        end_row_index: i64,
        token: &ContinuationToken,
    ) -> Result<ReadBatch, QueueError> {
        let fence = self.input.fence(self.partition);
        if begin_row_index >= fence {
            // Live tailing past the cutover fence.
            let mut batch = self.live.read(begin_row_index, end_row_index, token)?;
            if let Some(m) = &self.input.metrics {
                m.add(
                    names::COLD_LIVE_BYTES_READ,
                    batch.rowset.byte_size() as u64,
                );
            }
            batch.next_token = ContinuationToken("live".to_string());
            return Ok(batch);
        }

        // Historical range: serve from the chunk containing
        // `begin_row_index`, never crossing the chunk end or the fence.
        let end = end_row_index.min(fence);
        let chunks = self
            .input
            .cold
            .segment_chunks(self.partition)
            .map_err(|_| QueueError::Unavailable(self.partition))?;
        let Some(meta) = chunks
            .iter()
            .find(|m| m.begin_row <= begin_row_index && begin_row_index < m.end_row)
            .cloned()
        else {
            return match chunks.first() {
                Some(first) if begin_row_index < first.begin_row => Err(QueueError::Trimmed {
                    partition: self.partition,
                    requested: begin_row_index,
                    first_available: first.begin_row,
                }),
                // Gap between the last chunk and the fence (rows trimmed
                // but not compacted never happen on the cold path; this is
                // the "cold tier enabled late" case): fall through to the
                // live table, which still errors Trimmed if they are gone.
                _ => self.live.read(begin_row_index, end, token),
            };
        };
        self.fetch_chunk(&meta)?;
        // protolint: allow(panic, "fetch_chunk returned Ok on the line above, whose postcondition is self.cached = Some for this chunk")
        let (_, rows) = self.cached.as_ref().expect("chunk cached by fetch_chunk");
        let lo = (begin_row_index - meta.begin_row) as usize;
        let hi = (end.min(meta.end_row) - meta.begin_row) as usize;
        let slice = UnversionedRowset::new(rows.name_table().clone(), rows.rows()[lo..hi].to_vec());
        Ok(ReadBatch {
            rowset: slice,
            next_token: ContinuationToken(format!("cold:{}", meta.chunk_id)),
        })
    }

    fn trim(&mut self, _row_index: i64, _token: &ContinuationToken) -> Result<(), QueueError> {
        // A backfill consumer never owns the source: live rows may feed
        // other consumers, cold chunks are immutable. Total no-op.
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dyntable::DynTableStore;
    use crate::queue::input_name_table;
    use crate::row;
    use crate::rows::RowsetBuilder;
    use crate::storage::WriteAccounting;

    use crate::coldtier::store::KIND_SEGMENT;

    /// Build a 1-partition world: rows 0..12 compacted into two cold
    /// chunks (0..5, 5..12), live table trimmed to 12 and extended to 16.
    fn world() -> (Arc<DynTableStore>, Arc<ColdInput>) {
        let accounting = WriteAccounting::new();
        let store = DynTableStore::new(accounting.clone());
        let cold = ColdStore::new(store.clone(), "//sys/cold/r");
        cold.ensure_tables(None).unwrap();
        let live = OrderedTable::new("//input/r", input_name_table(), 1, accounting);

        let payload = |i: i64| row![format!("row {i}"), 10_000 + i];
        live.append(0, (0..16).map(payload).collect()).unwrap();
        for (chunk, range) in [(0i64, 0..5i64), (5, 5..12)] {
            let mut b = RowsetBuilder::new(input_name_table());
            for i in range.clone() {
                b.push(payload(i));
            }
            let mut txn = store.begin();
            cold.compact_into(
                &mut txn,
                0,
                KIND_SEGMENT,
                chunk,
                range.start,
                &b.build(),
                Some(1),
                None,
            )
            .unwrap();
            txn.commit().unwrap();
        }
        live.trim_tablet(0, 12).unwrap();
        let input = ColdInput::new(cold, live, vec![12], None);
        (store, input)
    }

    fn read_all(input: &Arc<ColdInput>) -> Vec<String> {
        let mut reader = input.reader(0);
        let mut out = Vec::new();
        let mut at = 0i64;
        let mut token = ContinuationToken::initial();
        while at < 16 {
            let batch = reader.read(at, at + 4, &token).unwrap();
            assert!(!batch.rowset.is_empty(), "stuck at {at}");
            for r in batch.rowset.rows() {
                out.push(r.get(0).unwrap().as_str().unwrap().to_string());
            }
            at += batch.rowset.len() as i64;
            token = batch.next_token;
        }
        out
    }

    #[test]
    fn backfill_then_cutover_reads_every_row_once() {
        let (_store, input) = world();
        let got = read_all(&input);
        let want: Vec<String> = (0..16).map(|i| format!("row {i}")).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn reads_never_cross_chunk_or_fence() {
        let (_store, input) = world();
        let mut reader = input.reader(0);
        let t = ContinuationToken::initial();
        // A wide read starting in chunk 0 stops at the chunk boundary…
        let b = reader.read(3, 16, &t).unwrap();
        assert_eq!(b.rowset.len(), 2); // rows 3..5
        assert_eq!(b.next_token.0, "cold:0");
        // …one starting in chunk 1 stops at the fence…
        let b = reader.read(10, 16, &t).unwrap();
        assert_eq!(b.rowset.len(), 2); // rows 10..12
        assert_eq!(b.next_token.0, "cold:5");
        // …and at the fence the live table takes over.
        let b = reader.read(12, 16, &t).unwrap();
        assert_eq!(b.rowset.len(), 4);
        assert_eq!(b.next_token.0, "live");
    }

    #[test]
    fn trim_is_a_no_op() {
        let (_store, input) = world();
        let mut reader = input.reader(0);
        reader
            .trim(16, &ContinuationToken::initial())
            .expect("no-op trim");
        // The live tail (and the cold chunks) are still fully readable.
        assert_eq!(read_all(&input).len(), 16);
        assert_eq!(input.live().first_index(0), 12);
    }
}
