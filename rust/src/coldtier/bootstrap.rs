//! Reshard bootstrap from cold: rebuild a windowed stage's state when the
//! migration handoff is empty because the exporter is gone (retired fleet
//! crashed past recovery, state tables dropped, or a brand-new consumer
//! adopting day-N state).
//!
//! The split of responsibilities mirrors what the cold tier stores:
//!
//! * **Open-window accumulators** are rebuilt by *re-draining* the cold
//!   segment chunks through [`crate::coordinator::InputSpec::BoundedRange`]
//!   — the normal fold path over history, no special rehydration code.
//! * **The fired-watermark marker** cannot be re-derived that way: without
//!   it, re-drained rows of already-fired windows would re-open and
//!   re-fire them, duplicating output. [`ColdWindowBootstrap`] restores it
//!   from the *history* chunks — each fired-window GC pass wrote one chunk
//!   whose `chunk_id` is the fire watermark, so the max history `chunk_id`
//!   is exactly the last fired watermark — inside the same bootstrap
//!   transaction the import runs in.
//!
//! When the handoff does contain rows, this importer is transparent: it
//! delegates to the ordinary [`WindowResidualImporter`] wholesale, so a
//! healthy reshard is bit-for-bit unchanged.

use std::sync::Arc;

use crate::dyntable::{Transaction, TxnError};
use crate::eventtime::migrate::WindowMigrators;
use crate::eventtime::windowed::{
    ensure_window_state_table, restore_fired_marker, window_state_table,
};
use crate::reshard::migration::{ImportCtx, ResidualImporter};
use crate::rows::UnversionedRow;

use super::store::ColdStore;

/// A [`ResidualImporter`] that falls back to the cold tier's fired-window
/// history when the migration handoff arrives empty.
pub struct ColdWindowBootstrap {
    migrators: Arc<WindowMigrators>,
    inner: Arc<dyn ResidualImporter>,
    cold: Arc<ColdStore>,
}

impl ColdWindowBootstrap {
    pub fn new(migrators: Arc<WindowMigrators>, cold: Arc<ColdStore>) -> Arc<ColdWindowBootstrap> {
        let (_, inner) = migrators.pair();
        Arc::new(ColdWindowBootstrap {
            migrators,
            inner,
            cold,
        })
    }

    /// Last fired watermark recorded in the cold tier (`None` when no
    /// window ever fired with history compaction on).
    pub fn fired_watermark_from_cold(&self) -> Option<i64> {
        self.cold
            .history_chunks()
            .ok()?
            .iter()
            .map(|m| m.chunk_id)
            .max()
    }
}

impl ResidualImporter for ColdWindowBootstrap {
    fn import(
        &self,
        ctx: &ImportCtx,
        rows: &[UnversionedRow],
        txn: &mut Transaction,
    ) -> Result<(), TxnError> {
        if !rows.is_empty() {
            return self.inner.import(ctx, rows, txn);
        }
        let Some(wm) = self.fired_watermark_from_cold() else {
            return Ok(()); // no handoff, no history: genuinely day-zero
        };
        let m = &self.migrators;
        let table = window_state_table(&m.state_base, ctx.epoch);
        ensure_window_state_table(&m.store, &table, m.scope.clone())
            .map_err(TxnError::NoSuchTable)?;
        restore_fired_marker(txn, &table, ctx.new_index, wm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::partitioning;
    use crate::coldtier::store::KIND_HISTORY;
    use crate::dyntable::DynTableStore;
    use crate::eventtime::migrate::KIND_WINDOW_STATE;
    use crate::eventtime::windowed::{WindowFold, MARKER_WINDOW};
    use crate::row;
    use crate::rows::{NameTable, RowsetBuilder, Value};
    use crate::storage::WriteAccounting;
    use crate::util::yson::Yson;

    const BASE: &str = "//sys/cb/window_state";

    struct CountFold;
    impl WindowFold for CountFold {
        fn event_ts(&self, _row: &UnversionedRow) -> Option<i64> {
            None
        }
        fn key(&self, _row: &UnversionedRow) -> Option<String> {
            None
        }
        fn zero(&self) -> Yson {
            Yson::Int(0)
        }
        fn fold(&self, _acc: &mut Yson, _row: &UnversionedRow) {}
        fn merge(&self, into: &mut Yson, other: &Yson) {
            *into = Yson::Int(into.as_i64().unwrap_or(0) + other.as_i64().unwrap_or(0));
        }
        fn emit(
            &self,
            _w: i64,
            _e: i64,
            _k: &str,
            _a: &Yson,
            _t: &mut Transaction,
        ) -> Result<(), TxnError> {
            Ok(())
        }
    }

    fn rig() -> (Arc<DynTableStore>, Arc<ColdWindowBootstrap>, Arc<ColdStore>) {
        let store = DynTableStore::new(WriteAccounting::new());
        let cold = ColdStore::new(store.clone(), "//sys/cold/b");
        cold.ensure_tables(None).unwrap();
        let migrators = WindowMigrators::new(store.clone(), Arc::new(CountFold), BASE, None);
        let boot = ColdWindowBootstrap::new(migrators, cold.clone());
        (store, boot, cold)
    }

    fn history_chunk(store: &Arc<DynTableStore>, cold: &Arc<ColdStore>, reducer: usize, wm: i64) {
        let nt = NameTable::new(&["window_start", "win_key", "acc"]);
        let mut b = RowsetBuilder::new(nt);
        b.push(row![wm - 250, "alice", "{}"]);
        let mut txn = store.begin();
        cold.compact_into(&mut txn, reducer, KIND_HISTORY, wm, 0, &b.build(), Some(0), Some(1))
            .unwrap();
        txn.commit().unwrap();
    }

    #[test]
    fn empty_handoff_restores_fired_marker_from_history() {
        let (store, boot, cold) = rig();
        history_chunk(&store, &cold, 0, 500);
        history_chunk(&store, &cold, 1, 750);

        let ctx = ImportCtx {
            new_index: 0,
            new_partitions: 2,
            epoch: 1,
        };
        let mut txn = store.begin();
        boot.import(&ctx, &[], &mut txn).unwrap();
        txn.commit().unwrap();

        // Marker = max fire watermark across all reducers' history.
        let table = window_state_table(BASE, 1);
        let rows = store.scan(&table).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get(0).unwrap().as_i64(), Some(MARKER_WINDOW));
        assert_eq!(
            Yson::parse(rows[0].get(2).unwrap().as_str().unwrap())
                .unwrap()
                .as_i64()
                .unwrap(),
            750
        );
    }

    #[test]
    fn empty_handoff_without_history_is_day_zero() {
        let (store, boot, _cold) = rig();
        let ctx = ImportCtx {
            new_index: 0,
            new_partitions: 1,
            epoch: 1,
        };
        let mut txn = store.begin();
        boot.import(&ctx, &[], &mut txn).unwrap();
        txn.commit().unwrap();
        assert!(store.scan(&window_state_table(BASE, 1)).is_err());
    }

    #[test]
    fn non_empty_handoff_delegates_to_the_normal_importer() {
        let (store, boot, cold) = rig();
        // History exists, but the handoff wins: healthy reshards are
        // unchanged by the cold tier.
        history_chunk(&store, &cold, 0, 999_999);
        let key = "alice";
        let owner = partitioning::hash_partition(key, 1);
        let ctx = ImportCtx {
            new_index: owner,
            new_partitions: 1,
            epoch: 2,
        };
        let payload = Yson::map(vec![
            ("w", Yson::Int(0)),
            ("k", Yson::str(key)),
            ("a", Yson::str(&Yson::Int(4).to_string())),
        ])
        .to_string();
        let rows = vec![UnversionedRow::new(vec![
            Value::Int64(0),
            Value::from(KIND_WINDOW_STATE),
            Value::from(payload.as_str()),
        ])];
        let mut txn = store.begin();
        boot.import(&ctx, &rows, &mut txn).unwrap();
        txn.commit().unwrap();
        let out = store.scan(&window_state_table(BASE, 2)).unwrap();
        // One state row, no marker from history (delegation path).
        assert_eq!(out.len(), 1);
        assert_ne!(out[0].get(0).unwrap().as_i64(), Some(MARKER_WINDOW));
    }
}
