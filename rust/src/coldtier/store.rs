//! Chunk compaction: trimmed segments and fired-window history become
//! immutable, columnar cold chunks.
//!
//! A chunk is a [`RowBatch`]-encoded blob plus a manifest row describing
//! it (kind, row-index range, event-time range, key range, content hash,
//! encoded size). Both rows are written **inside the caller's
//! transaction** — the same CAS that advances mapper trim state or the
//! reducer's fired-window marker — so a chunk becomes visible if and only
//! if the state advance that produced it commits. Twins lose the CAS race
//! and their chunk writes abort with the rest of the transaction; reruns
//! recompute byte-identical chunks (compaction is a pure function of the
//! segment) and skip the write when the manifest row already exists.
//!
//! The dyntable cell model is UTF-8 (`ByteStr`), so the binary chunk
//! payload is **hex-encoded** into its payload row. This doubles the
//! journaled `ColdTier` bytes relative to the raw encoding — an honest
//! cost of keeping chunk writes fully transactional in this store; the
//! manifest `bytes` column records the raw encoded length, which is what
//! a backfill read actually moves.

use std::sync::Arc;

use crate::dyntable::store::StoreError;
use crate::dyntable::{DynTableStore, Transaction, TxnError};
use crate::row;
use crate::rows::{
    ColumnSchema, ColumnType, RowBatch, TableSchema, UnversionedRow, UnversionedRowset, Value,
};
use crate::storage::WriteCategory;

/// Chunk kind for trimmed ordered-table segments (mapper trim path).
pub const KIND_SEGMENT: &str = "segment";
/// Chunk kind for fired-window history (windowed-reducer GC path).
pub const KIND_HISTORY: &str = "history";

/// Cold-tier configuration carried on
/// [`crate::coordinator::ProcessorConfig`]. Presence turns compact-on-trim
/// on; `base` roots the manifest and payload tables.
#[derive(Debug, Clone, PartialEq)]
pub struct ColdTierConfig {
    /// Table-path root: manifest at `{base}/manifest`, payloads at
    /// `{base}/chunks`.
    pub base: String,
}

impl Default for ColdTierConfig {
    fn default() -> Self {
        ColdTierConfig {
            base: "//sys/cold".to_string(),
        }
    }
}

/// One manifest row, decoded.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkMeta {
    pub partition: i64,
    pub kind: String,
    /// Segment chunks: the begin row index (deterministic identity —
    /// continuity means `next.begin_row == prev.end_row`). History
    /// chunks: the fire watermark, so `max(chunk_id)` over history chunks
    /// is the last fired watermark — what bootstrap-from-cold restores.
    pub chunk_id: i64,
    pub begin_row: i64,
    pub end_row: i64,
    pub min_ts: i64,
    pub max_ts: i64,
    pub key_min: String,
    pub key_max: String,
    /// FNV-1a 64 over the raw encoded payload, `{:016x}`.
    pub hash: String,
    /// Raw (pre-hex) encoded payload length.
    pub bytes: i64,
}

/// Why a chunk read failed (reader + fsck).
#[derive(Debug, Clone, PartialEq)]
pub enum ChunkError {
    Store(StoreError),
    MissingPayload,
    BadHex,
    HashMismatch { want: String, got: String },
    Decode(String),
}

impl std::fmt::Display for ChunkError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ChunkError::Store(e) => write!(f, "store error: {e}"),
            ChunkError::MissingPayload => write!(f, "manifest row has no payload row"),
            ChunkError::BadHex => write!(f, "payload is not valid hex"),
            ChunkError::HashMismatch { want, got } => {
                write!(f, "content hash mismatch: manifest {want}, payload {got}")
            }
            ChunkError::Decode(e) => write!(f, "chunk decode failed: {e}"),
        }
    }
}

/// FNV-1a 64 content hash — chunk identity is a pure function of its
/// encoded bytes, so reruns and fsck recompute the same value.
pub fn content_hash(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

const HEX: &[u8; 16] = b"0123456789abcdef";

/// Lowercase hex encoding (payload cells are UTF-8 `ByteStr`s).
pub fn hex_encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(HEX[(b >> 4) as usize] as char);
        s.push(HEX[(b & 0x0f) as usize] as char);
    }
    s
}

/// Inverse of [`hex_encode`]; `None` on odd length or non-hex bytes.
pub fn hex_decode(s: &str) -> Option<Vec<u8>> {
    let b = s.as_bytes();
    if b.len() % 2 != 0 {
        return None;
    }
    let nib = |c: u8| -> Option<u8> {
        match c {
            b'0'..=b'9' => Some(c - b'0'),
            b'a'..=b'f' => Some(c - b'a' + 10),
            b'A'..=b'F' => Some(c - b'A' + 10),
            _ => None,
        }
    };
    let mut out = Vec::with_capacity(b.len() / 2);
    for pair in b.chunks_exact(2) {
        out.push(nib(pair[0])? << 4 | nib(pair[1])?);
    }
    Some(out)
}

/// The cold tier over one dyntable store: a manifest table plus a payload
/// table, both accounted under [`WriteCategory::ColdTier`].
#[derive(Debug)]
pub struct ColdStore {
    store: Arc<DynTableStore>,
    base: String,
}

fn manifest_schema() -> TableSchema {
    TableSchema::new(vec![
        ColumnSchema::key("partition", ColumnType::Int64),
        ColumnSchema::key("kind", ColumnType::Str),
        ColumnSchema::key("chunk_id", ColumnType::Int64),
        ColumnSchema::value("begin_row", ColumnType::Int64),
        ColumnSchema::value("end_row", ColumnType::Int64),
        ColumnSchema::value("min_ts", ColumnType::Int64),
        ColumnSchema::value("max_ts", ColumnType::Int64),
        ColumnSchema::value("key_min", ColumnType::Str),
        ColumnSchema::value("key_max", ColumnType::Str),
        ColumnSchema::value("hash", ColumnType::Str),
        ColumnSchema::value("bytes", ColumnType::Int64),
    ])
}

fn payload_schema() -> TableSchema {
    TableSchema::new(vec![
        ColumnSchema::key("partition", ColumnType::Int64),
        ColumnSchema::key("kind", ColumnType::Str),
        ColumnSchema::key("chunk_id", ColumnType::Int64),
        ColumnSchema::value("payload", ColumnType::Str),
    ])
}

impl ColdStore {
    pub fn new(store: Arc<DynTableStore>, base: &str) -> Arc<ColdStore> {
        Arc::new(ColdStore {
            store,
            base: base.to_string(),
        })
    }

    pub fn from_config(store: Arc<DynTableStore>, cfg: &ColdTierConfig) -> Arc<ColdStore> {
        ColdStore::new(store, &cfg.base)
    }

    pub fn base(&self) -> &str {
        &self.base
    }

    pub fn store(&self) -> &Arc<DynTableStore> {
        &self.store
    }

    pub fn manifest_table(&self) -> String {
        format!("{}/manifest", self.base)
    }

    pub fn payload_table(&self) -> String {
        format!("{}/chunks", self.base)
    }

    /// Create both tables (idempotent).
    pub fn ensure_tables(&self, scope: Option<String>) -> Result<(), StoreError> {
        for (path, schema) in [
            (self.manifest_table(), manifest_schema()),
            (self.payload_table(), payload_schema()),
        ] {
            match self.store.create_table_scoped(
                &path,
                schema,
                WriteCategory::ColdTier,
                scope.clone(),
            ) {
                Ok(_) | Err(StoreError::AlreadyExists(_)) => {}
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Compact `rowset` into one immutable chunk inside `txn`.
    ///
    /// `begin_row` is the absolute row index of the first row for segment
    /// chunks (0 for history chunks, where `end_row` is just the row
    /// count). `ts_col`/`key_col` select the columns whose min/max become
    /// the manifest's event-time and key ranges; when absent the range is
    /// recorded empty (`min_ts=0, max_ts=-1` / empty strings).
    ///
    /// Idempotent: if the manifest row already exists (a rerun after a
    /// commit that died before its side effects were observed, or a twin
    /// that lost the race later), the existing meta is returned and
    /// nothing is rewritten — the lookup still joins the transaction's
    /// read set, so a concurrent writer conflicts the commit.
    #[allow(clippy::too_many_arguments)]
    pub fn compact_into(
        &self,
        txn: &mut Transaction,
        partition: usize,
        kind: &str,
        chunk_id: i64,
        begin_row: i64,
        rowset: &UnversionedRowset,
        ts_col: Option<usize>,
        key_col: Option<usize>,
    ) -> Result<ChunkMeta, TxnError> {
        let manifest = self.manifest_table();
        let key = [
            Value::Int64(partition as i64),
            Value::from(kind),
            Value::Int64(chunk_id),
        ];
        if let Some(existing) = txn.lookup(&manifest, &key)? {
            if let Some(meta) = decode_manifest_row(&existing) {
                return Ok(meta);
            }
        }

        let encoded = RowBatch::from_rowset(rowset).encode();
        let hash = format!("{:016x}", content_hash(&encoded));
        let payload_hex = hex_encode(&encoded);

        let (mut min_ts, mut max_ts) = (0i64, -1i64);
        if let Some(c) = ts_col {
            for row in rowset.rows() {
                if let Some(ts) = row.get(c).and_then(Value::as_i64) {
                    if max_ts < min_ts {
                        min_ts = ts;
                        max_ts = ts;
                    } else {
                        min_ts = min_ts.min(ts);
                        max_ts = max_ts.max(ts);
                    }
                }
            }
        }
        let (mut key_min, mut key_max) = (String::new(), String::new());
        if let Some(c) = key_col {
            for row in rowset.rows() {
                if let Some(k) = row.get(c).and_then(Value::as_str) {
                    if key_min.is_empty() || k < key_min.as_str() {
                        key_min = k.to_string();
                    }
                    if k > key_max.as_str() {
                        key_max = k.to_string();
                    }
                }
            }
        }

        let meta = ChunkMeta {
            partition: partition as i64,
            kind: kind.to_string(),
            chunk_id,
            begin_row,
            end_row: begin_row + rowset.rows().len() as i64,
            min_ts,
            max_ts,
            key_min,
            key_max,
            hash,
            bytes: encoded.len() as i64,
        };
        txn.write(
            &manifest,
            row![
                meta.partition,
                meta.kind.clone(),
                meta.chunk_id,
                meta.begin_row,
                meta.end_row,
                meta.min_ts,
                meta.max_ts,
                meta.key_min.clone(),
                meta.key_max.clone(),
                meta.hash.clone(),
                meta.bytes
            ],
        )?;
        txn.write(
            &self.payload_table(),
            row![meta.partition, meta.kind.clone(), meta.chunk_id, payload_hex],
        )?;
        Ok(meta)
    }

    /// Every manifest row, key order (partition, kind, chunk_id).
    pub fn manifest_scan(&self) -> Result<Vec<ChunkMeta>, StoreError> {
        let rows = self.store.scan(&self.manifest_table())?;
        Ok(rows.iter().filter_map(decode_manifest_row).collect())
    }

    /// Segment chunks of one partition, ascending chunk id.
    pub fn segment_chunks(&self, partition: usize) -> Result<Vec<ChunkMeta>, StoreError> {
        Ok(self
            .manifest_scan()?
            .into_iter()
            .filter(|m| m.partition == partition as i64 && m.kind == KIND_SEGMENT)
            .collect())
    }

    /// History chunks across all partitions, ascending (partition, id).
    pub fn history_chunks(&self) -> Result<Vec<ChunkMeta>, StoreError> {
        Ok(self
            .manifest_scan()?
            .into_iter()
            .filter(|m| m.kind == KIND_HISTORY)
            .collect())
    }

    /// Fetch + verify + decode one chunk back into rows.
    pub fn read_chunk(&self, meta: &ChunkMeta) -> Result<UnversionedRowset, ChunkError> {
        let key = [
            Value::Int64(meta.partition),
            Value::from(meta.kind.as_str()),
            Value::Int64(meta.chunk_id),
        ];
        let row = self
            .store
            .lookup(&self.payload_table(), &key)
            .map_err(ChunkError::Store)?
            .ok_or(ChunkError::MissingPayload)?;
        let hex = row
            .get(3)
            .and_then(Value::as_str)
            .ok_or(ChunkError::MissingPayload)?;
        let raw = hex_decode(hex).ok_or(ChunkError::BadHex)?;
        let got = format!("{:016x}", content_hash(&raw));
        if got != meta.hash {
            return Err(ChunkError::HashMismatch {
                want: meta.hash.clone(),
                got,
            });
        }
        let shared: Arc<[u8]> = raw.into();
        let batch = RowBatch::decode_shared(&shared).map_err(|e| ChunkError::Decode(e.to_string()))?;
        Ok(batch.to_rowset())
    }
}

/// Decode a manifest row; `None` on shape mismatch.
pub fn decode_manifest_row(row: &UnversionedRow) -> Option<ChunkMeta> {
    Some(ChunkMeta {
        partition: row.get(0)?.as_i64()?,
        kind: row.get(1)?.as_str()?.to_string(),
        chunk_id: row.get(2)?.as_i64()?,
        begin_row: row.get(3)?.as_i64()?,
        end_row: row.get(4)?.as_i64()?,
        min_ts: row.get(5)?.as_i64()?,
        max_ts: row.get(6)?.as_i64()?,
        key_min: row.get(7)?.as_str()?.to_string(),
        key_max: row.get(8)?.as_str()?.to_string(),
        hash: row.get(9)?.as_str()?.to_string(),
        bytes: row.get(10)?.as_i64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queue::input_name_table;
    use crate::rows::RowsetBuilder;
    use crate::storage::WriteAccounting;

    fn test_store() -> Arc<DynTableStore> {
        DynTableStore::new(WriteAccounting::new())
    }

    fn sample_rowset(n: usize, salt: i64) -> UnversionedRowset {
        let mut b = RowsetBuilder::new(input_name_table());
        for i in 0..n {
            b.push(row![
                format!("line {} salt {}", i, salt),
                1_000 + salt + i as i64
            ]);
        }
        b.build()
    }

    #[test]
    fn hex_roundtrip() {
        let data: Vec<u8> = (0..=255u8).collect();
        let hex = hex_encode(&data);
        assert_eq!(hex.len(), 512);
        assert_eq!(hex_decode(&hex).unwrap(), data);
        assert!(hex_decode("0g").is_none());
        assert!(hex_decode("abc").is_none());
    }

    #[test]
    fn content_hash_is_stable() {
        // Pinned FNV-1a 64 vectors — the manifest hash must never drift
        // across refactors or old chunks become unreadable.
        assert_eq!(content_hash(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(content_hash(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(content_hash(b"ab"), content_hash(b"ba"));
    }

    #[test]
    fn compact_roundtrip_and_ranges() {
        let store = test_store();
        let cold = ColdStore::new(store.clone(), "//sys/cold/t");
        cold.ensure_tables(None).unwrap();
        let rs = sample_rowset(8, 7);

        let mut txn = store.begin();
        let meta = cold
            .compact_into(&mut txn, 2, KIND_SEGMENT, 100, 100, &rs, Some(1), None)
            .unwrap();
        txn.commit().unwrap();

        assert_eq!(meta.begin_row, 100);
        assert_eq!(meta.end_row, 108);
        assert_eq!(meta.min_ts, 1_007);
        assert_eq!(meta.max_ts, 1_014);
        assert_eq!(meta.hash.len(), 16);

        let metas = cold.segment_chunks(2).unwrap();
        assert_eq!(metas, vec![meta.clone()]);
        let back = cold.read_chunk(&meta).unwrap();
        assert_eq!(back.rows(), rs.rows());
    }

    #[test]
    fn compaction_is_deterministic_and_idempotent() {
        // Same trimmed segment ⇒ byte-identical chunk + hash, across
        // independent stores; a rerun over an existing manifest row is a
        // no-op that returns the committed meta.
        let rs = sample_rowset(16, 3);
        let mut metas = Vec::new();
        for _ in 0..2 {
            let store = test_store();
            let cold = ColdStore::new(store.clone(), "//sys/cold/d");
            cold.ensure_tables(None).unwrap();
            let mut txn = store.begin();
            let meta = cold
                .compact_into(&mut txn, 0, KIND_SEGMENT, 0, 0, &rs, Some(1), None)
                .unwrap();
            txn.commit().unwrap();
            // Rerun: same identity, nothing rewritten.
            let mut txn = store.begin();
            let again = cold
                .compact_into(&mut txn, 0, KIND_SEGMENT, 0, 0, &rs, Some(1), None)
                .unwrap();
            txn.commit().unwrap();
            assert_eq!(again, meta);
            metas.push(meta);
        }
        assert_eq!(metas[0], metas[1]);
    }

    #[test]
    fn read_chunk_detects_corruption() {
        let store = test_store();
        let cold = ColdStore::new(store.clone(), "//sys/cold/c");
        cold.ensure_tables(None).unwrap();
        let rs = sample_rowset(4, 1);
        let mut txn = store.begin();
        let meta = cold
            .compact_into(&mut txn, 0, KIND_SEGMENT, 0, 0, &rs, None, None)
            .unwrap();
        txn.commit().unwrap();

        // Flip one payload byte.
        let mut txn = store.begin();
        let corrupt = hex_encode(&{
            let row = store
                .lookup(&cold.payload_table(), &[
                    Value::Int64(0),
                    Value::from(KIND_SEGMENT),
                    Value::Int64(0),
                ])
                .unwrap()
                .unwrap();
            let mut raw = hex_decode(row.get(3).unwrap().as_str().unwrap()).unwrap();
            raw[0] ^= 0xff;
            raw
        });
        txn.write(
            &cold.payload_table(),
            row![0i64, KIND_SEGMENT, 0i64, corrupt],
        )
        .unwrap();
        txn.commit().unwrap();

        assert!(matches!(
            cold.read_chunk(&meta),
            Err(ChunkError::HashMismatch { .. })
        ));
    }
}
