//! Manifest fsck: offline verification that the cold tier is internally
//! consistent — every manifest row has a payload whose content hash and
//! row count match, and segment chunks tile each partition's row-index
//! space with no gap and no overlap.

use std::fmt;
use std::sync::Arc;

use crate::dyntable::store::StoreError;
use crate::dyntable::DynTableStore;

use super::store::{ChunkError, ColdStore, KIND_HISTORY, KIND_SEGMENT};

/// Summary of a clean fsck pass.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FsckReport {
    pub chunks: usize,
    pub segment_chunks: usize,
    pub history_chunks: usize,
    /// Sum of raw (pre-hex) encoded chunk bytes.
    pub payload_bytes: u64,
}

impl fmt::Display for FsckReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "fsck ok: {} chunks ({} segment, {} history), {} payload bytes",
            self.chunks, self.segment_chunks, self.history_chunks, self.payload_bytes
        )
    }
}

/// First inconsistency found (fsck stops at the first error so the exit
/// status is unambiguous).
#[derive(Debug, Clone, PartialEq)]
pub enum FsckError {
    Store(StoreError),
    /// Payload missing / corrupt / hash-mismatched for one chunk.
    Chunk {
        partition: i64,
        kind: String,
        chunk_id: i64,
        error: ChunkError,
    },
    /// Decoded row count disagrees with the manifest row-index range.
    RowCountMismatch {
        partition: i64,
        kind: String,
        chunk_id: i64,
        manifest_rows: i64,
        decoded_rows: i64,
    },
    /// Segment chunks do not tile the partition contiguously.
    Discontinuity {
        partition: i64,
        expected_begin: i64,
        got_begin: i64,
    },
}

impl fmt::Display for FsckError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FsckError::Store(e) => write!(f, "fsck: store error: {e}"),
            FsckError::Chunk {
                partition,
                kind,
                chunk_id,
                error,
            } => write!(f, "fsck: chunk {partition}/{kind}/{chunk_id}: {error}"),
            FsckError::RowCountMismatch {
                partition,
                kind,
                chunk_id,
                manifest_rows,
                decoded_rows,
            } => write!(
                f,
                "fsck: chunk {partition}/{kind}/{chunk_id}: manifest claims {manifest_rows} rows, payload decodes to {decoded_rows}"
            ),
            FsckError::Discontinuity {
                partition,
                expected_begin,
                got_begin,
            } => write!(
                f,
                "fsck: partition {partition}: segment chain broken — expected next chunk to begin at row {expected_begin}, found {got_begin}"
            ),
        }
    }
}

/// Verify every chunk under `base` (hash, decodability, row counts) and
/// the per-partition continuity of the segment chain.
pub fn fsck(store: &Arc<DynTableStore>, base: &str) -> Result<FsckReport, FsckError> {
    let cold = ColdStore::new(store.clone(), base);
    let metas = cold.manifest_scan().map_err(FsckError::Store)?;
    let mut report = FsckReport::default();
    let mut prev_segment: Option<(i64, i64)> = None; // (partition, end_row)

    for meta in &metas {
        let rows = cold.read_chunk(meta).map_err(|error| FsckError::Chunk {
            partition: meta.partition,
            kind: meta.kind.clone(),
            chunk_id: meta.chunk_id,
            error,
        })?;
        let manifest_rows = meta.end_row - meta.begin_row;
        if rows.len() as i64 != manifest_rows {
            return Err(FsckError::RowCountMismatch {
                partition: meta.partition,
                kind: meta.kind.clone(),
                chunk_id: meta.chunk_id,
                manifest_rows,
                decoded_rows: rows.len() as i64,
            });
        }
        report.chunks += 1;
        report.payload_bytes += meta.bytes as u64;
        match meta.kind.as_str() {
            KIND_SEGMENT => {
                // Manifest scan is key-ordered (partition, kind, chunk_id)
                // and segment chunk_id == begin_row, so each partition's
                // segments arrive in begin order: the chain is continuous
                // iff each begins where the previous ended.
                if let Some((p, end)) = prev_segment {
                    if p == meta.partition && meta.begin_row != end {
                        return Err(FsckError::Discontinuity {
                            partition: meta.partition,
                            expected_begin: end,
                            got_begin: meta.begin_row,
                        });
                    }
                }
                prev_segment = Some((meta.partition, meta.end_row));
                report.segment_chunks += 1;
            }
            KIND_HISTORY => report.history_chunks += 1,
            _ => {}
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queue::input_name_table;
    use crate::row;
    use crate::rows::RowsetBuilder;
    use crate::storage::WriteAccounting;

    fn chunked_store(ranges: &[(i64, i64)]) -> (Arc<DynTableStore>, Arc<ColdStore>) {
        let store = DynTableStore::new(WriteAccounting::new());
        let cold = ColdStore::new(store.clone(), "//sys/cold/f");
        cold.ensure_tables(None).unwrap();
        for &(begin, end) in ranges {
            let mut b = RowsetBuilder::new(input_name_table());
            for i in begin..end {
                b.push(row![format!("r{i}"), i]);
            }
            let mut txn = store.begin();
            cold.compact_into(&mut txn, 0, KIND_SEGMENT, begin, begin, &b.build(), Some(1), None)
                .unwrap();
            txn.commit().unwrap();
        }
        (store, cold)
    }

    #[test]
    fn clean_chain_passes() {
        let (store, _cold) = chunked_store(&[(0, 4), (4, 9), (9, 10)]);
        let report = fsck(&store, "//sys/cold/f").unwrap();
        assert_eq!(report.chunks, 3);
        assert_eq!(report.segment_chunks, 3);
        assert!(report.payload_bytes > 0);
    }

    #[test]
    fn gap_in_chain_is_a_discontinuity() {
        let (store, _cold) = chunked_store(&[(0, 4), (6, 9)]);
        assert_eq!(
            fsck(&store, "//sys/cold/f"),
            Err(FsckError::Discontinuity {
                partition: 0,
                expected_begin: 4,
                got_begin: 6,
            })
        );
    }

    #[test]
    fn corrupted_payload_is_detected() {
        use crate::coldtier::store::hex_encode;
        let (store, cold) = chunked_store(&[(0, 4)]);
        let mut txn = store.begin();
        txn.write(
            &cold.payload_table(),
            row![0i64, KIND_SEGMENT, 0i64, hex_encode(b"not a row batch")],
        )
        .unwrap();
        txn.commit().unwrap();
        assert!(matches!(
            fsck(&store, "//sys/cold/f"),
            Err(FsckError::Chunk { .. })
        ));
    }
}
