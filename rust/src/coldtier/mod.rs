//! The cold tier: compacted history + unified backfill (DESIGN.md §3.7).
//!
//! The hot path's low-write-amplification story deletes everything it no
//! longer needs: mappers trim consumed ordered-table segments, windowed
//! reducers delete fired-window state. That makes any *new* consumer — a
//! reprocessing job, a reshard bootstrap whose exporter died, a stage
//! added to a running topology — re-ingest the source from scratch. The
//! cold tier closes that gap with three pieces:
//!
//! * [`store`] — **compact-on-trim**: the bytes a trim or fired-window GC
//!   is about to delete are first compacted into an immutable, columnar
//!   ([`crate::rows::RowBatch`]-encoded) chunk with a manifest row (kind,
//!   row-index range, event-time range, key range, content hash, size),
//!   written *inside the same exactly-once transaction* that performs the
//!   trim/fire and accounted under
//!   [`crate::storage::WriteCategory::ColdTier`].
//! * [`reader`] — **unified backfill**:
//!   [`crate::coordinator::InputSpec::BoundedRange`] drains the historical
//!   range from cold chunks (per-chunk checkpoints, hash-verified reads)
//!   and cuts over seamlessly to live tailing at a fenced row index.
//! * [`bootstrap`] + [`fsck`] — rebuild a windowed stage's fired marker
//!   from history chunks when the migration handoff is empty, and verify
//!   the whole tier offline (`yt-stream fsck`).

pub mod bootstrap;
pub mod fsck;
pub mod reader;
pub mod store;

pub use bootstrap::ColdWindowBootstrap;
pub use fsck::{fsck, FsckError, FsckReport};
pub use reader::{ColdInput, ColdReader};
pub use store::{
    content_hash, decode_manifest_row, hex_decode, hex_encode, ChunkError, ChunkMeta, ColdStore,
    ColdTierConfig, KIND_HISTORY, KIND_SEGMENT,
};
