//! The Cypress tree: nodes, attributes, sessions and ephemeral locks.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::storage::{WriteAccounting, WriteCategory};
use crate::util::yson::Yson;
use crate::util::Clock;
use crate::util;

/// A client session. Ephemeral nodes live exactly as long as their session
/// keeps heartbeating within the TTL.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SessionId(pub u64);

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum CypressError {
    #[error("node '{0}' not found")]
    NotFound(String),
    #[error("node '{0}' already exists")]
    AlreadyExists(String),
    #[error("node '{0}' is locked by another session")]
    Locked(String),
    #[error("unknown session {0:?}")]
    NoSuchSession(SessionId),
    #[error("invalid path '{0}'")]
    BadPath(String),
}

#[derive(Debug)]
struct Node {
    attributes: BTreeMap<String, Yson>,
    children: BTreeMap<String, Node>,
    /// Ephemeral nodes are removed when their owning session expires; the
    /// owning session also holds the exclusive lock on the node.
    owner: Option<SessionId>,
}

impl Node {
    fn new() -> Node {
        Node {
            attributes: BTreeMap::new(),
            children: BTreeMap::new(),
            owner: None,
        }
    }
}

#[derive(Debug)]
struct SessionState {
    last_heartbeat_ms: u64,
    ttl_ms: u64,
}

/// The shared metainformation tree.
#[derive(Debug)]
pub struct Cypress {
    root: Mutex<Node>,
    sessions: Mutex<HashMap<SessionId, SessionState>>,
    next_session: AtomicU64,
    clock: Clock,
    accounting: Arc<WriteAccounting>,
}

fn split_path(path: &str) -> Result<Vec<&str>, CypressError> {
    let stripped = path
        .strip_prefix("//")
        .ok_or_else(|| CypressError::BadPath(path.to_string()))?;
    if stripped.is_empty() {
        return Ok(Vec::new());
    }
    let parts: Vec<&str> = stripped.split('/').collect();
    if parts.iter().any(|p| p.is_empty()) {
        return Err(CypressError::BadPath(path.to_string()));
    }
    Ok(parts)
}

impl Cypress {
    pub fn new(clock: Clock, accounting: Arc<WriteAccounting>) -> Arc<Cypress> {
        Arc::new(Cypress {
            root: Mutex::new(Node::new()),
            sessions: Mutex::new(HashMap::new()),
            next_session: AtomicU64::new(1),
            clock,
            accounting,
        })
    }

    // -- sessions ----------------------------------------------------------

    /// Open a session with the given TTL. The owner must heartbeat at least
    /// every `ttl_ms` of simulated time or its ephemeral nodes vanish.
    pub fn open_session(&self, ttl_ms: u64) -> SessionId {
        let id = SessionId(self.next_session.fetch_add(1, Ordering::Relaxed));
        util::lock(&self.sessions).insert(
            id,
            SessionState {
                last_heartbeat_ms: self.clock.now_ms(),
                ttl_ms,
            },
        );
        id
    }

    pub fn heartbeat(&self, session: SessionId) -> Result<(), CypressError> {
        let mut sessions = util::lock(&self.sessions);
        let s = sessions
            .get_mut(&session)
            .ok_or(CypressError::NoSuchSession(session))?;
        s.last_heartbeat_ms = self.clock.now_ms();
        Ok(())
    }

    /// Explicitly close a session (a *clean* worker shutdown). Crashed
    /// workers never call this — their nodes linger until TTL expiry,
    /// which is the staleness window.
    pub fn close_session(&self, session: SessionId) {
        util::lock(&self.sessions).remove(&session);
        self.sweep_expired();
    }

    /// Remove ephemeral nodes whose sessions expired. Called lazily from
    /// every read path; also callable directly (tests, drills).
    pub fn sweep_expired(&self) {
        let now = self.clock.now_ms();
        let live: std::collections::HashSet<SessionId> = {
            let mut sessions = util::lock(&self.sessions);
            sessions.retain(|_, s| now.saturating_sub(s.last_heartbeat_ms) <= s.ttl_ms);
            sessions.keys().copied().collect()
        };
        let mut root = util::lock(&self.root);
        fn prune(node: &mut Node, live: &std::collections::HashSet<SessionId>) {
            node.children.retain(|_, child| {
                child.owner.map(|o| live.contains(&o)).unwrap_or(true)
            });
            for child in node.children.values_mut() {
                prune(child, live);
            }
        }
        prune(&mut root, &live);
    }

    // -- nodes -------------------------------------------------------------

    /// Create a persistent node (and missing parents).
    pub fn create(&self, path: &str) -> Result<(), CypressError> {
        self.create_inner(path, None)
    }

    /// Create an ephemeral node owned (and exclusively locked) by
    /// `session`. Fails if the node exists and is held by a *live* other
    /// session; a node whose owner expired is replaced. This is the
    /// "create and take a lock on key-named nodes" primitive of §4.5.
    pub fn create_ephemeral(&self, path: &str, session: SessionId) -> Result<(), CypressError> {
        util::lock(&self.sessions)
            .contains_key(&session)
            .then_some(())
            .ok_or(CypressError::NoSuchSession(session))?;
        self.create_inner(path, Some(session))
    }

    fn create_inner(&self, path: &str, owner: Option<SessionId>) -> Result<(), CypressError> {
        self.sweep_expired();
        let parts = split_path(path)?;
        if parts.is_empty() {
            return Err(CypressError::AlreadyExists("//".to_string()));
        }
        let bytes = path.len() as u64 + 16;
        let mut root = util::lock(&self.root);
        let mut node = &mut *root;
        for (i, part) in parts.iter().enumerate() {
            let last = i == parts.len() - 1;
            if last {
                if node.children.contains_key(*part) {
                    return Err(CypressError::AlreadyExists(path.to_string()));
                }
                let mut fresh = Node::new();
                fresh.owner = owner;
                node.children.insert(part.to_string(), fresh);
            } else {
                node = node.children.entry(part.to_string()).or_insert_with(Node::new);
            }
        }
        self.accounting.record(WriteCategory::CypressMeta, bytes);
        Ok(())
    }

    pub fn exists(&self, path: &str) -> bool {
        self.sweep_expired();
        let Ok(parts) = split_path(path) else {
            return false;
        };
        let root = util::lock(&self.root);
        let mut node = &*root;
        for part in parts {
            match node.children.get(part) {
                Some(n) => node = n,
                None => return false,
            }
        }
        true
    }

    /// Remove a node and its subtree. Only the owning session may remove an
    /// ephemeral node; persistent nodes are free for all.
    pub fn remove(&self, path: &str, session: Option<SessionId>) -> Result<(), CypressError> {
        let parts = split_path(path)?;
        if parts.is_empty() {
            return Err(CypressError::BadPath(path.to_string()));
        }
        let mut root = util::lock(&self.root);
        let mut node = &mut *root;
        for part in &parts[..parts.len() - 1] {
            node = node
                .children
                .get_mut(*part)
                .ok_or_else(|| CypressError::NotFound(path.to_string()))?;
        }
        let last = parts[parts.len() - 1];
        let target = node
            .children
            .get(last)
            .ok_or_else(|| CypressError::NotFound(path.to_string()))?;
        if let Some(owner) = target.owner {
            if session != Some(owner) {
                return Err(CypressError::Locked(path.to_string()));
            }
        }
        node.children.remove(last);
        self.accounting
            .record(WriteCategory::CypressMeta, path.len() as u64);
        Ok(())
    }

    /// List child names of a directory node (discovery's group listing).
    pub fn list(&self, path: &str) -> Result<Vec<String>, CypressError> {
        self.sweep_expired();
        let parts = split_path(path)?;
        let root = util::lock(&self.root);
        let mut node = &*root;
        for part in parts {
            node = node
                .children
                .get(part)
                .ok_or_else(|| CypressError::NotFound(path.to_string()))?;
        }
        Ok(node.children.keys().cloned().collect())
    }

    // -- attributes ---------------------------------------------------------

    pub fn set_attr(&self, path: &str, key: &str, value: Yson) -> Result<(), CypressError> {
        let parts = split_path(path)?;
        let bytes = (key.len() + value.to_string().len()) as u64;
        let mut root = util::lock(&self.root);
        let mut node = &mut *root;
        for part in parts {
            node = node
                .children
                .get_mut(part)
                .ok_or_else(|| CypressError::NotFound(path.to_string()))?;
        }
        node.attributes.insert(key.to_string(), value);
        self.accounting.record(WriteCategory::CypressMeta, bytes);
        Ok(())
    }

    pub fn get_attr(&self, path: &str, key: &str) -> Result<Option<Yson>, CypressError> {
        let parts = split_path(path)?;
        let root = util::lock(&self.root);
        let mut node = &*root;
        for part in parts {
            node = node
                .children
                .get(part)
                .ok_or_else(|| CypressError::NotFound(path.to_string()))?;
        }
        Ok(node.attributes.get(key).cloned())
    }

    pub fn attrs(&self, path: &str) -> Result<BTreeMap<String, Yson>, CypressError> {
        let parts = split_path(path)?;
        let root = util::lock(&self.root);
        let mut node = &*root;
        for part in parts {
            node = node
                .children
                .get(part)
                .ok_or_else(|| CypressError::NotFound(path.to_string()))?;
        }
        Ok(node.attributes.clone())
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cypress() -> Arc<Cypress> {
        Cypress::new(Clock::realtime(), WriteAccounting::new())
    }

    #[test]
    fn create_list_remove() {
        let c = cypress();
        c.create("//sys/discovery/mappers").unwrap();
        c.create("//sys/discovery/reducers").unwrap();
        assert!(c.exists("//sys/discovery"));
        assert_eq!(
            c.list("//sys/discovery").unwrap(),
            vec!["mappers".to_string(), "reducers".to_string()]
        );
        c.remove("//sys/discovery/mappers", None).unwrap();
        assert!(!c.exists("//sys/discovery/mappers"));
        assert!(matches!(
            c.list("//nope"),
            Err(CypressError::NotFound(_))
        ));
    }

    #[test]
    fn duplicate_create_rejected() {
        let c = cypress();
        c.create("//a/b").unwrap();
        assert!(matches!(c.create("//a/b"), Err(CypressError::AlreadyExists(_))));
    }

    #[test]
    fn bad_paths_rejected() {
        let c = cypress();
        assert!(matches!(c.create("no-slashes"), Err(CypressError::BadPath(_))));
        assert!(matches!(c.create("//a//b"), Err(CypressError::BadPath(_))));
        assert!(!c.exists("relative/path"));
    }

    #[test]
    fn attributes_roundtrip() {
        let c = cypress();
        c.create("//workers/m0").unwrap();
        c.set_attr("//workers/m0", "address", Yson::str("mapper-0.local")).unwrap();
        c.set_attr("//workers/m0", "index", Yson::Int(0)).unwrap();
        assert_eq!(
            c.get_attr("//workers/m0", "address").unwrap(),
            Some(Yson::str("mapper-0.local"))
        );
        assert_eq!(c.get_attr("//workers/m0", "missing").unwrap(), None);
        assert_eq!(c.attrs("//workers/m0").unwrap().len(), 2);
    }

    #[test]
    fn ephemeral_node_owned_and_protected() {
        let c = cypress();
        c.create("//group").unwrap();
        let s1 = c.open_session(10_000);
        let s2 = c.open_session(10_000);
        c.create_ephemeral("//group/worker-a", s1).unwrap();
        // Another session cannot remove it.
        assert!(matches!(
            c.remove("//group/worker-a", Some(s2)),
            Err(CypressError::Locked(_))
        ));
        assert!(matches!(
            c.remove("//group/worker-a", None),
            Err(CypressError::Locked(_))
        ));
        // The owner can.
        c.remove("//group/worker-a", Some(s1)).unwrap();
        assert!(!c.exists("//group/worker-a"));
    }

    #[test]
    fn session_expiry_removes_ephemeral_nodes() {
        let clock = Clock::scaled(1000); // 1ms wall = 1s simulated
        let c = Cypress::new(clock.clone(), WriteAccounting::new());
        c.create("//group").unwrap();
        let s = c.open_session(50); // 50 simulated ms TTL
        c.create_ephemeral("//group/w", s).unwrap();
        assert!(c.exists("//group/w"));
        std::thread::sleep(std::time::Duration::from_millis(5)); // ≥5000 sim ms
        c.sweep_expired();
        assert!(!c.exists("//group/w"), "expired session's node must vanish");
        assert!(matches!(c.heartbeat(s), Err(CypressError::NoSuchSession(_))));
    }

    #[test]
    fn heartbeat_keeps_session_alive() {
        let clock = Clock::scaled(100);
        let c = Cypress::new(clock.clone(), WriteAccounting::new());
        c.create("//g").unwrap();
        let s = c.open_session(500);
        c.create_ephemeral("//g/w", s).unwrap();
        for _ in 0..5 {
            std::thread::sleep(std::time::Duration::from_millis(2));
            c.heartbeat(s).unwrap();
        }
        assert!(c.exists("//g/w"));
    }

    #[test]
    fn close_session_is_clean_departure() {
        let c = cypress();
        c.create("//g").unwrap();
        let s = c.open_session(60_000);
        c.create_ephemeral("//g/w", s).unwrap();
        c.close_session(s);
        assert!(!c.exists("//g/w"));
    }

    #[test]
    fn replacement_after_expiry_can_reuse_name() {
        let clock = Clock::scaled(1000);
        let c = Cypress::new(clock.clone(), WriteAccounting::new());
        c.create("//g").unwrap();
        let old = c.open_session(10);
        c.create_ephemeral("//g/mapper-3", old).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(3));
        // Old session expired; a restarted worker re-registers.
        let fresh = c.open_session(10_000);
        c.create_ephemeral("//g/mapper-3", fresh).unwrap();
        assert!(c.exists("//g/mapper-3"));
    }

    #[test]
    fn cypress_writes_are_accounted() {
        let acc = WriteAccounting::new();
        let c = Cypress::new(Clock::realtime(), acc.clone());
        c.create("//x").unwrap();
        c.set_attr("//x", "k", Yson::Int(1)).unwrap();
        assert!(acc.bytes(WriteCategory::CypressMeta) > 0);
    }
}
