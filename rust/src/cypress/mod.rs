//! Cypress — the filesystem-like metainformation store (chapter 3).
//!
//! "Cypress, a filesystem-like metainformation store, which can also keep
//! an attribute mapping in its nodes and supports transactions and locks.
//! This allows it to be used similarly to Apache ZooKeeper."
//!
//! The reproduction provides exactly what discovery (§4.5) consumes:
//! slash-separated paths, per-node attribute maps, **ephemeral
//! session-scoped locks** with TTL expiry, and directory listing. Lock
//! expiry is swept lazily, which *naturally* produces the staleness window
//! the paper warns about: "in case of failures, or even on startup, the
//! information in these discovery groups can be stale … a failed mapper
//! and its newly-alive replacement could temporarily both appear in
//! discovery."

pub mod tree;
pub mod discovery;

pub use discovery::{DiscoveryGroup, MemberInfo};
pub use tree::{Cypress, CypressError, SessionId};
