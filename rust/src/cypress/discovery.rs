//! Worker discovery over Cypress (§4.5).
//!
//! "Participants of a discovery group create and take a lock on key-named
//! nodes in a shared Cypress directory, storing any necessary information
//! in the node's attributes. … Other clients can fetch a list of nodes in
//! this directory and retrieve the relevant attributes."
//!
//! Mappers join `<dir>/mappers` keyed by GUID with `address`, `port` and
//! `index` attributes; reducers join `<dir>/reducers` keyed by index. The
//! listing is *allowed to be stale* — the reducer main loop (§4.4.2) and
//! the `mapper_id` check in GetRows (§4.3.4) are the defences.

use std::sync::Arc;

use super::tree::{Cypress, CypressError, SessionId};
use crate::util::yson::Yson;
use crate::util::Guid;

/// One member of a discovery group, as seen by a (possibly stale) listing.
#[derive(Debug, Clone, PartialEq)]
pub struct MemberInfo {
    /// The node key: worker GUID string for mappers, index for reducers.
    pub key: String,
    /// RPC address registered by the worker.
    pub address: String,
    /// Worker index within its role.
    pub index: i64,
    /// Worker GUID.
    pub guid: Guid,
}

/// A handle for participating in / observing one discovery directory.
#[derive(Clone)]
pub struct DiscoveryGroup {
    cypress: Arc<Cypress>,
    dir: String,
}

impl DiscoveryGroup {
    /// Open (creating the directory if needed).
    pub fn open(cypress: Arc<Cypress>, dir: &str) -> Result<DiscoveryGroup, CypressError> {
        if !cypress.exists(dir) {
            // Races with other openers are benign.
            match cypress.create(dir) {
                Ok(()) | Err(CypressError::AlreadyExists(_)) => {}
                Err(e) => return Err(e),
            }
        }
        Ok(DiscoveryGroup {
            cypress,
            dir: dir.to_string(),
        })
    }

    /// Join the group: create the locked key node and publish attributes.
    /// Returns an error if a *live* holder already owns the key (e.g. a
    /// split-brain twin that has not expired yet) — callers retry after a
    /// backoff, exactly like a restarted YT job waits out its predecessor.
    pub fn join(
        &self,
        session: SessionId,
        key: &str,
        address: &str,
        index: i64,
        guid: Guid,
    ) -> Result<(), CypressError> {
        let path = format!("{}/{}", self.dir, key);
        self.cypress.create_ephemeral(&path, session)?;
        self.cypress.set_attr(&path, "address", Yson::str(address))?;
        self.cypress.set_attr(&path, "index", Yson::Int(index))?;
        self.cypress
            .set_attr(&path, "guid", Yson::str(&guid.to_string()))?;
        Ok(())
    }

    /// Leave cleanly (crashed workers never call this).
    pub fn leave(&self, session: SessionId, key: &str) -> Result<(), CypressError> {
        let path = format!("{}/{}", self.dir, key);
        self.cypress.remove(&path, Some(session))
    }

    /// Fetch the current membership. May include expired-but-unswept
    /// entries and may miss very recent joiners — consumers must tolerate
    /// both (§4.5).
    pub fn list(&self) -> Result<Vec<MemberInfo>, CypressError> {
        let keys = self.cypress.list(&self.dir)?;
        let mut members = Vec::with_capacity(keys.len());
        for key in keys {
            let path = format!("{}/{}", self.dir, key);
            let attrs = match self.cypress.attrs(&path) {
                Ok(a) => a,
                // Node vanished between list and attrs — skip, that is
                // exactly the staleness consumers must survive.
                Err(CypressError::NotFound(_)) => continue,
                Err(e) => return Err(e),
            };
            let address = attrs
                .get("address")
                .and_then(|v| v.as_str().ok().map(String::from))
                .unwrap_or_default();
            let index = attrs.get("index").and_then(|v| v.as_i64().ok()).unwrap_or(-1);
            let guid = attrs
                .get("guid")
                .and_then(|v| v.as_str().ok())
                .and_then(Guid::parse)
                .unwrap_or(Guid::ZERO);
            members.push(MemberInfo {
                key,
                address,
                index,
                guid,
            });
        }
        Ok(members)
    }

    /// Find the member registered under a given index (reducers address
    /// mappers by index, §4.4.2 step 3).
    pub fn find_by_index(&self, index: i64) -> Result<Option<MemberInfo>, CypressError> {
        Ok(self.list()?.into_iter().find(|m| m.index == index))
    }

    pub fn dir(&self) -> &str {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::WriteAccounting;
    use crate::util::Clock;

    fn group(clock: Clock) -> (Arc<Cypress>, DiscoveryGroup) {
        let c = Cypress::new(clock, WriteAccounting::new());
        let g = DiscoveryGroup::open(c.clone(), "//discovery/mappers").unwrap();
        (c, g)
    }

    #[test]
    fn join_list_leave() {
        let (c, g) = group(Clock::realtime());
        let s = c.open_session(60_000);
        let guid = Guid::from_seed(1);
        g.join(s, &guid.to_string(), "addr-0", 0, guid).unwrap();
        let members = g.list().unwrap();
        assert_eq!(members.len(), 1);
        assert_eq!(members[0].address, "addr-0");
        assert_eq!(members[0].index, 0);
        assert_eq!(members[0].guid, guid);
        g.leave(s, &guid.to_string()).unwrap();
        assert!(g.list().unwrap().is_empty());
    }

    #[test]
    fn double_join_same_key_fails_while_alive() {
        let (c, g) = group(Clock::realtime());
        let s1 = c.open_session(60_000);
        let s2 = c.open_session(60_000);
        let guid = Guid::from_seed(2);
        g.join(s1, "mapper-0", "addr-a", 0, guid).unwrap();
        // A replacement with the same key must wait for expiry.
        assert!(matches!(
            g.join(s2, "mapper-0", "addr-b", 0, Guid::from_seed(3)),
            Err(CypressError::AlreadyExists(_))
        ));
    }

    #[test]
    fn split_brain_twins_both_visible_under_distinct_keys() {
        // Mappers key by GUID, so a stale twin and its replacement can be
        // listed simultaneously — the scenario §4.5 warns about.
        let (c, g) = group(Clock::realtime());
        let s1 = c.open_session(60_000);
        let s2 = c.open_session(60_000);
        let old = Guid::from_seed(10);
        let new = Guid::from_seed(11);
        g.join(s1, &old.to_string(), "addr-old", 3, old).unwrap();
        g.join(s2, &new.to_string(), "addr-new", 3, new).unwrap();
        let members = g.list().unwrap();
        let with_index_3: Vec<_> = members.iter().filter(|m| m.index == 3).collect();
        assert_eq!(with_index_3.len(), 2, "both twins must be observable");
    }

    #[test]
    fn expiry_clears_crashed_member() {
        let clock = Clock::scaled(1000);
        let (c, g) = group(clock);
        let s = c.open_session(20);
        let guid = Guid::from_seed(4);
        g.join(s, &guid.to_string(), "addr", 0, guid).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(5));
        // No heartbeat → swept on next read.
        assert!(g.list().unwrap().is_empty());
        // Replacement may now claim the same key.
        let s2 = c.open_session(60_000);
        g.join(s2, &guid.to_string(), "addr2", 0, guid).unwrap();
    }

    #[test]
    fn find_by_index() {
        let (c, g) = group(Clock::realtime());
        for i in 0..3 {
            let s = c.open_session(60_000);
            let guid = Guid::from_seed(20 + i as u64);
            g.join(s, &guid.to_string(), &format!("addr-{i}"), i, guid).unwrap();
        }
        let m = g.find_by_index(1).unwrap().unwrap();
        assert_eq!(m.address, "addr-1");
        assert!(g.find_by_index(9).unwrap().is_none());
    }

    #[test]
    fn open_idempotent() {
        let c = Cypress::new(Clock::realtime(), WriteAccounting::new());
        let _a = DiscoveryGroup::open(c.clone(), "//d").unwrap();
        let _b = DiscoveryGroup::open(c, "//d").unwrap();
    }
}
