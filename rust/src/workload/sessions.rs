//! The two-stage chained workload: **sessionize** raw logs (stage 1),
//! **aggregate** sessions (stage 2).
//!
//! Stage 1 reuses the §5.2 analytics mapper (split batched messages,
//! parse, filter lines without a user, hash-partition by (user, cluster))
//! and sessionizes each reducer batch: one *session row* per (user,
//! cluster) per batch — `(user, cluster, events, first_ts_ms, last_ts_ms)`
//! — handed to stage 2 through the ordered handoff table.
//!
//! Stage 2 re-shuffles session rows by (user, cluster) and folds them into
//! the sorted [`SESSIONS_TABLE`]: `events` sums, `first_ts_ms` takes the
//! min, `last_ts_ms` the max. All three folds are **batch-invariant**:
//! however the stream was batched (or re-batched by retries and failure
//! drills), the drained output table is byte-identical — which is exactly
//! what the chained exactly-once tests assert. The total `events` sum
//! equals the number of input log lines carrying a user field, the same
//! ground truth the single-stage suite counts.

use std::collections::HashMap;
use std::sync::Arc;

use crate::api::{
    partitioning, Client, Mapper, MapperFactory, MapperSpec, PartitionedRowset, Reducer,
    ReducerFactory, ReducerSpec,
};
use crate::coordinator::config::ComputeMode;
use crate::dataflow::{EmitReducer, EmitterFactory, StageSpec, Topology};
use crate::dyntable::Transaction;
use crate::queue::input_name_table;
use crate::row;
use crate::rows::{
    ColumnSchema, ColumnType, NameTable, RowBatch, RowsetBuilder, TableSchema, UnversionedRow,
    UnversionedRowset, Value,
};
use crate::storage::WriteCategory;
use crate::util::yson::Yson;
use crate::coordinator::ProcessorConfig;

use super::analytics::analytics_mapper_factory;

/// The chained pipeline's final output table:
/// (user, cluster) → (events, first_ts_ms, last_ts_ms).
pub const SESSIONS_TABLE: &str = "//out/user_sessions";

/// Columns of the stage-1 → stage-2 handoff rows.
pub fn session_name_table() -> Arc<NameTable> {
    NameTable::new(&["user", "cluster", "events", "first_ts_ms", "last_ts_ms"])
}

/// Schema of [`SESSIONS_TABLE`].
pub fn sessions_schema() -> TableSchema {
    TableSchema::new(vec![
        ColumnSchema::key("user", ColumnType::Str),
        ColumnSchema::key("cluster", ColumnType::Str),
        ColumnSchema::value("events", ColumnType::Int64),
        ColumnSchema::value("first_ts_ms", ColumnType::Int64),
        ColumnSchema::value("last_ts_ms", ColumnType::Int64),
    ])
}

/// Create [`SESSIONS_TABLE`] if missing. Drivers call this once up front
/// and propagate; worker factories re-invoke it best-effort.
pub fn ensure_sessions_table(client: &Client) -> Result<(), crate::dyntable::store::StoreError> {
    use crate::dyntable::store::StoreError;
    match client
        .store
        .create_table(SESSIONS_TABLE, sessions_schema(), WriteCategory::UserOutput)
    {
        Ok(_) | Err(StoreError::AlreadyExists(_)) => Ok(()),
        Err(e) => Err(e),
    }
}

/// Per-key accumulators of the shared (user, cluster) fold: keys in
/// first-seen order, event sums, min/max timestamps.
struct KeyedFold {
    keys: Vec<(Value, Value)>,
    events: Vec<i64>,
    first_ts: Vec<i64>,
    last_ts: Vec<i64>,
}

/// The grouped fold both stages share. `stats` extracts one row's
/// contribution `(events, first_ts, last_ts)` — weight 1 and the raw `ts`
/// for stage 1, the session row's own columns for stage 2 — or `None` to
/// skip a malformed row. All three accumulators are **batch-invariant**
/// (sum / min / max), which is what makes the drained chain output
/// byte-identical across fault schedules; keep them that way.
fn fold_by_user_cluster(
    rows: &UnversionedRowset,
    u_col: usize,
    c_col: usize,
    stats: impl Fn(&UnversionedRow) -> Option<(i64, i64, i64)>,
) -> KeyedFold {
    // Interned keys borrow the decoded cells; the stored keys are cheap
    // ByteStr clones — no string copies per group (same zero-copy policy
    // as the analytics reducer).
    let mut slot_of: HashMap<(&str, &str), usize> = HashMap::new();
    let mut fold = KeyedFold {
        keys: Vec::new(),
        events: Vec::new(),
        first_ts: Vec::new(),
        last_ts: Vec::new(),
    };
    for r in rows.rows() {
        let (Some(uv), Some(cv), Some((e, f, l))) = (r.get(u_col), r.get(c_col), stats(r))
        else {
            continue;
        };
        let (Some(u), Some(c)) = (uv.as_str(), cv.as_str()) else {
            continue;
        };
        let next = fold.keys.len();
        let slot = *slot_of.entry((u, c)).or_insert_with(|| {
            fold.keys.push((uv.clone(), cv.clone()));
            fold.events.push(0);
            fold.first_ts.push(i64::MAX);
            fold.last_ts.push(i64::MIN);
            next
        });
        fold.events[slot] += e;
        fold.first_ts[slot] = fold.first_ts[slot].min(f);
        fold.last_ts[slot] = fold.last_ts[slot].max(l);
    }
    fold
}

/// Stage-1 sessionizer: fold one shuffled batch of (user, cluster, ts)
/// rows into one session row per distinct key, in first-seen order
/// (deterministic for a given batch).
pub struct SessionizeEmitter;

impl EmitReducer for SessionizeEmitter {
    fn emit(&mut self, rows: UnversionedRowset) -> Vec<UnversionedRow> {
        let nt = rows.name_table();
        let (Some(u_col), Some(c_col), Some(t_col)) =
            (nt.id("user"), nt.id("cluster"), nt.id("ts"))
        else {
            return Vec::new();
        };
        let KeyedFold {
            keys,
            events,
            first_ts,
            last_ts,
        } = fold_by_user_cluster(&rows, u_col, c_col, |r| {
            r.get(t_col).and_then(Value::as_i64).map(|t| (1, t, t))
        });
        keys.into_iter()
            .enumerate()
            .map(|(slot, (user, cluster))| {
                row![user, cluster, events[slot], first_ts[slot], last_ts[slot]]
            })
            .collect()
    }
}

/// `CreateReducer` analogue for the sessionize stage.
pub fn sessionize_emitter_factory() -> EmitterFactory {
    Arc::new(|_cfg: &Yson, _client: &Client, _spec: &ReducerSpec| {
        Box::new(SessionizeEmitter) as Box<dyn EmitReducer>
    })
}

/// Stage-2 mapper: route session rows to reducers by (user, cluster);
/// pass the columns through unchanged. Deterministic by construction.
pub struct SessionRouteMapper {
    num_reducers: usize,
    out_nt: Arc<NameTable>,
}

impl Mapper for SessionRouteMapper {
    fn map(&mut self, rows: UnversionedRowset) -> PartitionedRowset {
        let nt = rows.name_table();
        let (Some(u_col), Some(c_col)) = (nt.id("user"), nt.id("cluster")) else {
            return PartitionedRowset::empty(self.out_nt.clone());
        };
        // One vectorized hash pass over the key columns (no per-row
        // composite-key String); each surviving row carries its hash so
        // the runtime can re-derive ownership under any epoch's count.
        let hash_col = RowBatch::key_hash_column_of(&rows, &[u_col, c_col]);
        let mut b = RowsetBuilder::new(self.out_nt.clone());
        let mut partitions = Vec::with_capacity(rows.len());
        let mut hashes = Vec::with_capacity(rows.len());
        for (r, h) in rows.rows().iter().zip(hash_col) {
            let Some(h) = h else {
                continue; // malformed handoff row: drop deterministically
            };
            partitions.push(partitioning::owner(h, self.num_reducers));
            hashes.push(h);
            b.push(r.clone());
        }
        PartitionedRowset::with_key_hashes(b.build(), partitions, hashes)
    }

    fn publishes_key_hashes(&self) -> bool {
        true
    }
}

/// `CreateMapper` for the aggregate stage.
pub fn session_route_mapper_factory() -> MapperFactory {
    Arc::new(
        |_cfg: &Yson, _client: &Client, _input_nt: Arc<NameTable>, spec: &MapperSpec| {
            Box::new(SessionRouteMapper {
                num_reducers: spec.num_reducers,
                out_nt: session_name_table(),
            }) as Box<dyn Mapper>
        },
    )
}

/// Stage-2 reducer: fold session rows into [`SESSIONS_TABLE`] inside the
/// exactly-once commit transaction.
pub struct SessionAggregateReducer {
    client: Client,
}

impl Reducer for SessionAggregateReducer {
    fn reduce(&mut self, rows: UnversionedRowset) -> Option<Transaction> {
        if rows.is_empty() {
            return None;
        }
        let nt = rows.name_table();
        let (u_col, c_col, e_col, f_col, l_col) = (
            nt.id("user")?,
            nt.id("cluster")?,
            nt.id("events")?,
            nt.id("first_ts_ms")?,
            nt.id("last_ts_ms")?,
        );

        // Pre-aggregate the batch per key, then one lookup+upsert per key.
        let KeyedFold {
            keys,
            events,
            first_ts,
            last_ts,
        } = fold_by_user_cluster(&rows, u_col, c_col, |r| {
            match (
                r.get(e_col).and_then(Value::as_i64),
                r.get(f_col).and_then(Value::as_i64),
                r.get(l_col).and_then(Value::as_i64),
            ) {
                (Some(e), Some(f), Some(l)) => Some((e, f, l)),
                _ => None,
            }
        });
        if keys.is_empty() {
            return None;
        }

        let mut txn = self.client.begin();
        for (slot, (user, cluster)) in keys.iter().enumerate() {
            let key = vec![user.clone(), cluster.clone()];
            let (mut ev, mut fts, mut lts) = (0i64, i64::MAX, i64::MIN);
            if let Ok(Some(existing)) = txn.lookup(SESSIONS_TABLE, &key) {
                ev = existing.get(2).and_then(Value::as_i64).unwrap_or(0);
                fts = existing.get(3).and_then(Value::as_i64).unwrap_or(i64::MAX);
                lts = existing.get(4).and_then(Value::as_i64).unwrap_or(i64::MIN);
            }
            let out = row![
                user.clone(),
                cluster.clone(),
                ev + events[slot],
                fts.min(first_ts[slot]),
                lts.max(last_ts[slot])
            ];
            txn.write(SESSIONS_TABLE, out).ok()?;
        }
        Some(txn)
    }
}

/// `CreateReducer` for the aggregate stage.
pub fn session_aggregate_reducer_factory() -> ReducerFactory {
    Arc::new(|_cfg: &Yson, client: &Client, _spec: &ReducerSpec| {
        // Best-effort in the factory (it cannot propagate): a failure here
        // surfaces as retried lookup errors in the reducer loop.
        let _ = ensure_sessions_table(client);
        Box::new(SessionAggregateReducer {
            client: client.clone(),
        }) as Box<dyn Reducer>
    })
}

/// Assemble the two-stage sessionize→aggregate [`Topology`].
///
/// * stage `sessionize`: `s1_mappers` mappers (must equal the source's
///   partition count) and `s1_reducers` reducers emitting session rows.
/// * stage `aggregate`: one mapper per stage-1 reducer, `s2_reducers`
///   reducers folding into [`SESSIONS_TABLE`].
///
/// `base` carries the shared timing tunables (backoffs, trim period, …).
pub fn two_stage_topology(
    base: ProcessorConfig,
    s1_mappers: usize,
    s1_reducers: usize,
    s2_reducers: usize,
    compute: ComputeMode,
) -> Topology {
    let s1_cfg = ProcessorConfig {
        mapper_count: s1_mappers,
        reducer_count: s1_reducers,
        ..base.clone()
    };
    let s2_cfg = ProcessorConfig {
        mapper_count: s1_reducers,
        reducer_count: s2_reducers,
        ..base
    };
    Topology::new("two_stage_sessions")
        .stage(StageSpec::intermediate(
            "sessionize",
            s1_cfg,
            input_name_table(),
            session_name_table(),
            analytics_mapper_factory(compute),
            sessionize_emitter_factory(),
        ))
        .stage(StageSpec::final_stage(
            "aggregate",
            s2_cfg,
            session_name_table(),
            session_route_mapper_factory(),
            session_aggregate_reducer_factory(),
        ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::processor::ClusterEnv;
    use crate::util::Clock;

    fn session_rowset(rows: &[(&str, &str, i64)]) -> UnversionedRowset {
        let mut b = RowsetBuilder::new(NameTable::new(&["user", "cluster", "ts"]));
        for (u, c, t) in rows {
            b.push(row![*u, *c, *t]);
        }
        b.build()
    }

    #[test]
    fn sessionize_folds_per_key_deterministically() {
        let mut e = SessionizeEmitter;
        let out = e.emit(session_rowset(&[
            ("alice", "hahn", 100),
            ("root", "freud", 50),
            ("alice", "hahn", 300),
            ("alice", "hahn", 200),
        ]));
        assert_eq!(out.len(), 2);
        // First-seen order: alice first.
        assert_eq!(out[0].get(0).unwrap().as_str(), Some("alice"));
        assert_eq!(out[0].get(2).unwrap().as_i64(), Some(3));
        assert_eq!(out[0].get(3).unwrap().as_i64(), Some(100));
        assert_eq!(out[0].get(4).unwrap().as_i64(), Some(300));
        assert_eq!(out[1].get(0).unwrap().as_str(), Some("root"));
        assert_eq!(out[1].get(2).unwrap().as_i64(), Some(1));

        // Determinism: identical batch, identical emission.
        let again = SessionizeEmitter.emit(session_rowset(&[
            ("alice", "hahn", 100),
            ("root", "freud", 50),
            ("alice", "hahn", 300),
            ("alice", "hahn", 200),
        ]));
        assert_eq!(out, again);
    }

    #[test]
    fn sessionize_skips_malformed_rows() {
        let mut b = RowsetBuilder::new(NameTable::new(&["user", "cluster", "ts"]));
        b.push(row!["alice", "hahn", 5i64]);
        b.push(UnversionedRow::new(vec![
            Value::Int64(9), // wrong type in the user column
            Value::from("hahn"),
            Value::Int64(6),
        ]));
        let out = SessionizeEmitter.emit(b.build());
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn route_mapper_same_key_same_partition_and_passthrough() {
        let mut m = SessionRouteMapper {
            num_reducers: 4,
            out_nt: session_name_table(),
        };
        let mut b = RowsetBuilder::new(session_name_table());
        b.push(row!["alice", "hahn", 2i64, 10i64, 20i64]);
        b.push(row!["alice", "hahn", 1i64, 30i64, 30i64]);
        b.push(row!["root", "bohr", 5i64, 1i64, 9i64]);
        let out = m.map(b.build());
        assert_eq!(out.rowset.len(), 3);
        assert_eq!(out.partition_indexes.len(), 3);
        assert_eq!(out.partition_indexes[0], out.partition_indexes[1]);
        assert!(out.partition_indexes.iter().all(|&p| p < 4));
        assert_eq!(out.rowset.cell(2, "events").unwrap().as_i64(), Some(5));
    }

    #[test]
    fn aggregate_reducer_folds_batch_invariantly() {
        let env = ClusterEnv::new(Clock::realtime(), 3);
        let client = env.client();
        ensure_sessions_table(&client).unwrap();
        let mut r = SessionAggregateReducer {
            client: client.clone(),
        };

        let mut b = RowsetBuilder::new(session_name_table());
        b.push(row!["alice", "hahn", 2i64, 100i64, 300i64]);
        b.push(row!["alice", "hahn", 1i64, 50i64, 120i64]);
        let txn = r.reduce(b.build()).expect("txn");
        txn.commit().unwrap();

        let mut b = RowsetBuilder::new(session_name_table());
        b.push(row!["alice", "hahn", 4i64, 400i64, 500i64]);
        let txn = r.reduce(b.build()).expect("txn");
        txn.commit().unwrap();

        let rows = client.store.scan(SESSIONS_TABLE).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get(2).unwrap().as_i64(), Some(7), "events sum");
        assert_eq!(rows[0].get(3).unwrap().as_i64(), Some(50), "min first_ts");
        assert_eq!(rows[0].get(4).unwrap().as_i64(), Some(500), "max last_ts");
    }

    #[test]
    fn two_stage_topology_validates_against_matching_source() {
        use crate::coordinator::InputSpec;
        use crate::queue::ordered_table::OrderedTable;
        use crate::storage::WriteAccounting;

        let t = two_stage_topology(ProcessorConfig::default(), 4, 2, 2, ComputeMode::Native);
        let source = InputSpec::Ordered(OrderedTable::new(
            "//input/x",
            input_name_table(),
            4,
            WriteAccounting::new(),
        ));
        t.validate(&source).unwrap();
    }
}
