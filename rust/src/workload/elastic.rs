//! The elastic workload scenario: the §5.2 analytics pipeline under live
//! partition-count changes.
//!
//! A run feeds fully **deterministic** log waves into the input table and
//! performs one reshard between consecutive waves (optionally injecting
//! failure drills mid-migration), then drains. Because the input is a pure
//! function of (wave, partition, message, line) and the analytics fold is
//! batch-invariant, the drained output table of *any* run over the same
//! wave plan — resharded or static, drilled or fault-free — must be
//! byte-identical. That is the scenario's headline assertion, used by
//! `figure reshard` and the fault-injection suite.

use std::sync::Arc;

use crate::coordinator::processor::ClusterEnv;
use crate::coordinator::{ComputeMode, InputSpec, ProcessorConfig, StreamingProcessor};
use crate::metrics::hub::names;
use crate::metrics::WaReport;
use crate::queue::input_name_table;
use crate::queue::ordered_table::OrderedTable;
use crate::reshard::{AutoscalerConfig, DriverConfig, PlanPhase, ReshardPlan, ReshardStats};
use crate::row;
use crate::rows::{UnversionedRow, Value};
use crate::util::yson::Yson;
use crate::util::Clock;
use crate::workload::analytics::{
    analytics_mapper_factory, analytics_reducer_factory, ensure_output_table, OUTPUT_TABLE,
};

const CLUSTERS: [&str; 3] = ["hahn", "freud", "bohr"];
const USERS: [&str; 5] = ["root", "alice", "bob", "carol", "dave"];
const METHODS: [&str; 4] = ["GetNode", "SetNode", "Commit", "Heartbeat"];

/// The pure ground truth of one deterministic wave: every log line that
/// carries a user field, as `(partition, user, cluster, ts)`. **Must
/// mirror [`fill_deterministic_wave`]'s formula exactly** — the windowed
/// workload folds this directly to predict its output tables.
pub fn deterministic_wave_user_events(
    partitions: usize,
    wave: usize,
    messages_per_partition: usize,
) -> Vec<(usize, &'static str, &'static str, i64)> {
    let mut out = Vec::new();
    for p in 0..partitions {
        let cluster = CLUSTERS[(p + wave) % CLUSTERS.len()];
        for m in 0..messages_per_partition {
            let lines = 3 + (p + m + wave) % 4;
            for l in 0..lines {
                if (p + m + l) % 3 == 0 {
                    let ts = 10_000
                        + (wave as i64) * 4_000_000
                        + (p as i64) * 500_000
                        + (m as i64) * 100
                        + l as i64;
                    let user = USERS[(m + l + wave) % USERS.len()];
                    out.push((p, user, cluster, ts));
                }
            }
        }
    }
    out
}

/// Fill one deterministic wave of log messages: fixed timestamps, users
/// and clusters derived from (wave, partition, message, line) indexes
/// only. Two fills with the same coordinates are byte-identical, so two
/// drained pipeline runs can be compared row for row. Returns the ground
/// truth: the number of lines carrying a user field.
pub fn fill_deterministic_wave(
    table: &Arc<OrderedTable>,
    wave: usize,
    messages_per_partition: usize,
) -> i64 {
    fill_deterministic_wave_slice(table, wave, 0, messages_per_partition)
}

/// Append only the message range `[m_begin, m_end)` of a deterministic
/// wave — byte-identical content and per-tablet order to the full fill,
/// just pausable between slices. The windowed scenario uses this to
/// spread one wave over several reducer commits, so the per-batch-upsert
/// baseline demonstrably re-writes its output keys. Returns the user
/// lines in the slice.
pub fn fill_deterministic_wave_slice(
    table: &Arc<OrderedTable>,
    wave: usize,
    m_begin: usize,
    m_end: usize,
) -> i64 {
    let mut user_lines = 0i64;
    for p in 0..table.tablet_count() {
        let cluster = CLUSTERS[(p + wave) % CLUSTERS.len()];
        for m in m_begin..m_end {
            let lines = 3 + (p + m + wave) % 4;
            let mut payload = String::new();
            for l in 0..lines {
                if l > 0 {
                    payload.push('\n');
                }
                // Keep every timestamp below 2^24: the analytics reducer
                // aggregates per-batch ts *offsets* in f32, and offsets
                // must stay exactly representable or the reconstructed
                // last_ts would depend on batching — breaking the
                // byte-identity this scenario asserts across runs.
                let ts = 10_000
                    + (wave as i64) * 4_000_000
                    + (p as i64) * 500_000
                    + (m as i64) * 100
                    + l as i64;
                let method = METHODS[(p + m + l) % METHODS.len()];
                if (p + m + l) % 3 == 0 {
                    let user = USERS[(m + l + wave) % USERS.len()];
                    payload.push_str(&format!(
                        "ts={ts} cluster={cluster} method={method} user={user} dur=42"
                    ));
                    user_lines += 1;
                } else {
                    payload.push_str(&format!(
                        "ts={ts} cluster={cluster} method={method} dur=42"
                    ));
                }
            }
            let write_ts = 10_000 + (p as i64) * 1_000_000 + (m as i64) * 100;
            table
                .append(p, vec![row![payload, write_ts]])
                .expect("deterministic wave fill");
        }
    }
    user_lines
}

/// Enforce the generator's f32-exactness precondition: the largest
/// timestamp any wave can emit must stay below 2^24, or the byte-identity
/// the scenarios assert becomes batching-dependent (the analytics reducer
/// aggregates per-batch ts *offsets* in f32). Must mirror the timestamp
/// formula in [`fill_deterministic_wave`].
fn assert_wave_plan_f32_exact(cfg: &ElasticCfg) {
    let max_ts = 10_000
        + (cfg.waves.saturating_sub(1) as i64) * 4_000_000
        + (cfg.partitions.saturating_sub(1) as i64) * 500_000
        + (cfg.messages_per_wave as i64) * 100
        + 8;
    assert!(
        max_ts < (1 << 24),
        "wave plan would emit ts {max_ts} >= 2^24; shrink waves/partitions/messages \
         (f32 ts offsets must stay exactly representable)"
    );
}

/// Scenario knobs.
#[derive(Debug, Clone)]
pub struct ElasticCfg {
    pub partitions: usize,
    pub initial_reducers: usize,
    /// Total input waves. **Independent of `reshard_to`** so a static
    /// baseline (`reshard_to: []`) over the same `waves` ingests input
    /// byte-identical to a resharded run — the whole point of the
    /// comparison. Must be > `reshard_to.len()` (each reshard runs after
    /// one wave, with at least one wave left to drain through the final
    /// fleet).
    pub waves: usize,
    /// Reducer-count targets applied between waves: `[8, 4]` means wave 0
    /// runs at `initial_reducers`, then a live reshard to 8, wave 1, a
    /// live reshard to 4, then the remaining waves, drain. Empty = static
    /// run (the byte-identity baseline).
    pub reshard_to: Vec<usize>,
    pub messages_per_wave: usize,
    pub seed: u64,
    /// Base timings (worker cadences); counts are overwritten.
    pub base: ProcessorConfig,
    /// Wall-clock budget for each migration to drain + finalize.
    pub reshard_timeout_ms: u64,
    /// Wall-clock budget for the final drain.
    pub drain_timeout_ms: u64,
}

impl Default for ElasticCfg {
    fn default() -> Self {
        ElasticCfg {
            partitions: 4,
            initial_reducers: 4,
            waves: 3,
            reshard_to: vec![8, 4],
            messages_per_wave: 60,
            seed: 0xE1A5,
            base: ProcessorConfig {
                backoff_ms: 5,
                trim_period_ms: 100,
                restart_delay_ms: 100,
                split_brain_delay_ms: 50,
                session_ttl_ms: 1_500,
                heartbeat_period_ms: 100,
                ..ProcessorConfig::default()
            },
            reshard_timeout_ms: 30_000,
            drain_timeout_ms: 45_000,
        }
    }
}

/// Everything an elastic run leaves behind for assertions and reporting.
pub struct ElasticOutcome {
    /// Ground truth: input lines with a user field.
    pub expected_lines: i64,
    /// Observed sum of the output `count` column after drain.
    pub output_lines: i64,
    /// Full drained output table in key order (byte-identical across
    /// resharded/drilled/static runs over the same wave plan).
    pub rows: Vec<UnversionedRow>,
    pub report: WaReport,
    /// One entry per completed migration.
    pub reshards: Vec<ReshardStats>,
    /// The final persisted plan.
    pub final_plan: Option<ReshardPlan>,
    pub retired_reducers: u64,
    pub bootstrapped_reducers: u64,
    pub env: ClusterEnv,
}

/// Sum of the output table's `count` column.
fn output_count_sum(env: &ClusterEnv) -> i64 {
    env.store
        .scan(OUTPUT_TABLE)
        .map(|rows| {
            rows.iter()
                .map(|r| r.get(2).and_then(Value::as_i64).unwrap_or(0))
                .sum()
        })
        .unwrap_or(0)
}

fn wait_for_output(env: &ClusterEnv, expected: i64, wall_ms: u64) -> i64 {
    let deadline = std::time::Instant::now() + std::time::Duration::from_millis(wall_ms);
    let mut last = -1;
    while std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(50));
        let cur = output_count_sum(env);
        if cur == expected {
            return cur;
        }
        last = cur;
    }
    last
}

/// Run the elastic scenario. `drill` fires once per migration, right
/// after [`StreamingProcessor::begin_reshard`] — mid-cutover, before the
/// old fleet finished draining — with `(processor, migration_index)`;
/// the old fleet is epoch `migration_index`, the incoming fleet epoch
/// `migration_index + 1` (slot ids via [`crate::reshard::plan::reducer_slot`]).
pub fn run_elastic(
    cfg: &ElasticCfg,
    drill: impl Fn(&StreamingProcessor, usize),
) -> ElasticOutcome {
    let clock = Clock::scaled(4);
    let env = ClusterEnv::new(clock.clone(), cfg.seed);
    // protolint: allow(category, "source input table: the SourceIngest default is the intent")
    let table = OrderedTable::new(
        "//input/elastic",
        input_name_table(),
        cfg.partitions,
        env.accounting.clone(),
    );
    ensure_output_table(&env.client()).expect("create analytics output table");

    let proc_cfg = ProcessorConfig {
        mapper_count: cfg.partitions,
        reducer_count: cfg.initial_reducers,
        ..cfg.base.clone()
    };
    let processor = StreamingProcessor::launch(
        proc_cfg,
        env.clone(),
        InputSpec::Ordered(table.clone()),
        analytics_mapper_factory(ComputeMode::Native),
        analytics_reducer_factory(ComputeMode::Native),
        Yson::parse("{}").unwrap(),
    )
    .expect("launch elastic processor");

    assert!(
        cfg.waves > cfg.reshard_to.len(),
        "need more waves ({}) than reshards ({})",
        cfg.waves,
        cfg.reshard_to.len()
    );
    assert_wave_plan_f32_exact(cfg);
    let mut expected = 0i64;
    let mut reshards = Vec::new();
    for wave in 0..cfg.waves {
        expected += fill_deterministic_wave(&table, wave, cfg.messages_per_wave);
        if let Some(&target) = cfg.reshard_to.get(wave) {
            // Let the wave start flowing before resizing under it.
            std::thread::sleep(std::time::Duration::from_millis(150));
            processor
                .begin_reshard(target)
                .expect("begin live reshard");
            drill(&processor, wave);
            let stats = processor
                .finish_reshard(cfg.reshard_timeout_ms)
                .expect("migration must drain and finalize");
            reshards.push(stats);
        }
    }

    let output_lines = wait_for_output(&env, expected, cfg.drain_timeout_ms);
    let report = processor.wa_report("elastic analytics");
    let final_plan = processor.current_plan();
    let retired = env.metrics.get_counter(names::RESHARD_RETIRED);
    let bootstrapped = env.metrics.get_counter(names::RESHARD_BOOTSTRAPPED);
    processor.stop();

    let rows = env.store.scan(OUTPUT_TABLE).unwrap_or_default();
    ElasticOutcome {
        expected_lines: expected,
        output_lines,
        rows,
        report,
        reshards,
        final_plan,
        retired_reducers: retired,
        bootstrapped_reducers: bootstrapped,
        env,
    }
}

/// The resident-driver tuning the hands-off scenario (and `figure reshard
/// --auto`) uses: watermarks low enough that one deterministic wave
/// reliably reads as overload against `initial` reducers, a floor at
/// `initial` so the fleet settles back where it started, and a cap one
/// doubling above it — so an unattended run performs at least one grow
/// and one shrink, both decided purely from lag+backlog signals.
pub fn auto_driver_config(cfg: &ElasticCfg) -> DriverConfig {
    DriverConfig {
        autoscaler: AutoscalerConfig {
            backlog_high_per_reducer: 8.0,
            backlog_low_per_reducer: 2.0,
            // The deterministic waves carry synthetic (small) write
            // timestamps, so read-lag/commit-latency means are clamped
            // near zero while rows flow and vanish when drained — the
            // backlog watermarks are the decisive signals here.
            lag_high_ms: 60_000.0,
            lag_low_ms: 60_000.0,
            latency_high_ms: 60_000.0,
            latency_low_ms: 60_000.0,
            hysteresis_ticks: 2,
            cooldown_ms: 1_000,
            min_reducers: cfg.initial_reducers,
            max_reducers: cfg.initial_reducers * 2,
        },
        tick_period_ms: 100,
        signal_window_ms: 1_500,
        reshard_timeout_ms: cfg.reshard_timeout_ms,
    }
}

/// Hands-off variant of [`run_elastic`]: **no manual `reshard()` calls**.
/// The processor's resident autoscale driver watches the fused lag+backlog
/// signals and performs every resize itself — each wave's backlog reads as
/// overload (grow), the post-drain quiet reads as over-provisioning
/// (shrink back to the floor). `drill` fires once per *observed* migration
/// — the harness polls the plan row and calls it the first time each new
/// epoch appears mid-flight, so fault drills land mid-cutover exactly like
/// the manual scenario's. Returns once the output drained and the driver
/// settled the fleet back to the configured floor with a stable plan (or
/// the respective timeouts expired; the caller asserts).
///
/// `ElasticOutcome::reshards` is empty here — the driver owns the
/// migrations; counts live in the `autoscale/*` counters of
/// `ElasticOutcome::env.metrics`.
pub fn run_elastic_auto(
    cfg: &ElasticCfg,
    dcfg: DriverConfig,
    drill: impl Fn(&StreamingProcessor, usize),
) -> ElasticOutcome {
    let clock = Clock::scaled(4);
    let env = ClusterEnv::new(clock.clone(), cfg.seed);
    // protolint: allow(category, "source input table: the SourceIngest default is the intent")
    let table = OrderedTable::new(
        "//input/elastic",
        input_name_table(),
        cfg.partitions,
        env.accounting.clone(),
    );
    ensure_output_table(&env.client()).expect("create analytics output table");

    let proc_cfg = ProcessorConfig {
        mapper_count: cfg.partitions,
        reducer_count: cfg.initial_reducers,
        ..cfg.base.clone()
    };
    let processor = StreamingProcessor::launch(
        proc_cfg,
        env.clone(),
        InputSpec::Ordered(table.clone()),
        analytics_mapper_factory(ComputeMode::Native),
        analytics_reducer_factory(ComputeMode::Native),
        Yson::parse("{}").unwrap(),
    )
    .expect("launch elastic processor");
    assert_wave_plan_f32_exact(cfg);

    let settle_floor = dcfg.autoscaler.min_reducers;
    processor.start_autoscaler(dcfg);

    // Poll-observe the plan and fire the drill hook on each migration the
    // driver starts.
    let mut next_drill_epoch = 1i64;
    let mut migrations_seen = 0usize;
    let mut observe_and_drill = |processor: &StreamingProcessor| {
        if let Some(plan) = processor.current_plan() {
            if plan.phase == PlanPhase::Migrating && plan.next_epoch() >= next_drill_epoch {
                drill(processor, migrations_seen);
                migrations_seen += 1;
                next_drill_epoch = plan.next_epoch() + 1;
            }
        }
    };

    let mut expected = 0i64;
    for wave in 0..cfg.waves {
        expected += fill_deterministic_wave(&table, wave, cfg.messages_per_wave);
        // Let the wave flow (and the driver react to it) before the next.
        let until = std::time::Instant::now() + std::time::Duration::from_millis(700);
        while std::time::Instant::now() < until {
            std::thread::sleep(std::time::Duration::from_millis(20));
            observe_and_drill(&processor);
        }
    }

    // Drain, still watching for driver-started migrations.
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_millis(cfg.drain_timeout_ms);
    let mut output_lines = -1i64;
    while std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(50));
        observe_and_drill(&processor);
        output_lines = output_count_sum(&env);
        if output_lines == expected {
            break;
        }
    }

    // Let the driver settle the fleet back to its floor (the unattended
    // shrink) before reporting.
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_millis(cfg.reshard_timeout_ms);
    while std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(50));
        observe_and_drill(&processor);
        if processor
            .current_plan()
            .is_some_and(|p| p.phase == PlanPhase::Stable && p.partitions <= settle_floor)
        {
            break;
        }
    }

    let report = processor.wa_report("elastic analytics (hands-off)");
    let final_plan = processor.current_plan();
    let retired = env.metrics.get_counter(names::RESHARD_RETIRED);
    let bootstrapped = env.metrics.get_counter(names::RESHARD_BOOTSTRAPPED);
    processor.stop();

    let rows = env.store.scan(OUTPUT_TABLE).unwrap_or_default();
    ElasticOutcome {
        expected_lines: expected,
        output_lines,
        rows,
        report,
        reshards: Vec::new(),
        final_plan,
        retired_reducers: retired,
        bootstrapped_reducers: bootstrapped,
        env,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::WriteAccounting;

    #[test]
    fn ground_truth_matches_fill() {
        let acc = WriteAccounting::new();
        let t = OrderedTable::new("gt", input_name_table(), 3, acc);
        let filled_user_lines = fill_deterministic_wave(&t, 2, 7);
        let events = deterministic_wave_user_events(3, 2, 7);
        assert_eq!(events.len() as i64, filled_user_lines);
        // Spot-check: every predicted event appears verbatim in the fill.
        for (p, user, cluster, ts) in events.iter().take(5) {
            let rows = t.read_tablet(*p, 0, t.end_index(*p)).unwrap();
            let needle = format!("ts={ts} cluster={cluster}");
            let found = rows.iter().any(|r| {
                r.get(0)
                    .and_then(crate::rows::Value::as_str)
                    .is_some_and(|s| s.contains(&needle) && s.contains(&format!("user={user}")))
            });
            assert!(found, "event {user}@{cluster} ts={ts} missing from partition {p}");
        }
    }

    #[test]
    fn sliced_fill_is_byte_identical_to_whole_fill() {
        let acc = WriteAccounting::new();
        let whole = OrderedTable::new("w", input_name_table(), 2, acc.clone());
        let sliced = OrderedTable::new("s", input_name_table(), 2, acc);
        let a = fill_deterministic_wave(&whole, 1, 8);
        let b1 = fill_deterministic_wave_slice(&sliced, 1, 0, 3);
        let b2 = fill_deterministic_wave_slice(&sliced, 1, 3, 8);
        assert_eq!(a, b1 + b2);
        for p in 0..2 {
            assert_eq!(whole.end_index(p), sliced.end_index(p));
            assert_eq!(
                whole.read_tablet(p, 0, whole.end_index(p)).unwrap(),
                sliced.read_tablet(p, 0, sliced.end_index(p)).unwrap(),
            );
        }
    }

    #[test]
    fn deterministic_wave_is_reproducible() {
        let acc = WriteAccounting::new();
        let a = OrderedTable::new("a", input_name_table(), 2, acc.clone());
        let b = OrderedTable::new("b", input_name_table(), 2, acc);
        let na = fill_deterministic_wave(&a, 1, 5);
        let nb = fill_deterministic_wave(&b, 1, 5);
        assert_eq!(na, nb);
        assert!(na > 0);
        // Byte-identical payloads.
        for p in 0..2 {
            assert_eq!(a.end_index(p), b.end_index(p));
            let ra = a.read_tablet(p, 0, a.end_index(p)).unwrap();
            let rb = b.read_tablet(p, 0, b.end_index(p)).unwrap();
            assert_eq!(ra, rb);
        }
        // Different waves differ.
        let c = OrderedTable::new("c", input_name_table(), 2, WriteAccounting::new());
        fill_deterministic_wave(&c, 2, 5);
        let r1 = a.read_tablet(0, 0, 1).unwrap();
        let r2 = c.read_tablet(0, 0, 1).unwrap();
        assert_ne!(r1, r2);
    }
}
