//! The backfill scenario: a day-N consumer bootstrapped from the cold
//! tier instead of re-ingesting history from the source.
//!
//! One environment hosts the whole life cycle:
//!
//! 1. **Origin phase** — a final-fire windowed consumer with the cold tier
//!    enabled drains the historical waves; every trimmed input segment and
//!    every fired-window GC pass is compacted into cold chunks inside the
//!    same exactly-once transactions (accounted under
//!    [`WriteCategory::ColdTier`]). The consumer is then stopped and its
//!    low water marks become the **cutover fences**.
//! 2. **Live tail** — more waves arrive while no consumer is running.
//! 3. **Backfill phase** — a brand-new consumer (fresh state tables, own
//!    output table) launches against
//!    [`crate::coordinator::InputSpec::BoundedRange`]: it drains the
//!    bounded historical range from cold chunks, cuts over to live tailing
//!    at the fences, and final-fires every window. Its residual importer
//!    is [`ColdWindowBootstrap`], so an empty-handoff reshard would
//!    restore the fired marker from cold history.
//!
//! A control run (`re-ingest from source`) processes the identical waves
//! live from day zero in a fresh environment. `figure backfill` gates that
//! the backfill output is **byte-identical** to the control's and that the
//! backfill moved strictly fewer bytes than re-ingesting.

use std::sync::Arc;

use crate::coldtier::{ColdInput, ColdStore, ColdTierConfig, ColdWindowBootstrap};
use crate::coordinator::processor::ClusterEnv;
use crate::coordinator::{EventTimeConfig, InputSpec, ProcessorConfig, StreamingProcessor};
use crate::dyntable::{Transaction, TxnError};
use crate::eventtime::windowed::window_state_table;
use crate::eventtime::{
    windowed_reducer_factory, WindowFold, WindowMigrators, WindowSpec, WindowedDeps,
    EVENT_TIME_CLOSED,
};
use crate::metrics::hub::names;
use crate::metrics::WaReport;
use crate::queue::input_name_table;
use crate::queue::ordered_table::OrderedTable;
use crate::reshard::migration::{ImportCtx, ResidualImporter};
use crate::reshard::ReshardRuntime;
use crate::row;
use crate::rows::UnversionedRow;
use crate::storage::accounting::AccountingSnapshot;
use crate::storage::WriteCategory;
use crate::util::yson::Yson;
use crate::util::Clock;
use crate::workload::elastic::fill_deterministic_wave;
use crate::workload::windowed::{
    expected_windowed_rows, windowed_mapped_name_table, windowed_mapper_factory, windowed_schema,
    ActivityWindowFold, WindowedCfg,
};

/// Output table of the origin-phase consumer.
pub const BACKFILL_ORIGIN_TABLE: &str = "//out/backfill_origin";
/// Output table of the day-N backfill consumer — compared byte-for-byte
/// against [`BACKFILL_CONTROL_TABLE`].
pub const BACKFILL_TABLE: &str = "//out/backfill_day_n";
/// Output table of the re-ingest-from-source control run.
pub const BACKFILL_CONTROL_TABLE: &str = "//out/backfill_day0";

/// [`ActivityWindowFold`] with a configurable output table, so the origin,
/// backfill and control consumers write to distinct tables that can be
/// scanned and compared independently.
pub struct RoutedActivityFold {
    pub table: String,
}

impl WindowFold for RoutedActivityFold {
    fn event_ts(&self, row: &UnversionedRow) -> Option<i64> {
        ActivityWindowFold.event_ts(row)
    }

    fn key(&self, row: &UnversionedRow) -> Option<String> {
        ActivityWindowFold.key(row)
    }

    fn zero(&self) -> Yson {
        ActivityWindowFold.zero()
    }

    fn fold(&self, acc: &mut Yson, row: &UnversionedRow) {
        ActivityWindowFold.fold(acc, row)
    }

    fn merge(&self, into: &mut Yson, other: &Yson) {
        ActivityWindowFold.merge(into, other)
    }

    fn emit(
        &self,
        window_start: i64,
        _window_end: i64,
        key: &str,
        acc: &Yson,
        txn: &mut Transaction,
    ) -> Result<(), TxnError> {
        let mut parts = key.split('\u{1f}');
        let (Some(user), Some(cluster)) = (parts.next(), parts.next()) else {
            return Ok(());
        };
        let (count, last_ts) = ActivityWindowFold::unpack(acc);
        txn.write(&self.table, row![window_start, user, cluster, count, last_ts])
    }
}

fn ensure_table_at(
    env: &ClusterEnv,
    path: &str,
) -> Result<(), crate::dyntable::store::StoreError> {
    use crate::dyntable::store::StoreError;
    match env
        .store
        .create_table(path, windowed_schema(), WriteCategory::UserOutput)
    {
        Ok(_) | Err(StoreError::AlreadyExists(_)) => Ok(()),
        Err(e) => Err(e),
    }
}

/// Scenario knobs (same deterministic wave plan as the windowed scenario).
#[derive(Debug, Clone)]
pub struct BackfillCfg {
    pub partitions: usize,
    pub reducers: usize,
    /// Waves the origin consumer drains (and the cold tier compacts)
    /// before it is stopped. Must be < `total_waves`.
    pub history_waves: usize,
    /// Total waves; `history_waves..total_waves` arrive as the live tail
    /// the backfill consumer cuts over into.
    pub total_waves: usize,
    pub messages_per_wave: usize,
    pub seed: u64,
    pub window: WindowSpec,
    /// Table-path root of the cold tier.
    pub cold_base: String,
    pub base: ProcessorConfig,
    /// Wall-clock budget for the origin phase to drain + trim every
    /// historical row (the fences depend on it).
    pub trim_timeout_ms: u64,
    pub drain_timeout_ms: u64,
}

impl Default for BackfillCfg {
    fn default() -> Self {
        BackfillCfg {
            partitions: 4,
            reducers: 4,
            history_waves: 2,
            total_waves: 3,
            messages_per_wave: 40,
            seed: 0xBF11,
            window: WindowSpec::tumbling(250_000),
            cold_base: "//sys/cold/backfill".to_string(),
            base: ProcessorConfig {
                backoff_ms: 5,
                trim_period_ms: 100,
                restart_delay_ms: 100,
                split_brain_delay_ms: 50,
                session_ttl_ms: 1_500,
                heartbeat_period_ms: 100,
                ..ProcessorConfig::default()
            },
            trim_timeout_ms: 45_000,
            drain_timeout_ms: 45_000,
        }
    }
}

/// Where in the backfill the drill hook is being invoked.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackfillDrillPoint {
    /// Shortly after launch, while the historical range is draining from
    /// cold chunks.
    MidBackfill,
    /// Right after the first live-tail read — the consumer just crossed
    /// the cutover fence.
    AtCutover,
}

/// What a backfill run leaves behind.
pub struct BackfillOutcome {
    /// Pure ground truth over all `total_waves` — what both the backfill
    /// and the control output tables must equal.
    pub expected: Vec<UnversionedRow>,
    /// Drained output of the day-N backfill consumer, key order.
    pub backfill_rows: Vec<UnversionedRow>,
    /// Drained output of the re-ingest-from-source control, key order.
    pub control_rows: Vec<UnversionedRow>,
    /// Cutover fences (the origin run's final low water marks).
    pub fences: Vec<i64>,
    pub segment_chunks: usize,
    pub history_chunks: usize,
    /// Fired watermark [`ColdWindowBootstrap`] restored in the
    /// empty-handoff demo (`None` if no window fired during the origin
    /// phase).
    pub restored_fired_marker: Option<i64>,
    /// The restored marker was read back from the bootstrap epoch's state
    /// table and matched.
    pub bootstrap_marker_verified: bool,
    /// WA report of the cold-tier environment (origin + backfill phases).
    pub report: WaReport,
    /// WA report of the control environment.
    pub control_report: WaReport,
    /// Raw (pre-hex) cold chunk bytes the backfill read.
    pub chunk_bytes_read: u64,
    /// Live-tail bytes the backfill read past the fence.
    pub live_bytes_read: u64,
    /// `UserOutput` bytes the backfill consumer wrote.
    pub backfill_user_output: u64,
    /// `SourceIngest` bytes the control paid to re-append all history.
    pub reingest_source_bytes: u64,
    /// Bytes the control's mappers read from the re-ingested source.
    pub reingest_mapper_read: u64,
    /// `UserOutput` bytes the control wrote (must equal the backfill's —
    /// the cold tier never inflates the exactly-once hot path).
    pub reingest_user_output: u64,
    /// Rows on the backfill consumer's late side channel (0 expected for
    /// the in-order waves).
    pub late_rows: i64,
    /// The cold-tier environment, for accounting/metrics assertions.
    pub env: ClusterEnv,
    /// The control environment.
    pub control_env: ClusterEnv,
}

impl BackfillOutcome {
    /// Bytes the backfill moved to reach day-N output: compact chunk reads
    /// plus the live tail plus its own output writes.
    pub fn backfill_bytes_moved(&self) -> u64 {
        self.chunk_bytes_read + self.live_bytes_read + self.backfill_user_output
    }

    /// Bytes re-ingesting moved for the same output: re-appending all
    /// history to a source, reading it all back, writing the output.
    pub fn reingest_bytes_moved(&self) -> u64 {
        self.reingest_source_bytes + self.reingest_mapper_read + self.reingest_user_output
    }
}

/// Launch one final-fire windowed consumer with namespaced state tables.
///
/// `cold_write` enables compact-on-trim + fired-history compaction (the
/// origin consumer); `cold_bootstrap` wires [`ColdWindowBootstrap`] as the
/// reshard residual importer (the backfill consumer — it reads the cold
/// tier but must never write it, its input *is* the cold tier).
#[allow(clippy::too_many_arguments)]
fn launch_final_fire(
    env: &ClusterEnv,
    input: InputSpec,
    ns: &str,
    out_table: &str,
    window: WindowSpec,
    partitions: usize,
    reducers: usize,
    base: &ProcessorConfig,
    cold_write: Option<(Arc<ColdStore>, ColdTierConfig)>,
    cold_bootstrap: Option<Arc<ColdStore>>,
) -> (StreamingProcessor, Arc<OrderedTable>) {
    ensure_table_at(env, out_table).expect("create backfill output table");
    let (cold_deps, cold_cfg) = match cold_write {
        Some((c, cfg)) => (Some(c), Some(cfg)),
        None => (None, None),
    };
    let proc_cfg = ProcessorConfig {
        mapper_count: partitions,
        reducer_count: reducers,
        mapper_state_table: format!("//sys/{ns}/mapper_state"),
        reducer_state_table: format!("//sys/{ns}/reducer_state"),
        reshard_plan_table: format!("//sys/{ns}/reshard_plan"),
        discovery_dir: format!("//sys/{ns}/discovery"),
        event_time: Some(EventTimeConfig { column: "ts".into() }),
        cold_tier: cold_cfg,
        ..base.clone()
    };
    let fold: Arc<dyn WindowFold> = Arc::new(RoutedActivityFold {
        table: out_table.to_string(),
    });
    let late = OrderedTable::new_with_category(
        &format!("//sys/{ns}/late"),
        windowed_mapped_name_table(),
        reducers,
        env.accounting.clone(),
        WriteCategory::UserOutput,
    );
    let deps = Arc::new(WindowedDeps {
        spec: window,
        fold: fold.clone(),
        state_base: format!("//sys/{ns}/window_state"),
        plan_table: proc_cfg.reshard_plan_table.clone(),
        mapper_state_table: proc_cfg.mapper_state_table.clone(),
        late: late.clone(),
        metrics: env.metrics.clone(),
        scope: proc_cfg.scope_label.clone(),
        consistency: proc_cfg.consistency,
        cold: cold_deps,
    });
    let migrators = WindowMigrators::new(
        env.store.clone(),
        fold,
        deps.state_base.clone(),
        proc_cfg.scope_label.clone(),
    );
    let (exporter, importer) = migrators.pair();
    let importer: Arc<dyn ResidualImporter> = match cold_bootstrap {
        Some(c) => ColdWindowBootstrap::new(migrators.clone(), c),
        None => importer,
    };
    let runtime = ReshardRuntime::new_with_migrators(
        proc_cfg.reshard_plan_table.clone(),
        env.accounting.clone(),
        proc_cfg.scope_label.clone(),
        exporter,
        importer,
    );
    let processor = StreamingProcessor::launch_with_runtime(
        proc_cfg,
        env.clone(),
        input,
        windowed_mapper_factory(),
        windowed_reducer_factory(deps),
        Yson::parse("{}").unwrap(),
        runtime,
    )
    .expect("launch final-fire consumer");
    (processor, late)
}

fn scan_sorted(env: &ClusterEnv, table: &str) -> Vec<UnversionedRow> {
    env.store.scan(table).unwrap_or_default()
}

fn wait_for_rows(
    env: &ClusterEnv,
    table: &str,
    expected: &[UnversionedRow],
    wall_ms: u64,
) -> Vec<UnversionedRow> {
    let deadline = std::time::Instant::now() + std::time::Duration::from_millis(wall_ms);
    let mut rows = Vec::new();
    while std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(50));
        rows = scan_sorted(env, table);
        if rows == expected {
            break;
        }
    }
    rows
}

fn user_output_bytes(snap: &AccountingSnapshot) -> u64 {
    snap.bytes_of(WriteCategory::UserOutput)
}

/// Run the backfill scenario. `drill` is invoked on the **backfill**
/// consumer at [`BackfillDrillPoint::MidBackfill`] and
/// [`BackfillDrillPoint::AtCutover`] — kill/twin drills there must not
/// change one output byte.
pub fn run_backfill(
    cfg: &BackfillCfg,
    drill: impl Fn(&StreamingProcessor, BackfillDrillPoint),
) -> BackfillOutcome {
    assert!(
        cfg.history_waves < cfg.total_waves,
        "need a live tail: history_waves ({}) must be < total_waves ({})",
        cfg.history_waves,
        cfg.total_waves
    );
    let clock = Clock::scaled(4);
    let env = ClusterEnv::new(clock.clone(), cfg.seed);
    // protolint: allow(category, "source input table: the SourceIngest default is the intent")
    let table = OrderedTable::new(
        "//input/backfill",
        input_name_table(),
        cfg.partitions,
        env.accounting.clone(),
    );
    let cold_cfg = ColdTierConfig {
        base: cfg.cold_base.clone(),
    };
    let cold = ColdStore::from_config(env.store.clone(), &cold_cfg);

    // --- origin phase: drain history with the cold tier on ---------------
    let (origin, _origin_late) = launch_final_fire(
        &env,
        InputSpec::Ordered(table.clone()),
        "bf_origin",
        BACKFILL_ORIGIN_TABLE,
        cfg.window,
        cfg.partitions,
        cfg.reducers,
        &cfg.base,
        Some((cold.clone(), cold_cfg.clone())),
        None,
    );
    for wave in 0..cfg.history_waves {
        fill_deterministic_wave(&table, wave, cfg.messages_per_wave);
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    // Every historical row must be consumed, persisted, and trimmed —
    // i.e. compacted into cold — before the fences are cut.
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_millis(cfg.trim_timeout_ms);
    loop {
        let marks = table.low_water_marks();
        if (0..cfg.partitions).all(|p| marks[p] == table.end_index(p)) {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "origin consumer failed to trim all history within {} ms \
             (low water marks {marks:?})",
            cfg.trim_timeout_ms
        );
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    origin.stop();
    let fences = table.low_water_marks();
    let segment_chunks: usize = (0..cfg.partitions)
        .map(|p| cold.segment_chunks(p).map(|c| c.len()).unwrap_or(0))
        .sum();
    let history_chunks = cold.history_chunks().map(|c| c.len()).unwrap_or(0);

    // --- live tail arrives while no consumer is running ------------------
    let chunk_read_0 = env.metrics.get_counter(names::COLD_CHUNK_BYTES_READ);
    let live_read_0 = env.metrics.get_counter(names::COLD_LIVE_BYTES_READ);
    let snap_0 = env.accounting.snapshot();
    for wave in cfg.history_waves..cfg.total_waves {
        fill_deterministic_wave(&table, wave, cfg.messages_per_wave);
    }

    // --- backfill phase: a day-N consumer over cold chunks + live tail ---
    let input = ColdInput::new(
        cold.clone(),
        table.clone(),
        fences.clone(),
        Some(env.metrics.clone()),
    );
    let (backfill, late) = launch_final_fire(
        &env,
        InputSpec::BoundedRange(input),
        "bf_day_n",
        BACKFILL_TABLE,
        cfg.window,
        cfg.partitions,
        cfg.reducers,
        &cfg.base,
        None, // a backfill consumer never writes the tier it reads
        Some(cold.clone()),
    );
    std::thread::sleep(std::time::Duration::from_millis(100));
    drill(&backfill, BackfillDrillPoint::MidBackfill);
    // Wait for the first live-tail read — the cutover — then drill again.
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_millis(cfg.drain_timeout_ms);
    while env.metrics.get_counter(names::COLD_LIVE_BYTES_READ) == live_read_0
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    drill(&backfill, BackfillDrillPoint::AtCutover);

    backfill
        .close_event_time(EVENT_TIME_CLOSED)
        .expect("close event time");
    let expected = expected_windowed_rows(&WindowedCfg {
        partitions: cfg.partitions,
        waves: cfg.total_waves,
        messages_per_wave: cfg.messages_per_wave,
        window: cfg.window,
        ..WindowedCfg::default()
    });
    let backfill_rows = wait_for_rows(&env, BACKFILL_TABLE, &expected, cfg.drain_timeout_ms);

    let snap_1 = env.accounting.snapshot();
    let chunk_bytes_read = env.metrics.get_counter(names::COLD_CHUNK_BYTES_READ) - chunk_read_0;
    let live_bytes_read = env.metrics.get_counter(names::COLD_LIVE_BYTES_READ) - live_read_0;
    let backfill_user_output = user_output_bytes(&snap_1) - user_output_bytes(&snap_0);
    let late_rows: i64 = (0..late.tablet_count()).map(|i| late.end_index(i)).sum();
    let report = backfill.wa_report("backfill from cold (day-N consumer)");
    backfill.stop();

    // --- reshard-bootstrap-from-cold demo: an empty handoff (exporter
    // gone) restores the fired marker from the cold history chunks -------
    let boot_base = "//sys/bf_boot/window_state";
    let migrators = WindowMigrators::new(
        env.store.clone(),
        Arc::new(RoutedActivityFold {
            table: BACKFILL_TABLE.to_string(),
        }) as Arc<dyn WindowFold>,
        boot_base,
        None,
    );
    let boot = ColdWindowBootstrap::new(migrators, cold.clone());
    let restored_fired_marker = boot.fired_watermark_from_cold();
    let mut bootstrap_marker_verified = false;
    if let Some(wm) = restored_fired_marker {
        let ctx = ImportCtx {
            new_index: 0,
            new_partitions: cfg.reducers,
            epoch: 1,
        };
        let mut txn = env.store.begin();
        boot.import(&ctx, &[], &mut txn)
            .expect("bootstrap import from cold");
        txn.commit().expect("commit bootstrap import");
        bootstrap_marker_verified = env
            .store
            .scan(&window_state_table(boot_base, 1))
            .ok()
            .and_then(|rows| {
                let acc = rows.first()?.get(2)?.as_str()?.to_string();
                Yson::parse(&acc).ok()?.as_i64().ok()
            })
            .is_some_and(|v| v == wm);
    }

    // --- control: re-ingest everything from the source, day zero ---------
    let control_env = ClusterEnv::new(Clock::scaled(4), cfg.seed ^ 0x5A5A);
    // protolint: allow(category, "source input table: the SourceIngest default is the intent")
    let control_table = OrderedTable::new(
        "//input/backfill_live",
        input_name_table(),
        cfg.partitions,
        control_env.accounting.clone(),
    );
    let (control, _control_late) = launch_final_fire(
        &control_env,
        InputSpec::Ordered(control_table.clone()),
        "bf_day0",
        BACKFILL_CONTROL_TABLE,
        cfg.window,
        cfg.partitions,
        cfg.reducers,
        &cfg.base,
        None,
        None,
    );
    for wave in 0..cfg.total_waves {
        fill_deterministic_wave(&control_table, wave, cfg.messages_per_wave);
    }
    control
        .close_event_time(EVENT_TIME_CLOSED)
        .expect("close control event time");
    let control_rows = wait_for_rows(
        &control_env,
        BACKFILL_CONTROL_TABLE,
        &expected,
        cfg.drain_timeout_ms,
    );
    let control_report = control.wa_report("re-ingest from source (control)");
    control.stop();
    let control_snap = control_env.accounting.snapshot();

    BackfillOutcome {
        expected,
        backfill_rows,
        control_rows,
        fences,
        segment_chunks,
        history_chunks,
        restored_fired_marker,
        bootstrap_marker_verified,
        report,
        control_report,
        chunk_bytes_read,
        live_bytes_read,
        backfill_user_output,
        reingest_source_bytes: control_snap.bytes_of(WriteCategory::SourceIngest),
        reingest_mapper_read: control_env.metrics.get_counter(names::MAPPER_BYTES_READ),
        reingest_user_output: user_output_bytes(&control_snap),
        late_rows,
        env,
        control_env,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dyntable::DynTableStore;
    use crate::rows::RowsetBuilder;
    use crate::storage::WriteAccounting;

    #[test]
    fn default_cfg_keeps_timestamps_f32_exact() {
        // Same precondition the elastic generator enforces: the largest
        // wave timestamp must stay below 2^24 or byte-identity becomes
        // batching-dependent.
        let cfg = BackfillCfg::default();
        let max_ts = 10_000
            + (cfg.total_waves as i64 - 1) * 4_000_000
            + (cfg.partitions as i64 - 1) * 500_000
            + (cfg.messages_per_wave as i64) * 100
            + 8;
        assert!(max_ts < (1 << 24), "wave plan emits ts {max_ts} >= 2^24");
        assert!(cfg.history_waves < cfg.total_waves);
    }

    #[test]
    fn routed_fold_writes_to_its_own_table() {
        let store = DynTableStore::new(WriteAccounting::new());
        store
            .create_table("//out/routed", windowed_schema(), WriteCategory::UserOutput)
            .unwrap();
        let fold = RoutedActivityFold {
            table: "//out/routed".to_string(),
        };
        let mut b = RowsetBuilder::new(windowed_mapped_name_table());
        b.push(row!["alice", "hahn", 50i64]);
        let rs = b.build();
        let mut acc = fold.zero();
        fold.fold(&mut acc, &rs.rows()[0]);
        let key = fold.key(&rs.rows()[0]).unwrap();

        let mut txn = store.begin();
        fold.emit(0, 250_000, &key, &acc, &mut txn).unwrap();
        txn.commit().unwrap();
        let rows = store.scan("//out/routed").unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get(1).unwrap().as_str(), Some("alice"));
        assert_eq!(rows[0].get(3).unwrap().as_i64(), Some(1));
        assert_eq!(rows[0].get(4).unwrap().as_i64(), Some(50));
    }

    #[test]
    fn expected_rows_cover_all_waves() {
        let cfg = BackfillCfg::default();
        let all = expected_windowed_rows(&WindowedCfg {
            partitions: cfg.partitions,
            waves: cfg.total_waves,
            messages_per_wave: cfg.messages_per_wave,
            window: cfg.window,
            ..WindowedCfg::default()
        });
        let history_only = expected_windowed_rows(&WindowedCfg {
            partitions: cfg.partitions,
            waves: cfg.history_waves,
            messages_per_wave: cfg.messages_per_wave,
            window: cfg.window,
            ..WindowedCfg::default()
        });
        // The live tail genuinely extends the output: the byte-identity
        // gate cannot pass on a backfill that never cut over.
        assert!(all.len() > history_only.len());
    }
}
