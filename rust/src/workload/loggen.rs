//! Synthetic master-node log generator.
//!
//! Each produced input row is a *batched message*: several joined log
//! lines (the paper: "messages consisting of batched and joined master
//! node log entries"; mappers "split each read message back into
//! individual log messages"). Line format:
//!
//! ```text
//! ts=<ms> cluster=<name> method=<op> [user=<name>] dur=<us>
//! ```
//!
//! * ~85 % of lines carry no `user=` field (the paper's 80–90 % filter);
//! * users are zipf-distributed with `root` as rank 0 (the paper's skew);
//! * per-partition rates vary (configured in [`super::producer`]).

use crate::util::prng::{Prng, Zipf};
use crate::util::Clock;

/// Knobs for the generator.
#[derive(Debug, Clone)]
pub struct LogGenConfig {
    /// Distinct user names (rank 0 = "root").
    pub user_count: usize,
    /// Zipf exponent for user frequency.
    pub zipf_s: f64,
    /// Probability a log line has a user field.
    pub user_field_prob: f64,
    /// Log lines joined into one batched message.
    pub lines_per_message: (u64, u64),
    /// Cluster names (the paper's topic spanned 5 clusters).
    pub clusters: Vec<String>,
}

impl Default for LogGenConfig {
    fn default() -> Self {
        LogGenConfig {
            user_count: 500,
            zipf_s: 1.2,
            user_field_prob: 0.15,
            lines_per_message: (4, 12),
            clusters: ["hahn", "arnold", "freud", "markov", "bohr"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
        }
    }
}

const METHODS: &[&str] = &[
    "LookupRows", "WriteRows", "Commit", "StartTransaction", "PingTransaction", "GetNode",
    "SetNode", "ListNode", "CreateObject", "Heartbeat",
];

/// Deterministic generator of batched log messages.
pub struct LogGen {
    cfg: LogGenConfig,
    users: Vec<String>,
    zipf: Zipf,
    prng: Prng,
    clock: Clock,
    /// Cluster this generator instance writes for (paper: each partition
    /// belongs to one cluster).
    cluster: String,
}

impl LogGen {
    pub fn new(cfg: LogGenConfig, clock: Clock, seed: u64, partition: usize) -> LogGen {
        let mut users = Vec::with_capacity(cfg.user_count);
        users.push("root".to_string());
        let mut name_rng = Prng::seeded(0xD06F00D);
        for i in 1..cfg.user_count {
            users.push(format!("{}-{i}", name_rng.ident(5)));
        }
        let cluster = cfg.clusters[partition % cfg.clusters.len()].clone();
        LogGen {
            zipf: Zipf::new(cfg.user_count, cfg.zipf_s),
            users,
            prng: Prng::seeded(seed ^ (partition as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            clock,
            cfg,
            cluster,
        }
    }

    /// One batched message (several joined lines) + its line count.
    pub fn next_message(&mut self) -> (String, usize) {
        let (lo, hi) = self.cfg.lines_per_message;
        let lines = self.prng.gen_range(lo, hi) as usize;
        let now = self.clock.now_ms();
        let mut out = String::with_capacity(lines * 64);
        for i in 0..lines {
            if i > 0 {
                out.push('\n');
            }
            let method = self.prng.choose(METHODS);
            let dur = self.prng.gen_range(10, 50_000);
            if self.prng.chance(self.cfg.user_field_prob) {
                let user = &self.users[self.zipf.sample(&mut self.prng)];
                out.push_str(&format!(
                    "ts={now} cluster={} method={method} user={user} dur={dur}",
                    self.cluster
                ));
            } else {
                out.push_str(&format!(
                    "ts={now} cluster={} method={method} dur={dur}",
                    self.cluster
                ));
            }
        }
        (out, lines)
    }

    pub fn cluster(&self) -> &str {
        &self.cluster
    }
}

/// One parsed log line (what the analytics mapper extracts).
#[derive(Debug, Clone, PartialEq)]
pub struct ParsedLine<'a> {
    pub ts: i64,
    pub cluster: &'a str,
    pub user: Option<&'a str>,
}

/// Parse a single log line; `None` for malformed input (dropped, never
/// panics — poison-pill safety).
///
/// Byte-level scanner (§Perf iteration 6): the str `split`/`split_once`
/// version showed up as ~7 % of the end-to-end profile (CharSearcher +
/// memchr); this loop walks the bytes once with no intermediate slices
/// beyond the field views themselves.
pub fn parse_line(line: &str) -> Option<ParsedLine<'_>> {
    let bytes = line.as_bytes();
    let mut ts = None;
    let mut cluster = None;
    let mut user = None;
    let mut i = 0;
    while i < bytes.len() {
        // Field start; find '=' and the field end.
        let start = i;
        let mut eq = None;
        while i < bytes.len() && bytes[i] != b' ' {
            if bytes[i] == b'=' && eq.is_none() {
                eq = Some(i);
            }
            i += 1;
        }
        let end = i;
        i += 1; // skip the space
        let eq = eq?;
        let key = &bytes[start..eq];
        // SAFETY-free: slices at byte positions of ASCII delimiters keep
        // UTF-8 boundaries intact.
        let value = &line[eq + 1..end];
        match key {
            b"ts" => ts = value.parse::<i64>().ok(),
            b"cluster" => cluster = Some(value),
            b"user" => user = Some(value),
            _ => {}
        }
    }
    Some(ParsedLine {
        ts: ts?,
        cluster: cluster?,
        user,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> LogGen {
        LogGen::new(LogGenConfig::default(), Clock::realtime(), 42, 0)
    }

    #[test]
    fn messages_are_batched_lines() {
        let mut g = gen();
        let (msg, lines) = g.next_message();
        assert_eq!(msg.lines().count(), lines);
        let (lo, hi) = LogGenConfig::default().lines_per_message;
        assert!((lo as usize..=hi as usize).contains(&lines));
    }

    #[test]
    fn lines_parse_back() {
        let mut g = gen();
        for _ in 0..50 {
            let (msg, _) = g.next_message();
            for line in msg.lines() {
                let p = parse_line(line).unwrap_or_else(|| panic!("unparseable: {line}"));
                assert!(p.ts >= 0);
                assert!(!p.cluster.is_empty());
            }
        }
    }

    #[test]
    fn filter_rate_roughly_85_percent() {
        let mut g = gen();
        let mut total = 0;
        let mut with_user = 0;
        for _ in 0..500 {
            let (msg, _) = g.next_message();
            for line in msg.lines() {
                total += 1;
                if parse_line(line).unwrap().user.is_some() {
                    with_user += 1;
                }
            }
        }
        let frac = with_user as f64 / total as f64;
        assert!(
            (0.10..=0.20).contains(&frac),
            "user-field fraction {frac} outside the paper's 10–20 %"
        );
    }

    #[test]
    fn users_are_zipf_skewed_with_root_on_top() {
        let mut g = gen();
        let mut root = 0u32;
        let mut other = 0u32;
        for _ in 0..3000 {
            let (msg, _) = g.next_message();
            for line in msg.lines() {
                if let Some(u) = parse_line(line).unwrap().user {
                    if u == "root" {
                        root += 1;
                    } else {
                        other += 1;
                    }
                }
            }
        }
        assert!(root > 0);
        // rank-0 of zipf(1.2, 500) carries ~15 % of mass; "overwhelmingly
        // more … than regular users" (each regular user ≤ a few percent).
        assert!(
            root as f64 > 0.05 * (root + other) as f64,
            "root too rare: {root}/{}",
            root + other
        );
    }

    #[test]
    fn partitions_map_to_clusters_deterministically() {
        let cfg = LogGenConfig::default();
        let a = LogGen::new(cfg.clone(), Clock::realtime(), 1, 0);
        let b = LogGen::new(cfg.clone(), Clock::realtime(), 1, 5);
        assert_eq!(a.cluster(), b.cluster(), "0 and 5 share a cluster (mod 5)");
        let c = LogGen::new(cfg, Clock::realtime(), 1, 2);
        assert_ne!(a.cluster(), c.cluster());
    }

    #[test]
    fn generator_deterministic_given_seed() {
        let clock = Clock::realtime();
        let cfg = LogGenConfig::default();
        let mut a = LogGen::new(cfg.clone(), clock.clone(), 7, 3);
        let mut b = LogGen::new(cfg, clock, 7, 3);
        // Timestamps differ by clock reads; compare the structure instead.
        let (ma, la) = a.next_message();
        let (mb, lb) = b.next_message();
        assert_eq!(la, lb);
        let strip = |s: &str| {
            s.lines()
                .map(|l| {
                    l.split(' ')
                        .filter(|f| !f.starts_with("ts="))
                        .collect::<Vec<_>>()
                        .join(" ")
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(strip(&ma), strip(&mb));
    }

    #[test]
    fn parse_line_rejects_garbage() {
        assert!(parse_line("").is_none());
        assert!(parse_line("no fields here").is_none());
        assert!(parse_line("cluster=x dur=1").is_none()); // missing ts
        assert!(parse_line("ts=abc cluster=x").is_none()); // bad ts
    }
}
