//! The evaluation workload (§5.2), synthesized.
//!
//! The paper's testbed consumed the YT master-node log topic: 450
//! partitions, ~3.5 GB/s of "batched and joined master node log entries",
//! where 80–90 % of individual messages lack a `user` field and key
//! frequency is heavily skewed ("root and a few other system users").
//! [`loggen`] reproduces those *statistical* properties at laptop scale;
//! [`producer`] feeds the generated batches into the input queues at a
//! configurable (uneven per-partition) rate; [`analytics`] is the user
//! code of the experiment: split batched messages, filter rows without a
//! user, hash-partition by (user, cluster), and aggregate
//! (count, last-access timestamp) per (user, cluster) into a shared sorted
//! table.

pub mod loggen;
pub mod producer;
pub mod analytics;
pub mod sessions;
pub mod elastic;
pub mod windowed;
pub mod consistency;
pub mod backfill;

pub use analytics::{analytics_mapper_factory, analytics_reducer_factory, OUTPUT_TABLE};
pub use backfill::{run_backfill, BackfillCfg, BackfillDrillPoint, BackfillOutcome};
pub use consistency::{
    divergence_vs_truth, ground_truth_counts, run_consistency_tier, ConsistencyCfg, TierOutcome,
};
pub use elastic::{
    auto_driver_config, run_elastic, run_elastic_auto, ElasticCfg, ElasticOutcome,
};
pub use loggen::{LogGen, LogGenConfig};
pub use producer::{start_producers, ProducerConfig, ProducerHandle};
pub use sessions::{two_stage_topology, SESSIONS_TABLE};
pub use windowed::{run_windowed, WindowedCfg, WindowedMode, WindowedOutcome, WINDOWED_TABLE};
