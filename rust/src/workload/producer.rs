//! Producer threads feeding generated log batches into the input queues.
//!
//! "The write rate to the topic is steady … the write rate into individual
//! partitions varies with time and even more across clusters" (§5.2) —
//! each partition gets its own rate multiplier plus a slow sinusoidal
//! modulation.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::coordinator::InputSpec;
use crate::row;
use crate::rows::UnversionedRow;
use crate::util::{Clock, Prng};

use super::loggen::{LogGen, LogGenConfig};

/// Producer tuning.
#[derive(Debug, Clone)]
pub struct ProducerConfig {
    /// Mean messages per second per partition.
    pub messages_per_sec: f64,
    /// Messages appended per queue write.
    pub batch_size: usize,
    /// Max multiplier spread across partitions (1.0 = even).
    pub unevenness: f64,
    pub loggen: LogGenConfig,
}

impl Default for ProducerConfig {
    fn default() -> Self {
        ProducerConfig {
            messages_per_sec: 400.0,
            batch_size: 16,
            unevenness: 2.0,
            loggen: LogGenConfig::default(),
        }
    }
}

/// Handle over the running producer fleet.
pub struct ProducerHandle {
    stop: Arc<AtomicBool>,
    joins: Vec<std::thread::JoinHandle<()>>,
    produced_rows: Arc<AtomicU64>,
    produced_bytes: Arc<AtomicU64>,
}

impl ProducerHandle {
    /// Stop all producers; returns the final (rows, bytes) totals.
    pub fn stop(self) -> (u64, u64) {
        self.stop.store(true, Ordering::SeqCst);
        for j in self.joins {
            let _ = j.join();
        }
        (
            self.produced_rows.load(Ordering::Relaxed),
            self.produced_bytes.load(Ordering::Relaxed),
        )
    }

    pub fn produced_rows(&self) -> u64 {
        self.produced_rows.load(Ordering::Relaxed)
    }

    pub fn produced_bytes(&self) -> u64 {
        self.produced_bytes.load(Ordering::Relaxed)
    }
}

/// Start one producer thread per input partition.
pub fn start_producers(
    input: InputSpec,
    clock: Clock,
    cfg: ProducerConfig,
    seed: u64,
) -> ProducerHandle {
    let stop = Arc::new(AtomicBool::new(false));
    let produced_rows = Arc::new(AtomicU64::new(0));
    let produced_bytes = Arc::new(AtomicU64::new(0));
    let mut joins = Vec::new();
    let mut seeder = Prng::seeded(seed);

    // Producers feed *source* partitions; for grouped inputs that is the
    // underlying partition count, not the (smaller) mapper count.
    let produce_partitions = match &input {
        InputSpec::Grouped(g) => g.source.partition_count(),
        other => other.partition_count(),
    };
    for partition in 0..produce_partitions {
        let input = input.clone();
        let clock = clock.clone();
        let cfg = cfg.clone();
        let stop = stop.clone();
        let produced_rows = produced_rows.clone();
        let produced_bytes = produced_bytes.clone();
        let mut prng = seeder.fork();

        joins.push(
            std::thread::Builder::new()
                .name(format!("producer-{partition}"))
                .spawn(move || {
                    let mut gen = LogGen::new(cfg.loggen.clone(), clock.clone(), seed, partition);
                    // Static per-partition unevenness in [1/u, u].
                    let spread = cfg.unevenness.max(1.0);
                    let mult = spread.powf(prng.next_f64() * 2.0 - 1.0);
                    let rate = cfg.messages_per_sec * mult;
                    let mut budget = 0.0f64;
                    let mut last_ms = clock.now_ms();
                    while !stop.load(Ordering::SeqCst) {
                        let now = clock.now_ms();
                        // Slow sinusoidal modulation ±30 %.
                        let phase = (now as f64 / 10_000.0 + partition as f64).sin() * 0.3 + 1.0;
                        budget += rate * phase * (now - last_ms) as f64 / 1000.0;
                        last_ms = now;
                        let n = (budget as usize).min(cfg.batch_size * 4);
                        if n == 0 {
                            clock.sleep_ms(5);
                            continue;
                        }
                        budget -= n as f64;
                        let mut rows: Vec<UnversionedRow> = Vec::with_capacity(n);
                        let mut bytes = 0u64;
                        for _ in 0..n {
                            let (msg, _) = gen.next_message();
                            bytes += msg.len() as u64;
                            rows.push(row![msg, clock.now_ms() as i64]);
                        }
                        let append = match &input {
                            InputSpec::Ordered(t) => t.append(partition, rows).map(|_| ()),
                            InputSpec::LogBroker(t) => t.append(partition, rows),
                            // Producers always feed the *source* partitions;
                            // grouping only changes the consumer side.
                            InputSpec::Grouped(g) => match &g.source {
                                InputSpec::Ordered(t) => t.append(partition, rows).map(|_| ()),
                                InputSpec::LogBroker(t) => t.append(partition, rows),
                                InputSpec::Grouped(_) => {
                                    unreachable!("nested grouped inputs are not supported")
                                }
                            },
                        };
                        if append.is_ok() {
                            produced_rows.fetch_add(n as u64, Ordering::Relaxed);
                            produced_bytes.fetch_add(bytes, Ordering::Relaxed);
                        }
                        clock.sleep_ms(5);
                    }
                })
                .expect("spawn producer"),
        );
    }

    ProducerHandle {
        stop,
        joins,
        produced_rows,
        produced_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queue::input_name_table;
    use crate::queue::ordered_table::OrderedTable;
    use crate::storage::WriteAccounting;

    #[test]
    fn producers_fill_partitions_and_stop() {
        let clock = Clock::scaled(20); // speed the sim up
        let table = OrderedTable::new("in", input_name_table(), 3, WriteAccounting::new());
        let input = InputSpec::Ordered(table.clone());
        let cfg = ProducerConfig {
            messages_per_sec: 2000.0,
            ..ProducerConfig::default()
        };
        let h = start_producers(input, clock, cfg, 1);
        std::thread::sleep(std::time::Duration::from_millis(120));
        h.stop();
        let total: i64 = (0..3).map(|p| table.end_index(p)).sum();
        assert!(total > 0, "producers wrote nothing");
        for p in 0..3 {
            assert!(table.end_index(p) > 0, "partition {p} starved");
        }
    }

    #[test]
    fn produced_counters_track() {
        let clock = Clock::scaled(20);
        let table = OrderedTable::new("in", input_name_table(), 1, WriteAccounting::new());
        let h = start_producers(
            InputSpec::Ordered(table.clone()),
            clock,
            ProducerConfig {
                messages_per_sec: 2000.0,
                ..ProducerConfig::default()
            },
            2,
        );
        std::thread::sleep(std::time::Duration::from_millis(100));
        let (rows, bytes) = h.stop();
        assert!(rows > 0);
        assert!(bytes > rows, "bytes should exceed row count");
        assert_eq!(table.end_index(0) as u64, rows);
    }
}
