//! The event-time windowing scenario: per-(user, cluster) activity counts
//! over tumbling event-time windows, run two ways over **identical**
//! input:
//!
//! * **per-batch upsert** — the classic shape every shared-table workload
//!   here uses: each reducer batch re-commits the touched
//!   `(window, user, cluster)` output rows, so `UserOutput` bytes scale
//!   with O(batches per key);
//! * **final-fire** — the [`crate::eventtime`] subsystem: open windows
//!   accumulate in compact `EventTime` state and each output row is
//!   written exactly once when the fleet watermark passes window end.
//!
//! Both variants drain to the *same* output table contents (the fold is
//! batch-invariant), so `figure window` can compare their WA honestly and
//! assert byte-identity — including a drilled final-fire run (kill +
//! duplicate reducer, one mid-window 4→8 reshard migrating the open
//! windows) against the fault-free static run.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::api::{
    hash_partition, partitioning, Client, Mapper, MapperFactory, MapperSpec, PartitionedRowset,
    Reducer, ReducerFactory, ReducerSpec,
};
use crate::coordinator::processor::ClusterEnv;
use crate::coordinator::{EventTimeConfig, InputSpec, ProcessorConfig, StreamingProcessor};
use crate::dyntable::{Transaction, TxnError};
use crate::eventtime::{
    windowed_reducer_factory, WindowFold, WindowMigrators, WindowSpec, WindowedDeps,
    EVENT_TIME_CLOSED,
};
use crate::metrics::hub::names;
use crate::metrics::WaReport;
use crate::queue::ordered_table::OrderedTable;
use crate::queue::{input_name_table, INPUT_COL_PAYLOAD};
use crate::reshard::{ReshardRuntime, ReshardStats};
use crate::row;
use crate::rows::{
    ColumnSchema, ColumnType, NameTable, RowsetBuilder, TableSchema, UnversionedRow,
    UnversionedRowset, Value,
};
use crate::storage::WriteCategory;
use crate::util::yson::Yson;
use crate::util::Clock;
use crate::workload::elastic::{deterministic_wave_user_events, fill_deterministic_wave_slice};
use crate::workload::loggen::parse_line;

/// The windowed output table:
/// (window_start, user, cluster) → (count, last_ts).
pub const WINDOWED_TABLE: &str = "//out/windowed_activity";

/// Columns of the mapped (shuffled) rows; `ts` is the event-time column.
pub fn windowed_mapped_name_table() -> Arc<NameTable> {
    NameTable::new(&["user", "cluster", "ts"])
}

const COL_USER: usize = 0;
const COL_CLUSTER: usize = 1;
const COL_TS: usize = 2;

/// Schema of [`WINDOWED_TABLE`].
pub fn windowed_schema() -> TableSchema {
    TableSchema::new(vec![
        ColumnSchema::key("window_start", ColumnType::Int64),
        ColumnSchema::key("user", ColumnType::Str),
        ColumnSchema::key("cluster", ColumnType::Str),
        ColumnSchema::value("count", ColumnType::Int64),
        ColumnSchema::value("last_ts", ColumnType::Int64),
    ])
}

/// Create [`WINDOWED_TABLE`] if missing.
pub fn ensure_windowed_table(client: &Client) -> Result<(), crate::dyntable::store::StoreError> {
    use crate::dyntable::store::StoreError;
    match client
        .store
        .create_table(WINDOWED_TABLE, windowed_schema(), WriteCategory::UserOutput)
    {
        Ok(_) | Err(StoreError::AlreadyExists(_)) => Ok(()),
        Err(e) => Err(e),
    }
}

/// The windowed log mapper: parse log lines, filter rows without a user,
/// route by `owner(composite_key_hash(user, cluster))` — the *same*
/// ownership function the window state uses, which is what lets the
/// final-fire reducer (and the reshard migrators) re-derive who owns a
/// window. Publishes the hash column so the reshard dual-route can re-own
/// every routed row under the old partition count without a second map.
struct WindowedLogMapper {
    reducers: usize,
}

impl Mapper for WindowedLogMapper {
    fn map(&mut self, rows: UnversionedRowset) -> PartitionedRowset {
        let mut b = RowsetBuilder::new(windowed_mapped_name_table());
        let mut partitions = Vec::new();
        let mut hashes = Vec::new();
        for r in rows.rows() {
            let Some(payload) = r.get(INPUT_COL_PAYLOAD).and_then(Value::as_str) else {
                continue;
            };
            for raw in payload.lines() {
                let Some(p) = parse_line(raw) else { continue };
                let Some(user) = p.user else { continue };
                // Hash the composite key once; the partition index and
                // the published hash column both derive from it.
                let h = partitioning::composite_key_hash(&[user, p.cluster]);
                partitions.push(partitioning::owner(h, self.reducers));
                hashes.push(h);
                b.push(row![user, p.cluster, p.ts]);
            }
        }
        PartitionedRowset::with_key_hashes(b.build(), partitions, hashes)
    }

    fn publishes_key_hashes(&self) -> bool {
        true
    }
}

/// `CreateMapper` for [`WindowedLogMapper`].
pub fn windowed_mapper_factory() -> MapperFactory {
    Arc::new(
        |_cfg: &Yson, _client: &Client, _nt: Arc<NameTable>, spec: &MapperSpec| {
            Box::new(WindowedLogMapper {
                reducers: spec.num_reducers,
            }) as Box<dyn Mapper>
        },
    )
}

/// The windowed activity fold: count rows + max ts per
/// (window, user, cluster); accumulator `[count; last_ts]`.
pub struct ActivityWindowFold;

impl ActivityWindowFold {
    pub(crate) fn unpack(acc: &Yson) -> (i64, i64) {
        let list = acc.as_list().ok().unwrap_or(&[]);
        (
            list.first().and_then(|v| v.as_i64().ok()).unwrap_or(0),
            list.get(1).and_then(|v| v.as_i64().ok()).unwrap_or(i64::MIN),
        )
    }

    fn pack(count: i64, last_ts: i64) -> Yson {
        Yson::List(vec![Yson::Int(count), Yson::Int(last_ts)])
    }
}

impl WindowFold for ActivityWindowFold {
    fn event_ts(&self, row: &UnversionedRow) -> Option<i64> {
        row.get(COL_TS).and_then(Value::as_i64)
    }

    fn key(&self, row: &UnversionedRow) -> Option<String> {
        let user = row.get(COL_USER).and_then(Value::as_str)?;
        let cluster = row.get(COL_CLUSTER).and_then(Value::as_str)?;
        Some(partitioning::composite_key(&[user, cluster]))
    }

    fn zero(&self) -> Yson {
        Self::pack(0, i64::MIN)
    }

    fn fold(&self, acc: &mut Yson, row: &UnversionedRow) {
        let (count, last) = Self::unpack(acc);
        let ts = row.get(COL_TS).and_then(Value::as_i64).unwrap_or(i64::MIN);
        *acc = Self::pack(count + 1, last.max(ts));
    }

    fn merge(&self, into: &mut Yson, other: &Yson) {
        let (c1, l1) = Self::unpack(into);
        let (c2, l2) = Self::unpack(other);
        *into = Self::pack(c1 + c2, l1.max(l2));
    }

    fn emit(
        &self,
        window_start: i64,
        _window_end: i64,
        key: &str,
        acc: &Yson,
        txn: &mut Transaction,
    ) -> Result<(), TxnError> {
        let mut parts = key.split('\u{1f}');
        let (Some(user), Some(cluster)) = (parts.next(), parts.next()) else {
            return Ok(()); // unreachable for keys this workload builds
        };
        let (count, last_ts) = Self::unpack(acc);
        txn.write(
            WINDOWED_TABLE,
            row![window_start, user, cluster, count, last_ts],
        )
    }
}

/// The per-batch-upsert baseline reducer: identical fold, but every batch
/// re-commits the touched output rows (read-modify-write in the
/// exactly-once transaction) — the classic WA shape.
pub struct WindowedUpsertReducer {
    client: Client,
    window: WindowSpec,
}

impl WindowedUpsertReducer {
    fn attempt(
        &self,
        folds: &BTreeMap<(i64, String, String), (i64, i64)>,
    ) -> Result<Transaction, crate::dyntable::TxnError> {
        let mut txn = self.client.begin();
        for ((w, user, cluster), (count, last_ts)) in folds {
            let key = vec![
                Value::Int64(*w),
                Value::from(user.as_str()),
                Value::from(cluster.as_str()),
            ];
            // Lookup errors must propagate: treating an unreadable row as
            // absent would blind-write a reset count without the read
            // joining the CAS set.
            let (mut c, mut l) = (0i64, i64::MIN);
            if let Some(existing) = txn.lookup(WINDOWED_TABLE, &key)? {
                c = existing.get(3).and_then(Value::as_i64).unwrap_or(0);
                l = existing.get(4).and_then(Value::as_i64).unwrap_or(i64::MIN);
            }
            txn.write(
                WINDOWED_TABLE,
                row![*w, user.as_str(), cluster.as_str(), c + count, l.max(*last_ts)],
            )?;
        }
        Ok(txn)
    }
}

impl Reducer for WindowedUpsertReducer {
    fn reduce(&mut self, rows: UnversionedRowset) -> Option<Transaction> {
        if rows.is_empty() {
            return None;
        }
        // Pre-aggregate the batch per (window, user, cluster).
        let mut folds: BTreeMap<(i64, String, String), (i64, i64)> = BTreeMap::new();
        for r in rows.rows() {
            let (Some(user), Some(cluster), Some(ts)) = (
                r.get(COL_USER).and_then(Value::as_str),
                r.get(COL_CLUSTER).and_then(Value::as_str),
                r.get(COL_TS).and_then(Value::as_i64),
            ) else {
                continue;
            };
            let w = self.window.window_start(ts);
            let e = folds
                .entry((w, user.to_string(), cluster.to_string()))
                .or_insert((0, i64::MIN));
            e.0 += 1;
            e.1 = e.1.max(ts);
        }
        if folds.is_empty() {
            return None;
        }
        // Returning `None` here would let the main procedure advance the
        // meta-state without these folds (silent row loss) — same policy
        // as [`crate::eventtime::WindowedReducer`]: retry transient
        // failures, crash for a supervisor restart if they persist.
        for _ in 0..500 {
            match self.attempt(&folds) {
                Ok(txn) => return Some(txn),
                Err(_) => std::thread::sleep(std::time::Duration::from_millis(2)),
            }
        }
        panic!("windowed upsert reducer: store kept failing; crashing for restart");
    }
}

/// `CreateReducer` for the upsert baseline.
pub fn windowed_upsert_reducer_factory(window: WindowSpec) -> ReducerFactory {
    Arc::new(move |_cfg: &Yson, client: &Client, _spec: &ReducerSpec| {
        let _ = ensure_windowed_table(client);
        Box::new(WindowedUpsertReducer {
            client: client.clone(),
            window,
        }) as Box<dyn Reducer>
    })
}

/// Which output discipline a run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WindowedMode {
    /// Watermark-driven final-fire through [`crate::eventtime`].
    FinalFire,
    /// Classic per-batch upsert (the WA baseline).
    PerBatchUpsert,
}

/// Scenario knobs (same deterministic wave plan as the elastic scenario).
#[derive(Debug, Clone)]
pub struct WindowedCfg {
    pub partitions: usize,
    pub initial_reducers: usize,
    /// Total input waves (each wave's events are fully deterministic).
    pub waves: usize,
    /// Reducer-count targets applied after the matching wave, exactly
    /// like [`crate::workload::elastic::ElasticCfg::reshard_to`] — with
    /// open windows, every reshard is a *mid-window* reshard.
    pub reshard_to: Vec<usize>,
    pub messages_per_wave: usize,
    pub seed: u64,
    pub window: WindowSpec,
    pub base: ProcessorConfig,
    pub reshard_timeout_ms: u64,
    pub drain_timeout_ms: u64,
}

impl Default for WindowedCfg {
    fn default() -> Self {
        WindowedCfg {
            partitions: 4,
            initial_reducers: 4,
            waves: 2,
            reshard_to: vec![],
            messages_per_wave: 40,
            seed: 0x51DE,
            window: WindowSpec::tumbling(250_000),
            base: ProcessorConfig {
                backoff_ms: 5,
                trim_period_ms: 100,
                restart_delay_ms: 100,
                split_brain_delay_ms: 50,
                session_ttl_ms: 1_500,
                heartbeat_period_ms: 100,
                ..ProcessorConfig::default()
            },
            reshard_timeout_ms: 30_000,
            drain_timeout_ms: 45_000,
        }
    }
}

/// What a windowed run leaves behind.
pub struct WindowedOutcome {
    /// Predicted output rows, in table key order.
    pub expected: Vec<UnversionedRow>,
    /// Drained output rows, in table key order.
    pub rows: Vec<UnversionedRow>,
    pub report: WaReport,
    /// Rows that landed on the late side channel (0 for the in-order
    /// deterministic waves — asserted by the figure).
    pub late_rows: i64,
    /// Windows final-fired (0 for the upsert baseline).
    pub windows_fired: u64,
    pub reshards: Vec<ReshardStats>,
    pub env: ClusterEnv,
}

/// Fold the pure wave ground truth into the expected output rows.
pub fn expected_windowed_rows(cfg: &WindowedCfg) -> Vec<UnversionedRow> {
    let mut folds: BTreeMap<(i64, String, String), (i64, i64)> = BTreeMap::new();
    for wave in 0..cfg.waves {
        for (_p, user, cluster, ts) in
            deterministic_wave_user_events(cfg.partitions, wave, cfg.messages_per_wave)
        {
            let w = cfg.window.window_start(ts);
            let e = folds
                .entry((w, user.to_string(), cluster.to_string()))
                .or_insert((0, i64::MIN));
            e.0 += 1;
            e.1 = e.1.max(ts);
        }
    }
    folds
        .into_iter()
        .map(|((w, user, cluster), (count, last_ts))| {
            row![w, user.as_str(), cluster.as_str(), count, last_ts]
        })
        .collect()
}

fn scan_output(env: &ClusterEnv) -> Vec<UnversionedRow> {
    env.store.scan(WINDOWED_TABLE).unwrap_or_default()
}

/// Run the windowed scenario in the given mode. `drill` fires once per
/// migration, right after `begin_reshard` — mid-window, mid-cutover —
/// with `(processor, migration_index)` (same contract as
/// [`crate::workload::elastic::run_elastic`]).
pub fn run_windowed(
    cfg: &WindowedCfg,
    mode: WindowedMode,
    drill: impl Fn(&StreamingProcessor, usize),
) -> WindowedOutcome {
    let clock = Clock::scaled(4);
    let env = ClusterEnv::new(clock.clone(), cfg.seed);
    // protolint: allow(category, "source input table: the SourceIngest default is the intent")
    let table = OrderedTable::new(
        "//input/windowed",
        input_name_table(),
        cfg.partitions,
        env.accounting.clone(),
    );
    ensure_windowed_table(&env.client()).expect("create windowed output table");

    let mut proc_cfg = ProcessorConfig {
        mapper_count: cfg.partitions,
        reducer_count: cfg.initial_reducers,
        ..cfg.base.clone()
    };

    let mut late_table: Option<Arc<OrderedTable>> = None;
    let processor = match mode {
        WindowedMode::PerBatchUpsert => StreamingProcessor::launch(
            proc_cfg,
            env.clone(),
            InputSpec::Ordered(table.clone()),
            windowed_mapper_factory(),
            windowed_upsert_reducer_factory(cfg.window),
            Yson::parse("{}").unwrap(),
        )
        .expect("launch upsert processor"),
        WindowedMode::FinalFire => {
            proc_cfg.event_time = Some(EventTimeConfig {
                column: "ts".into(),
            });
            let fold: Arc<dyn WindowFold> = Arc::new(ActivityWindowFold);
            let late = OrderedTable::new_with_category(
                "//sys/windowed/late",
                windowed_mapped_name_table(),
                cfg.initial_reducers,
                env.accounting.clone(),
                WriteCategory::UserOutput,
            );
            late_table = Some(late.clone());
            let deps = Arc::new(WindowedDeps {
                spec: cfg.window,
                fold: fold.clone(),
                state_base: "//sys/windowed/window_state".into(),
                plan_table: proc_cfg.reshard_plan_table.clone(),
                mapper_state_table: proc_cfg.mapper_state_table.clone(),
                late,
                metrics: env.metrics.clone(),
                scope: proc_cfg.scope_label.clone(),
                consistency: proc_cfg.consistency,
                cold: None,
            });
            let migrators = WindowMigrators::new(
                env.store.clone(),
                fold,
                deps.state_base.clone(),
                proc_cfg.scope_label.clone(),
            );
            let (exporter, importer) = migrators.pair();
            let runtime = ReshardRuntime::new_with_migrators(
                proc_cfg.reshard_plan_table.clone(),
                env.accounting.clone(),
                proc_cfg.scope_label.clone(),
                exporter,
                importer,
            );
            StreamingProcessor::launch_with_runtime(
                proc_cfg,
                env.clone(),
                InputSpec::Ordered(table.clone()),
                windowed_mapper_factory(),
                windowed_reducer_factory(deps),
                Yson::parse("{}").unwrap(),
                runtime,
            )
            .expect("launch final-fire processor")
        }
    };

    assert!(
        cfg.waves > cfg.reshard_to.len(),
        "need more waves ({}) than reshards ({})",
        cfg.waves,
        cfg.reshard_to.len()
    );
    let mut reshards = Vec::new();
    for wave in 0..cfg.waves {
        // Fill in two paced slices: every (window, key) of the wave
        // receives rows in both (users cycle with the message index), so
        // the per-batch-upsert baseline demonstrably re-commits its
        // output keys — the WA contrast `figure window` gates on cannot
        // degenerate into a single-batch tie.
        let half = cfg.messages_per_wave / 2;
        fill_deterministic_wave_slice(&table, wave, 0, half);
        std::thread::sleep(std::time::Duration::from_millis(300));
        fill_deterministic_wave_slice(&table, wave, half, cfg.messages_per_wave);
        std::thread::sleep(std::time::Duration::from_millis(300));
        if let Some(&target) = cfg.reshard_to.get(wave) {
            // Let the wave start flowing, then resize under the open
            // windows (every window spans the whole run until close, so
            // this is a genuinely mid-window migration).
            std::thread::sleep(std::time::Duration::from_millis(150));
            processor.begin_reshard(target).expect("begin live reshard");
            drill(&processor, wave);
            let stats = processor
                .finish_reshard(cfg.reshard_timeout_ms)
                .expect("migration must drain and finalize");
            reshards.push(stats);
        }
    }

    if mode == WindowedMode::FinalFire {
        // The waves are all appended: declare the stream closed so the
        // fleet watermark can reach +∞ and every window final-fires.
        processor
            .close_event_time(EVENT_TIME_CLOSED)
            .expect("close event time");
    }

    let expected = expected_windowed_rows(cfg);
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_millis(cfg.drain_timeout_ms);
    let mut rows = Vec::new();
    while std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(50));
        rows = scan_output(&env);
        if rows == expected {
            break;
        }
    }

    let report = processor.wa_report(match mode {
        WindowedMode::FinalFire => "windowed (final-fire)",
        WindowedMode::PerBatchUpsert => "windowed (per-batch upsert)",
    });
    let windows_fired = env.metrics.get_counter(names::EVENTTIME_WINDOWS_FIRED);
    processor.stop();

    // Late side-channel rows that actually **committed** (final-fire
    // only). The `eventtime/late_rows_total` counter is advisory and
    // pre-commit — a split-brain loser that classified rows late before
    // its CAS aborted bumps it without landing anything — so gates must
    // count the table, not the metric.
    let late_rows = late_table
        .map(|t| (0..t.tablet_count()).map(|i| t.end_index(i)).sum())
        .unwrap_or(0);

    WindowedOutcome {
        expected,
        rows,
        report,
        late_rows,
        windows_fired,
        reshards,
        env,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expected_rows_are_deterministic_and_sorted() {
        let cfg = WindowedCfg::default();
        let a = expected_windowed_rows(&cfg);
        let b = expected_windowed_rows(&cfg);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        // Key-ordered like a table scan: (window_start, user, cluster).
        for pair in a.windows(2) {
            let k = |r: &UnversionedRow| {
                (
                    r.get(0).unwrap().as_i64().unwrap(),
                    r.get(1).unwrap().as_str().unwrap().to_string(),
                    r.get(2).unwrap().as_str().unwrap().to_string(),
                )
            };
            assert!(k(&pair[0]) < k(&pair[1]));
        }
        // Counts sum to the ground-truth user lines.
        let total: i64 = a.iter().map(|r| r.get(3).unwrap().as_i64().unwrap()).sum();
        let lines: usize = (0..cfg.waves)
            .map(|w| {
                deterministic_wave_user_events(cfg.partitions, w, cfg.messages_per_wave).len()
            })
            .sum();
        assert_eq!(total, lines as i64);
    }

    #[test]
    fn mapper_routes_by_composite_key_ownership() {
        let mf = windowed_mapper_factory();
        let env = ClusterEnv::new(Clock::realtime(), 5);
        let spec = MapperSpec {
            processor_guid: crate::util::Guid::from_seed(1),
            state_table: "t".into(),
            index: 0,
            guid: crate::util::Guid::from_seed(2),
            num_reducers: 4,
        };
        let mut m = mf(
            &Yson::parse("{}").unwrap(),
            &env.client(),
            input_name_table(),
            &spec,
        );
        let mut b = RowsetBuilder::new(input_name_table());
        b.push(row![
            "ts=100 cluster=hahn method=GetNode user=alice dur=5\n\
             ts=101 cluster=hahn method=SetNode dur=6",
            0i64
        ]);
        let out = m.map(b.build());
        assert_eq!(out.rowset.len(), 1, "line without user filtered");
        assert_eq!(
            out.partition_indexes[0],
            hash_partition(&partitioning::composite_key(&["alice", "hahn"]), 4),
            "routing must match the window-state ownership function"
        );
    }

    #[test]
    fn upsert_reducer_folds_batch_invariantly() {
        let env = ClusterEnv::new(Clock::realtime(), 6);
        let client = env.client();
        ensure_windowed_table(&client).unwrap();
        let mut r = WindowedUpsertReducer {
            client: client.clone(),
            window: WindowSpec::tumbling(100),
        };
        let mut b = RowsetBuilder::new(windowed_mapped_name_table());
        b.push(row!["alice", "hahn", 10i64]);
        b.push(row!["alice", "hahn", 120i64]);
        r.reduce(b.build()).unwrap().commit().unwrap();
        let mut b = RowsetBuilder::new(windowed_mapped_name_table());
        b.push(row!["alice", "hahn", 20i64]);
        r.reduce(b.build()).unwrap().commit().unwrap();

        let rows = client.store.scan(WINDOWED_TABLE).unwrap();
        assert_eq!(rows.len(), 2, "two windows");
        assert_eq!(rows[0].get(0).unwrap().as_i64(), Some(0));
        assert_eq!(rows[0].get(3).unwrap().as_i64(), Some(2));
        assert_eq!(rows[0].get(4).unwrap().as_i64(), Some(20));
        assert_eq!(rows[1].get(0).unwrap().as_i64(), Some(100));
        assert_eq!(rows[1].get(3).unwrap().as_i64(), Some(1));
    }

    #[test]
    fn activity_fold_roundtrip_and_merge() {
        let f = ActivityWindowFold;
        let mut acc = f.zero();
        let mut b = RowsetBuilder::new(windowed_mapped_name_table());
        b.push(row!["alice", "hahn", 50i64]);
        let rs = b.build();
        let r = &rs.rows()[0];
        assert_eq!(f.event_ts(r), Some(50));
        assert_eq!(
            f.key(r).unwrap(),
            partitioning::composite_key(&["alice", "hahn"])
        );
        f.fold(&mut acc, r);
        f.fold(&mut acc, r);
        let mut other = f.zero();
        f.fold(&mut other, r);
        f.merge(&mut acc, &other);
        assert_eq!(ActivityWindowFold::unpack(&acc), (3, 50));
        // Accumulators survive the Yson text roundtrip the state table
        // applies.
        let reparsed = Yson::parse(&acc.to_string()).unwrap();
        assert_eq!(ActivityWindowFold::unpack(&reparsed), (3, 50));
    }
}
