//! The consistency-frontier scenario: the §5.2 analytics pipeline run
//! under each [`Consistency`] tier with identical deterministic input and
//! identical kill/split-brain drills, so the three runs differ **only**
//! in their fault-tolerance policy. `figure consistency` compares them:
//!
//! * state-write WA (the `reducer_meta` vs `anchor_state` lines),
//! * `UserOutput` WA,
//! * and *measured* output divergence against the pure ground truth of
//!   [`deterministic_wave_user_events`].
//!
//! Exactly-once must stay byte-identical to a drill-free baseline (the
//! seed guarantee, untouched); bounded-error must land strictly below
//! exactly-once on state-write bytes while its divergence stays within
//! the declared per-incident budget; at-most-once is reported as the
//! frontier's far end (cheapest writes, honest loss).

use std::collections::BTreeMap;

use crate::consistency::Consistency;
use crate::controller::Role;
use crate::coordinator::processor::ClusterEnv;
use crate::coordinator::{ComputeMode, InputSpec, ProcessorConfig, StreamingProcessor};
use crate::metrics::hub::names;
use crate::metrics::WaReport;
use crate::queue::input_name_table;
use crate::queue::ordered_table::OrderedTable;
use crate::reshard::plan::reducer_slot;
use crate::rows::{UnversionedRow, Value};
use crate::storage::WriteCategory;
use crate::util::yson::Yson;
use crate::util::Clock;
use crate::workload::analytics::{
    analytics_mapper_factory, analytics_reducer_factory, ensure_output_table, OUTPUT_TABLE,
};
use crate::workload::elastic::{deterministic_wave_user_events, fill_deterministic_wave};

/// Scenario knobs, shared by every tier's run (the comparison is only
/// meaningful because all of this is held constant across tiers).
#[derive(Debug, Clone)]
pub struct ConsistencyCfg {
    pub partitions: usize,
    pub reducers: usize,
    pub waves: usize,
    pub messages_per_wave: usize,
    pub seed: u64,
    /// Base timings (worker cadences); counts and the consistency policy
    /// are overwritten per run.
    pub base: ProcessorConfig,
    /// Reducer kills across the run (cycled over reducer indexes, one
    /// drill after each wave's fill).
    pub kills: usize,
    /// Split-brain twins spawned across the run (same cycling).
    pub twins: usize,
    /// The BoundedError tier's declared budget (rows per failure event).
    pub divergence_budget: u64,
    /// The BoundedError tier's batch-cadence anchor floor.
    pub anchor_every_batches: u32,
    pub drain_timeout_ms: u64,
}

impl Default for ConsistencyCfg {
    fn default() -> Self {
        ConsistencyCfg {
            partitions: 4,
            reducers: 2,
            waves: 3,
            messages_per_wave: 40,
            seed: 0xC0_75,
            base: ProcessorConfig {
                backoff_ms: 5,
                trim_period_ms: 100,
                restart_delay_ms: 100,
                split_brain_delay_ms: 50,
                session_ttl_ms: 1_500,
                heartbeat_period_ms: 100,
                ..ProcessorConfig::default()
            },
            kills: 2,
            twins: 1,
            divergence_budget: 64,
            anchor_every_batches: 4,
            drain_timeout_ms: 45_000,
        }
    }
}

impl ConsistencyCfg {
    /// The BoundedError policy this config declares.
    pub fn bounded_policy(&self) -> Consistency {
        Consistency::BoundedError {
            divergence_budget: self.divergence_budget,
            anchor_every_batches: self.anchor_every_batches,
        }
    }

    /// The figure's divergence gate: per-incident budget × incidents ×2
    /// (the ×2 covers the twin-abdication window — a twin that anchors
    /// once before abdicating can both replay and strand up to one
    /// budget's worth of rows).
    pub fn divergence_allowance(&self) -> u64 {
        self.divergence_budget * (self.kills + self.twins).max(1) as u64 * 2
    }
}

/// Everything one tier's run leaves behind for the frontier comparison.
pub struct TierOutcome {
    pub tier: Consistency,
    /// Whether the kill/twin drills ran (false = clean baseline).
    pub drilled: bool,
    /// Ground truth: input lines carrying a user field.
    pub expected_lines: i64,
    /// Observed sum of the output `count` column after drain.
    pub output_lines: i64,
    /// Full drained output table in key order.
    pub rows: Vec<UnversionedRow>,
    /// Σ per-key |count − truth| (0 ⇔ the output is exactly the truth).
    pub divergence: u64,
    /// Reducer-state bytes under the exactly-once category.
    pub reducer_meta_bytes: u64,
    /// Reducer-state bytes under the approximate (anchor) category.
    pub anchor_state_bytes: u64,
    pub user_output_bytes: u64,
    pub ingest_bytes: u64,
    pub anchor_commits: u64,
    pub skipped_persists: u64,
    pub abdications: u64,
    pub discard_rounds: u64,
    pub report: WaReport,
    pub env: ClusterEnv,
}

impl TierOutcome {
    /// Total reducer-state bytes, whichever category they landed in — the
    /// frontier's y-axis.
    pub fn state_bytes(&self) -> u64 {
        self.reducer_meta_bytes + self.anchor_state_bytes
    }

    /// State-write amplification against this run's own ingest.
    pub fn state_wa(&self) -> f64 {
        if self.ingest_bytes == 0 {
            0.0
        } else {
            self.state_bytes() as f64 / self.ingest_bytes as f64
        }
    }

    /// UserOutput write amplification against this run's own ingest.
    pub fn user_output_wa(&self) -> f64 {
        if self.ingest_bytes == 0 {
            0.0
        } else {
            self.user_output_bytes as f64 / self.ingest_bytes as f64
        }
    }
}

/// The pure per-key ground truth of the whole wave plan:
/// `(user, cluster) → count` (mirrors what a perfect pipeline commits).
pub fn ground_truth_counts(
    partitions: usize,
    waves: usize,
    messages_per_wave: usize,
) -> BTreeMap<(String, String), i64> {
    let mut truth: BTreeMap<(String, String), i64> = BTreeMap::new();
    for wave in 0..waves {
        for (_, user, cluster, _) in
            deterministic_wave_user_events(partitions, wave, messages_per_wave)
        {
            *truth.entry((user.to_string(), cluster.to_string())).or_insert(0) += 1;
        }
    }
    truth
}

/// Σ per-key |count − truth| over the union of keys: counts both
/// replayed (inflated) and lost rows, in rows.
pub fn divergence_vs_truth(
    rows: &[UnversionedRow],
    truth: &BTreeMap<(String, String), i64>,
) -> u64 {
    let mut got: BTreeMap<(String, String), i64> = BTreeMap::new();
    for r in rows {
        let (Some(user), Some(cluster), Some(count)) = (
            r.get(0).and_then(Value::as_str),
            r.get(1).and_then(Value::as_str),
            r.get(2).and_then(Value::as_i64),
        ) else {
            continue;
        };
        got.insert((user.to_string(), cluster.to_string()), count);
    }
    let mut div = 0u64;
    for (key, want) in truth {
        div += (got.remove(key).unwrap_or(0) - want).unsigned_abs();
    }
    for (_, extra) in got {
        div += extra.unsigned_abs();
    }
    div
}

fn output_count_sum(env: &ClusterEnv) -> i64 {
    env.store
        .scan(OUTPUT_TABLE)
        .map(|rows| {
            rows.iter()
                .map(|r| r.get(2).and_then(Value::as_i64).unwrap_or(0))
                .sum()
        })
        .unwrap_or(0)
}

/// Run the wave plan once under `tier`. With `drilled`, each wave's fill
/// is followed by one fault drill — kills first, then twins, cycling over
/// reducer indexes — so every tier faces the *same* failure schedule.
pub fn run_consistency_tier(cfg: &ConsistencyCfg, tier: Consistency, drilled: bool) -> TierOutcome {
    let clock = Clock::scaled(4);
    let env = ClusterEnv::new(clock.clone(), cfg.seed);
    // protolint: allow(category, "source input table: the SourceIngest default is the intent")
    let table = OrderedTable::new(
        "//input/consistency",
        input_name_table(),
        cfg.partitions,
        env.accounting.clone(),
    );
    ensure_output_table(&env.client()).expect("create analytics output table");

    let proc_cfg = ProcessorConfig {
        mapper_count: cfg.partitions,
        reducer_count: cfg.reducers,
        consistency: tier,
        ..cfg.base.clone()
    };
    let processor = StreamingProcessor::launch(
        proc_cfg,
        env.clone(),
        InputSpec::Ordered(table.clone()),
        analytics_mapper_factory(ComputeMode::Native),
        analytics_reducer_factory(ComputeMode::Native),
        Yson::parse("{}").unwrap(),
    )
    .expect("launch consistency processor");

    // The drill schedule: `kills` kill drills then `twins` twin drills,
    // one after each wave's fill (wrapping if there are more drills than
    // waves), victims cycling over the reducer fleet. Purely a function
    // of (cfg, wave) — every tier sees the same schedule.
    let drills: Vec<(bool, usize)> = (0..cfg.kills)
        .map(|i| (true, i % cfg.reducers))
        .chain((0..cfg.twins).map(|i| (false, i % cfg.reducers)))
        .collect();

    let mut expected = 0i64;
    for wave in 0..cfg.waves {
        expected += fill_deterministic_wave(&table, wave, cfg.messages_per_wave);
        // Let the wave start flowing before (possibly) drilling into it.
        std::thread::sleep(std::time::Duration::from_millis(200));
        if !drilled {
            continue;
        }
        let sup = processor.supervisor();
        for (d, (is_kill, victim)) in drills.iter().enumerate() {
            if d % cfg.waves != wave {
                continue;
            }
            if *is_kill {
                sup.kill(Role::Reducer, reducer_slot(0, *victim));
            } else {
                sup.duplicate(Role::Reducer, reducer_slot(0, *victim));
            }
        }
    }

    if drilled && tier.is_approximate() {
        // End twin contention deterministically: under bounded-error a
        // twin abdicates at the next anchor it loses, but an at-most-once
        // twin never writes state and so never collapses on its own. A
        // retire→revive bounce kills incumbent + twins and respawns one
        // fresh incarnation per slot (its recovery drift is part of what
        // the figure measures).
        std::thread::sleep(std::time::Duration::from_millis(400));
        let sup = processor.supervisor();
        for i in 0..cfg.reducers {
            sup.retire(Role::Reducer, reducer_slot(0, i));
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
        for i in 0..cfg.reducers {
            sup.revive(Role::Reducer, reducer_slot(0, i));
        }
    }

    // Drain. Exactly-once converges on the exact expectation; the
    // approximate tiers settle near it (that distance *is* the measured
    // divergence), so their verdict is stability: the drained backlog and
    // an output sum unchanged across a quiet window.
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_millis(cfg.drain_timeout_ms);
    let mut output_lines;
    let mut stable_since: Option<(i64, std::time::Instant)> = None;
    loop {
        std::thread::sleep(std::time::Duration::from_millis(50));
        output_lines = output_count_sum(&env);
        if tier.is_exactly_once() && output_lines == expected {
            break;
        }
        let drained = processor.input.retained_rows() == 0;
        match stable_since {
            Some((v, t0)) if v == output_lines && drained => {
                if t0.elapsed() >= std::time::Duration::from_millis(1_200) {
                    break;
                }
            }
            _ => stable_since = Some((output_lines, std::time::Instant::now())),
        }
        if std::time::Instant::now() >= deadline {
            break;
        }
    }

    let report = processor.wa_report(&format!("consistency [{}]", tier.label()));
    let ingest_bytes = processor.ingested_bytes();
    let anchor_commits = env.metrics.get_counter(names::REDUCER_ANCHOR_COMMITS);
    let skipped_persists = env.metrics.get_counter(names::REDUCER_SKIPPED_PERSISTS);
    let abdications = env.metrics.get_counter(names::REDUCER_ABDICATIONS);
    let discard_rounds = env.metrics.get_counter(names::REDUCER_DISCARD_ROUNDS);
    processor.stop();

    let rows = env.store.scan(OUTPUT_TABLE).unwrap_or_default();
    let truth = ground_truth_counts(cfg.partitions, cfg.waves, cfg.messages_per_wave);
    let divergence = divergence_vs_truth(&rows, &truth);
    TierOutcome {
        tier,
        drilled,
        expected_lines: expected,
        output_lines,
        rows,
        divergence,
        reducer_meta_bytes: env.accounting.bytes(WriteCategory::ReducerMeta),
        anchor_state_bytes: env.accounting.bytes(WriteCategory::AnchorState),
        user_output_bytes: env.accounting.bytes(WriteCategory::UserOutput),
        ingest_bytes,
        anchor_commits,
        skipped_persists,
        abdications,
        discard_rounds,
        report,
        env,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::row;

    #[test]
    fn ground_truth_counts_sum_to_user_lines() {
        let truth = ground_truth_counts(3, 2, 7);
        let total: i64 = truth.values().sum();
        let per_wave: usize = (0..2)
            .map(|w| deterministic_wave_user_events(3, w, 7).len())
            .sum();
        assert_eq!(total, per_wave as i64);
        assert!(!truth.is_empty());
    }

    #[test]
    fn divergence_counts_inflation_loss_and_strays() {
        let mut truth = BTreeMap::new();
        truth.insert(("alice".to_string(), "hahn".to_string()), 10i64);
        truth.insert(("bob".to_string(), "bohr".to_string()), 5i64);
        // Exact output: zero divergence.
        let exact = vec![
            row!["alice", "hahn", 10i64, 0i64],
            row!["bob", "bohr", 5i64, 0i64],
        ];
        assert_eq!(divergence_vs_truth(&exact, &truth), 0);
        // Inflated by 2, short by 1, plus a stray key worth 3: total 6.
        let off = vec![
            row!["alice", "hahn", 12i64, 0i64],
            row!["bob", "bohr", 4i64, 0i64],
            row!["eve", "hahn", 3i64, 0i64],
        ];
        assert_eq!(divergence_vs_truth(&off, &truth), 6);
        // Missing key counts fully.
        let missing = vec![row!["alice", "hahn", 10i64, 0i64]];
        assert_eq!(divergence_vs_truth(&missing, &truth), 5);
    }

    #[test]
    fn allowance_scales_with_incidents() {
        let cfg = ConsistencyCfg {
            divergence_budget: 64,
            kills: 2,
            twins: 1,
            ..ConsistencyCfg::default()
        };
        assert_eq!(cfg.divergence_allowance(), 64 * 3 * 2);
        let quiet = ConsistencyCfg {
            kills: 0,
            twins: 0,
            ..cfg
        };
        assert_eq!(quiet.divergence_allowance(), 64 * 2, "min one incident");
    }
}
