//! The paper's evaluation user code (§5.2): log analytics.
//!
//! "The mappers' Map implementation split each read message back into
//! individual log messages. These messages were then parsed and hash
//! partitioned by their respective user and cluster fields. Log messages
//! that didn't have a user field were simply ignored … The remainder was
//! processed by 10 reducer workers, which grouped messages by user and
//! cluster, writing the timestamp of the user's last access to the cluster
//! and a tally of the number of corresponding messages in the batch to a
//! sorted dynamic table shared by all reducers."
//!
//! String parsing and row codecs stay in rust; the numeric inner loops
//! (shuffle hash, grouped aggregation) run through a [`ComputeStage`] —
//! either the native reference or the AOT-compiled Pallas kernels.

use std::collections::HashMap;
use std::sync::Arc;

use crate::api::{
    Client, Mapper, MapperFactory, MapperSpec, PartitionedRowset, Reducer, ReducerFactory,
    ReducerSpec,
};
use crate::compute::native::NativeStage;
use crate::compute::{fnv1a32, shuffle_mix, ComputeStage};
use crate::coordinator::config::ComputeMode;
use crate::dyntable::store::StoreError;
use crate::dyntable::Transaction;
use crate::queue::INPUT_COL_PAYLOAD;
use crate::row;
use crate::rows::{ColumnSchema, ColumnType, NameTable, RowsetBuilder, TableSchema, UnversionedRowset, Value};
use crate::storage::WriteCategory;
use crate::util::yson::Yson;

use super::loggen::parse_line;

/// The shared output table (user, cluster) → (count, last_ts).
pub const OUTPUT_TABLE: &str = "//out/user_activity";

/// Schema of [`OUTPUT_TABLE`].
pub fn output_schema() -> TableSchema {
    TableSchema::new(vec![
        ColumnSchema::key("user", ColumnType::Str),
        ColumnSchema::key("cluster", ColumnType::Str),
        ColumnSchema::value("count", ColumnType::Int64),
        ColumnSchema::value("last_ts", ColumnType::Int64),
    ])
}

/// Create [`OUTPUT_TABLE`] if missing (examples / figures call this once
/// up front and propagate the error; worker factories re-invoke it
/// best-effort, where a transient failure surfaces later as a retried
/// store error rather than a crash).
pub fn ensure_output_table(client: &Client) -> Result<(), StoreError> {
    match client
        .store
        .create_table(OUTPUT_TABLE, output_schema(), WriteCategory::UserOutput)
    {
        Ok(_) | Err(StoreError::AlreadyExists(_)) => Ok(()),
        Err(e) => Err(e),
    }
}

/// Columns of the mapped (shuffled) rows.
pub fn mapped_name_table() -> Arc<NameTable> {
    NameTable::new(&["user", "cluster", "ts"])
}

/// Build a [`ComputeStage`] per the processor's compute mode.
pub fn stage_for(mode: ComputeMode, artifacts_dir: &str) -> Arc<dyn ComputeStage> {
    match mode {
        ComputeMode::Native => Arc::new(NativeStage),
        ComputeMode::Hlo => crate::compute::hlo::HloStage::load(std::path::Path::new(
            artifacts_dir,
        ))
        .expect("loading AOT artifacts (build with `--features pjrt`, run `make artifacts`)"),
    }
}

/// The §5.2 mapper: split batched messages, parse, filter, shuffle.
pub struct LogAnalyticsMapper {
    stage: Arc<dyn ComputeStage>,
    num_reducers: u32,
    out_nt: Arc<NameTable>,
}

impl Mapper for LogAnalyticsMapper {
    fn map(&mut self, rows: UnversionedRowset) -> PartitionedRowset {
        // 1. Split batched messages into individual lines and parse.
        // Parsed fields stay *borrowed* from the payload strings — ~85 %
        // of lines are filtered out, so materializing them would waste
        // two string allocations per dropped line (§Perf optimization 3).
        let mut lines: Vec<(Option<&str>, &str, i64)> = Vec::new();
        let mut user_hash = Vec::new();
        let mut cluster_hash = Vec::new();
        let mut has_user = Vec::new();
        for r in rows.rows() {
            let Some(payload) = r.get(INPUT_COL_PAYLOAD).and_then(Value::as_str) else {
                continue;
            };
            for raw in payload.lines() {
                let Some(p) = parse_line(raw) else { continue };
                user_hash.push(fnv1a32(p.user.unwrap_or("")));
                cluster_hash.push(fnv1a32(p.cluster));
                has_user.push(p.user.is_some());
                lines.push((p.user, p.cluster, p.ts));
            }
        }

        // 2. Numeric stage: filter mask + shuffle function.
        let out = self
            .stage
            .map_stage(&user_hash, &cluster_hash, &has_user, self.num_reducers);

        // 3. Materialize only the surviving rows, carrying the routing
        // hash the stage partitioned by. `reducer = shuffle_mix(u, c) %
        // num_reducers` in every stage implementation, so the published
        // u64 hash re-derives this row's owner under *any* partition
        // count — which is what lets the runtime skip the second full
        // map call during a reshard's dual-route window.
        let mut b = RowsetBuilder::new(self.out_nt.clone());
        let mut partitions = Vec::new();
        let mut hashes = Vec::new();
        for (i, (user, cluster, ts)) in lines.into_iter().enumerate() {
            if out.keep[i] {
                b.push(row![user.unwrap_or(""), cluster, ts]);
                partitions.push(out.reducer[i] as usize);
                hashes.push(shuffle_mix(user_hash[i], cluster_hash[i]) as u64);
            }
        }
        PartitionedRowset::with_key_hashes(b.build(), partitions, hashes)
    }

    fn publishes_key_hashes(&self) -> bool {
        true
    }
}

/// The §5.2 reducer: group by (user, cluster), count + max-ts, upsert into
/// the shared output table inside the exactly-once transaction.
pub struct LogAnalyticsReducer {
    stage: Arc<dyn ComputeStage>,
    client: Client,
}

impl Reducer for LogAnalyticsReducer {
    fn reduce(&mut self, rows: UnversionedRowset) -> Option<Transaction> {
        if rows.is_empty() {
            return None;
        }
        // 1. Slot assignment in first-seen order (deterministic). Group
        // keys are cheap clones of the decoded cells (ByteStr refcount
        // bumps) — zero string copies per group, per row, or at write-out
        // (§Perf iteration 7; the dyntable commit detaches at the persist
        // boundary).
        let mut slot_of: HashMap<(&str, &str), u32> = HashMap::new();
        let mut keys: Vec<(Value, Value)> = Vec::new();
        let mut slots = Vec::with_capacity(rows.len());
        let mut ts_off = Vec::with_capacity(rows.len());
        let mut valid = Vec::with_capacity(rows.len());

        let nt = rows.name_table();
        let (u_col, c_col, t_col) = (nt.id("user")?, nt.id("cluster")?, nt.id("ts")?);
        // f32 offsets keep millisecond precision within a batch.
        let base_ts = rows
            .rows()
            .iter()
            .filter_map(|r| r.get(t_col).and_then(Value::as_i64))
            .min()
            .unwrap_or(0);
        for r in rows.rows() {
            let (Some(uv), Some(cv), Some(t)) = (
                r.get(u_col),
                r.get(c_col),
                r.get(t_col).and_then(Value::as_i64),
            ) else {
                continue;
            };
            let (Some(u), Some(c)) = (uv.as_str(), cv.as_str()) else {
                continue;
            };
            let key = (u, c);
            let next = slot_of.len() as u32;
            let slot = *slot_of.entry(key).or_insert_with(|| {
                keys.push((uv.clone(), cv.clone()));
                next
            });
            slots.push(slot);
            ts_off.push((t - base_ts) as f32);
            valid.push(true);
        }
        if slots.is_empty() {
            return None;
        }

        // 2. Numeric stage: per-slot count + max ts offset.
        let agg = self
            .stage
            .reduce_stage(&slots, &ts_off, &valid, keys.len() as u32);

        // 3. Upsert aggregates transactionally; the reducer instance will
        // add its meta-state to this same transaction (§4.4.2 step 6).
        let mut txn = self.client.begin();
        for (slot, (user, cluster)) in keys.iter().enumerate() {
            if agg.counts[slot] == 0 {
                continue;
            }
            let last_ts = base_ts + agg.max_ts[slot] as i64;
            let key = vec![user.clone(), cluster.clone()];
            let (mut count, mut max_ts) = (0i64, i64::MIN);
            if let Ok(Some(existing)) = txn.lookup(OUTPUT_TABLE, &key) {
                count = existing.get(2).and_then(Value::as_i64).unwrap_or(0);
                max_ts = existing.get(3).and_then(Value::as_i64).unwrap_or(i64::MIN);
            }
            let row = row![
                user.clone(),
                cluster.clone(),
                count + agg.counts[slot],
                max_ts.max(last_ts)
            ];
            txn.write(OUTPUT_TABLE, row).ok()?;
        }
        Some(txn)
    }
}

/// `CreateMapper` for the analytics workload.
pub fn analytics_mapper_factory(mode: ComputeMode) -> MapperFactory {
    Arc::new(
        move |user_cfg: &Yson, _client: &Client, _input_nt: Arc<NameTable>, spec: &MapperSpec| {
            let artifacts = user_cfg.get_str_or("artifacts_dir", "artifacts").to_string();
            Box::new(LogAnalyticsMapper {
                stage: stage_for(mode, &artifacts),
                num_reducers: spec.num_reducers as u32,
                out_nt: mapped_name_table(),
            }) as Box<dyn Mapper>
        },
    )
}

/// `CreateReducer` for the analytics workload.
pub fn analytics_reducer_factory(mode: ComputeMode) -> ReducerFactory {
    Arc::new(move |user_cfg: &Yson, client: &Client, _spec: &ReducerSpec| {
        let artifacts = user_cfg.get_str_or("artifacts_dir", "artifacts").to_string();
        // Best-effort in the factory (it cannot propagate): a failure here
        // surfaces as retried lookup errors in the reducer loop.
        let _ = ensure_output_table(client);
        Box::new(LogAnalyticsReducer {
            stage: stage_for(mode, &artifacts),
            client: client.clone(),
        }) as Box<dyn Reducer>
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::processor::ClusterEnv;
    use crate::queue::input_name_table;
    use crate::util::Clock;

    fn input_rowset(payloads: &[&str]) -> UnversionedRowset {
        let mut b = RowsetBuilder::new(input_name_table());
        for p in payloads {
            b.push(row![*p, 0i64]);
        }
        b.build()
    }

    fn mapper(num_reducers: u32) -> LogAnalyticsMapper {
        LogAnalyticsMapper {
            stage: Arc::new(NativeStage),
            num_reducers,
            out_nt: mapped_name_table(),
        }
    }

    #[test]
    fn mapper_splits_filters_and_partitions() {
        let mut m = mapper(4);
        let out = m.map(input_rowset(&[
            "ts=100 cluster=hahn method=GetNode user=alice dur=5\n\
             ts=101 cluster=hahn method=SetNode dur=6\n\
             ts=102 cluster=freud method=Commit user=root dur=7",
            "ts=103 cluster=bohr method=Heartbeat dur=8",
        ]));
        // Only the two lines with user= survive.
        assert_eq!(out.rowset.len(), 2);
        assert_eq!(out.partition_indexes.len(), 2);
        assert!(out.partition_indexes.iter().all(|&p| p < 4));
        assert_eq!(out.rowset.cell(0, "user").unwrap().as_str(), Some("alice"));
        assert_eq!(out.rowset.cell(1, "user").unwrap().as_str(), Some("root"));
        assert_eq!(out.rowset.cell(1, "cluster").unwrap().as_str(), Some("freud"));
    }

    #[test]
    fn mapper_is_deterministic() {
        let mut m1 = mapper(8);
        let mut m2 = mapper(8);
        let input = input_rowset(&[
            "ts=1 cluster=a method=M user=u1 dur=1\nts=2 cluster=b method=M user=u2 dur=2",
        ]);
        let a = m1.map(input.clone());
        let b = m2.map(input);
        assert_eq!(a.rowset, b.rowset);
        assert_eq!(a.partition_indexes, b.partition_indexes);
    }

    #[test]
    fn mapper_same_key_same_reducer() {
        let mut m = mapper(4);
        let out = m.map(input_rowset(&[
            "ts=1 cluster=hahn method=A user=bob dur=1",
            "ts=9 cluster=hahn method=B user=bob dur=2",
        ]));
        assert_eq!(out.partition_indexes[0], out.partition_indexes[1]);
    }

    #[test]
    fn mapper_survives_garbage_payloads() {
        let mut m = mapper(2);
        let out = m.map(input_rowset(&["%%% not a log line", ""]));
        assert_eq!(out.rowset.len(), 0);
    }

    #[test]
    fn reducer_aggregates_into_output_table() {
        let env = ClusterEnv::new(Clock::realtime(), 1);
        let client = env.client();
        ensure_output_table(&client).unwrap();
        let mut r = LogAnalyticsReducer {
            stage: Arc::new(NativeStage),
            client: client.clone(),
        };
        let mut b = RowsetBuilder::new(mapped_name_table());
        b.push(row!["alice", "hahn", 100i64]);
        b.push(row!["alice", "hahn", 300i64]);
        b.push(row!["root", "freud", 200i64]);
        let txn = r.reduce(b.build()).expect("reducer should open a txn");
        txn.commit().unwrap();

        let rows = client.store.scan(OUTPUT_TABLE).unwrap();
        assert_eq!(rows.len(), 2);
        let alice = &rows[0];
        assert_eq!(alice.get(0).unwrap().as_str(), Some("alice"));
        assert_eq!(alice.get(2).unwrap().as_i64(), Some(2));
        assert_eq!(alice.get(3).unwrap().as_i64(), Some(300));

        // Second batch accumulates.
        let mut b = RowsetBuilder::new(mapped_name_table());
        b.push(row!["alice", "hahn", 250i64]);
        let txn = r.reduce(b.build()).unwrap();
        txn.commit().unwrap();
        let rows = client.store.scan(OUTPUT_TABLE).unwrap();
        assert_eq!(rows[0].get(2).unwrap().as_i64(), Some(3));
        assert_eq!(rows[0].get(3).unwrap().as_i64(), Some(300), "max ts keeps 300");
    }

    #[test]
    fn reducer_empty_batch_returns_none() {
        let env = ClusterEnv::new(Clock::realtime(), 1);
        let client = env.client();
        ensure_output_table(&client).unwrap();
        let mut r = LogAnalyticsReducer {
            stage: Arc::new(NativeStage),
            client,
        };
        assert!(r
            .reduce(UnversionedRowset::empty(mapped_name_table()))
            .is_none());
    }

    #[test]
    fn factories_build_workers() {
        let env = ClusterEnv::new(Clock::realtime(), 1);
        let client = env.client();
        let mf = analytics_mapper_factory(ComputeMode::Native);
        let rf = analytics_reducer_factory(ComputeMode::Native);
        let mspec = MapperSpec {
            processor_guid: crate::util::Guid::from_seed(1),
            state_table: "t".into(),
            index: 0,
            guid: crate::util::Guid::from_seed(2),
            num_reducers: 2,
        };
        let rspec = ReducerSpec {
            processor_guid: crate::util::Guid::from_seed(1),
            state_table: "t".into(),
            index: 0,
            guid: crate::util::Guid::from_seed(3),
            num_mappers: 2,
            epoch: 0,
        };
        let cfg = Yson::parse("{}").unwrap();
        let _m = mf(&cfg, &client, input_name_table(), &mspec);
        let _r = rf(&cfg, &client, &rspec);
        assert!(client.store.scan(OUTPUT_TABLE).is_ok());
    }
}
