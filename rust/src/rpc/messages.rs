//! RPC wire messages.
//!
//! `TReqGetRows` / `TRspGetRows` follow §4.3.4:
//!
//! ```protobuf
//! message TReqGetRows {
//!   optional int64 count = 1;
//!   optional int64 reducer_index = 2;
//!   optional int64 committed_row_index = 3;
//!   optional string mapper_id = 4;
//! }
//! message TRspGetRows {
//!   optional int64 row_count = 1;
//!   optional int64 last_shuffle_row_index = 2;
//! }
//! ```
//!
//! "The actual rows are returned as attachments in a binary format" — the
//! attachment carries a [`crate::rows::codec`]-encoded rowset.
//!
//! Attachments are [`Attachment`]s (`Arc<[u8]>`): every hop that used to
//! memcpy the payload — the bench/replay servers, fault-plan duplication,
//! spill records, journal reads — is now a refcount bump, and the reducer
//! decodes them zero-copy via
//! [`crate::rows::codec::decode_rowset_shared`].

use std::sync::{Arc, OnceLock};

/// Shared immutable payload bytes carried alongside an RPC response.
/// Cloning is a refcount bump; the decoder borrows string cells straight
/// out of this buffer.
pub type Attachment = Arc<[u8]>;

/// The empty [`Attachment`], shared process-wide: empty responses are the
/// common idle-poll case, so they must not allocate per call.
pub fn empty_attachment() -> Attachment {
    static EMPTY: OnceLock<Attachment> = OnceLock::new();
    EMPTY.get_or_init(|| Arc::from(&[][..])).clone()
}

/// Reducer → mapper row pull (§4.3.4).
#[derive(Debug, Clone, PartialEq)]
pub struct ReqGetRows {
    /// How many of the reducer's assigned rows to return (a hint; the
    /// mapper may return fewer, or zero).
    pub count: i64,
    /// Index of the requesting reducer.
    pub reducer_index: i64,
    /// Partition-map epoch the requesting reducer belongs to. The mapper
    /// serves each epoch from that epoch's own bucket set; an epoch it
    /// does not (yet) route for gets an empty response.
    pub epoch: i64,
    /// Shuffle index of the last row this reducer successfully processed
    /// and committed; everything at or below is acknowledged.
    pub committed_row_index: i64,
    /// GUID the reducer believes it is talking to; a mismatch (stale
    /// discovery) makes the mapper reject the call.
    pub mapper_id: String,
}

/// Mapper → reducer response.
#[derive(Debug, Clone, PartialEq)]
pub struct RspGetRows {
    /// Number of rows in the attachment.
    pub row_count: i64,
    /// Shuffle index of the *last* returned row. Needed because rows
    /// assigned to one reducer do not have sequential shuffle indexes.
    pub last_shuffle_row_index: i64,
    /// codec-encoded rowset ([`crate::rows::codec::encode_rowset`]),
    /// shared rather than copied across RPC/bench/replay paths.
    pub attachment: Attachment,
    /// Reshard drain signal: true iff the requested epoch is older than
    /// the mapper's current routing epoch, the mapper has mapped every row
    /// below the cutover, and the requested epoch's bucket and spill queue
    /// for this reducer are empty — i.e. this mapper will never again hold
    /// unacknowledged rows for (epoch, reducer). A retiring reducer needs
    /// this flag from every mapper in one cycle before it may retire.
    pub drained: bool,
}

impl RspGetRows {
    /// An empty response (no rows available / nothing new).
    pub fn empty() -> RspGetRows {
        RspGetRows {
            row_count: 0,
            last_shuffle_row_index: -1,
            attachment: empty_attachment(),
            drained: false,
        }
    }

    /// An empty response that also reports the requested epoch drained.
    pub fn empty_drained() -> RspGetRows {
        RspGetRows {
            drained: true,
            ..RspGetRows::empty()
        }
    }
}

/// All request kinds carried by the simulated bus.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    GetRows(ReqGetRows),
    /// Liveness probe (controller health checks).
    Ping,
}

/// All response kinds.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    GetRows(RspGetRows),
    Pong,
}

impl Request {
    /// Approximate wire size (for network metrics).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Request::GetRows(r) => 8 * 4 + r.mapper_id.len(),
            Request::Ping => 1,
        }
    }
}

impl Response {
    pub fn wire_bytes(&self) -> usize {
        match self {
            Response::GetRows(r) => 17 + r.attachment.len(),
            Response::Pong => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_response_shape() {
        let r = RspGetRows::empty();
        assert_eq!(r.row_count, 0);
        assert_eq!(r.last_shuffle_row_index, -1);
        assert!(r.attachment.is_empty());
        assert!(!r.drained);
        assert!(RspGetRows::empty_drained().drained);
    }

    #[test]
    fn wire_sizes_positive() {
        let req = Request::GetRows(ReqGetRows {
            count: 10,
            reducer_index: 1,
            epoch: 0,
            committed_row_index: -1,
            mapper_id: "a-b-c-d".into(),
        });
        assert!(req.wire_bytes() > 32);
        let rsp = Response::GetRows(RspGetRows {
            row_count: 1,
            last_shuffle_row_index: 0,
            attachment: vec![0; 100].into(),
            drained: false,
        });
        assert_eq!(rsp.wire_bytes(), 117);
    }

    #[test]
    fn attachment_clone_is_shared() {
        let rsp = RspGetRows {
            row_count: 1,
            last_shuffle_row_index: 0,
            attachment: vec![1, 2, 3].into(),
            drained: false,
        };
        let dup = rsp.clone();
        assert!(Arc::ptr_eq(&rsp.attachment, &dup.attachment));
    }
}
