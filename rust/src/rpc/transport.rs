//! The simulated bus: address registry + fault-filtered synchronous calls.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use super::fault::FaultPlan;
use super::messages::{Request, Response};
use crate::util::{Clock, Prng};
use crate::util;

/// A service mounted at an address. Handlers run on the caller's thread
/// (the in-process analogue of a synchronous RPC).
pub trait RpcService: Send + Sync {
    fn handle(&self, req: Request) -> Result<Response, String>;
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum RpcError {
    #[error("no service at '{0}' (not registered or shut down)")]
    NoSuchService(String),
    #[error("rpc timeout from '{src}' to '{dst}' (dropped by fault plan)")]
    Timeout { src: String, dst: String },
    #[error("network partition between '{src}' and '{dst}'")]
    Partitioned { src: String, dst: String },
    #[error("handler error: {0}")]
    Handler(String),
}

/// Per-net call statistics (observability; not used for control flow).
#[derive(Debug, Default)]
pub struct NetStats {
    pub calls: AtomicU64,
    pub dropped: AtomicU64,
    pub duplicated: AtomicU64,
    pub partition_rejects: AtomicU64,
    pub bytes_sent: AtomicU64,
    pub bytes_received: AtomicU64,
}

/// The in-process network fabric shared by all simulated workers.
pub struct RpcNet {
    services: RwLock<HashMap<String, Arc<dyn RpcService>>>,
    faults: Mutex<FaultPlan>,
    prng: Mutex<Prng>,
    clock: Clock,
    pub stats: NetStats,
}

impl RpcNet {
    pub fn new(clock: Clock, prng: Prng) -> Arc<RpcNet> {
        Arc::new(RpcNet {
            services: RwLock::new(HashMap::new()),
            faults: Mutex::new(FaultPlan::healthy()),
            prng: Mutex::new(prng),
            clock,
            stats: NetStats::default(),
        })
    }

    /// Mount a service; replaces any previous holder of the address (a
    /// restarted worker re-registers its address).
    pub fn register(&self, address: &str, service: Arc<dyn RpcService>) {
        self.services
            .write()
            .unwrap()
            .insert(address.to_string(), service);
    }

    /// Unmount (worker death). Subsequent calls see `NoSuchService`.
    pub fn unregister(&self, address: &str) {
        util::wlock(&self.services).remove(address);
    }

    pub fn is_registered(&self, address: &str) -> bool {
        util::rlock(&self.services).contains_key(address)
    }

    /// Mutate the fault plan (drills, tests).
    pub fn with_faults(&self, f: impl FnOnce(&mut FaultPlan)) {
        f(&mut util::lock(&self.faults));
    }

    /// Perform a call from `src` to `dst`, subject to the fault plan.
    pub fn call(&self, src: &str, dst: &str, req: Request) -> Result<Response, RpcError> {
        self.stats.calls.fetch_add(1, Ordering::Relaxed);
        self.stats
            .bytes_sent
            .fetch_add(req.wire_bytes() as u64, Ordering::Relaxed);

        // Fault decisions are made under the prng lock for determinism.
        let (cut, dropped, duplicated, delay_ms) = {
            let faults = util::lock(&self.faults);
            let mut prng = util::lock(&self.prng);
            let cut = faults.is_cut(src, dst);
            let dropped = !cut && faults.drop_prob > 0.0 && prng.chance(faults.drop_prob);
            let duplicated = !cut && !dropped && faults.dup_prob > 0.0 && prng.chance(faults.dup_prob);
            let delay_ms = if faults.delay_ms.1 > 0 {
                prng.gen_range(faults.delay_ms.0, faults.delay_ms.1)
            } else {
                0
            };
            (cut, dropped, duplicated, delay_ms)
        };

        if cut {
            self.stats.partition_rejects.fetch_add(1, Ordering::Relaxed);
            return Err(RpcError::Partitioned {
                src: src.to_string(),
                dst: dst.to_string(),
            });
        }
        if dropped {
            self.stats.dropped.fetch_add(1, Ordering::Relaxed);
            return Err(RpcError::Timeout {
                src: src.to_string(),
                dst: dst.to_string(),
            });
        }
        if delay_ms > 0 {
            self.clock.sleep_ms(delay_ms);
        }

        let service = self
            .services
            .read()
            .unwrap()
            .get(dst)
            .cloned()
            .ok_or_else(|| RpcError::NoSuchService(dst.to_string()))?;

        let first = service.handle(req.clone()).map_err(RpcError::Handler);
        if duplicated {
            // At-least-once delivery: the handler observes the request
            // twice; the caller gets the first outcome.
            self.stats.duplicated.fetch_add(1, Ordering::Relaxed);
            let _ = service.handle(req);
        }
        if let Ok(rsp) = &first {
            self.stats
                .bytes_received
                .fetch_add(rsp.wire_bytes() as u64, Ordering::Relaxed);
        }
        first
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::messages::{ReqGetRows, RspGetRows};
    use std::sync::atomic::AtomicU64;

    struct Echo {
        hits: AtomicU64,
    }

    impl RpcService for Echo {
        fn handle(&self, req: Request) -> Result<Response, String> {
            self.hits.fetch_add(1, Ordering::Relaxed);
            match req {
                Request::Ping => Ok(Response::Pong),
                Request::GetRows(r) => Ok(Response::GetRows(RspGetRows {
                    row_count: r.count,
                    last_shuffle_row_index: r.committed_row_index + r.count,
                    attachment: crate::rpc::empty_attachment(),
                    drained: false,
                })),
            }
        }
    }

    fn net() -> Arc<RpcNet> {
        RpcNet::new(Clock::realtime(), Prng::seeded(1))
    }

    #[test]
    fn basic_call() {
        let n = net();
        n.register("m0", Arc::new(Echo { hits: AtomicU64::new(0) }));
        let rsp = n.call("r0", "m0", Request::Ping).unwrap();
        assert_eq!(rsp, Response::Pong);
        assert_eq!(n.stats.calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn unknown_address() {
        let n = net();
        assert!(matches!(
            n.call("r0", "ghost", Request::Ping),
            Err(RpcError::NoSuchService(_))
        ));
    }

    #[test]
    fn unregister_kills_service() {
        let n = net();
        n.register("m0", Arc::new(Echo { hits: AtomicU64::new(0) }));
        n.unregister("m0");
        assert!(!n.is_registered("m0"));
        assert!(n.call("r0", "m0", Request::Ping).is_err());
    }

    #[test]
    fn reregistration_replaces() {
        let n = net();
        let a = Arc::new(Echo { hits: AtomicU64::new(0) });
        let b = Arc::new(Echo { hits: AtomicU64::new(0) });
        n.register("m0", a.clone());
        n.register("m0", b.clone());
        n.call("r0", "m0", Request::Ping).unwrap();
        assert_eq!(a.hits.load(Ordering::Relaxed), 0);
        assert_eq!(b.hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn partition_blocks_both_ways() {
        let n = net();
        n.register("m0", Arc::new(Echo { hits: AtomicU64::new(0) }));
        n.register("r0", Arc::new(Echo { hits: AtomicU64::new(0) }));
        n.with_faults(|f| f.partition("r0", "m0"));
        assert!(matches!(
            n.call("r0", "m0", Request::Ping),
            Err(RpcError::Partitioned { .. })
        ));
        assert!(matches!(
            n.call("m0", "r0", Request::Ping),
            Err(RpcError::Partitioned { .. })
        ));
        n.with_faults(|f| f.heal("r0", "m0"));
        assert!(n.call("r0", "m0", Request::Ping).is_ok());
    }

    #[test]
    fn drops_are_probabilistic_and_deterministic() {
        let n = net();
        n.register("m0", Arc::new(Echo { hits: AtomicU64::new(0) }));
        n.with_faults(|f| f.drop_prob = 0.5);
        let outcomes: Vec<bool> = (0..100)
            .map(|_| n.call("r0", "m0", Request::Ping).is_ok())
            .collect();
        let ok = outcomes.iter().filter(|b| **b).count();
        assert!((20..=80).contains(&ok), "drop rate wildly off: {ok}/100");
        assert!(n.stats.dropped.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn duplication_runs_handler_twice() {
        let n = net();
        let svc = Arc::new(Echo { hits: AtomicU64::new(0) });
        n.register("m0", svc.clone());
        n.with_faults(|f| f.dup_prob = 1.0);
        let rsp = n.call("r0", "m0", Request::Ping).unwrap();
        assert_eq!(rsp, Response::Pong);
        assert_eq!(svc.hits.load(Ordering::Relaxed), 2);
        assert_eq!(n.stats.duplicated.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn getrows_roundtrip_shape() {
        let n = net();
        n.register("m0", Arc::new(Echo { hits: AtomicU64::new(0) }));
        let rsp = n
            .call(
                "r0",
                "m0",
                Request::GetRows(ReqGetRows {
                    count: 5,
                    reducer_index: 2,
                    epoch: 0,
                    committed_row_index: 10,
                    mapper_id: "g".into(),
                }),
            )
            .unwrap();
        match rsp {
            Response::GetRows(r) => {
                assert_eq!(r.row_count, 5);
                assert_eq!(r.last_shuffle_row_index, 15);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn handler_errors_propagate() {
        struct Failing;
        impl RpcService for Failing {
            fn handle(&self, _req: Request) -> Result<Response, String> {
                Err("boom".into())
            }
        }
        let n = net();
        n.register("m0", Arc::new(Failing));
        assert_eq!(
            n.call("r0", "m0", Request::Ping),
            Err(RpcError::Handler("boom".into()))
        );
    }
}
