//! Network fault plans: drop, delay, duplicate, partition.
//!
//! "We consider that any worker can fail spontaneously. Moreover, …
//! we can temporarily end up with multiple instances of the same mapper or
//! reducer if network partitions occur, producing a so-called split-brain
//! scenario." (§4.6) — this module is where those conditions are
//! manufactured, deterministically, from a seed.

use std::collections::HashSet;

/// Mutable description of the network's current misbehaviour.
#[derive(Debug, Default, Clone)]
pub struct FaultPlan {
    /// Probability a call is dropped (caller sees a timeout).
    pub drop_prob: f64,
    /// Probability a delivered call is *duplicated* (handler runs twice;
    /// the caller sees the first response). At-least-once networks do
    /// this; exactly-once processing must survive it.
    pub dup_prob: f64,
    /// Uniform artificial latency range, simulated milliseconds.
    pub delay_ms: (u64, u64),
    /// Severed directed links.
    cut_links: HashSet<(String, String)>,
    /// Fully isolated nodes (no traffic in or out).
    isolated: HashSet<String>,
}

impl FaultPlan {
    pub fn healthy() -> FaultPlan {
        FaultPlan::default()
    }

    /// Sever both directions between two addresses.
    pub fn partition(&mut self, a: &str, b: &str) {
        self.cut_links.insert((a.to_string(), b.to_string()));
        self.cut_links.insert((b.to_string(), a.to_string()));
    }

    /// Restore both directions between two addresses.
    pub fn heal(&mut self, a: &str, b: &str) {
        self.cut_links.remove(&(a.to_string(), b.to_string()));
        self.cut_links.remove(&(b.to_string(), a.to_string()));
    }

    /// Cut a node off from everyone.
    pub fn isolate(&mut self, node: &str) {
        self.isolated.insert(node.to_string());
    }

    pub fn rejoin(&mut self, node: &str) {
        self.isolated.remove(node);
    }

    /// Clear everything back to a healthy network.
    pub fn heal_all(&mut self) {
        *self = FaultPlan::default();
    }

    /// Is the (src → dst) path currently severed?
    pub fn is_cut(&self, src: &str, dst: &str) -> bool {
        self.isolated.contains(src)
            || self.isolated.contains(dst)
            || self.cut_links.contains(&(src.to_string(), dst.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_plan_cuts_nothing() {
        let p = FaultPlan::healthy();
        assert!(!p.is_cut("a", "b"));
        assert_eq!(p.drop_prob, 0.0);
    }

    #[test]
    fn partition_and_heal_symmetric() {
        let mut p = FaultPlan::healthy();
        p.partition("a", "b");
        assert!(p.is_cut("a", "b"));
        assert!(p.is_cut("b", "a"));
        assert!(!p.is_cut("a", "c"));
        p.heal("b", "a");
        assert!(!p.is_cut("a", "b"));
    }

    #[test]
    fn isolation_blocks_all_traffic() {
        let mut p = FaultPlan::healthy();
        p.isolate("m0");
        assert!(p.is_cut("m0", "r1"));
        assert!(p.is_cut("r1", "m0"));
        assert!(!p.is_cut("r1", "r2"));
        p.rejoin("m0");
        assert!(!p.is_cut("m0", "r1"));
    }

    #[test]
    fn heal_all_resets() {
        let mut p = FaultPlan::healthy();
        p.drop_prob = 0.5;
        p.partition("a", "b");
        p.isolate("c");
        p.heal_all();
        assert!(!p.is_cut("a", "b"));
        assert!(!p.is_cut("c", "a"));
        assert_eq!(p.drop_prob, 0.0);
    }
}
