//! In-process RPC with fault injection.
//!
//! Reducers pull rows from mappers with `GetRows` calls (§4.3.4); the wire
//! messages in [`messages`] mirror the paper's protobuf schema field for
//! field. [`transport::RpcNet`] is the simulated network: services
//! register under string addresses (the ones workers publish in
//! discovery), and every call passes through a [`fault::FaultPlan`] that
//! can drop, delay, duplicate or partition traffic — the raw material for
//! the §4.6 split-brain and failure drills.

pub mod messages;
pub mod fault;
pub mod transport;

pub use fault::FaultPlan;
pub use messages::{empty_attachment, Attachment, ReqGetRows, Request, Response, RspGetRows};
pub use transport::{RpcError, RpcNet, RpcService};
