//! `yt-stream-obs-v1`: the versioned observability export.
//!
//! One JSON document per `figure` run, written next to `BENCH_*.json`,
//! carrying everything the run observed: the stat lines the figure
//! printed, every counter, every latency histogram, the WA report(s),
//! and the flight-recorder spans. The console output is *routed
//! through* this collector ([`ObsExport::stat`] prints and records in
//! one call), so the text a human read and the JSON a tool parses can
//! never disagree.
//!
//! Hand-rolled serialization, same policy as `util::benchkit`: the
//! crate takes no serde dependency, and the document is flat enough
//! that a writer is ~100 lines. `u64` ids are emitted as fixed-width
//! hex *strings* — JSON numbers lose integer precision past 2^53.

use std::fmt::Display;
use std::path::PathBuf;
use std::sync::Arc;

use crate::metrics::wa::WaReport;
use crate::metrics::MetricsHub;
use crate::obs::span::{SpanOutcome, TxnSpan};
use crate::storage::accounting::ALL_CATEGORIES;

/// Schema identifier; bump on any shape change.
pub const OBS_SCHEMA: &str = "yt-stream-obs-v1";

/// Collector for one labeled run (one figure invocation).
pub struct ObsExport {
    label: String,
    metrics: Arc<MetricsHub>,
    reports: Vec<WaReport>,
    stats: Vec<(String, String)>,
}

impl ObsExport {
    pub fn new(label: impl Into<String>, metrics: Arc<MetricsHub>) -> ObsExport {
        ObsExport {
            label: label.into(),
            metrics,
            reports: Vec::new(),
            stats: Vec::new(),
        }
    }

    /// Print one stat line (`name: value`) *and* record it in the
    /// export — the single path figure drivers use for result lines.
    pub fn stat(&mut self, name: &str, value: impl Display) {
        let rendered = value.to_string();
        println!("{name}: {rendered}");
        self.stats.push((name.to_string(), rendered));
    }

    /// Attach a WA report. The export serializes the report's own
    /// accounting snapshot, so the JSON per-category totals are equal
    /// to the `WaReport` by construction.
    pub fn add_report(&mut self, report: &WaReport) {
        self.reports.push(report.clone());
    }

    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(16 * 1024);
        out.push_str("{\n");
        out.push_str(&format!("  \"schema\": \"{}\",\n", OBS_SCHEMA));
        out.push_str(&format!("  \"label\": {},\n", json_str(&self.label)));

        out.push_str("  \"stats\": [");
        push_list(&mut out, self.stats.iter(), |o, (k, v)| {
            o.push_str(&format!(
                "{{\"name\": {}, \"value\": {}}}",
                json_str(k),
                json_str(v)
            ));
        });
        out.push_str("],\n");

        out.push_str("  \"counters\": [");
        push_list(&mut out, self.metrics.counters_snapshot().iter(), |o, (k, v)| {
            o.push_str(&format!("{{\"name\": {}, \"value\": {v}}}", json_str(k)));
        });
        out.push_str("],\n");

        out.push_str("  \"histograms\": [");
        push_list(&mut out, self.metrics.histograms_snapshot().iter(), |o, (k, h)| {
            let buckets: Vec<String> = h
                .nonzero_buckets()
                .iter()
                .map(|(ub, n)| format!("[{ub}, {n}]"))
                .collect();
            o.push_str(&format!(
                "{{\"name\": {}, \"count\": {}, \"p50\": {}, \"p99\": {}, \"max\": {}, \"buckets\": [{}]}}",
                json_str(k),
                h.count(),
                h.p50(),
                h.p99(),
                h.max(),
                buckets.join(", ")
            ));
        });
        out.push_str("],\n");

        out.push_str("  \"wa\": [");
        push_list(&mut out, self.reports.iter(), |o, r| {
            o.push_str(&wa_json(r));
        });
        out.push_str("],\n");

        let rec = self.metrics.recorder();
        out.push_str("  \"spans\": {\n");
        out.push_str(&format!(
            "    \"recorded_total\": {},\n    \"dropped_total\": {},\n",
            rec.recorded_total(),
            rec.dropped_total()
        ));
        out.push_str("    \"workers\": [");
        push_list(&mut out, rec.snapshot().iter(), |o, ws| {
            o.push_str(&format!(
                "{{\"worker\": {}, \"dropped\": {}, \"spans\": [",
                json_str(&ws.worker),
                ws.dropped
            ));
            push_list(o, ws.spans.iter(), |o2, s| o2.push_str(&span_json(s)));
            o.push_str("]}");
        });
        out.push_str("]\n  }\n");
        out.push_str("}\n");
        out
    }

    /// Write `obs-<label>.json` into `$YT_OBS_DIR` (default: the
    /// working directory, i.e. next to `BENCH_*.json` in CI runs).
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var("YT_OBS_DIR").unwrap_or_else(|_| ".".to_string());
        let file = format!("obs-{}.json", sanitize(&self.label));
        let path = PathBuf::from(dir).join(file);
        std::fs::write(&path, self.to_json())?;
        println!("obs export: wrote {}", path.display());
        Ok(path)
    }
}

fn wa_json(r: &WaReport) -> String {
    let mut bytes = Vec::new();
    for cat in ALL_CATEGORIES {
        let (b, o) = (r.snapshot.bytes_of(cat), r.snapshot.ops_of(cat));
        if b > 0 || o > 0 {
            bytes.push(format!(
                "{{\"category\": \"{}\", \"bytes\": {b}, \"ops\": {o}}}",
                cat.name()
            ));
        }
    }
    format!(
        "{{\"label\": {}, \"ingested_bytes\": {}, \"factor\": {:.6}, \"bytes\": [{}]}}",
        json_str(&r.label),
        r.ingested_bytes,
        r.factor(),
        bytes.join(", ")
    )
}

fn span_json(s: &TxnSpan) -> String {
    let mut bytes = Vec::new();
    for cat in ALL_CATEGORIES {
        let b = s.bytes_by_category[cat.index()];
        if b > 0 {
            bytes.push(format!("{{\"category\": \"{}\", \"bytes\": {b}}}", cat.name()));
        }
    }
    let losing = match &s.outcome {
        SpanOutcome::Conflicted { losing_row } => {
            format!(", \"losing_row\": {}", json_str(losing_row))
        }
        _ => String::new(),
    };
    format!(
        "{{\"txn_id\": {}, \"trace_id\": \"{:016x}\", \"worker\": {}, \"scope\": {}, \
         \"read_set\": {}, \"outcome\": \"{}\"{}, \"bytes\": [{}], \
         \"start_ms\": {}, \"end_ms\": {}}}",
        s.txn_id,
        s.trace_id,
        json_str(&s.worker.address()),
        json_str(&s.scope),
        s.read_set,
        s.outcome.name(),
        losing,
        bytes.join(", "),
        s.start_ms,
        s.end_ms
    )
}

fn push_list<T>(out: &mut String, items: impl Iterator<Item = T>, f: impl Fn(&mut String, T)) {
    for (i, item) in items.enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        f(out, item);
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn sanitize(label: &str) -> String {
    label
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '-' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::WorkerId;
    use crate::storage::accounting::{AccountingSnapshot, WriteCategory, CATEGORY_COUNT};

    #[test]
    fn export_round_trips_wa_totals() {
        let hub = MetricsHub::new();
        hub.add("reducer/commits_total", 3);
        hub.histogram("reducer/000/commit_latency_ms").record(12);
        let mut snap = AccountingSnapshot::default();
        snap.bytes[WriteCategory::ReducerMeta.index()] = 4096;
        snap.ops[WriteCategory::ReducerMeta.index()] = 2;
        let report = WaReport::new("drill", 1024, snap);
        let mut exp = ObsExport::new("unit", hub.clone());
        exp.add_report(&report);
        exp.stat("byte-identity", "EXACT");
        let json = exp.to_json();
        assert!(json.contains("\"schema\": \"yt-stream-obs-v1\""), "{json}");
        // The WA section carries exactly the report's per-category bytes.
        assert!(
            json.contains("{\"category\": \"reducer_meta\", \"bytes\": 4096, \"ops\": 2}"),
            "{json}"
        );
        assert!(json.contains("\"name\": \"byte-identity\", \"value\": \"EXACT\""), "{json}");
        assert!(json.contains("\"p50\": 12"), "{json}");
    }

    #[test]
    fn spans_serialize_with_hex_trace_ids() {
        let hub = MetricsHub::new();
        let mut by_cat = [0u64; CATEGORY_COUNT];
        by_cat[WriteCategory::ReducerMeta.index()] = 7;
        hub.recorder().record(TxnSpan {
            txn_id: 0,
            trace_id: 0xdead_beef,
            worker: WorkerId::reducer(2, "g9"),
            scope: "stage1".into(),
            read_set: 4,
            outcome: SpanOutcome::Conflicted { losing_row: "state/\"k\"".into() },
            bytes_by_category: by_cat,
            start_ms: 5,
            end_ms: 9,
        });
        let json = ObsExport::new("unit2", hub).to_json();
        assert!(json.contains("\"trace_id\": \"00000000deadbeef\""), "{json}");
        assert!(json.contains("\"outcome\": \"conflicted\""), "{json}");
        assert!(json.contains("\\\"k\\\""), "escaped losing row: {json}");
        assert!(json.contains("\"worker\": \"reducer-2/g9\""), "{json}");
    }
}
