//! Commit-spine observability: the transaction flight recorder.
//!
//! Every transaction attempt on the commit spine — reducer batch
//! commits, mapper trim/adopt CAS, reshard plan and finalize commits,
//! cold-tier compaction (which rides the trim transaction) — records a
//! [`span::TxnSpan`] into the [`recorder::FlightRecorder`] owned by
//! the `MetricsHub`. Spans carry the worker incarnation, stage scope,
//! CAS read-set size, per-`WriteCategory` bytes and a trace id derived
//! from the source row-index range, so a drill failure is answered
//! with a causal record ([`forensics`]) instead of a bare exit code,
//! and every figure run emits a machine-readable `yt-stream-obs-v1`
//! document ([`export`]).
//!
//! Recording is strictly off-transaction: a span is written after the
//! commit call returns and never joins the CAS read set, so enabling
//! or disabling the recorder cannot change any commit outcome.

pub mod export;
pub mod forensics;
pub mod recorder;
pub mod span;

pub use export::{ObsExport, OBS_SCHEMA};
pub use recorder::{FlightRecorder, WorkerSpans, DEFAULT_RING_CAPACITY};
pub use span::{trace_id, SpanOutcome, TxnSpan, WorkerId, WorkerKind, ALL_OUTCOMES, OUTCOME_COUNT};
