//! Transaction spans: the unit of record of the flight recorder.
//!
//! A [`TxnSpan`] is written for every transaction *attempt* on the
//! commit spine — committed or not — by the worker that drove the
//! attempt. Spans are recorded strictly off-transaction (after the
//! commit call returns, never inside the CAS read set), so observing
//! the protocol can never perturb it: a run with recording enabled and
//! a run with it disabled execute byte-identical commit sequences.
//!
//! The `trace_id` ties a span back to the source rows the transaction
//! moved: it is an FNV-1a-64 hash over the `(partition, begin, end)`
//! row-index ranges the attempt covered. A reducer commit over shuffle
//! rows, the mapper trim that later retires those rows, and the cold
//! chunk the trim compacts them into all hash the *same* range, so a
//! row's provenance (ingest → handoff → fire → output) is
//! reconstructible by joining spans on `trace_id` across stages.

use crate::storage::accounting::CATEGORY_COUNT;

/// Which commit-spine role produced a span.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkerKind {
    Mapper,
    Reducer,
    Resharder,
}

impl WorkerKind {
    pub fn name(self) -> &'static str {
        match self {
            WorkerKind::Mapper => "mapper",
            WorkerKind::Reducer => "reducer",
            WorkerKind::Resharder => "resharder",
        }
    }
}

/// Identity of the worker incarnation that drove a transaction attempt.
///
/// Worker identity in this tree is `(kind, index, guid)` — there is no
/// numeric incarnation counter; the spawn guid *is* the incarnation.
/// Two spans with the same kind/index but different `incarnation`
/// strings are a twin pair, which is exactly what drill forensics needs
/// to name the split-brain loser.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct WorkerId {
    pub kind: WorkerKind,
    pub index: usize,
    /// Spawn guid (the incarnation); stable for a worker's lifetime.
    pub incarnation: String,
}

impl WorkerId {
    pub fn mapper(index: usize, guid: &str) -> Self {
        WorkerId { kind: WorkerKind::Mapper, index, incarnation: guid.to_string() }
    }

    pub fn reducer(index: usize, guid: &str) -> Self {
        WorkerId { kind: WorkerKind::Reducer, index, incarnation: guid.to_string() }
    }

    pub fn resharder(index: usize, guid: &str) -> Self {
        WorkerId { kind: WorkerKind::Resharder, index, incarnation: guid.to_string() }
    }

    /// `kind-index/incarnation`, matching the address strings the
    /// coordinator already prints (`mapper-3/abc123`).
    pub fn address(&self) -> String {
        format!("{}-{}/{}", self.kind.name(), self.index, self.incarnation)
    }
}

/// How a transaction attempt ended.
///
/// Kept mutually exhaustive with [`OUTCOME_COUNT`], [`ALL_OUTCOMES`]
/// and [`SpanOutcome::name`] — protolint R3 checks the four stay in
/// sync, so a new variant cannot ship without its export name.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpanOutcome {
    /// The CAS validated and the write set was applied.
    Committed,
    /// Lost the CAS race; `losing_row` names the table/key whose
    /// timestamp moved under the transaction.
    Conflicted { losing_row: String },
    /// The worker discovered it is a stale twin (split-brain fence,
    /// reshard fence, ownership moved) and stood down without writing.
    Abdicated,
    /// Transient failure before an outcome (I/O, decode, lookup).
    Error,
}

/// Number of [`SpanOutcome`] variants; must track the enum.
pub const OUTCOME_COUNT: usize = 4;

/// Every outcome's export name, in declaration order. Export and query
/// code iterates this instead of hand-listing outcomes.
pub const ALL_OUTCOMES: [&str; OUTCOME_COUNT] = [
    "committed",
    "conflicted",
    "abdicated",
    "error",
];

impl SpanOutcome {
    /// Stable lower-case name used in exports and `obs` query filters.
    pub fn name(&self) -> &'static str {
        match self {
            SpanOutcome::Committed => "committed",
            SpanOutcome::Conflicted { .. } => "conflicted",
            SpanOutcome::Abdicated => "abdicated",
            SpanOutcome::Error => "error",
        }
    }
}

/// One recorded transaction attempt on the commit spine.
#[derive(Debug, Clone)]
pub struct TxnSpan {
    /// Recorder-assigned sequence number (global across workers).
    pub txn_id: u64,
    /// FNV-1a-64 over the source row-index ranges (see [`trace_id`]).
    pub trace_id: u64,
    pub worker: WorkerId,
    /// Stage scope (the WA accounting scope), "" for unscoped txns.
    pub scope: String,
    /// CAS read-set size at commit time (rows validated).
    pub read_set: usize,
    pub outcome: SpanOutcome,
    /// Bytes written per `WriteCategory` (index order), zero unless
    /// the attempt committed.
    pub bytes_by_category: [u64; CATEGORY_COUNT],
    pub start_ms: u64,
    pub end_ms: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_u64(mut h: u64, v: u64) -> u64 {
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Derive a trace id from the source row-index ranges a transaction
/// covered: one `(partition, begin, end)` triple per source, `end`
/// exclusive. Deterministic, so the reducer commit over shuffle rows
/// `[a, b)` of partition `p` and the trim/compaction that later
/// retires exactly those rows produce the same id.
pub fn trace_id(ranges: &[(usize, i64, i64)]) -> u64 {
    let mut h = FNV_OFFSET;
    for &(part, begin, end) in ranges {
        h = fnv_u64(h, part as u64);
        h = fnv_u64(h, begin as u64);
        h = fnv_u64(h, end as u64);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_names_cover_all_outcomes() {
        let outcomes = [
            SpanOutcome::Committed,
            SpanOutcome::Conflicted { losing_row: "t/k".into() },
            SpanOutcome::Abdicated,
            SpanOutcome::Error,
        ];
        assert_eq!(outcomes.len(), OUTCOME_COUNT);
        for (o, want) in outcomes.iter().zip(ALL_OUTCOMES) {
            assert_eq!(o.name(), want);
        }
    }

    #[test]
    fn trace_id_is_deterministic_and_range_sensitive() {
        let a = trace_id(&[(0, 0, 128), (1, 0, 64)]);
        assert_eq!(a, trace_id(&[(0, 0, 128), (1, 0, 64)]));
        assert_ne!(a, trace_id(&[(0, 0, 128), (1, 0, 65)]));
        assert_ne!(a, trace_id(&[(1, 0, 64), (0, 0, 128)]));
        assert_ne!(trace_id(&[]), trace_id(&[(0, 0, 0)]));
    }

    #[test]
    fn worker_address_matches_coordinator_format() {
        assert_eq!(WorkerId::mapper(3, "abc").address(), "mapper-3/abc");
        assert_eq!(WorkerId::reducer(0, "g").address(), "reducer-0/g");
        assert_eq!(WorkerId::resharder(0, "driver").address(), "resharder-0/driver");
    }
}
