//! Drill forensics: turn the flight recorder into an explanation.
//!
//! When a figure's byte-identity or WA gate fails, a bare `exit 1`
//! says *that* exactly-once broke, not *which* transaction lost
//! *which* conflict. These helpers render the recorded spans as a
//! causal timeline — losers first-class, twins named by incarnation —
//! so a failed drill prints the incident record StreamShield-style
//! instead of an assert.

use crate::obs::recorder::FlightRecorder;
use crate::obs::span::{SpanOutcome, TxnSpan, ALL_OUTCOMES};

/// Snapshot every span matching the filters, sorted by `(end_ms,
/// txn_id)` so concurrent attempts read as a timeline. All filters are
/// substring matches; `None` matches everything.
pub fn spans_matching(
    rec: &FlightRecorder,
    worker: Option<&str>,
    scope: Option<&str>,
    outcome: Option<&str>,
) -> Vec<TxnSpan> {
    let mut out = Vec::new();
    for ws in rec.snapshot() {
        if let Some(w) = worker {
            if !ws.worker.contains(w) {
                continue;
            }
        }
        for s in ws.spans {
            if let Some(sc) = scope {
                if !s.scope.contains(sc) {
                    continue;
                }
            }
            if let Some(o) = outcome {
                if s.outcome.name() != o {
                    continue;
                }
            }
            out.push(s);
        }
    }
    out.sort_by_key(|s| (s.end_ms, s.txn_id));
    out
}

/// One timeline line for a span.
pub fn format_span(s: &TxnSpan) -> String {
    let detail = match &s.outcome {
        SpanOutcome::Conflicted { losing_row } => {
            format!("conflicted(losing_row={losing_row})")
        }
        other => other.name().to_string(),
    };
    let bytes: u64 = s.bytes_by_category.iter().sum();
    format!(
        "[{:>6}ms..{:>6}ms] txn#{:<5} trace={:016x} {:<24} scope={:<12} read_set={:<3} bytes={:<8} {}",
        s.start_ms,
        s.end_ms,
        s.txn_id,
        s.trace_id,
        s.worker.address(),
        if s.scope.is_empty() { "-" } else { &s.scope },
        s.read_set,
        bytes,
        detail,
    )
}

/// Render the conflict/abdication timeline for a failed gate: every
/// non-committed span (newest `limit` of them), then a per-worker
/// outcome census so the losing incarnation is named even when its
/// spans scrolled out of the ring.
pub fn conflict_timeline(rec: &FlightRecorder, scope: Option<&str>, limit: usize) -> String {
    let mut out = String::new();
    let losers: Vec<TxnSpan> = spans_matching(rec, None, scope, None)
        .into_iter()
        .filter(|s| !matches!(s.outcome, SpanOutcome::Committed))
        .collect();
    let skip = losers.len().saturating_sub(limit);
    out.push_str(&format!(
        "conflict timeline ({} non-committed span(s){}):\n",
        losers.len(),
        if skip > 0 {
            format!(", newest {limit} shown")
        } else {
            String::new()
        }
    ));
    if losers.is_empty() {
        out.push_str("  (none recorded — every attempt committed)\n");
    }
    for s in losers.iter().skip(skip) {
        out.push_str("  ");
        out.push_str(&format_span(s));
        out.push('\n');
    }
    out.push_str("per-worker outcomes:\n");
    for ws in rec.snapshot() {
        let mut counts = [0u64; ALL_OUTCOMES.len()];
        for s in &ws.spans {
            if let Some(i) = ALL_OUTCOMES.iter().position(|n| *n == s.outcome.name()) {
                counts[i] += 1;
            }
        }
        let cells: Vec<String> = ALL_OUTCOMES
            .iter()
            .zip(counts)
            .map(|(n, c)| format!("{n}={c}"))
            .collect();
        out.push_str(&format!(
            "  {:<24} {} dropped={}\n",
            ws.worker,
            cells.join(" "),
            ws.dropped
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::WorkerId;
    use crate::storage::accounting::CATEGORY_COUNT;

    fn span(guid: &str, outcome: SpanOutcome, end_ms: u64) -> TxnSpan {
        TxnSpan {
            txn_id: 0,
            trace_id: 7,
            worker: WorkerId::reducer(0, guid),
            scope: "stage0".into(),
            read_set: 2,
            outcome,
            bytes_by_category: [0; CATEGORY_COUNT],
            start_ms: end_ms.saturating_sub(1),
            end_ms,
        }
    }

    #[test]
    fn timeline_names_the_losing_incarnation() {
        let rec = FlightRecorder::default();
        rec.record(span("winner", SpanOutcome::Committed, 10));
        rec.record(span(
            "loser",
            SpanOutcome::Conflicted { losing_row: "state/k3".into() },
            11,
        ));
        rec.record(span("loser", SpanOutcome::Abdicated, 12));
        let text = conflict_timeline(&rec, Some("stage0"), 16);
        assert!(text.contains("reducer-0/loser"), "{text}");
        assert!(text.contains("losing_row=state/k3"), "{text}");
        assert!(text.contains("2 non-committed span(s)"), "{text}");
        // The census row still names the winner's incarnation.
        assert!(text.contains("reducer-0/winner"), "{text}");
    }

    #[test]
    fn filters_compose() {
        let rec = FlightRecorder::default();
        rec.record(span("a", SpanOutcome::Committed, 1));
        rec.record(span("b", SpanOutcome::Abdicated, 2));
        assert_eq!(spans_matching(&rec, Some("reducer-0"), None, None).len(), 2);
        assert_eq!(
            spans_matching(&rec, None, Some("stage0"), Some("abdicated")).len(),
            1
        );
        assert_eq!(spans_matching(&rec, Some("mapper"), None, None).len(), 0);
    }
}
