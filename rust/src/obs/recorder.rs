//! The flight recorder: bounded per-worker rings of [`TxnSpan`]s.
//!
//! Lock-light by construction. The hot path (`record`) takes one
//! atomic load when recording is disabled and, when enabled, one
//! read-lock on the ring map plus the owning worker's ring mutex —
//! never a global serialization point across workers. Rings are
//! bounded drop-oldest: a long run cannot grow memory without bound,
//! and every evicted span is counted so exports can say exactly how
//! much history was lost.
//!
//! Recording is strictly off-transaction: spans are written after the
//! commit call returns and never join the CAS read set, so the
//! recorder cannot change which twin wins a race (DESIGN.md §3).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::obs::span::TxnSpan;
use crate::util;

/// Default per-worker ring capacity. Sized so the figure drills
/// (thousands of commits per run) keep their full span history while a
/// pathological hot loop still tops out at a few MB per worker.
pub const DEFAULT_RING_CAPACITY: usize = 2048;

#[derive(Debug, Default)]
struct WorkerRing {
    spans: Mutex<VecDeque<TxnSpan>>,
    dropped: AtomicU64,
}

/// All spans currently retained for one worker, plus its drop count.
#[derive(Debug, Clone)]
pub struct WorkerSpans {
    /// The worker's address (`kind-index/incarnation`).
    pub worker: String,
    /// Spans evicted from this ring since the run started.
    pub dropped: u64,
    /// Retained spans, oldest first.
    pub spans: Vec<TxnSpan>,
}

/// Per-process span recorder, owned by the `MetricsHub` so every
/// worker holding a metrics handle can record without new plumbing.
#[derive(Debug)]
pub struct FlightRecorder {
    enabled: AtomicBool,
    capacity: AtomicUsize,
    next_txn_id: AtomicU64,
    rings: RwLock<HashMap<String, Arc<WorkerRing>>>,
}

impl Default for FlightRecorder {
    fn default() -> Self {
        FlightRecorder {
            enabled: AtomicBool::new(true),
            capacity: AtomicUsize::new(DEFAULT_RING_CAPACITY),
            next_txn_id: AtomicU64::new(0),
            rings: RwLock::new(HashMap::new()),
        }
    }
}

impl FlightRecorder {
    /// The one hot-path check: call sites skip span construction
    /// entirely when this is false.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Change the per-worker ring bound (existing rings shrink lazily
    /// on their next record).
    pub fn set_capacity(&self, cap: usize) {
        self.capacity.store(cap.max(1), Ordering::Relaxed);
    }

    /// Record one transaction attempt. Assigns the span's `txn_id`;
    /// drops the oldest span(s) if the worker's ring is full.
    pub fn record(&self, mut span: TxnSpan) {
        if !self.enabled() {
            return;
        }
        span.txn_id = self.next_txn_id.fetch_add(1, Ordering::Relaxed) + 1;
        let key = span.worker.address();
        let ring = {
            let rings = util::rlock(&self.rings);
            rings.get(&key).cloned()
        };
        let ring = match ring {
            Some(r) => r,
            None => {
                let mut rings = util::wlock(&self.rings);
                rings.entry(key).or_default().clone()
            }
        };
        let cap = self.capacity.load(Ordering::Relaxed).max(1);
        let mut spans = util::lock(&ring.spans);
        while spans.len() >= cap {
            spans.pop_front();
            ring.dropped.fetch_add(1, Ordering::Relaxed);
        }
        spans.push_back(span);
    }

    /// Total spans accepted (retained + dropped) since the start.
    pub fn recorded_total(&self) -> u64 {
        self.next_txn_id.load(Ordering::Relaxed)
    }

    /// Total spans evicted across all rings.
    pub fn dropped_total(&self) -> u64 {
        let rings = util::rlock(&self.rings);
        rings.values().map(|r| r.dropped.load(Ordering::Relaxed)).sum()
    }

    /// Copy out every ring, sorted by worker address.
    pub fn snapshot(&self) -> Vec<WorkerSpans> {
        let mut out: Vec<WorkerSpans> = {
            let rings = util::rlock(&self.rings);
            rings
                .iter()
                .map(|(k, r)| WorkerSpans {
                    worker: k.clone(),
                    dropped: r.dropped.load(Ordering::Relaxed),
                    spans: util::lock(&r.spans).iter().cloned().collect(),
                })
                .collect()
        };
        out.sort_by(|a, b| a.worker.cmp(&b.worker));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::{SpanOutcome, WorkerId};
    use crate::storage::accounting::CATEGORY_COUNT;

    fn span(worker: &WorkerId, i: u64) -> TxnSpan {
        TxnSpan {
            txn_id: 0,
            trace_id: i,
            worker: worker.clone(),
            scope: String::new(),
            read_set: 1,
            outcome: SpanOutcome::Committed,
            bytes_by_category: [0; CATEGORY_COUNT],
            start_ms: i,
            end_ms: i + 1,
        }
    }

    #[test]
    fn ring_overflow_accounts_for_every_evicted_span() {
        let rec = FlightRecorder::default();
        rec.set_capacity(8);
        let w = WorkerId::reducer(0, "g1");
        for i in 0..20 {
            rec.record(span(&w, i));
        }
        let snap = rec.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].spans.len(), 8);
        assert_eq!(snap[0].dropped, 12);
        // Exact accounting: accepted == retained + dropped.
        assert_eq!(
            rec.recorded_total(),
            snap[0].spans.len() as u64 + rec.dropped_total()
        );
        // Drop-oldest: the survivors are the 8 newest (trace ids 12..20).
        assert_eq!(snap[0].spans[0].trace_id, 12);
        assert_eq!(snap[0].spans[7].trace_id, 19);
        // txn ids are assigned in record order, monotonically.
        assert!(snap[0].spans.windows(2).all(|w| w[0].txn_id < w[1].txn_id));
    }

    #[test]
    fn disabled_recorder_accepts_nothing() {
        let rec = FlightRecorder::default();
        rec.set_enabled(false);
        rec.record(span(&WorkerId::mapper(0, "g"), 0));
        assert_eq!(rec.recorded_total(), 0);
        assert!(rec.snapshot().is_empty());
        rec.set_enabled(true);
        rec.record(span(&WorkerId::mapper(0, "g"), 1));
        assert_eq!(rec.recorded_total(), 1);
    }

    #[test]
    fn rings_are_per_worker() {
        let rec = FlightRecorder::default();
        rec.record(span(&WorkerId::reducer(0, "a"), 0));
        rec.record(span(&WorkerId::reducer(0, "b"), 1));
        rec.record(span(&WorkerId::reducer(1, "a"), 2));
        let snap = rec.snapshot();
        let names: Vec<&str> = snap.iter().map(|w| w.worker.as_str()).collect();
        assert_eq!(names, ["reducer-0/a", "reducer-0/b", "reducer-1/a"]);
    }
}
