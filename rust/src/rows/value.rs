//! Strictly-typed data values.

use std::cmp::Ordering;
use std::fmt;

use super::bytestr::ByteStr;

/// A single typed cell of an [`super::UnversionedRow`].
///
/// `Value` has a *total* order (variant rank first, then payload; doubles
/// via `total_cmp`) so rows can serve as keys of sorted dynamic tables.
///
/// String cells are [`ByteStr`]s — shared slices of an `Arc`'d backing
/// buffer — so cloning a `Value` (and hence a row or rowset) never copies
/// string payloads (§Perf: the zero-copy row pipeline).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int64(i64),
    Uint64(u64),
    Double(f64),
    Str(ByteStr),
}

impl Value {
    /// Rank used as the major sort key; mirrors YT's type ordering where
    /// null sorts first.
    fn rank(&self) -> u8 {
        match self {
            Value::Null => 0,
            Value::Bool(_) => 1,
            Value::Int64(_) => 2,
            Value::Uint64(_) => 3,
            Value::Double(_) => 4,
            Value::Str(_) => 5,
        }
    }

    /// Approximate in-memory/wire footprint in bytes; drives the mapper
    /// memory semaphore (§4.3.3 step 6) and all throughput metrics.
    ///
    /// This is the *logical* size: a `Str` cell that views a larger shared
    /// buffer pins that whole buffer while retained (see
    /// [`Value::detached`]).
    pub fn byte_size(&self) -> usize {
        match self {
            Value::Null => 1,
            Value::Bool(_) => 1,
            Value::Int64(_) | Value::Uint64(_) | Value::Double(_) => 8,
            Value::Str(s) => 4 + s.len(),
        }
    }

    /// A copy whose string payload (if any) owns a minimal backing buffer
    /// — severs the tie to a shared attachment at persist boundaries
    /// ([`super::bytestr::ByteStr::detached`]).
    pub fn detached(&self) -> Value {
        match self {
            Value::Str(s) => Value::Str(s.detached()),
            other => other.clone(),
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int64(v) => Some(*v),
            Value::Uint64(v) if *v <= i64::MAX as u64 => Some(*v as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Uint64(v) => Some(*v),
            Value::Int64(v) if *v >= 0 => Some(*v as u64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Double(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }
}

impl Eq for Value {}

impl Ord for Value {
    fn cmp(&self, other: &Self) -> Ordering {
        match (self, other) {
            (Value::Bool(a), Value::Bool(b)) => a.cmp(b),
            (Value::Int64(a), Value::Int64(b)) => a.cmp(b),
            (Value::Uint64(a), Value::Uint64(b)) => a.cmp(b),
            (Value::Double(a), Value::Double(b)) => a.total_cmp(b),
            (Value::Str(a), Value::Str(b)) => a.cmp(b),
            _ => self.rank().cmp(&other.rank()),
        }
    }
}

impl PartialOrd for Value {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl std::hash::Hash for Value {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.rank().hash(state);
        match self {
            Value::Null => {}
            Value::Bool(b) => b.hash(state),
            Value::Int64(v) => v.hash(state),
            Value::Uint64(v) => v.hash(state),
            Value::Double(v) => v.to_bits().hash(state),
            Value::Str(s) => s.hash(state),
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "#"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Int64(v) => write!(f, "{v}"),
            Value::Uint64(v) => write!(f, "{v}u"),
            Value::Double(v) => write!(f, "{v}"),
            Value::Str(s) => write!(f, "{s:?}"),
        }
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int64(v)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::Uint64(v)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Double(v)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(ByteStr::new(v))
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(ByteStr::new(&v))
    }
}
impl From<ByteStr> for Value {
    fn from(v: ByteStr) -> Self {
        Value::Str(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_order_across_types() {
        let vals = vec![
            Value::Null,
            Value::Bool(false),
            Value::Bool(true),
            Value::Int64(-5),
            Value::Int64(10),
            Value::Uint64(3),
            Value::Double(f64::NEG_INFINITY),
            Value::Double(2.5),
            Value::Double(f64::NAN),
            Value::Str("a".into()),
            Value::Str("b".into()),
        ];
        // Already sorted by construction; verify Ord agrees.
        for w in vals.windows(2) {
            assert!(w[0] < w[1] || (w[0].rank() == w[1].rank()), "{:?} !< {:?}", w[0], w[1]);
        }
        let mut shuffled = vals.clone();
        shuffled.reverse();
        shuffled.sort();
        // sort must be stable total order: same multiset, nulls first, strings last
        assert_eq!(shuffled.first().unwrap(), &Value::Null);
        assert_eq!(shuffled.last().unwrap(), &Value::Str("b".into()));
    }

    #[test]
    fn nan_has_a_home() {
        let a = Value::Double(f64::NAN);
        let b = Value::Double(f64::NAN);
        assert_eq!(a.cmp(&b), Ordering::Equal);
        assert!(Value::Double(f64::INFINITY) < a);
    }

    #[test]
    fn byte_sizes() {
        assert_eq!(Value::Null.byte_size(), 1);
        assert_eq!(Value::Int64(0).byte_size(), 8);
        assert_eq!(Value::Str("abcd".into()).byte_size(), 8);
    }

    #[test]
    fn accessors() {
        assert_eq!(Value::Int64(5).as_i64(), Some(5));
        assert_eq!(Value::Uint64(5).as_i64(), Some(5));
        assert_eq!(Value::Uint64(u64::MAX).as_i64(), None);
        assert_eq!(Value::Int64(-1).as_u64(), None);
        assert_eq!(Value::Str("x".into()).as_str(), Some("x"));
        assert_eq!(Value::Bool(true).as_bool(), Some(true));
        assert!(Value::Null.is_null());
    }

    #[test]
    fn clone_shares_string_payload() {
        let v = Value::from("not copied on clone");
        let w = v.clone();
        match (&v, &w) {
            (Value::Str(a), Value::Str(b)) => {
                assert_eq!(a.payload_ptr(), b.payload_ptr());
                assert!(ByteStr::same_backing(a, b));
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn from_impls() {
        assert_eq!(Value::from(1i64), Value::Int64(1));
        assert_eq!(Value::from(1u64), Value::Uint64(1));
        assert_eq!(Value::from(1.5), Value::Double(1.5));
        assert_eq!(Value::from("s"), Value::Str("s".into()));
        assert_eq!(Value::from(true), Value::Bool(true));
    }
}
