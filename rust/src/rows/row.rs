//! A single schematized row.

use super::value::Value;

/// An array of strictly-typed values; column meaning is given by the
/// enclosing rowset's [`super::NameTable`] (§4.1).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct UnversionedRow {
    values: Vec<Value>,
}

impl UnversionedRow {
    pub fn new(values: Vec<Value>) -> Self {
        UnversionedRow { values }
    }

    pub fn values(&self) -> &[Value] {
        &self.values
    }

    pub fn into_values(self) -> Vec<Value> {
        self.values
    }

    pub fn get(&self, idx: usize) -> Option<&Value> {
        self.values.get(idx)
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Approximate in-memory/wire footprint; sum of cell sizes plus a
    /// fixed per-row header. Drives the memory semaphore and MB/s metrics.
    pub fn byte_size(&self) -> usize {
        8 + self.values.iter().map(Value::byte_size).sum::<usize>()
    }

    /// A copy whose string cells own minimal backing buffers, so retaining
    /// this row cannot pin the (much larger) shared attachment it was
    /// decoded from. Used at persist boundaries (dynamic-table commits).
    pub fn detached(&self) -> UnversionedRow {
        UnversionedRow {
            values: self.values.iter().map(Value::detached).collect(),
        }
    }
}

impl From<Vec<Value>> for UnversionedRow {
    fn from(values: Vec<Value>) -> Self {
        UnversionedRow::new(values)
    }
}

/// Build a row from heterogeneous literals: `row![1i64, "s", 2.5]`.
#[macro_export]
macro_rules! row {
    ($($v:expr),* $(,)?) => {
        $crate::rows::UnversionedRow::new(vec![$($crate::rows::Value::from($v)),*])
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let r = UnversionedRow::new(vec![Value::Int64(1), Value::Str("x".into())]);
        assert_eq!(r.len(), 2);
        assert_eq!(r.get(0), Some(&Value::Int64(1)));
        assert_eq!(r.get(5), None);
        assert!(!r.is_empty());
    }

    #[test]
    fn byte_size_includes_header() {
        let r = UnversionedRow::new(vec![Value::Int64(1)]);
        assert_eq!(r.byte_size(), 8 + 8);
    }

    #[test]
    fn row_macro() {
        let r = row![1i64, "hello", 2.5, true];
        assert_eq!(
            r.values(),
            &[
                Value::Int64(1),
                Value::Str("hello".into()),
                Value::Double(2.5),
                Value::Bool(true)
            ]
        );
    }

    #[test]
    fn rows_order_lexicographically() {
        let a = row![1i64, "a"];
        let b = row![1i64, "b"];
        let c = row![2i64];
        assert!(a < b);
        assert!(b < c);
    }
}
