//! Binary wire format for rows and rowsets.
//!
//! Used for (a) `GetRows` RPC attachments (§4.3.4: rows "are returned as
//! attachments in a binary format"), (b) journal/state byte accounting —
//! the write-amplification meter counts *encoded* bytes, and (c) spill
//! chunks (§6).
//!
//! Layout (little-endian):
//!
//! ```text
//! rowset  := u32 magic | u16 version | name_table | u32 row_count | row*
//! name_table := u16 count | (u16 len | bytes)*
//! row     := u16 value_count | value*
//! value   := u8 tag | payload
//! ```
//!
//! Varint is deliberately not used: fixed-width ints make the encoder ~2×
//! faster and the shuffle payload is dominated by strings anyway (profiled
//! in EXPERIMENTS.md §Perf).
//!
//! # Zero-copy decode (§Perf)
//!
//! String cells decode as [`ByteStr`]s — *(Arc buffer, offset, length)*
//! views into a **single shared backing buffer** per attachment — so a
//! string-bearing rowset costs one heap allocation for payload bytes, not
//! one per cell, and cloning any decoded row afterwards is a refcount
//! bump. Use [`decode_rowset_shared`]/[`decode_rows_shared`] when the
//! encoded bytes already live in an `Arc<[u8]>` (the RPC attachment path):
//! that is fully zero-copy. The `&[u8]` entry points
//! ([`decode_rowset`]/[`decode_rows`]) first copy the input into a fresh
//! `Arc<[u8]>` — still a single bulk memcpy rather than per-cell
//! allocations.
//!
//! # Exact-size encode (§Perf)
//!
//! [`encoded_size_rowset`] (and friends) compute the exact wire size from
//! the name table + rows, so every `encode_*` preallocates precisely
//! instead of guessing; debug builds assert `buf.len()` matches the
//! prediction.

use std::sync::Arc;

use super::bytestr::ByteStr;
use super::name_table::NameTable;
use super::row::UnversionedRow;
use super::rowset::UnversionedRowset;
use super::value::Value;

pub(crate) const MAGIC: u32 = 0x59_54_52_53; // "YTRS"
pub(crate) const VERSION: u16 = 2;

const TAG_NULL: u8 = 0;
const TAG_BOOL_FALSE: u8 = 1;
const TAG_BOOL_TRUE: u8 = 2;
const TAG_INT64: u8 = 3;
const TAG_UINT64: u8 = 4;
const TAG_DOUBLE: u8 = 5;
const TAG_STR: u8 = 6;

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum CodecError {
    #[error("codec: truncated input at byte {0}")]
    Truncated(usize),
    #[error("codec: bad magic {0:#x}")]
    BadMagic(u32),
    #[error("codec: unsupported version {0}")]
    BadVersion(u16),
    #[error("codec: unknown value tag {0}")]
    BadTag(u8),
    #[error("codec: invalid utf-8 in string")]
    BadUtf8,
    #[error("codec: string cell at byte {0} exceeds the 4 GiB ByteStr offset range")]
    OffsetOverflow(usize),
}

/// Exact wire size of one value (`u8` tag + payload).
#[inline]
pub fn encoded_size_value(v: &Value) -> usize {
    match v {
        Value::Null | Value::Bool(_) => 1,
        Value::Int64(_) | Value::Uint64(_) | Value::Double(_) => 1 + 8,
        Value::Str(s) => 1 + 4 + s.len(),
    }
}

/// Exact wire size of one row (`u16` count + values).
#[inline]
pub fn encoded_size_row(row: &UnversionedRow) -> usize {
    2 + row.values().iter().map(encoded_size_value).sum::<usize>()
}

/// Exact wire size of [`encode_rowset`]'s output.
pub fn encoded_size_rowset(rs: &UnversionedRowset) -> usize {
    4 + 2
        + rs.name_table().wire_size()
        + 4
        + rs.rows().iter().map(encoded_size_row).sum::<usize>()
}

/// Exact wire size of [`encode_rowset_refs`]'s output.
pub fn encoded_size_rowset_refs(nt: &NameTable, rows: &[&UnversionedRow]) -> usize {
    4 + 2
        + nt.wire_size()
        + 4
        + rows.iter().map(|r| encoded_size_row(r)).sum::<usize>()
}

/// Exact wire size of [`encode_rows`]'s output.
pub fn encoded_size_rows(rows: &[UnversionedRow]) -> usize {
    4 + rows.iter().map(encoded_size_row).sum::<usize>()
}

/// Streaming encoder over a byte buffer.
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    pub fn new() -> Self {
        Encoder { buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Encoder {
            buf: Vec::with_capacity(n),
        }
    }

    #[inline]
    pub(crate) fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    #[inline]
    pub(crate) fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub(crate) fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub(crate) fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub(crate) fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    pub fn value(&mut self, v: &Value) {
        match v {
            Value::Null => self.u8(TAG_NULL),
            Value::Bool(false) => self.u8(TAG_BOOL_FALSE),
            Value::Bool(true) => self.u8(TAG_BOOL_TRUE),
            Value::Int64(x) => {
                self.u8(TAG_INT64);
                self.u64(*x as u64);
            }
            Value::Uint64(x) => {
                self.u8(TAG_UINT64);
                self.u64(*x);
            }
            Value::Double(x) => {
                self.u8(TAG_DOUBLE);
                self.u64(x.to_bits());
            }
            Value::Str(s) => {
                self.u8(TAG_STR);
                self.u32(s.len() as u32);
                self.bytes(s.as_bytes());
            }
        }
    }

    pub fn row(&mut self, row: &UnversionedRow) {
        self.u16(row.len() as u16);
        for v in row.values() {
            self.value(v);
        }
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

impl Default for Encoder {
    fn default() -> Self {
        Self::new()
    }
}

fn encode_name_table(e: &mut Encoder, nt: &NameTable) {
    e.u16(nt.len() as u16);
    for name in nt.names() {
        e.u16(name.len() as u16);
        e.bytes(name.as_bytes());
    }
}

/// Encode a full rowset (name table + rows).
pub fn encode_rowset(rs: &UnversionedRowset) -> Vec<u8> {
    let predicted = encoded_size_rowset(rs);
    let mut e = Encoder::with_capacity(predicted);
    e.u32(MAGIC);
    e.u16(VERSION);
    encode_name_table(&mut e, rs.name_table());
    e.u32(rs.len() as u32);
    for row in rs.rows() {
        e.row(row);
    }
    let buf = e.finish();
    debug_assert_eq!(buf.len(), predicted, "encoded_size_rowset mispredicted");
    buf
}

/// Encode a rowset directly from borrowed rows, without building an
/// intermediate `UnversionedRowset` (§Perf: the mapper's GetRows serving
/// path was cloning every served value just to encode it).
pub fn encode_rowset_refs(nt: &NameTable, rows: &[&UnversionedRow]) -> Vec<u8> {
    let predicted = encoded_size_rowset_refs(nt, rows);
    let mut e = Encoder::with_capacity(predicted);
    e.u32(MAGIC);
    e.u16(VERSION);
    encode_name_table(&mut e, nt);
    e.u32(rows.len() as u32);
    for row in rows {
        e.row(row);
    }
    let buf = e.finish();
    debug_assert_eq!(buf.len(), predicted, "encoded_size_rowset_refs mispredicted");
    buf
}

/// Encode only the rows (for journal accounting where the name table is
/// amortized away).
pub fn encode_rows(rows: &[UnversionedRow]) -> Vec<u8> {
    let predicted = encoded_size_rows(rows);
    let mut e = Encoder::with_capacity(predicted);
    e.u32(rows.len() as u32);
    for r in rows {
        e.row(r);
    }
    let buf = e.finish();
    debug_assert_eq!(buf.len(), predicted, "encoded_size_rows mispredicted");
    buf
}

/// Decoder over a shared backing buffer: string cells are produced as
/// [`ByteStr`] views into `arc` instead of freshly-allocated `String`s.
///
/// `pub(crate)` so [`super::batch`] parses the identical wire format with
/// the identical error semantics instead of re-implementing the grammar.
pub(crate) struct Decoder<'a> {
    arc: &'a Arc<[u8]>,
    i: usize,
}

impl<'a> Decoder<'a> {
    pub(crate) fn new(arc: &'a Arc<[u8]>) -> Decoder<'a> {
        Decoder { arc, i: 0 }
    }

    /// Current byte position (for trailing-garbage checks by callers).
    pub(crate) fn pos(&self) -> usize {
        self.i
    }

    fn b(&self) -> &[u8] {
        self.arc
    }

    fn need(&self, n: usize) -> Result<(), CodecError> {
        if self.i + n > self.b().len() {
            Err(CodecError::Truncated(self.i))
        } else {
            Ok(())
        }
    }

    pub(crate) fn u8(&mut self) -> Result<u8, CodecError> {
        self.need(1)?;
        let v = self.b()[self.i];
        self.i += 1;
        Ok(v)
    }

    pub(crate) fn u16(&mut self) -> Result<u16, CodecError> {
        self.need(2)?;
        let v = u16::from_le_bytes(self.b()[self.i..self.i + 2].try_into().unwrap());
        self.i += 2;
        Ok(v)
    }

    pub(crate) fn u32(&mut self) -> Result<u32, CodecError> {
        self.need(4)?;
        let v = u32::from_le_bytes(self.b()[self.i..self.i + 4].try_into().unwrap());
        self.i += 4;
        Ok(v)
    }

    pub(crate) fn u64(&mut self) -> Result<u64, CodecError> {
        self.need(8)?;
        let v = u64::from_le_bytes(self.b()[self.i..self.i + 8].try_into().unwrap());
        self.i += 8;
        Ok(v)
    }

    /// Owned string (name-table entries: few, amortized over the rowset).
    pub(crate) fn str(&mut self, n: usize) -> Result<String, CodecError> {
        self.need(n)?;
        let s = std::str::from_utf8(&self.b()[self.i..self.i + n])
            .map_err(|_| CodecError::BadUtf8)?
            .to_string();
        self.i += n;
        Ok(s)
    }

    /// Shared-slice string cell: validates UTF-8 once, allocates nothing.
    pub(crate) fn bytestr(&mut self, n: usize) -> Result<ByteStr, CodecError> {
        self.need(n)?;
        // Distinguish the ByteStr u32 offset limit from actual UTF-8
        // corruption so huge attachments get a diagnosable error. (`n`
        // itself comes from a u32 field and cannot overflow.)
        if self.i > u32::MAX as usize {
            return Err(CodecError::OffsetOverflow(self.i));
        }
        let s = ByteStr::from_utf8_slice(self.arc, self.i, n).ok_or(CodecError::BadUtf8)?;
        self.i += n;
        Ok(s)
    }

    pub(crate) fn value(&mut self) -> Result<Value, CodecError> {
        Ok(match self.u8()? {
            TAG_NULL => Value::Null,
            TAG_BOOL_FALSE => Value::Bool(false),
            TAG_BOOL_TRUE => Value::Bool(true),
            TAG_INT64 => Value::Int64(self.u64()? as i64),
            TAG_UINT64 => Value::Uint64(self.u64()?),
            TAG_DOUBLE => Value::Double(f64::from_bits(self.u64()?)),
            TAG_STR => {
                let n = self.u32()? as usize;
                Value::Str(self.bytestr(n)?)
            }
            t => return Err(CodecError::BadTag(t)),
        })
    }

    pub(crate) fn row(&mut self) -> Result<UnversionedRow, CodecError> {
        let n = self.u16()? as usize;
        let mut vals = Vec::with_capacity(n);
        for _ in 0..n {
            vals.push(self.value()?);
        }
        Ok(UnversionedRow::new(vals))
    }
}

/// Decode a rowset produced by [`encode_rowset`].
///
/// Copies `bytes` once into a fresh shared backing buffer; all string
/// cells then reference that single allocation. Prefer
/// [`decode_rowset_shared`] when the bytes are already `Arc`'d.
pub fn decode_rowset(bytes: &[u8]) -> Result<UnversionedRowset, CodecError> {
    // Reject a bad header before paying the bulk copy into shared
    // storage; error positions mirror the decoder's own checks.
    if bytes.len() < 4 {
        return Err(CodecError::Truncated(0));
    }
    let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(CodecError::BadMagic(magic));
    }
    if bytes.len() < 6 {
        return Err(CodecError::Truncated(4));
    }
    let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
    if version != VERSION {
        return Err(CodecError::BadVersion(version));
    }
    let shared: Arc<[u8]> = Arc::from(bytes);
    decode_rowset_shared(&shared)
}

/// Decode a rowset from an already-shared buffer — fully zero-copy: every
/// string cell is a [`ByteStr`] view into `buf`.
pub fn decode_rowset_shared(buf: &Arc<[u8]>) -> Result<UnversionedRowset, CodecError> {
    let mut d = Decoder { arc: buf, i: 0 };
    let magic = d.u32()?;
    if magic != MAGIC {
        return Err(CodecError::BadMagic(magic));
    }
    let version = d.u16()?;
    if version != VERSION {
        return Err(CodecError::BadVersion(version));
    }
    let ncols = d.u16()? as usize;
    let mut names = Vec::with_capacity(ncols);
    for _ in 0..ncols {
        let n = d.u16()? as usize;
        names.push(d.str(n)?);
    }
    let nt: Arc<NameTable> = NameTable::from_names(names);
    let nrows = d.u32()? as usize;
    let mut rows = Vec::with_capacity(nrows);
    for _ in 0..nrows {
        rows.push(d.row()?);
    }
    if d.i != buf.len() {
        return Err(CodecError::Truncated(d.i));
    }
    Ok(UnversionedRowset::new(nt, rows))
}

/// Decode rows produced by [`encode_rows`] (copies `bytes` once into a
/// shared backing buffer; see [`decode_rows_shared`]).
pub fn decode_rows(bytes: &[u8]) -> Result<Vec<UnversionedRow>, CodecError> {
    let shared: Arc<[u8]> = Arc::from(bytes);
    decode_rows_shared(&shared)
}

/// Decode rows from an already-shared buffer — zero-copy string cells.
pub fn decode_rows_shared(buf: &Arc<[u8]>) -> Result<Vec<UnversionedRow>, CodecError> {
    let mut d = Decoder { arc: buf, i: 0 };
    let n = d.u32()? as usize;
    let mut rows = Vec::with_capacity(n);
    for _ in 0..n {
        rows.push(d.row()?);
    }
    Ok(rows)
}

/// Decode one [`encode_rows`] record that starts at `offset` inside a
/// larger shared buffer holding several records back to back (the spill
/// queue packs a whole routed batch into one buffer). Returns the rows and
/// the offset one past the record's end. String cells are zero-copy views
/// into `buf`, exactly as with [`decode_rows_shared`].
pub fn decode_rows_shared_at(
    buf: &Arc<[u8]>,
    offset: usize,
) -> Result<(Vec<UnversionedRow>, usize), CodecError> {
    if offset > buf.len() {
        return Err(CodecError::Truncated(offset));
    }
    let mut d = Decoder { arc: buf, i: offset };
    let n = d.u32()? as usize;
    let mut rows = Vec::with_capacity(n);
    for _ in 0..n {
        rows.push(d.row()?);
    }
    Ok((rows, d.i))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::row;
    use crate::rows::rowset::RowsetBuilder;
    use crate::util::miniprop;
    use crate::util::prng::Prng;

    fn sample() -> UnversionedRowset {
        let nt = NameTable::new(&["user", "cluster", "ts", "payload", "flag"]);
        let mut b = RowsetBuilder::new(nt);
        b.push(row!["alice", "hahn", 123i64, 42.5, true]);
        b.push(row!["bob", "freud", -7i64, 0.0, false]);
        b.push(UnversionedRow::new(vec![
            Value::Null,
            Value::Uint64(u64::MAX),
            Value::Int64(i64::MIN),
            Value::Double(f64::NAN),
            Value::Null,
        ]));
        b.build()
    }

    #[test]
    fn rowset_roundtrip() {
        let rs = sample();
        let bytes = encode_rowset(&rs);
        assert_eq!(bytes.len(), encoded_size_rowset(&rs));
        let back = decode_rowset(&bytes).unwrap();
        assert_eq!(back.name_table().names(), rs.name_table().names());
        assert_eq!(back.len(), rs.len());
        // NaN != NaN under PartialEq, so compare via total order per value.
        for (a, b) in rs.rows().iter().zip(back.rows()) {
            assert_eq!(a.cmp(b), std::cmp::Ordering::Equal);
        }
    }

    #[test]
    fn rows_roundtrip() {
        let rows = vec![row![1i64, "x"], row![2i64, "y"]];
        let bytes = encode_rows(&rows);
        assert_eq!(bytes.len(), encoded_size_rows(&rows));
        assert_eq!(decode_rows(&bytes).unwrap(), rows);
    }

    #[test]
    fn rows_decode_at_offsets_across_packed_records() {
        let a = vec![row![1i64, "x"], row![2i64, "y"]];
        let b = vec![row![3i64, "zz"]];
        let mut packed = encode_rows(&a);
        packed.extend_from_slice(&encode_rows(&b));
        let shared: Arc<[u8]> = packed.into();
        let (rows_a, next) = decode_rows_shared_at(&shared, 0).unwrap();
        assert_eq!(rows_a, a);
        assert_eq!(next, encoded_size_rows(&a));
        let (rows_b, end) = decode_rows_shared_at(&shared, next).unwrap();
        assert_eq!(rows_b, b);
        assert_eq!(end, shared.len());
        assert!(matches!(
            decode_rows_shared_at(&shared, shared.len() + 1),
            Err(CodecError::Truncated(_))
        ));
    }

    #[test]
    fn detects_corruption() {
        let rs = sample();
        let bytes = encode_rowset(&rs);
        assert!(matches!(
            decode_rowset(&bytes[..bytes.len() - 1]),
            Err(CodecError::Truncated(_))
        ));
        let mut bad_magic = bytes.clone();
        bad_magic[0] ^= 0xFF;
        assert!(matches!(decode_rowset(&bad_magic), Err(CodecError::BadMagic(_))));
        let mut bad_ver = bytes.clone();
        bad_ver[4] = 0xEE;
        assert!(matches!(decode_rowset(&bad_ver), Err(CodecError::BadVersion(_))));
    }

    #[test]
    fn rejects_trailing_garbage() {
        let rs = sample();
        let mut bytes = encode_rowset(&rs);
        bytes.push(0);
        assert!(matches!(decode_rowset(&bytes), Err(CodecError::Truncated(_))));
    }

    #[test]
    fn empty_rowset_roundtrip() {
        let nt = NameTable::new(&["a"]);
        let rs = UnversionedRowset::empty(nt);
        let back = decode_rowset(&encode_rowset(&rs)).unwrap();
        assert!(back.is_empty());
        assert_eq!(back.name_table().names(), &["a".to_string()]);
    }

    #[test]
    fn decode_shares_one_backing_buffer() {
        // Every string cell of a decoded rowset must be a view into the
        // same single payload allocation (acceptance: one heap allocation
        // per string-bearing rowset).
        let rs = sample();
        let shared: Arc<[u8]> = encode_rowset(&rs).into();
        let back = decode_rowset_shared(&shared).unwrap();
        let cells: Vec<&ByteStr> = back
            .rows()
            .iter()
            .flat_map(|r| r.values())
            .filter_map(|v| match v {
                Value::Str(s) => Some(s),
                _ => None,
            })
            .collect();
        assert!(cells.len() >= 4, "sample must contain string cells");
        for c in &cells {
            assert!(ByteStr::same_backing(c, cells[0]));
            // Zero-copy: the cell points straight into the attachment.
            let start = shared.as_ptr() as usize;
            let p = c.payload_ptr() as usize;
            assert!(p >= start && p + c.len() <= start + shared.len());
        }
    }

    #[test]
    fn cloning_decoded_rowset_copies_no_payloads() {
        let rs = sample();
        let bytes = encode_rowset(&rs);
        let back = decode_rowset(&bytes).unwrap();
        let cloned = back.clone();
        for (a, b) in back.rows().iter().zip(cloned.rows()) {
            for (va, vb) in a.values().iter().zip(b.values()) {
                if let (Value::Str(sa), Value::Str(sb)) = (va, vb) {
                    assert_eq!(sa.payload_ptr(), sb.payload_ptr());
                    assert!(ByteStr::same_backing(sa, sb));
                }
            }
        }
    }

    fn arbitrary_value(rng: &mut Prng) -> Value {
        match rng.next_below(6) {
            0 => Value::Null,
            1 => Value::Bool(rng.chance(0.5)),
            2 => Value::Int64(rng.next_u64() as i64),
            3 => Value::Uint64(rng.next_u64()),
            4 => Value::Double(f64::from_bits(rng.next_u64())),
            _ => {
                let n = rng.next_below(20) as usize;
                Value::from(rng.ident(n))
            }
        }
    }

    fn arbitrary_rowset(rng: &mut Prng) -> UnversionedRowset {
        let ncols = rng.gen_range(1, 6) as usize;
        let names: Vec<String> = (0..ncols).map(|i| format!("c{i}_{}", rng.ident(3))).collect();
        let nt = NameTable::from_names(names);
        let nrows = rng.next_below(20) as usize;
        let mut b = RowsetBuilder::new(nt);
        for _ in 0..nrows {
            let vals = (0..ncols).map(|_| arbitrary_value(rng)).collect();
            b.push_values(vals);
        }
        b.build()
    }

    #[test]
    fn property_roundtrip_arbitrary_rowsets() {
        miniprop::check("codec roundtrip", |rng| {
            let rs = arbitrary_rowset(rng);
            let back = decode_rowset(&encode_rowset(&rs))
                .map_err(|e| format!("decode failed: {e}"))?;
            crate::prop_assert_eq!(back.len(), rs.len());
            for (a, b) in rs.rows().iter().zip(back.rows()) {
                crate::prop_assert!(
                    a.cmp(b) == std::cmp::Ordering::Equal,
                    "row mismatch: {a:?} vs {b:?}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn property_encoded_size_is_exact() {
        miniprop::check("encoded_size exact", |rng| {
            let rs = arbitrary_rowset(rng);
            let bytes = encode_rowset(&rs);
            crate::prop_assert_eq!(bytes.len(), encoded_size_rowset(&rs));

            let rows: Vec<UnversionedRow> = rs.rows().to_vec();
            let bytes = encode_rows(&rows);
            crate::prop_assert_eq!(bytes.len(), encoded_size_rows(&rows));

            let refs: Vec<&UnversionedRow> = rs.rows().iter().collect();
            let bytes = encode_rowset_refs(rs.name_table(), &refs);
            crate::prop_assert_eq!(
                bytes.len(),
                encoded_size_rowset_refs(rs.name_table(), &refs)
            );
            Ok(())
        });
    }

    #[test]
    fn property_shared_decode_equals_plain_decode() {
        miniprop::check("shared decode equivalence", |rng| {
            let rs = arbitrary_rowset(rng);
            let bytes = encode_rowset(&rs);
            let shared: Arc<[u8]> = bytes.clone().into();
            let a = decode_rowset(&bytes).map_err(|e| format!("plain: {e}"))?;
            let b = decode_rowset_shared(&shared).map_err(|e| format!("shared: {e}"))?;
            crate::prop_assert_eq!(a.len(), b.len());
            for (x, y) in a.rows().iter().zip(b.rows()) {
                crate::prop_assert!(
                    x.cmp(y) == std::cmp::Ordering::Equal,
                    "row mismatch: {x:?} vs {y:?}"
                );
            }
            Ok(())
        });
    }
}
