//! Binary wire format for rows and rowsets.
//!
//! Used for (a) `GetRows` RPC attachments (§4.3.4: rows "are returned as
//! attachments in a binary format"), (b) journal/state byte accounting —
//! the write-amplification meter counts *encoded* bytes, and (c) spill
//! chunks (§6).
//!
//! Layout (little-endian):
//!
//! ```text
//! rowset  := u32 magic | u16 version | name_table | u32 row_count | row*
//! name_table := u16 count | (u16 len | bytes)*
//! row     := u16 value_count | value*
//! value   := u8 tag | payload
//! ```
//!
//! Varint is deliberately not used: fixed-width ints make the encoder ~2×
//! faster and the shuffle payload is dominated by strings anyway (profiled
//! in EXPERIMENTS.md §Perf).

use std::sync::Arc;

use super::name_table::NameTable;
use super::row::UnversionedRow;
use super::rowset::UnversionedRowset;
use super::value::Value;

const MAGIC: u32 = 0x59_54_52_53; // "YTRS"
const VERSION: u16 = 2;

const TAG_NULL: u8 = 0;
const TAG_BOOL_FALSE: u8 = 1;
const TAG_BOOL_TRUE: u8 = 2;
const TAG_INT64: u8 = 3;
const TAG_UINT64: u8 = 4;
const TAG_DOUBLE: u8 = 5;
const TAG_STR: u8 = 6;

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum CodecError {
    #[error("codec: truncated input at byte {0}")]
    Truncated(usize),
    #[error("codec: bad magic {0:#x}")]
    BadMagic(u32),
    #[error("codec: unsupported version {0}")]
    BadVersion(u16),
    #[error("codec: unknown value tag {0}")]
    BadTag(u8),
    #[error("codec: invalid utf-8 in string")]
    BadUtf8,
}

/// Streaming encoder over a byte buffer.
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    pub fn new() -> Self {
        Encoder { buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Encoder {
            buf: Vec::with_capacity(n),
        }
    }

    #[inline]
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    #[inline]
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    pub fn value(&mut self, v: &Value) {
        match v {
            Value::Null => self.u8(TAG_NULL),
            Value::Bool(false) => self.u8(TAG_BOOL_FALSE),
            Value::Bool(true) => self.u8(TAG_BOOL_TRUE),
            Value::Int64(x) => {
                self.u8(TAG_INT64);
                self.u64(*x as u64);
            }
            Value::Uint64(x) => {
                self.u8(TAG_UINT64);
                self.u64(*x);
            }
            Value::Double(x) => {
                self.u8(TAG_DOUBLE);
                self.u64(x.to_bits());
            }
            Value::Str(s) => {
                self.u8(TAG_STR);
                self.u32(s.len() as u32);
                self.bytes(s.as_bytes());
            }
        }
    }

    pub fn row(&mut self, row: &UnversionedRow) {
        self.u16(row.len() as u16);
        for v in row.values() {
            self.value(v);
        }
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

impl Default for Encoder {
    fn default() -> Self {
        Self::new()
    }
}

/// Encode a full rowset (name table + rows).
pub fn encode_rowset(rs: &UnversionedRowset) -> Vec<u8> {
    let mut e = Encoder::with_capacity(16 + rs.byte_size() * 2);
    e.u32(MAGIC);
    e.u16(VERSION);
    e.u16(rs.name_table().len() as u16);
    for name in rs.name_table().names() {
        e.u16(name.len() as u16);
        e.bytes(name.as_bytes());
    }
    e.u32(rs.len() as u32);
    for row in rs.rows() {
        e.row(row);
    }
    e.finish()
}

/// Encode a rowset directly from borrowed rows, without building an
/// intermediate `UnversionedRowset` (§Perf: the mapper's GetRows serving
/// path was cloning every served value just to encode it).
pub fn encode_rowset_refs(nt: &NameTable, rows: &[&UnversionedRow]) -> Vec<u8> {
    let payload: usize = rows.iter().map(|r| r.byte_size()).sum();
    let mut e = Encoder::with_capacity(16 + payload * 2);
    e.u32(MAGIC);
    e.u16(VERSION);
    e.u16(nt.len() as u16);
    for name in nt.names() {
        e.u16(name.len() as u16);
        e.bytes(name.as_bytes());
    }
    e.u32(rows.len() as u32);
    for row in rows {
        e.row(row);
    }
    e.finish()
}

/// Encode only the rows (for journal accounting where the name table is
/// amortized away).
pub fn encode_rows(rows: &[UnversionedRow]) -> Vec<u8> {
    let mut e = Encoder::new();
    e.u32(rows.len() as u32);
    for r in rows {
        e.row(r);
    }
    e.finish()
}

struct Decoder<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Decoder<'a> {
    fn need(&self, n: usize) -> Result<(), CodecError> {
        if self.i + n > self.b.len() {
            Err(CodecError::Truncated(self.i))
        } else {
            Ok(())
        }
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        self.need(1)?;
        let v = self.b[self.i];
        self.i += 1;
        Ok(v)
    }

    fn u16(&mut self) -> Result<u16, CodecError> {
        self.need(2)?;
        let v = u16::from_le_bytes(self.b[self.i..self.i + 2].try_into().unwrap());
        self.i += 2;
        Ok(v)
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        self.need(4)?;
        let v = u32::from_le_bytes(self.b[self.i..self.i + 4].try_into().unwrap());
        self.i += 4;
        Ok(v)
    }

    fn u64(&mut self) -> Result<u64, CodecError> {
        self.need(8)?;
        let v = u64::from_le_bytes(self.b[self.i..self.i + 8].try_into().unwrap());
        self.i += 8;
        Ok(v)
    }

    fn str(&mut self, n: usize) -> Result<String, CodecError> {
        self.need(n)?;
        let s = std::str::from_utf8(&self.b[self.i..self.i + n])
            .map_err(|_| CodecError::BadUtf8)?
            .to_string();
        self.i += n;
        Ok(s)
    }

    fn value(&mut self) -> Result<Value, CodecError> {
        Ok(match self.u8()? {
            TAG_NULL => Value::Null,
            TAG_BOOL_FALSE => Value::Bool(false),
            TAG_BOOL_TRUE => Value::Bool(true),
            TAG_INT64 => Value::Int64(self.u64()? as i64),
            TAG_UINT64 => Value::Uint64(self.u64()?),
            TAG_DOUBLE => Value::Double(f64::from_bits(self.u64()?)),
            TAG_STR => {
                let n = self.u32()? as usize;
                Value::Str(self.str(n)?)
            }
            t => return Err(CodecError::BadTag(t)),
        })
    }

    fn row(&mut self) -> Result<UnversionedRow, CodecError> {
        let n = self.u16()? as usize;
        let mut vals = Vec::with_capacity(n);
        for _ in 0..n {
            vals.push(self.value()?);
        }
        Ok(UnversionedRow::new(vals))
    }
}

/// Decode a rowset produced by [`encode_rowset`].
pub fn decode_rowset(bytes: &[u8]) -> Result<UnversionedRowset, CodecError> {
    let mut d = Decoder { b: bytes, i: 0 };
    let magic = d.u32()?;
    if magic != MAGIC {
        return Err(CodecError::BadMagic(magic));
    }
    let version = d.u16()?;
    if version != VERSION {
        return Err(CodecError::BadVersion(version));
    }
    let ncols = d.u16()? as usize;
    let mut names = Vec::with_capacity(ncols);
    for _ in 0..ncols {
        let n = d.u16()? as usize;
        names.push(d.str(n)?);
    }
    let nt: Arc<NameTable> = NameTable::from_names(names);
    let nrows = d.u32()? as usize;
    let mut rows = Vec::with_capacity(nrows);
    for _ in 0..nrows {
        rows.push(d.row()?);
    }
    if d.i != bytes.len() {
        return Err(CodecError::Truncated(d.i));
    }
    Ok(UnversionedRowset::new(nt, rows))
}

/// Decode rows produced by [`encode_rows`].
pub fn decode_rows(bytes: &[u8]) -> Result<Vec<UnversionedRow>, CodecError> {
    let mut d = Decoder { b: bytes, i: 0 };
    let n = d.u32()? as usize;
    let mut rows = Vec::with_capacity(n);
    for _ in 0..n {
        rows.push(d.row()?);
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::row;
    use crate::rows::rowset::RowsetBuilder;
    use crate::util::miniprop;
    use crate::util::prng::Prng;

    fn sample() -> UnversionedRowset {
        let nt = NameTable::new(&["user", "cluster", "ts", "payload", "flag"]);
        let mut b = RowsetBuilder::new(nt);
        b.push(row!["alice", "hahn", 123i64, 42.5, true]);
        b.push(row!["bob", "freud", -7i64, 0.0, false]);
        b.push(UnversionedRow::new(vec![
            Value::Null,
            Value::Uint64(u64::MAX),
            Value::Int64(i64::MIN),
            Value::Double(f64::NAN),
            Value::Null,
        ]));
        b.build()
    }

    #[test]
    fn rowset_roundtrip() {
        let rs = sample();
        let bytes = encode_rowset(&rs);
        let back = decode_rowset(&bytes).unwrap();
        assert_eq!(back.name_table().names(), rs.name_table().names());
        assert_eq!(back.len(), rs.len());
        // NaN != NaN under PartialEq, so compare via total order per value.
        for (a, b) in rs.rows().iter().zip(back.rows()) {
            assert_eq!(a.cmp(b), std::cmp::Ordering::Equal);
        }
    }

    #[test]
    fn rows_roundtrip() {
        let rows = vec![row![1i64, "x"], row![2i64, "y"]];
        let bytes = encode_rows(&rows);
        assert_eq!(decode_rows(&bytes).unwrap(), rows);
    }

    #[test]
    fn detects_corruption() {
        let rs = sample();
        let bytes = encode_rowset(&rs);
        assert!(matches!(
            decode_rowset(&bytes[..bytes.len() - 1]),
            Err(CodecError::Truncated(_))
        ));
        let mut bad_magic = bytes.clone();
        bad_magic[0] ^= 0xFF;
        assert!(matches!(decode_rowset(&bad_magic), Err(CodecError::BadMagic(_))));
        let mut bad_ver = bytes.clone();
        bad_ver[4] = 0xEE;
        assert!(matches!(decode_rowset(&bad_ver), Err(CodecError::BadVersion(_))));
    }

    #[test]
    fn rejects_trailing_garbage() {
        let rs = sample();
        let mut bytes = encode_rowset(&rs);
        bytes.push(0);
        assert!(matches!(decode_rowset(&bytes), Err(CodecError::Truncated(_))));
    }

    #[test]
    fn empty_rowset_roundtrip() {
        let nt = NameTable::new(&["a"]);
        let rs = UnversionedRowset::empty(nt);
        let back = decode_rowset(&encode_rowset(&rs)).unwrap();
        assert!(back.is_empty());
        assert_eq!(back.name_table().names(), &["a".to_string()]);
    }

    fn arbitrary_value(rng: &mut Prng) -> Value {
        match rng.next_below(6) {
            0 => Value::Null,
            1 => Value::Bool(rng.chance(0.5)),
            2 => Value::Int64(rng.next_u64() as i64),
            3 => Value::Uint64(rng.next_u64()),
            4 => Value::Double(f64::from_bits(rng.next_u64())),
            _ => {
                let n = rng.next_below(20) as usize;
                Value::Str(rng.ident(n))
            }
        }
    }

    #[test]
    fn property_roundtrip_arbitrary_rowsets() {
        miniprop::check("codec roundtrip", |rng| {
            let ncols = rng.gen_range(1, 6) as usize;
            let names: Vec<String> =
                (0..ncols).map(|i| format!("c{i}_{}", rng.ident(3))).collect();
            let nt = NameTable::from_names(names);
            let nrows = rng.next_below(20) as usize;
            let mut b = RowsetBuilder::new(nt);
            for _ in 0..nrows {
                let vals = (0..ncols).map(|_| arbitrary_value(rng)).collect();
                b.push_values(vals);
            }
            let rs = b.build();
            let back = decode_rowset(&encode_rowset(&rs))
                .map_err(|e| format!("decode failed: {e}"))?;
            crate::prop_assert_eq!(back.len(), rs.len());
            for (a, b) in rs.rows().iter().zip(back.rows()) {
                crate::prop_assert!(
                    a.cmp(b) == std::cmp::Ordering::Equal,
                    "row mismatch: {a:?} vs {b:?}"
                );
            }
            Ok(())
        });
    }
}
