//! `ByteStr` — a cheaply-clonable string cell over a shared byte buffer.
//!
//! The row data plane moves string payloads around constantly: decode,
//! window buffering, GetRows serving, spill, reducer combine. With
//! `Value::Str(String)` every one of those steps deep-copied the payload.
//! `ByteStr` replaces the owned `String` with an *(Arc backing buffer,
//! offset, length)* triple:
//!
//! * cloning a cell (and therefore a row or a rowset) is a refcount bump;
//! * [`crate::rows::codec`] decodes every string cell of an attachment as
//!   a slice of **one** shared buffer — one allocation per attachment
//!   instead of one per cell;
//! * equality, ordering, hashing and display are all by *content*, so the
//!   representation change is invisible to the data model.
//!
//! The UTF-8 invariant is established once, at construction: every public
//! constructor validates its input, after which `as_str` is free.

use std::cmp::Ordering;
use std::fmt;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

/// A shared, immutable UTF-8 slice: `buf[off .. off + len]`.
///
/// Invariant: the `off..off+len` range lies inside `buf` and is valid
/// UTF-8. Both are checked by every constructor; the buffer behind the
/// `Arc` is never mutated.
#[derive(Clone)]
pub struct ByteStr {
    buf: Arc<[u8]>,
    off: u32,
    len: u32,
}

impl ByteStr {
    /// Copy `s` into a fresh single-owner backing buffer.
    ///
    /// Panics if `s` exceeds the `u32` length representation: the check is
    /// the soundness boundary for `as_str`'s unchecked UTF-8 read, so it
    /// must hold in release builds too (a silent `as u32` truncation could
    /// cut a multi-byte codepoint in half).
    pub fn new(s: &str) -> ByteStr {
        assert!(s.len() <= u32::MAX as usize, "string cell exceeds u32 length");
        ByteStr {
            buf: Arc::from(s.as_bytes()),
            off: 0,
            len: s.len() as u32,
        }
    }

    /// A view of `buf[off .. off + len]`, sharing the buffer.
    ///
    /// Returns `None` when the range is out of bounds, not valid UTF-8, or
    /// exceeds the `u32` offset/length representation (attachments are
    /// well under 4 GiB).
    pub fn from_utf8_slice(buf: &Arc<[u8]>, off: usize, len: usize) -> Option<ByteStr> {
        let end = off.checked_add(len)?;
        if end > buf.len() || off > u32::MAX as usize || len > u32::MAX as usize {
            return None;
        }
        std::str::from_utf8(&buf[off..end]).ok()?;
        Some(ByteStr {
            buf: buf.clone(),
            off: off as u32,
            len: len as u32,
        })
    }

    pub fn as_bytes(&self) -> &[u8] {
        let off = self.off as usize;
        &self.buf[off..off + self.len as usize]
    }

    pub fn as_str(&self) -> &str {
        // SAFETY: every constructor validates that `off..off+len` is valid
        // UTF-8 and the Arc'd buffer is immutable.
        unsafe { std::str::from_utf8_unchecked(self.as_bytes()) }
    }

    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Address of the first payload byte. Zero-copy tests compare this
    /// across clones / decodes to prove payloads were shared, not copied.
    pub fn payload_ptr(&self) -> *const u8 {
        self.as_bytes().as_ptr()
    }

    /// Whether two cells share the same backing buffer allocation.
    pub fn same_backing(a: &ByteStr, b: &ByteStr) -> bool {
        Arc::ptr_eq(&a.buf, &b.buf)
    }

    /// A copy whose backing buffer holds *only* this string.
    ///
    /// A decoded cell is a view into its whole attachment/record buffer
    /// and keeps that buffer alive; long-lived sinks (e.g. dynamic-table
    /// commits) call this at the persist boundary so one retained cell
    /// cannot pin a multi-KB attachment. No-op (shared, not copied) when
    /// the buffer is already exactly this string.
    pub fn detached(&self) -> ByteStr {
        if self.off == 0 && self.len as usize == self.buf.len() {
            return self.clone();
        }
        ByteStr::new(self.as_str())
    }
}

impl fmt::Debug for ByteStr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self.as_str(), f)
    }
}

impl fmt::Display for ByteStr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl PartialEq for ByteStr {
    fn eq(&self, other: &Self) -> bool {
        self.as_bytes() == other.as_bytes()
    }
}

impl Eq for ByteStr {}

impl PartialEq<str> for ByteStr {
    fn eq(&self, other: &str) -> bool {
        self.as_str() == other
    }
}

impl PartialEq<&str> for ByteStr {
    fn eq(&self, other: &&str) -> bool {
        self.as_str() == *other
    }
}

impl Ord for ByteStr {
    fn cmp(&self, other: &Self) -> Ordering {
        self.as_str().cmp(other.as_str())
    }
}

impl PartialOrd for ByteStr {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Hash for ByteStr {
    fn hash<H: Hasher>(&self, state: &mut H) {
        // Hash exactly like `String`/`str` so the switch from
        // `Value::Str(String)` is invisible to hashed collections.
        self.as_str().hash(state);
    }
}

impl From<&str> for ByteStr {
    fn from(s: &str) -> Self {
        ByteStr::new(s)
    }
}

impl From<String> for ByteStr {
    fn from(s: String) -> Self {
        ByteStr::new(&s)
    }
}

impl From<&String> for ByteStr {
    fn from(s: &String) -> Self {
        ByteStr::new(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_content() {
        let b = ByteStr::new("hello");
        assert_eq!(b.as_str(), "hello");
        assert_eq!(b.len(), 5);
        assert!(!b.is_empty());
        assert!(ByteStr::new("").is_empty());
    }

    #[test]
    fn clone_shares_payload() {
        let a = ByteStr::new("shared payload");
        let b = a.clone();
        assert_eq!(a, b);
        assert_eq!(a.payload_ptr(), b.payload_ptr());
        assert!(ByteStr::same_backing(&a, &b));
        // Distinct constructions do NOT share.
        let c = ByteStr::new("shared payload");
        assert_eq!(a, c);
        assert!(!ByteStr::same_backing(&a, &c));
    }

    #[test]
    fn slice_of_shared_buffer() {
        let buf: Arc<[u8]> = Arc::from(&b"xxhelloyy"[..]);
        let b = ByteStr::from_utf8_slice(&buf, 2, 5).unwrap();
        assert_eq!(b.as_str(), "hello");
        assert_eq!(b.payload_ptr(), buf[2..].as_ptr());
        // Out of bounds and invalid UTF-8 rejected.
        assert!(ByteStr::from_utf8_slice(&buf, 8, 5).is_none());
        let bad: Arc<[u8]> = Arc::from(&[0xFFu8, 0xFE][..]);
        assert!(ByteStr::from_utf8_slice(&bad, 0, 2).is_none());
    }

    #[test]
    fn detached_severs_large_backing() {
        let buf: Arc<[u8]> = Arc::from(&b"a-large-shared-attachment-buffer"[..]);
        let view = ByteStr::from_utf8_slice(&buf, 2, 5).unwrap();
        let det = view.detached();
        assert_eq!(det, view);
        assert!(!ByteStr::same_backing(&det, &view));
        assert_eq!(det.len(), 5);
        // Already-minimal buffers are shared, not copied.
        let minimal = ByteStr::new("abc");
        assert!(ByteStr::same_backing(&minimal, &minimal.detached()));
    }

    #[test]
    fn ordering_and_eq_by_content() {
        let a = ByteStr::new("a");
        let b = ByteStr::new("b");
        assert!(a < b);
        assert_eq!(a, "a");
        assert_eq!(format!("{a}"), "a");
        assert_eq!(format!("{a:?}"), "\"a\"");
    }

    #[test]
    fn hashes_like_str() {
        use std::collections::hash_map::DefaultHasher;
        fn hash_of<T: Hash + ?Sized>(t: &T) -> u64 {
            let mut s = DefaultHasher::new();
            t.hash(&mut s);
            s.finish()
        }
        assert_eq!(hash_of(&ByteStr::new("key")), hash_of("key"));
    }
}
