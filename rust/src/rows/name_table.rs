//! Column-name ↔ column-id mapping.

use std::collections::HashMap;
use std::sync::Arc;

/// Maps row array indexes to column-name strings (§4.1). Immutable once
/// built and shared by `Arc` between every row of a rowset, mirroring the
//  original system where rows carry ids only.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NameTable {
    names: Vec<String>,
    ids: HashMap<String, usize>,
}

impl NameTable {
    pub fn new(names: &[&str]) -> Arc<NameTable> {
        let names: Vec<String> = names.iter().map(|s| s.to_string()).collect();
        Self::from_names(names)
    }

    pub fn from_names(names: Vec<String>) -> Arc<NameTable> {
        let mut ids = HashMap::with_capacity(names.len());
        for (i, n) in names.iter().enumerate() {
            let prev = ids.insert(n.clone(), i);
            assert!(prev.is_none(), "duplicate column name '{n}'");
        }
        Arc::new(NameTable { names, ids })
    }

    /// Column id for `name`, if registered.
    pub fn id(&self, name: &str) -> Option<usize> {
        self.ids.get(name).copied()
    }

    /// Column name for `id`.
    pub fn name(&self, id: usize) -> &str {
        &self.names[id]
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Exact wire footprint of this table in the [`super::codec`] rowset
    /// layout: `u16` count + per name `u16` length + bytes.
    pub fn wire_size(&self) -> usize {
        2 + self.names.iter().map(|n| 2 + n.len()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_both_ways() {
        let nt = NameTable::new(&["user", "cluster", "ts"]);
        assert_eq!(nt.id("user"), Some(0));
        assert_eq!(nt.id("ts"), Some(2));
        assert_eq!(nt.id("missing"), None);
        assert_eq!(nt.name(1), "cluster");
        assert_eq!(nt.len(), 3);
    }

    #[test]
    #[should_panic(expected = "duplicate column name")]
    fn duplicate_names_rejected() {
        NameTable::new(&["a", "a"]);
    }

    #[test]
    fn empty_table() {
        let nt = NameTable::new(&[]);
        assert!(nt.is_empty());
        assert_eq!(nt.wire_size(), 2);
    }

    #[test]
    fn wire_size_counts_lengths() {
        let nt = NameTable::new(&["ab", "cde"]);
        assert_eq!(nt.wire_size(), 2 + (2 + 2) + (2 + 3));
    }
}
