//! The schematized key-value row model (§4.1).
//!
//! "The whole system operates within a schematized key-value row-based
//! data model, encapsulated in the UnversionedRow class. It is stored as an
//! array of strictly-typed data values, with a separate NameTable object
//! used to map the array's indexes to the corresponding key strings. An
//! UnversionedRowset object stores an array of UnversionedRow objects
//! along with a NameTable instance."
//!
//! [`codec`] provides the binary wire format used for RPC attachments
//! (§4.3.4: "the actual rows are returned as attachments in a binary
//! format") and for journal byte accounting.

pub mod bytestr;
pub mod value;
pub mod name_table;
pub mod schema;
pub mod row;
pub mod rowset;
pub mod codec;
pub mod batch;

pub use batch::RowBatch;
pub use bytestr::ByteStr;
pub use name_table::NameTable;
pub use row::UnversionedRow;
pub use rowset::{RowsetBuilder, UnversionedRowset};
pub use schema::{ColumnSchema, ColumnType, TableSchema};
pub use value::Value;
