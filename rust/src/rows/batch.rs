//! Columnar row batches (§6 "vectorized execution"): the same rows as an
//! [`UnversionedRowset`], laid out column-major so the hot loops of the
//! shuffle path — encode, decode, key hashing — run as tight per-column
//! passes instead of per-row virtual dispatch.
//!
//! A [`RowBatch`] is bit-equivalent to the rowset it came from: `encode`
//! produces **byte-identical** output to [`codec::encode_rowset`] and
//! `decode_shared` accepts exactly what [`codec::decode_rowset_shared`]
//! accepts (same grammar, same error positions, same trailing-garbage
//! rejection), so the two representations interconvert freely anywhere on
//! the wire path. Ragged wire input (rows with differing value counts) is
//! preserved exactly: internally short rows are padded with `Null` so every
//! column has one cell per row, but a per-row width column remembers the
//! true cell count and `encode`/`to_rowset` emit only that many.
//!
//! The perf claim this module exists for (measured in
//! `benches/micro_hot_paths.rs`, `batch/*` vs the per-row baselines):
//! batch-level `encode` walks each row's cells through one monomorphic
//! loop with a single exact-size preallocation, and [`RowBatch::key_hash_column`]
//! computes the routing hash of every row in one vectorized pass via
//! [`partitioning`] — without materializing a composite-key `String` per
//! row, which the scalar path pays today.

use std::sync::Arc;

use crate::api::partitioning;

use super::codec::{self, CodecError, Decoder, Encoder};
use super::name_table::NameTable;
use super::row::UnversionedRow;
use super::rowset::UnversionedRowset;
use super::value::Value;

/// A column-major batch of rows sharing one [`NameTable`].
#[derive(Debug, Clone, PartialEq)]
pub struct RowBatch {
    name_table: Arc<NameTable>,
    /// `columns[c][r]` = cell `c` of row `r`; every column holds exactly
    /// `widths.len()` cells (short rows padded with `Null`).
    columns: Vec<Vec<Value>>,
    /// True wire cell count of each row (`<= columns.len()`); the padding
    /// cells beyond it are internal only and never re-encoded.
    widths: Vec<u16>,
}

impl RowBatch {
    /// Transpose a rowset into columnar form. Cheap per cell: string
    /// payloads are refcounted [`super::ByteStr`] views, never copied.
    pub fn from_rowset(rs: &UnversionedRowset) -> RowBatch {
        let nrows = rs.len();
        let ncols = rs.rows().iter().map(UnversionedRow::len).max().unwrap_or(0);
        let mut columns: Vec<Vec<Value>> = (0..ncols)
            .map(|_| Vec::with_capacity(nrows))
            .collect();
        let mut widths = Vec::with_capacity(nrows);
        for row in rs.rows() {
            let vals = row.values();
            widths.push(vals.len() as u16);
            for (c, col) in columns.iter_mut().enumerate() {
                col.push(vals.get(c).cloned().unwrap_or(Value::Null));
            }
        }
        RowBatch {
            name_table: rs.name_table().clone(),
            columns,
            widths,
        }
    }

    /// Decode the [`codec::encode_rowset`] wire format straight into
    /// columnar form from an already-shared buffer — zero-copy string
    /// cells, identical acceptance/rejection to
    /// [`codec::decode_rowset_shared`].
    pub fn decode_shared(buf: &Arc<[u8]>) -> Result<RowBatch, CodecError> {
        let mut d = Decoder::new(buf);
        let magic = d.u32()?;
        if magic != codec::MAGIC {
            return Err(CodecError::BadMagic(magic));
        }
        let version = d.u16()?;
        if version != codec::VERSION {
            return Err(CodecError::BadVersion(version));
        }
        let ncols = d.u16()? as usize;
        let mut names = Vec::with_capacity(ncols);
        for _ in 0..ncols {
            let n = d.u16()? as usize;
            names.push(d.str(n)?);
        }
        let name_table = NameTable::from_names(names);
        let nrows = d.u32()? as usize;
        let mut columns: Vec<Vec<Value>> = Vec::new();
        let mut widths = Vec::with_capacity(nrows);
        for r in 0..nrows {
            let w = d.u16()? as usize;
            while columns.len() < w {
                // A row wider than any before it: open the column and
                // backfill the padding for the rows already parsed.
                let mut col = Vec::with_capacity(nrows);
                col.resize(r, Value::Null);
                columns.push(col);
            }
            for (c, col) in columns.iter_mut().enumerate() {
                col.push(if c < w { d.value()? } else { Value::Null });
            }
            widths.push(w as u16);
        }
        if d.pos() != buf.len() {
            return Err(CodecError::Truncated(d.pos()));
        }
        Ok(RowBatch {
            name_table,
            columns,
            widths,
        })
    }

    pub fn name_table(&self) -> &Arc<NameTable> {
        &self.name_table
    }

    pub fn len(&self) -> usize {
        self.widths.len()
    }

    pub fn is_empty(&self) -> bool {
        self.widths.is_empty()
    }

    /// Cell `(row, col)`; `None` beyond the row's true wire width.
    pub fn value(&self, row: usize, col: usize) -> Option<&Value> {
        if col < *self.widths.get(row)? as usize {
            self.columns.get(col)?.get(row)
        } else {
            None
        }
    }

    /// One full column as a slice (including `Null` padding for rows
    /// narrower than `col` — check [`RowBatch::value`] semantics when
    /// raggedness matters; homogeneous batches have none).
    pub fn column(&self, col: usize) -> Option<&[Value]> {
        self.columns.get(col).map(Vec::as_slice)
    }

    /// Exact wire size of [`RowBatch::encode`]'s output.
    pub fn encoded_size(&self) -> usize {
        let mut n = 4 + 2 + self.name_table.wire_size() + 4;
        for r in 0..self.len() {
            n += 2;
            for c in 0..self.widths[r] as usize {
                n += codec::encoded_size_value(&self.columns[c][r]);
            }
        }
        n
    }

    /// Encode the batch — byte-identical to
    /// [`codec::encode_rowset`] over [`RowBatch::to_rowset`]'s result, with
    /// one exact-size preallocation for the whole batch.
    pub fn encode(&self) -> Vec<u8> {
        let predicted = self.encoded_size();
        let mut e = Encoder::with_capacity(predicted);
        e.u32(codec::MAGIC);
        e.u16(codec::VERSION);
        e.u16(self.name_table.len() as u16);
        for name in self.name_table.names() {
            e.u16(name.len() as u16);
            e.bytes(name.as_bytes());
        }
        e.u32(self.len() as u32);
        for r in 0..self.len() {
            let w = self.widths[r] as usize;
            e.u16(w as u16);
            for c in 0..w {
                e.value(&self.columns[c][r]);
            }
        }
        let buf = e.finish();
        debug_assert_eq!(buf.len(), predicted, "RowBatch::encoded_size mispredicted");
        buf
    }

    /// Transpose back to row-major. Inverse of [`RowBatch::from_rowset`]
    /// including raggedness (row `r` gets exactly `widths[r]` cells).
    pub fn to_rowset(&self) -> UnversionedRowset {
        let rows = (0..self.len())
            .map(|r| {
                let w = self.widths[r] as usize;
                UnversionedRow::new((0..w).map(|c| self.columns[c][r].clone()).collect())
            })
            .collect();
        UnversionedRowset::new(self.name_table.clone(), rows)
    }

    /// Vectorized routing-hash column: for every row, the
    /// [`partitioning::key_hash`] of the composite key drawn from
    /// `key_cols` (joined exactly like [`partitioning::composite_key`] but
    /// hashed incrementally, so no per-row `String` is built). `None` for
    /// rows where any key column is missing or not a string — callers drop
    /// or default-route those, same as the scalar path.
    pub fn key_hash_column(&self, key_cols: &[usize]) -> Vec<Option<u64>> {
        let mut out = Vec::with_capacity(self.len());
        let mut parts: Vec<&str> = Vec::with_capacity(key_cols.len());
        'rows: for r in 0..self.len() {
            parts.clear();
            for &c in key_cols {
                match self.value(r, c).and_then(Value::as_str) {
                    Some(s) => parts.push(s),
                    None => {
                        out.push(None);
                        continue 'rows;
                    }
                }
            }
            out.push(Some(partitioning::composite_key_hash(&parts)));
        }
        out
    }

    /// The same vectorized hash pass over a row-major rowset, for callers
    /// (e.g. routing mappers) that only need the hash column and would
    /// waste the full columnar transpose. Identical output to
    /// `RowBatch::from_rowset(rs).key_hash_column(key_cols)`.
    pub fn key_hash_column_of(rs: &UnversionedRowset, key_cols: &[usize]) -> Vec<Option<u64>> {
        let mut out = Vec::with_capacity(rs.len());
        let mut parts: Vec<&str> = Vec::with_capacity(key_cols.len());
        'rows: for row in rs.rows() {
            parts.clear();
            for &c in key_cols {
                match row.get(c).and_then(Value::as_str) {
                    Some(s) => parts.push(s),
                    None => {
                        out.push(None);
                        continue 'rows;
                    }
                }
            }
            out.push(Some(partitioning::composite_key_hash(&parts)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::row;
    use crate::rows::rowset::RowsetBuilder;

    fn sample() -> UnversionedRowset {
        let nt = NameTable::new(&["user", "cluster", "ts", "score"]);
        let mut b = RowsetBuilder::new(nt);
        b.push(row!["alice", "hahn", 12i64, 1.5]);
        b.push(row!["bob", "freud", -3i64, 0.0]);
        b.push(UnversionedRow::new(vec![
            Value::Null,
            Value::Str("hahn".into()),
            Value::Uint64(7),
            Value::Bool(true),
        ]));
        b.build()
    }

    #[test]
    fn roundtrips_match_per_row_codec() {
        let rs = sample();
        let batch = RowBatch::from_rowset(&rs);
        assert_eq!(batch.len(), rs.len());
        assert_eq!(batch.encode(), codec::encode_rowset(&rs), "byte-identical encode");
        assert_eq!(batch.encoded_size(), codec::encoded_size_rowset(&rs));

        let shared: Arc<[u8]> = codec::encode_rowset(&rs).into();
        let decoded = RowBatch::decode_shared(&shared).unwrap();
        assert_eq!(decoded.to_rowset(), rs);
        assert_eq!(decoded, batch);
    }

    #[test]
    fn ragged_rows_survive_exactly() {
        // The wire format permits rows of differing widths; the columnar
        // form must neither drop cells nor leak its Null padding.
        let nt = NameTable::new(&["a", "b", "c"]);
        let rs = UnversionedRowset::new(
            nt,
            vec![row![1i64], row![2i64, "x", 3i64], UnversionedRow::new(vec![])],
        );
        let bytes = codec::encode_rowset(&rs);
        let batch = RowBatch::from_rowset(&rs);
        assert_eq!(batch.encode(), bytes);
        let shared: Arc<[u8]> = bytes.into();
        let decoded = RowBatch::decode_shared(&shared).unwrap();
        assert_eq!(decoded.to_rowset(), rs);
        assert_eq!(decoded.value(0, 1), None, "padding is not a cell");
        assert_eq!(decoded.value(1, 1).and_then(Value::as_str), Some("x"));
    }

    #[test]
    fn rejects_what_the_codec_rejects() {
        let rs = sample();
        let bytes = codec::encode_rowset(&rs);
        let mut garbage = bytes.clone();
        garbage.push(0);
        let shared: Arc<[u8]> = garbage.into();
        assert!(matches!(
            RowBatch::decode_shared(&shared),
            Err(CodecError::Truncated(_))
        ));
        let truncated: Arc<[u8]> = bytes[..bytes.len() - 1].to_vec().into();
        assert!(matches!(
            RowBatch::decode_shared(&truncated),
            Err(CodecError::Truncated(_))
        ));
        let mut bad_magic = bytes;
        bad_magic[0] ^= 0xFF;
        let shared: Arc<[u8]> = bad_magic.into();
        assert!(matches!(
            RowBatch::decode_shared(&shared),
            Err(CodecError::BadMagic(_))
        ));
    }

    #[test]
    fn hash_column_matches_scalar_hashing() {
        let rs = sample();
        let batch = RowBatch::from_rowset(&rs);
        // Composite (user, cluster) — row 2 has a Null user: None.
        let hashes = batch.key_hash_column(&[0, 1]);
        assert_eq!(
            hashes[0],
            Some(partitioning::key_hash(&partitioning::composite_key(&[
                "alice", "hahn"
            ])))
        );
        assert_eq!(
            hashes[1],
            Some(partitioning::key_hash(&partitioning::composite_key(&[
                "bob", "freud"
            ])))
        );
        assert_eq!(hashes[2], None);
        // Single-column key degenerates to the plain key hash.
        let single = batch.key_hash_column(&[1]);
        assert_eq!(single[0], Some(partitioning::key_hash("hahn")));
        // The row-major pass is the same function.
        assert_eq!(RowBatch::key_hash_column_of(&rs, &[0, 1]), hashes);
        assert_eq!(RowBatch::key_hash_column_of(&rs, &[1]), batch.key_hash_column(&[1]));
    }

    #[test]
    fn empty_batch() {
        let rs = UnversionedRowset::empty(NameTable::new(&["a"]));
        let batch = RowBatch::from_rowset(&rs);
        assert!(batch.is_empty());
        assert_eq!(batch.encode(), codec::encode_rowset(&rs));
        assert!(batch.key_hash_column(&[0]).is_empty());
    }
}
