//! Table schemas: typed columns, optional key prefix (for sorted dynamic
//! tables, chapter 3).

use std::sync::Arc;

use super::name_table::NameTable;
use super::row::UnversionedRow;
use super::value::Value;

/// Column value type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ColumnType {
    Bool,
    Int64,
    Uint64,
    Double,
    Str,
    /// Accepts any value (used by pass-through pipelines).
    Any,
}

impl ColumnType {
    pub fn accepts(&self, v: &Value) -> bool {
        matches!(
            (self, v),
            (ColumnType::Any, _)
                | (_, Value::Null)
                | (ColumnType::Bool, Value::Bool(_))
                | (ColumnType::Int64, Value::Int64(_))
                | (ColumnType::Uint64, Value::Uint64(_))
                | (ColumnType::Double, Value::Double(_))
                | (ColumnType::Str, Value::Str(_))
        )
    }

    pub fn name(&self) -> &'static str {
        match self {
            ColumnType::Bool => "boolean",
            ColumnType::Int64 => "int64",
            ColumnType::Uint64 => "uint64",
            ColumnType::Double => "double",
            ColumnType::Str => "string",
            ColumnType::Any => "any",
        }
    }
}

/// One column of a schema.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ColumnSchema {
    pub name: String,
    pub ty: ColumnType,
    /// Key columns form the sorted-table primary key (must be a prefix).
    pub key: bool,
}

impl ColumnSchema {
    pub fn value(name: &str, ty: ColumnType) -> Self {
        ColumnSchema {
            name: name.to_string(),
            ty,
            key: false,
        }
    }

    pub fn key(name: &str, ty: ColumnType) -> Self {
        ColumnSchema {
            name: name.to_string(),
            ty,
            key: true,
        }
    }
}

/// Full table schema. Key columns, if any, must form a prefix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TableSchema {
    columns: Vec<ColumnSchema>,
    key_count: usize,
    name_table: Arc<NameTable>,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum SchemaError {
    #[error("row has {got} values, schema has {want} columns")]
    WidthMismatch { got: usize, want: usize },
    #[error("column '{column}' expects {expected}, got {got:?}")]
    TypeMismatch {
        column: String,
        expected: &'static str,
        got: Value,
    },
    #[error("null in key column '{0}'")]
    NullKey(String),
}

impl TableSchema {
    pub fn new(columns: Vec<ColumnSchema>) -> TableSchema {
        let key_count = columns.iter().take_while(|c| c.key).count();
        assert!(
            columns.iter().skip(key_count).all(|c| !c.key),
            "key columns must form a prefix"
        );
        let name_table =
            NameTable::from_names(columns.iter().map(|c| c.name.clone()).collect());
        TableSchema {
            columns,
            key_count,
            name_table,
        }
    }

    pub fn columns(&self) -> &[ColumnSchema] {
        &self.columns
    }

    pub fn key_count(&self) -> usize {
        self.key_count
    }

    pub fn name_table(&self) -> Arc<NameTable> {
        self.name_table.clone()
    }

    pub fn width(&self) -> usize {
        self.columns.len()
    }

    /// Validate a row against this schema.
    pub fn validate(&self, row: &UnversionedRow) -> Result<(), SchemaError> {
        if row.len() != self.columns.len() {
            return Err(SchemaError::WidthMismatch {
                got: row.len(),
                want: self.columns.len(),
            });
        }
        for (col, v) in self.columns.iter().zip(row.values()) {
            if col.key && v.is_null() {
                return Err(SchemaError::NullKey(col.name.clone()));
            }
            if !col.ty.accepts(v) {
                return Err(SchemaError::TypeMismatch {
                    column: col.name.clone(),
                    expected: col.ty.name(),
                    got: v.clone(),
                });
            }
        }
        Ok(())
    }

    /// Extract the key prefix of a row (for sorted-table addressing).
    pub fn key_of(&self, row: &UnversionedRow) -> Vec<Value> {
        row.values()[..self.key_count].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn schema() -> TableSchema {
        TableSchema::new(vec![
            ColumnSchema::key("user", ColumnType::Str),
            ColumnSchema::key("cluster", ColumnType::Str),
            ColumnSchema::value("count", ColumnType::Int64),
            ColumnSchema::value("last_ts", ColumnType::Int64),
        ])
    }

    #[test]
    fn key_prefix_detected() {
        let s = schema();
        assert_eq!(s.key_count(), 2);
        assert_eq!(s.width(), 4);
    }

    #[test]
    #[should_panic(expected = "prefix")]
    fn non_prefix_keys_rejected() {
        TableSchema::new(vec![
            ColumnSchema::value("a", ColumnType::Int64),
            ColumnSchema::key("b", ColumnType::Int64),
        ]);
    }

    #[test]
    fn validate_accepts_good_row() {
        let s = schema();
        let row = UnversionedRow::new(vec![
            "alice".into(),
            "hahn".into(),
            Value::Int64(3),
            Value::Int64(1234),
        ]);
        assert_eq!(s.validate(&row), Ok(()));
        assert_eq!(s.key_of(&row), vec![Value::from("alice"), Value::from("hahn")]);
    }

    #[test]
    fn validate_rejects_bad_rows() {
        let s = schema();
        let narrow = UnversionedRow::new(vec!["a".into()]);
        assert!(matches!(s.validate(&narrow), Err(SchemaError::WidthMismatch { .. })));

        let wrong_ty = UnversionedRow::new(vec![
            "a".into(),
            "b".into(),
            Value::Double(1.0),
            Value::Int64(0),
        ]);
        assert!(matches!(s.validate(&wrong_ty), Err(SchemaError::TypeMismatch { .. })));

        let null_key = UnversionedRow::new(vec![
            Value::Null,
            "b".into(),
            Value::Int64(0),
            Value::Int64(0),
        ]);
        assert!(matches!(s.validate(&null_key), Err(SchemaError::NullKey(_))));
    }

    #[test]
    fn nullable_value_columns() {
        let s = schema();
        let row = UnversionedRow::new(vec![
            "a".into(),
            "b".into(),
            Value::Null,
            Value::Int64(0),
        ]);
        assert_eq!(s.validate(&row), Ok(()));
    }

    #[test]
    fn any_accepts_everything() {
        for v in [
            Value::Null,
            Value::Bool(true),
            Value::Int64(1),
            Value::Str("x".into()),
        ] {
            assert!(ColumnType::Any.accepts(&v));
        }
    }
}
