//! Rowsets: the batch unit flowing through the whole system.

use std::sync::Arc;

use super::name_table::NameTable;
use super::row::UnversionedRow;
use super::value::Value;

/// A batch of rows sharing one [`NameTable`] (§4.1). This is the unit that
/// mappers read, map, buffer in window entries, ship to reducers and that
/// user `Reduce` implementations receive.
#[derive(Debug, Clone, PartialEq)]
pub struct UnversionedRowset {
    name_table: Arc<NameTable>,
    rows: Vec<UnversionedRow>,
}

impl UnversionedRowset {
    pub fn new(name_table: Arc<NameTable>, rows: Vec<UnversionedRow>) -> Self {
        UnversionedRowset { name_table, rows }
    }

    pub fn empty(name_table: Arc<NameTable>) -> Self {
        UnversionedRowset {
            name_table,
            rows: Vec::new(),
        }
    }

    pub fn name_table(&self) -> &Arc<NameTable> {
        &self.name_table
    }

    pub fn rows(&self) -> &[UnversionedRow] {
        &self.rows
    }

    pub fn into_rows(self) -> Vec<UnversionedRow> {
        self.rows
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Approximate payload footprint of all rows.
    pub fn byte_size(&self) -> usize {
        self.rows.iter().map(UnversionedRow::byte_size).sum()
    }

    /// Cell at (row, column-name); `None` if the column is unknown.
    pub fn cell(&self, row: usize, column: &str) -> Option<&Value> {
        let id = self.name_table.id(column)?;
        self.rows.get(row)?.get(id)
    }

    /// Iterator over one column by name.
    pub fn column<'a>(&'a self, column: &str) -> Option<impl Iterator<Item = &'a Value>> {
        let id = self.name_table.id(column)?;
        Some(self.rows.iter().map(move |r| &r.values()[id]))
    }

    /// Select a subset of rows by index, sharing the name table. Row
    /// clones are cheap: string payloads are refcounted [`super::ByteStr`]
    /// views, never copied.
    pub fn select(&self, indexes: &[usize]) -> UnversionedRowset {
        UnversionedRowset {
            name_table: self.name_table.clone(),
            rows: indexes.iter().map(|&i| self.rows[i].clone()).collect(),
        }
    }

    /// Concatenate rowsets that share an identical name table. Used by the
    /// reducer main procedure (§4.4.2 step 5: "run the user-provided Reduce
    /// function on all of these rows combined into one batch").
    pub fn concat(parts: &[UnversionedRowset]) -> Option<UnversionedRowset> {
        let first = parts.iter().find(|p| !p.is_empty())?;
        let nt = first.name_table.clone();
        let mut rows = Vec::with_capacity(parts.iter().map(|p| p.len()).sum());
        for p in parts {
            if !p.is_empty() {
                assert_eq!(
                    p.name_table.names(),
                    nt.names(),
                    "concat requires identical name tables"
                );
            }
            rows.extend(p.rows.iter().cloned());
        }
        Some(UnversionedRowset {
            name_table: nt,
            rows,
        })
    }

    /// Consuming concat: moves rows out of `parts` instead of cloning.
    /// The reducer hot path uses this right after decoding attachments
    /// (§Perf: saves one full copy of every shuffled value per cycle).
    pub fn concat_owned(parts: Vec<UnversionedRowset>) -> Option<UnversionedRowset> {
        let nt = parts.iter().find(|p| !p.is_empty())?.name_table.clone();
        let mut rows = Vec::with_capacity(parts.iter().map(|p| p.len()).sum());
        for p in parts {
            if !p.is_empty() {
                assert_eq!(
                    p.name_table.names(),
                    nt.names(),
                    "concat requires identical name tables"
                );
                rows.extend(p.rows);
            }
        }
        Some(UnversionedRowset {
            name_table: nt,
            rows,
        })
    }
}

/// Incremental builder.
#[derive(Debug)]
pub struct RowsetBuilder {
    name_table: Arc<NameTable>,
    rows: Vec<UnversionedRow>,
}

impl RowsetBuilder {
    pub fn new(name_table: Arc<NameTable>) -> Self {
        RowsetBuilder {
            name_table,
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: UnversionedRow) -> &mut Self {
        debug_assert_eq!(
            row.len(),
            self.name_table.len(),
            "row width must match name table"
        );
        self.rows.push(row);
        self
    }

    pub fn push_values(&mut self, values: Vec<Value>) -> &mut Self {
        self.push(UnversionedRow::new(values))
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn build(self) -> UnversionedRowset {
        UnversionedRowset {
            name_table: self.name_table,
            rows: self.rows,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::row;

    fn sample() -> UnversionedRowset {
        let nt = NameTable::new(&["user", "count"]);
        let mut b = RowsetBuilder::new(nt);
        b.push(row!["alice", 1i64]);
        b.push(row!["bob", 2i64]);
        b.push(row!["carol", 3i64]);
        b.build()
    }

    #[test]
    fn builder_and_access() {
        let rs = sample();
        assert_eq!(rs.len(), 3);
        assert_eq!(rs.cell(1, "user"), Some(&Value::Str("bob".into())));
        assert_eq!(rs.cell(1, "missing"), None);
        assert_eq!(rs.cell(10, "user"), None);
    }

    #[test]
    fn column_iteration() {
        let rs = sample();
        let counts: Vec<i64> = rs
            .column("count")
            .unwrap()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(counts, vec![1, 2, 3]);
        assert!(rs.column("nope").is_none());
    }

    #[test]
    fn select_subset() {
        let rs = sample();
        let sub = rs.select(&[2, 0]);
        assert_eq!(sub.len(), 2);
        assert_eq!(sub.cell(0, "user"), Some(&Value::Str("carol".into())));
        assert_eq!(sub.cell(1, "user"), Some(&Value::Str("alice".into())));
    }

    #[test]
    fn concat_batches() {
        let a = sample();
        let b = sample();
        let nt = a.name_table().clone();
        let empty = UnversionedRowset::empty(nt);
        let all = UnversionedRowset::concat(&[empty.clone(), a, b]).unwrap();
        assert_eq!(all.len(), 6);
        assert!(UnversionedRowset::concat(&[empty.clone(), empty]).is_none());
    }

    #[test]
    fn byte_size_sums_rows() {
        let rs = sample();
        let total: usize = rs.rows().iter().map(|r| r.byte_size()).sum();
        assert_eq!(rs.byte_size(), total);
        assert!(total > 0);
    }
}
