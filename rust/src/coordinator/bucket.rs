//! Per-reducer bucket state inside a mapper (§4.3.1).
//!
//! "An array of BucketState objects, one for every reducer, which hold a
//! queue of shuffle row indexes that will need to be shipped to said
//! reducer, along with the window entry index in which the first of these
//! rows is to be found."

use std::collections::VecDeque;

/// One queued row reference: its shuffle index and the window entry that
//  holds it (recorded at push time so acknowledgement processing never
//  searches).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BucketRow {
    pub shuffle_index: i64,
    pub entry_index: u64,
}

/// The queue of rows destined for one reducer.
#[derive(Debug, Default)]
pub struct BucketState {
    queue: VecDeque<BucketRow>,
    /// Shuffle index of the last row ever enqueued (monotonicity guard).
    last_enqueued: Option<i64>,
}

/// What acknowledging rows did to the bucket head — the caller must apply
/// these to the window's bucket-pointer counts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AckOutcome {
    pub rows_popped: usize,
    /// Entry that held the head before the ack (decrement its count)…
    pub old_head_entry: Option<u64>,
    /// …and the entry holding the head now (increment its count). Equal
    /// values mean no pointer movement.
    pub new_head_entry: Option<u64>,
}

impl BucketState {
    pub fn new() -> BucketState {
        BucketState::default()
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Window entry holding the bucket's first queued row.
    pub fn first_entry_index(&self) -> Option<u64> {
        self.queue.front().map(|r| r.entry_index)
    }

    /// Enqueue a produced row. Returns `true` if this row became the new
    /// head (i.e. the bucket was empty — the caller increments the entry's
    /// pointer count, §4.3.3 step 6).
    pub fn push(&mut self, row: BucketRow) -> bool {
        if let Some(last) = self.last_enqueued {
            assert!(
                row.shuffle_index > last,
                "bucket rows must be enqueued in shuffle order ({} after {last})",
                row.shuffle_index
            );
        }
        self.last_enqueued = Some(row.shuffle_index);
        let was_empty = self.queue.is_empty();
        self.queue.push_back(row);
        was_empty
    }

    /// Acknowledge rows with `shuffle_index <= committed_row_index`
    /// (§4.3.4 step 2). Returns the pointer-count adjustments.
    pub fn ack(&mut self, committed_row_index: i64) -> AckOutcome {
        let old_head_entry = self.first_entry_index();
        let mut rows_popped = 0;
        while self
            .queue
            .front()
            .is_some_and(|r| r.shuffle_index <= committed_row_index)
        {
            self.queue.pop_front();
            rows_popped += 1;
        }
        AckOutcome {
            rows_popped,
            old_head_entry,
            new_head_entry: self.first_entry_index(),
        }
    }

    /// The first `count` unacknowledged rows (NOT removed — §4.3.4 step 4:
    /// "these rows are not deleted from the queue").
    pub fn peek(&self, count: usize) -> impl Iterator<Item = &BucketRow> {
        self.queue.iter().take(count)
    }

    /// Drop everything (split-brain reset).
    pub fn clear(&mut self) {
        self.queue.clear();
        self.last_enqueued = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(s: i64, e: u64) -> BucketRow {
        BucketRow {
            shuffle_index: s,
            entry_index: e,
        }
    }

    #[test]
    fn push_reports_head_transitions() {
        let mut b = BucketState::new();
        assert!(b.push(row(3, 0)), "first push becomes head");
        assert!(!b.push(row(7, 0)));
        assert!(!b.push(row(9, 1)));
        assert_eq!(b.len(), 3);
        assert_eq!(b.first_entry_index(), Some(0));
    }

    #[test]
    #[should_panic(expected = "shuffle order")]
    fn out_of_order_push_panics() {
        let mut b = BucketState::new();
        b.push(row(5, 0));
        b.push(row(4, 0));
    }

    #[test]
    fn ack_pops_prefix_and_reports_movement() {
        let mut b = BucketState::new();
        b.push(row(3, 0));
        b.push(row(7, 0));
        b.push(row(9, 1));
        b.push(row(12, 2));

        // Ack nothing (committed below head).
        let a = b.ack(2);
        assert_eq!(a.rows_popped, 0);
        assert_eq!(a.old_head_entry, Some(0));
        assert_eq!(a.new_head_entry, Some(0));

        // Ack through shuffle index 9: head moves to entry 2.
        let a = b.ack(9);
        assert_eq!(a.rows_popped, 3);
        assert_eq!(a.old_head_entry, Some(0));
        assert_eq!(a.new_head_entry, Some(2));
        assert_eq!(b.len(), 1);

        // Ack everything: bucket empties.
        let a = b.ack(100);
        assert_eq!(a.rows_popped, 1);
        assert_eq!(a.old_head_entry, Some(2));
        assert_eq!(a.new_head_entry, None);
        assert!(b.is_empty());

        // Ack on empty bucket is a no-op.
        let a = b.ack(100);
        assert_eq!(a.rows_popped, 0);
        assert_eq!(a.old_head_entry, None);
    }

    #[test]
    fn peek_does_not_consume() {
        let mut b = BucketState::new();
        for i in 0..5 {
            b.push(row(i, 0));
        }
        let seen: Vec<i64> = b.peek(3).map(|r| r.shuffle_index).collect();
        assert_eq!(seen, vec![0, 1, 2]);
        assert_eq!(b.len(), 5, "peek must not remove rows");
        let again: Vec<i64> = b.peek(10).map(|r| r.shuffle_index).collect();
        assert_eq!(again.len(), 5);
    }

    #[test]
    fn clear_resets_order_guard() {
        let mut b = BucketState::new();
        b.push(row(100, 0));
        b.clear();
        assert!(b.is_empty());
        // After a reset, lower shuffle indexes are legal again (fresh life).
        b.push(row(1, 0));
    }
}
