//! The streaming processor (chapter 4) — the paper's system contribution.
//!
//! "A single streaming task, which we call a *streaming processor*,
//! consists of endlessly running mapper and reducer jobs. Mappers read
//! their corresponding partitions and keep a rolling window of mapped rows
//! in memory. … Reducers, in turn, pull the corresponding rows from the
//! mappers and process these rows using the specified reduce function. …
//! The system will then commit the required internal meta-state changes in
//! the same transaction, guaranteeing that the effect of processing a
//! batch of rows is applied exactly once."
//!
//! | module | paper section |
//! |---|---|
//! | [`config`] | §4.5 configuration |
//! | [`state`] | §4.3.2 / §4.4.1 persistent state |
//! | [`window`] | §4.3.1 window entries, §4.3.5 trimming |
//! | [`bucket`] | §4.3.1 bucket states |
//! | [`mapper`] | §4.3 mapper workflow + §4.3.4 GetRows |
//! | [`reducer`] | §4.4 reducer workflow |
//! | [`processor`] | §4.5 assembly, discovery and control |

pub mod bucket;
pub mod config;
pub mod mapper;
pub mod processor;
pub mod reducer;
pub mod state;
pub mod window;

pub use crate::coldtier::ColdTierConfig;
pub use config::{ComputeMode, EventTimeConfig, ProcessorConfig, SpillConfig};
pub use processor::{ClusterEnv, InputSpec, StreamingProcessor};
pub use state::{MapperState, ReducerState};
