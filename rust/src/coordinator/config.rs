//! Streaming-processor configuration (§4.5).
//!
//! "The system is configured using YT's own JSON-like format, called
//! YSON." — [`ProcessorConfig::from_yson`] parses the same shape the
//! examples ship as `.yson` text; every field has a sane default so tests
//! can build configs programmatically.

use crate::coldtier::ColdTierConfig;
use crate::consistency::Consistency;
use crate::util::yson::{Yson, YsonError};

/// Which implementation computes the mapper/reducer numeric stages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeMode {
    /// Pure-rust reference path (always available; used by tests).
    Native,
    /// AOT-compiled HLO executed through PJRT (`runtime`); falls back to
    /// an error at startup if artifacts are missing.
    Hlo,
}

/// Straggler-spill thresholds (§6 future-work feature, implemented).
#[derive(Debug, Clone, PartialEq)]
pub struct SpillConfig {
    pub enabled: bool,
    /// Spill triggers when the window exceeds this fraction of the memory
    /// limit.
    pub trigger_fraction: f64,
    /// A bucket is spilled only if the *other* reducers have all acked
    /// past this fraction of the spilled range (i.e. one straggler is
    /// holding everyone back).
    pub straggler_quorum: f64,
}

impl Default for SpillConfig {
    fn default() -> Self {
        SpillConfig {
            enabled: false,
            trigger_fraction: 0.8,
            straggler_quorum: 0.75,
        }
    }
}

/// Event-time tracking knobs (the [`crate::eventtime`] subsystem). When
/// present, mappers track a low-water event time over their routed rows
/// and persist it as the `watermark_ms` column of their meta-state row;
/// windowed reducers consult the fleet minimum to final-fire windows.
#[derive(Debug, Clone, PartialEq)]
pub struct EventTimeConfig {
    /// Column of the *mapped* (shuffled) rows carrying the event time in
    /// ms. Rows without it are transparent to the watermark.
    pub column: String,
}

/// All tunables of one streaming processor.
#[derive(Debug, Clone, PartialEq)]
pub struct ProcessorConfig {
    pub name: String,
    pub mapper_count: usize,
    pub reducer_count: usize,

    /// Rows per partition-reader read (§4.3.3 step 2 batch size hint).
    pub read_batch_rows: usize,
    /// Back-off (§4.3.3 step 1 / §4.4.2 step 1), simulated ms.
    pub backoff_ms: u64,
    /// Split-brain wait before dropping internal state (§4.3.3 step 3).
    pub split_brain_delay_ms: u64,
    /// Mapper in-memory window budget, bytes (§4.3.3 step 8; the paper's
    /// production run used 8 GB — scaled down here).
    pub memory_limit_bytes: usize,
    /// Period of `TrimInputRows` (§4.3.5: "usually on the order of a few
    /// seconds"), simulated ms.
    pub trim_period_ms: u64,
    /// Rows a reducer requests per mapper per cycle (§4.3.4 `count`).
    pub fetch_count: usize,
    /// Group-commit coalescing: maximum fetch rounds a serial reducer
    /// merges into **one** exactly-once commit while the stream is backed
    /// up (a round is coalesced only when the previous one filled its
    /// `fetch_count` budget for some mapper, i.e. backlog is the
    /// bottleneck, not arrival rate). `1` disables coalescing. Amortizes
    /// the meta-state CAS + plan-fence validation and the `ReducerMeta`
    /// journal record over several fetched batches; delivery semantics are
    /// unchanged — a coalesced commit is simply a larger atomic commit.
    pub commit_coalesce_max: usize,

    /// Sorted-table paths for persistent state.
    pub mapper_state_table: String,
    /// Base path of the reducer state tables; reshard epochs derive their
    /// own tables from it (see [`crate::reshard::plan::reducer_state_table`]).
    pub reducer_state_table: String,
    /// The reshard plan table (one row: the live partition-map state
    /// machine every worker polls and CAS-validates against).
    pub reshard_plan_table: String,
    /// Cypress directory for discovery groups.
    pub discovery_dir: String,
    /// Discovery session TTL / heartbeat period, simulated ms.
    pub session_ttl_ms: u64,
    pub heartbeat_period_ms: u64,
    /// Controller restart delay after a worker death, simulated ms.
    pub restart_delay_ms: u64,

    pub spill: SpillConfig,
    pub compute: ComputeMode,
    /// Directory with AOT artifacts (`ComputeMode::Hlo`).
    pub artifacts_dir: String,
    /// §6 pipelined reducer: overlap fetch(n+1) with process/commit(n).
    pub pipelined_reducer: bool,
    /// §6 relaxed delivery: "not all tasks demand strict exactly-once
    /// guarantees". When set, reducers skip the in-transaction state CAS;
    /// the state update becomes a blind element-wise max — rows can be
    /// processed more than once under races, but never lost.
    pub at_least_once: bool,
    /// Per-stage fault-tolerance tier ([`crate::consistency`]): exactly-once
    /// (default, the seed behavior), bounded-error anchoring, or
    /// at-most-once. Approximate tiers skip reducer/window state persists
    /// and trade bounded output drift for lower state-write WA.
    pub consistency: Consistency,
    /// Acknowledges that an *upstream* stage of this exactly-once stage
    /// runs an approximate tier (its handoff can drift). Topology
    /// validation refuses the wiring without this explicit flag.
    pub tolerates_upstream_drift: bool,
    /// Write-accounting scope this processor's persisted bytes are
    /// attributed to (set by [`crate::dataflow`] topologies so the WA
    /// report can be broken down per stage). `None` = global-only.
    pub scope_label: Option<String>,
    /// Event-time tracking (`None` = disabled; the `watermark_ms` meta
    /// column stays at [`crate::eventtime::NO_WATERMARK`]).
    pub event_time: Option<EventTimeConfig>,
    /// Mapper state table of the *upstream* dataflow stage, when this
    /// processor consumes an event-timed handoff: the local watermark is
    /// capped by the upstream fleet watermark, so rows still buffered
    /// upstream (and their future emissions into the handoff) can never be
    /// overtaken. Wired by [`crate::dataflow::Topology::launch`]; `None`
    /// for source stages.
    pub upstream_watermark_table: Option<String>,
    /// Cold tier ([`crate::coldtier`]; `None` = disabled). When set,
    /// mapper trims and windowed fired-history GC compact the bytes they
    /// delete into immutable cold chunks under `cold_tier.base`, inside
    /// the same exactly-once transaction — accounted as
    /// [`crate::storage::WriteCategory::ColdTier`]. Requires an input
    /// whose reader can re-read by absolute row index (ordered tables).
    pub cold_tier: Option<ColdTierConfig>,
}

impl Default for ProcessorConfig {
    fn default() -> Self {
        ProcessorConfig {
            name: "streaming-processor".into(),
            mapper_count: 4,
            reducer_count: 2,
            read_batch_rows: 256,
            backoff_ms: 20,
            split_brain_delay_ms: 200,
            memory_limit_bytes: 64 << 20,
            trim_period_ms: 500,
            fetch_count: 1024,
            commit_coalesce_max: 4,
            mapper_state_table: "//sys/processor/mapper_state".into(),
            reducer_state_table: "//sys/processor/reducer_state".into(),
            reshard_plan_table: "//sys/processor/reshard_plan".into(),
            discovery_dir: "//sys/processor/discovery".into(),
            session_ttl_ms: 3_000,
            heartbeat_period_ms: 500,
            restart_delay_ms: 300,
            spill: SpillConfig::default(),
            compute: ComputeMode::Native,
            artifacts_dir: "artifacts".into(),
            pipelined_reducer: false,
            at_least_once: false,
            consistency: Consistency::ExactlyOnce,
            tolerates_upstream_drift: false,
            scope_label: None,
            event_time: None,
            upstream_watermark_table: None,
            cold_tier: None,
        }
    }
}

impl ProcessorConfig {
    /// Parse from a YSON map; missing keys keep their defaults.
    pub fn from_yson(y: &Yson) -> Result<ProcessorConfig, YsonError> {
        y.as_map()?; // the config must be a YSON map
        let d = ProcessorConfig::default();
        let spill_default = SpillConfig::default();
        let spill = match y.get_opt("spill") {
            Some(sy) => SpillConfig {
                enabled: sy.get_bool_or("enabled", spill_default.enabled),
                trigger_fraction: sy.get_f64_or("trigger_fraction", spill_default.trigger_fraction),
                straggler_quorum: sy.get_f64_or("straggler_quorum", spill_default.straggler_quorum),
            },
            None => spill_default,
        };
        let compute = match y.get_str_or("compute", "native") {
            "hlo" => ComputeMode::Hlo,
            _ => ComputeMode::Native,
        };
        Ok(ProcessorConfig {
            name: y.get_str_or("name", &d.name).to_string(),
            mapper_count: y.get_u64_or("mapper_count", d.mapper_count as u64) as usize,
            reducer_count: y.get_u64_or("reducer_count", d.reducer_count as u64) as usize,
            read_batch_rows: y.get_u64_or("read_batch_rows", d.read_batch_rows as u64) as usize,
            backoff_ms: y.get_u64_or("backoff_ms", d.backoff_ms),
            split_brain_delay_ms: y.get_u64_or("split_brain_delay_ms", d.split_brain_delay_ms),
            memory_limit_bytes: y.get_u64_or("memory_limit_bytes", d.memory_limit_bytes as u64)
                as usize,
            trim_period_ms: y.get_u64_or("trim_period_ms", d.trim_period_ms),
            fetch_count: y.get_u64_or("fetch_count", d.fetch_count as u64) as usize,
            commit_coalesce_max: (y
                .get_u64_or("commit_coalesce_max", d.commit_coalesce_max as u64)
                as usize)
                .max(1),
            mapper_state_table: y
                .get_str_or("mapper_state_table", &d.mapper_state_table)
                .to_string(),
            reducer_state_table: y
                .get_str_or("reducer_state_table", &d.reducer_state_table)
                .to_string(),
            reshard_plan_table: y
                .get_str_or("reshard_plan_table", &d.reshard_plan_table)
                .to_string(),
            discovery_dir: y.get_str_or("discovery_dir", &d.discovery_dir).to_string(),
            session_ttl_ms: y.get_u64_or("session_ttl_ms", d.session_ttl_ms),
            heartbeat_period_ms: y.get_u64_or("heartbeat_period_ms", d.heartbeat_period_ms),
            restart_delay_ms: y.get_u64_or("restart_delay_ms", d.restart_delay_ms),
            spill,
            compute,
            artifacts_dir: y.get_str_or("artifacts_dir", &d.artifacts_dir).to_string(),
            pipelined_reducer: y.get_bool_or("pipelined_reducer", d.pipelined_reducer),
            at_least_once: y.get_bool_or("at_least_once", d.at_least_once),
            consistency: match y.get_opt("consistency") {
                Some(cy) => Consistency::from_yson(cy),
                None => d.consistency,
            },
            tolerates_upstream_drift: y
                .get_bool_or("tolerates_upstream_drift", d.tolerates_upstream_drift),
            scope_label: y
                .get_opt("scope_label")
                .and_then(|v| v.as_str().ok())
                .map(str::to_string),
            event_time: y.get_opt("event_time").map(|ey| EventTimeConfig {
                column: ey.get_str_or("column", "ts").to_string(),
            }),
            upstream_watermark_table: y
                .get_opt("upstream_watermark_table")
                .and_then(|v| v.as_str().ok())
                .map(str::to_string),
            cold_tier: y.get_opt("cold_tier").map(|cy| ColdTierConfig {
                base: cy
                    .get_str_or("base", &ColdTierConfig::default().base)
                    .to_string(),
            }),
        })
    }

    /// Parse from YSON text.
    pub fn parse(text: &str) -> Result<ProcessorConfig, YsonError> {
        Self::from_yson(&Yson::parse(text)?)
    }

    /// Mapper discovery group directory.
    pub fn mapper_group(&self) -> String {
        format!("{}/mappers", self.discovery_dir)
    }

    /// Reducer discovery group directory.
    pub fn reducer_group(&self) -> String {
        format!("{}/reducers", self.discovery_dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = ProcessorConfig::default();
        assert!(c.mapper_count > 0 && c.reducer_count > 0);
        assert!(c.memory_limit_bytes > 1 << 20);
        assert_eq!(c.compute, ComputeMode::Native);
        assert!(!c.spill.enabled);
    }

    #[test]
    fn parse_overrides_subset() {
        let c = ProcessorConfig::parse(
            r#"{
                name = my_proc;
                mapper_count = 8;
                reducer_count = 3;
                memory_limit_bytes = 1048576;
                compute = hlo;
                spill = {enabled = %true; trigger_fraction = 0.5};
            }"#,
        )
        .unwrap();
        assert_eq!(c.name, "my_proc");
        assert_eq!(c.mapper_count, 8);
        assert_eq!(c.reducer_count, 3);
        assert_eq!(c.memory_limit_bytes, 1 << 20);
        assert_eq!(c.compute, ComputeMode::Hlo);
        assert!(c.spill.enabled);
        assert!((c.spill.trigger_fraction - 0.5).abs() < 1e-12);
        // Untouched keys keep defaults.
        assert_eq!(c.backoff_ms, ProcessorConfig::default().backoff_ms);
        assert!((c.spill.straggler_quorum - 0.75).abs() < 1e-12);
    }

    #[test]
    fn parse_commit_coalesce_floors_at_one() {
        let c = ProcessorConfig::parse("{commit_coalesce_max = 0}").unwrap();
        assert_eq!(c.commit_coalesce_max, 1, "0 would stall the main loop");
        let d = ProcessorConfig::parse("{commit_coalesce_max = 8}").unwrap();
        assert_eq!(d.commit_coalesce_max, 8);
        assert!(ProcessorConfig::default().commit_coalesce_max >= 1);
    }

    #[test]
    fn parse_event_time_section() {
        let c = ProcessorConfig::parse("{event_time = {column = first_ts_ms}}").unwrap();
        assert_eq!(
            c.event_time,
            Some(EventTimeConfig {
                column: "first_ts_ms".into()
            })
        );
        assert_eq!(c.upstream_watermark_table, None);
        let d = ProcessorConfig::parse("{}").unwrap();
        assert_eq!(d.event_time, None, "disabled by default");
    }

    #[test]
    fn parse_consistency_section() {
        let c = ProcessorConfig::parse(
            "{consistency = {mode = bounded_error; divergence_budget = 96; anchor_every_batches = 8}}",
        )
        .unwrap();
        assert_eq!(
            c.consistency,
            Consistency::BoundedError {
                divergence_budget: 96,
                anchor_every_batches: 8
            }
        );
        assert!(!c.tolerates_upstream_drift);
        let d = ProcessorConfig::parse(
            "{consistency = {mode = at_most_once}; tolerates_upstream_drift = %true}",
        )
        .unwrap();
        assert_eq!(d.consistency, Consistency::AtMostOnce);
        assert!(d.tolerates_upstream_drift);
        let e = ProcessorConfig::parse("{}").unwrap();
        assert_eq!(e.consistency, Consistency::ExactlyOnce, "default tier");
    }

    #[test]
    fn parse_cold_tier_section() {
        let c = ProcessorConfig::parse("{cold_tier = {base = \"//sys/cold/app\"}}").unwrap();
        assert_eq!(
            c.cold_tier,
            Some(ColdTierConfig {
                base: "//sys/cold/app".into()
            })
        );
        let d = ProcessorConfig::parse("{cold_tier = {}}").unwrap();
        assert_eq!(d.cold_tier, Some(ColdTierConfig::default()));
        let e = ProcessorConfig::parse("{}").unwrap();
        assert_eq!(e.cold_tier, None, "disabled by default");
    }

    #[test]
    fn parse_rejects_non_map() {
        assert!(ProcessorConfig::parse("[1;2]").is_err());
    }

    #[test]
    fn group_paths() {
        let c = ProcessorConfig::default();
        assert!(c.mapper_group().ends_with("/mappers"));
        assert!(c.reducer_group().ends_with("/reducers"));
    }
}
