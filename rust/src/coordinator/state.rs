//! Persistent worker state rows (§4.3.2, §4.4.1).
//!
//! Mapper state table columns: `mapper_index` (key),
//! `input_unread_row_index`, `shuffle_unread_row_index`,
//! `continuation_token` — "the index … of the first row that was not yet
//! successfully processed and committed by its corresponding reducer" —
//! plus the elastic-resharding columns `epoch`, `cutover_index` and
//! `prev_cutover_index`: the partition-map epoch this mapper routes for
//! and the shuffle-index boundaries of the current epoch transition
//! (rows in `[prev_cutover, cutover)` belong to the previous epoch's
//! partition map, rows `>= cutover` to the current one) and `retired`
//! (this mapper slot was drained and decommissioned; reducers exclude it
//! from their drain gate), plus the event-time column `watermark_ms`:
//! this mapper's low-water event time — every row it routed with event
//! time strictly below the watermark has been committed by its reducer
//! (see [`crate::eventtime`]). Monotone per mapper; the fleet watermark
//! is the min over live (non-retired) mappers. The columns are
//! CAS-updated like everything else, so split-brain twins always agree on
//! where the partition map changed.
//!
//! Reducer state table columns: `reducer_index` (key),
//! `committed_row_indices` — "a list of shuffle row indices, one for each
//! mapper, indicating that all rows up to said index were reliably
//! processed by the reducer" — plus `retired` (this reducer drained its
//! buckets and handed off its residual state; set exactly once by the
//! retirement transaction) and `bootstrapped` (a new-epoch reducer has
//! imported its migration-handoff tablet and may serve its key range).
//! The list is serialized as a YSON list. Reducer state tables are
//! per-epoch (see [`crate::reshard::plan::reducer_state_table`]), so the
//! row key stays the plain reducer index.

use crate::queue::ContinuationToken;
use crate::rows::{ColumnSchema, ColumnType, TableSchema, UnversionedRow, Value};
use crate::util::yson::Yson;

/// A mapper's persistent state (one row of the mapper state table).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MapperState {
    pub input_unread_row_index: i64,
    pub shuffle_unread_row_index: i64,
    pub continuation_token: ContinuationToken,
    /// Partition-map epoch this mapper currently routes new rows for.
    pub epoch: i64,
    /// Shuffle index where `epoch`'s partition map took over. Rows below
    /// it belong to earlier epochs (already-retired partition maps when
    /// the plan is stable).
    pub cutover_index: i64,
    /// Cutover of the *previous* epoch transition: rows below it were
    /// fully committed before the previous reshard finalized and are
    /// never re-routed.
    pub prev_cutover_index: i64,
    /// This mapper slot was retired (its partition drained for good —
    /// e.g. a downstream fleet shrank after an upstream reshard). Set by
    /// a CAS write in [`crate::coordinator::StreamingProcessor::retire_mapper`];
    /// reducers gate their drain check on the *live* (non-retired) set,
    /// so a dead index can never block a later reshard. Cleared (CAS)
    /// before the slot is revived.
    pub retired: bool,
    /// Event-time low water of this mapper: every row it routed with event
    /// time `< watermark_ms` has been committed by its reducer. Monotone
    /// (the mapper clamps before persisting); stays
    /// [`crate::eventtime::NO_WATERMARK`] when event time is disabled or
    /// nothing was ingested yet.
    pub watermark_ms: i64,
}

impl MapperState {
    pub fn initial() -> MapperState {
        MapperState {
            input_unread_row_index: 0,
            shuffle_unread_row_index: 0,
            continuation_token: ContinuationToken::initial(),
            epoch: 0,
            cutover_index: 0,
            prev_cutover_index: 0,
            retired: false,
            watermark_ms: crate::eventtime::NO_WATERMARK,
        }
    }

    pub fn schema() -> TableSchema {
        TableSchema::new(vec![
            ColumnSchema::key("mapper_index", ColumnType::Int64),
            ColumnSchema::value("input_unread_row_index", ColumnType::Int64),
            ColumnSchema::value("shuffle_unread_row_index", ColumnType::Int64),
            ColumnSchema::value("continuation_token", ColumnType::Str),
            ColumnSchema::value("epoch", ColumnType::Int64),
            ColumnSchema::value("cutover_index", ColumnType::Int64),
            ColumnSchema::value("prev_cutover_index", ColumnType::Int64),
            ColumnSchema::value("retired", ColumnType::Int64),
            ColumnSchema::value("watermark_ms", ColumnType::Int64),
        ])
    }

    pub fn to_row(&self, mapper_index: usize) -> UnversionedRow {
        UnversionedRow::new(vec![
            Value::Int64(mapper_index as i64),
            Value::Int64(self.input_unread_row_index),
            Value::Int64(self.shuffle_unread_row_index),
            Value::from(self.continuation_token.0.as_str()),
            Value::Int64(self.epoch),
            Value::Int64(self.cutover_index),
            Value::Int64(self.prev_cutover_index),
            Value::Int64(self.retired as i64),
            Value::Int64(self.watermark_ms),
        ])
    }

    pub fn from_row(row: &UnversionedRow) -> Option<MapperState> {
        Some(MapperState {
            input_unread_row_index: row.get(1)?.as_i64()?,
            shuffle_unread_row_index: row.get(2)?.as_i64()?,
            continuation_token: ContinuationToken(row.get(3)?.as_str()?.to_string()),
            epoch: row.get(4)?.as_i64()?,
            cutover_index: row.get(5)?.as_i64()?,
            prev_cutover_index: row.get(6)?.as_i64()?,
            retired: row.get(7)?.as_i64()? != 0,
            watermark_ms: row.get(8)?.as_i64()?,
        })
    }

    pub fn key(mapper_index: usize) -> Vec<Value> {
        vec![Value::Int64(mapper_index as i64)]
    }

    /// The state after adopting a new partition-map epoch at the given
    /// shuffle boundary: positions are untouched (the adoption transaction
    /// must not lose trim progress), the epoch window shifts.
    pub fn adopted(&self, new_epoch: i64, cutover_index: i64) -> MapperState {
        MapperState {
            epoch: new_epoch,
            prev_cutover_index: self.cutover_index,
            cutover_index,
            ..self.clone()
        }
    }
}

/// A reducer's persistent state (one row of its epoch's state table).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReducerState {
    /// `committed_row_indices[m]` = shuffle index of the last row from
    /// mapper `m` this reducer has committed; -1 = none yet.
    pub committed_row_indices: Vec<i64>,
    /// Set by the retirement transaction when this reducer's epoch was
    /// resharded away: buckets drained, residual state exported. A retired
    /// row is terminal — instances observing it exit.
    pub retired: bool,
    /// A new-epoch reducer has consumed its migration-handoff tablet and
    /// may serve its key range. Epoch-0 reducers are born bootstrapped.
    pub bootstrapped: bool,
}

impl ReducerState {
    pub fn initial(num_mappers: usize) -> ReducerState {
        ReducerState {
            committed_row_indices: vec![-1; num_mappers],
            retired: false,
            bootstrapped: true,
        }
    }

    /// Initial state for a reducer born by a reshard: it must import its
    /// migration-handoff tablet before serving.
    pub fn initial_migrating(num_mappers: usize) -> ReducerState {
        ReducerState {
            bootstrapped: false,
            ..ReducerState::initial(num_mappers)
        }
    }

    pub fn schema() -> TableSchema {
        TableSchema::new(vec![
            ColumnSchema::key("reducer_index", ColumnType::Int64),
            ColumnSchema::value("committed_row_indices", ColumnType::Str),
            ColumnSchema::value("retired", ColumnType::Int64),
            ColumnSchema::value("bootstrapped", ColumnType::Int64),
        ])
    }

    pub fn to_row(&self, reducer_index: usize) -> UnversionedRow {
        let list = Yson::List(
            self.committed_row_indices
                .iter()
                .map(|v| Yson::Int(*v))
                .collect(),
        );
        UnversionedRow::new(vec![
            Value::Int64(reducer_index as i64),
            Value::from(list.to_string()),
            Value::Int64(self.retired as i64),
            Value::Int64(self.bootstrapped as i64),
        ])
    }

    pub fn from_row(row: &UnversionedRow) -> Option<ReducerState> {
        let text = row.get(1)?.as_str()?;
        let y = Yson::parse(text).ok()?;
        let committed = y
            .as_list()
            .ok()?
            .iter()
            .map(|v| v.as_i64().ok())
            .collect::<Option<Vec<i64>>>()?;
        Some(ReducerState {
            committed_row_indices: committed,
            retired: row.get(2)?.as_i64()? != 0,
            bootstrapped: row.get(3)?.as_i64()? != 0,
        })
    }

    pub fn key(reducer_index: usize) -> Vec<Value> {
        vec![Value::Int64(reducer_index as i64)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapper_state_roundtrip() {
        let s = MapperState {
            input_unread_row_index: 42,
            shuffle_unread_row_index: 99,
            continuation_token: ContinuationToken("lb:123".into()),
            epoch: 2,
            cutover_index: 80,
            prev_cutover_index: 30,
            retired: true,
            watermark_ms: 12_345,
        };
        let row = s.to_row(3);
        MapperState::schema().validate(&row).unwrap();
        assert_eq!(MapperState::from_row(&row), Some(s));
        assert_eq!(row.get(0), Some(&Value::Int64(3)));
    }

    #[test]
    fn mapper_initial_state() {
        let s = MapperState::initial();
        assert_eq!(s.input_unread_row_index, 0);
        assert!(s.continuation_token.is_initial());
        assert_eq!(s.epoch, 0);
        assert_eq!(s.cutover_index, 0);
        assert_eq!(s.prev_cutover_index, 0);
        assert!(!s.retired, "mappers are born live");
        assert_eq!(
            s.watermark_ms,
            crate::eventtime::NO_WATERMARK,
            "no event time observed yet"
        );
    }

    #[test]
    fn mapper_adoption_shifts_epoch_window() {
        let mut s = MapperState::initial();
        s.input_unread_row_index = 10;
        s.shuffle_unread_row_index = 25;
        let a = s.adopted(1, 40);
        assert_eq!(a.epoch, 1);
        assert_eq!(a.cutover_index, 40);
        assert_eq!(a.prev_cutover_index, 0);
        assert_eq!(a.input_unread_row_index, 10, "trim progress untouched");
        let b = a.adopted(2, 90);
        assert_eq!(b.prev_cutover_index, 40, "old cutover becomes the floor");
        assert_eq!(b.cutover_index, 90);
    }

    #[test]
    fn reducer_state_roundtrip() {
        let s = ReducerState {
            committed_row_indices: vec![-1, 0, 12345, 7],
            retired: true,
            bootstrapped: false,
        };
        let row = s.to_row(1);
        ReducerState::schema().validate(&row).unwrap();
        assert_eq!(ReducerState::from_row(&row), Some(s));
    }

    #[test]
    fn reducer_initial_all_minus_one() {
        let s = ReducerState::initial(5);
        assert_eq!(s.committed_row_indices, vec![-1; 5]);
        assert!(!s.retired);
        assert!(s.bootstrapped, "epoch-0 reducers are born bootstrapped");
        let m = ReducerState::initial_migrating(5);
        assert!(!m.bootstrapped, "resharded-in reducers must import first");
        assert!(!m.retired);
    }

    #[test]
    fn from_row_rejects_garbage() {
        let bad = UnversionedRow::new(vec![
            Value::Int64(0),
            Value::Str("not yson list {".into()),
            Value::Int64(0),
            Value::Int64(1),
        ]);
        assert_eq!(ReducerState::from_row(&bad), None);
        let wrong_ty = UnversionedRow::new(vec![Value::Int64(0), Value::Int64(7)]);
        assert_eq!(ReducerState::from_row(&wrong_ty), None);
    }

    #[test]
    fn empty_committed_list_roundtrip() {
        let s = ReducerState {
            committed_row_indices: vec![],
            retired: false,
            bootstrapped: true,
        };
        let row = s.to_row(0);
        assert_eq!(ReducerState::from_row(&row), Some(s));
    }
}
