//! Persistent worker state rows (§4.3.2, §4.4.1).
//!
//! Mapper state table columns: `mapper_index` (key),
//! `input_unread_row_index`, `shuffle_unread_row_index`,
//! `continuation_token` — "the index … of the first row that was not yet
//! successfully processed and committed by its corresponding reducer".
//!
//! Reducer state table columns: `reducer_index` (key),
//! `committed_row_indices` — "a list of shuffle row indices, one for each
//! mapper, indicating that all rows up to said index were reliably
//! processed by the reducer". The list is serialized as a YSON list.

use crate::queue::ContinuationToken;
use crate::rows::{ColumnSchema, ColumnType, TableSchema, UnversionedRow, Value};
use crate::util::yson::Yson;

/// A mapper's persistent state (one row of the mapper state table).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MapperState {
    pub input_unread_row_index: i64,
    pub shuffle_unread_row_index: i64,
    pub continuation_token: ContinuationToken,
}

impl MapperState {
    pub fn initial() -> MapperState {
        MapperState {
            input_unread_row_index: 0,
            shuffle_unread_row_index: 0,
            continuation_token: ContinuationToken::initial(),
        }
    }

    pub fn schema() -> TableSchema {
        TableSchema::new(vec![
            ColumnSchema::key("mapper_index", ColumnType::Int64),
            ColumnSchema::value("input_unread_row_index", ColumnType::Int64),
            ColumnSchema::value("shuffle_unread_row_index", ColumnType::Int64),
            ColumnSchema::value("continuation_token", ColumnType::Str),
        ])
    }

    pub fn to_row(&self, mapper_index: usize) -> UnversionedRow {
        UnversionedRow::new(vec![
            Value::Int64(mapper_index as i64),
            Value::Int64(self.input_unread_row_index),
            Value::Int64(self.shuffle_unread_row_index),
            Value::from(self.continuation_token.0.as_str()),
        ])
    }

    pub fn from_row(row: &UnversionedRow) -> Option<MapperState> {
        Some(MapperState {
            input_unread_row_index: row.get(1)?.as_i64()?,
            shuffle_unread_row_index: row.get(2)?.as_i64()?,
            continuation_token: ContinuationToken(row.get(3)?.as_str()?.to_string()),
        })
    }

    pub fn key(mapper_index: usize) -> Vec<Value> {
        vec![Value::Int64(mapper_index as i64)]
    }
}

/// A reducer's persistent state (one row of the reducer state table).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReducerState {
    /// `committed_row_indices[m]` = shuffle index of the last row from
    /// mapper `m` this reducer has committed; -1 = none yet.
    pub committed_row_indices: Vec<i64>,
}

impl ReducerState {
    pub fn initial(num_mappers: usize) -> ReducerState {
        ReducerState {
            committed_row_indices: vec![-1; num_mappers],
        }
    }

    pub fn schema() -> TableSchema {
        TableSchema::new(vec![
            ColumnSchema::key("reducer_index", ColumnType::Int64),
            ColumnSchema::value("committed_row_indices", ColumnType::Str),
        ])
    }

    pub fn to_row(&self, reducer_index: usize) -> UnversionedRow {
        let list = Yson::List(
            self.committed_row_indices
                .iter()
                .map(|v| Yson::Int(*v))
                .collect(),
        );
        UnversionedRow::new(vec![
            Value::Int64(reducer_index as i64),
            Value::from(list.to_string()),
        ])
    }

    pub fn from_row(row: &UnversionedRow) -> Option<ReducerState> {
        let text = row.get(1)?.as_str()?;
        let y = Yson::parse(text).ok()?;
        let committed = y
            .as_list()
            .ok()?
            .iter()
            .map(|v| v.as_i64().ok())
            .collect::<Option<Vec<i64>>>()?;
        Some(ReducerState {
            committed_row_indices: committed,
        })
    }

    pub fn key(reducer_index: usize) -> Vec<Value> {
        vec![Value::Int64(reducer_index as i64)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapper_state_roundtrip() {
        let s = MapperState {
            input_unread_row_index: 42,
            shuffle_unread_row_index: 99,
            continuation_token: ContinuationToken("lb:123".into()),
        };
        let row = s.to_row(3);
        MapperState::schema().validate(&row).unwrap();
        assert_eq!(MapperState::from_row(&row), Some(s));
        assert_eq!(row.get(0), Some(&Value::Int64(3)));
    }

    #[test]
    fn mapper_initial_state() {
        let s = MapperState::initial();
        assert_eq!(s.input_unread_row_index, 0);
        assert!(s.continuation_token.is_initial());
    }

    #[test]
    fn reducer_state_roundtrip() {
        let s = ReducerState {
            committed_row_indices: vec![-1, 0, 12345, 7],
        };
        let row = s.to_row(1);
        ReducerState::schema().validate(&row).unwrap();
        assert_eq!(ReducerState::from_row(&row), Some(s));
    }

    #[test]
    fn reducer_initial_all_minus_one() {
        let s = ReducerState::initial(5);
        assert_eq!(s.committed_row_indices, vec![-1; 5]);
    }

    #[test]
    fn from_row_rejects_garbage() {
        let bad = UnversionedRow::new(vec![Value::Int64(0), Value::Str("not yson list {".into())]);
        assert_eq!(ReducerState::from_row(&bad), None);
        let wrong_ty = UnversionedRow::new(vec![Value::Int64(0), Value::Int64(7)]);
        assert_eq!(ReducerState::from_row(&wrong_ty), None);
    }

    #[test]
    fn empty_committed_list_roundtrip() {
        let s = ReducerState {
            committed_row_indices: vec![],
        };
        let row = s.to_row(0);
        assert_eq!(ReducerState::from_row(&row), Some(s));
    }
}
