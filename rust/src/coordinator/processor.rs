//! Streaming-processor assembly: wire config + substrates + user code into
//! a supervised fleet of mappers and reducers (§4.5, §4.6).

use std::sync::Arc;

use crate::api::{Client, MapperFactory, MapperSpec, ReducerFactory, ReducerSpec};
use crate::controller::{Role, Spawner, Supervisor, WorkerHandle};
use crate::coordinator::config::ProcessorConfig;
use crate::coordinator::mapper::{spawn_mapper, MapperDeps};
use crate::coordinator::reducer::{spawn_reducer, ReducerDeps};
use crate::coordinator::state::{MapperState, ReducerState};
use crate::cypress::{Cypress, DiscoveryGroup};
use crate::dyntable::DynTableStore;
use crate::metrics::{MetricsHub, WaReport};
use crate::queue::logbroker::LbTopic;
use crate::queue::ordered_table::OrderedTable;
use crate::queue::PartitionReader;
use crate::rows::NameTable;
use crate::rpc::RpcNet;
use crate::storage::{WriteAccounting, WriteCategory};
use crate::util::yson::Yson;
use crate::util::{Clock, Guid, Prng};

/// The input stream feeding the processor (§4.2): one mapper per partition.
#[derive(Clone)]
pub enum InputSpec {
    Ordered(Arc<OrderedTable>),
    LogBroker(Arc<LbTopic>),
    /// §6 multi-partition mappers: several source partitions per mapper,
    /// made deterministic by the order log (see [`crate::multipart`]).
    Grouped(Arc<crate::multipart::GroupedInput>),
}

impl InputSpec {
    pub fn partition_count(&self) -> usize {
        match self {
            InputSpec::Ordered(t) => t.tablet_count(),
            InputSpec::LogBroker(t) => t.partition_count(),
            InputSpec::Grouped(g) => g.mapper_count(),
        }
    }

    pub fn name_table(&self) -> Arc<NameTable> {
        match self {
            InputSpec::Ordered(t) => t.name_table(),
            InputSpec::LogBroker(t) => t.name_table(),
            InputSpec::Grouped(g) => g.source.name_table(),
        }
    }

    pub fn reader(&self, partition: usize) -> Box<dyn PartitionReader> {
        match self {
            InputSpec::Ordered(t) => Box::new(t.reader(partition)),
            InputSpec::LogBroker(t) => Box::new(t.reader(partition)),
            InputSpec::Grouped(g) => Box::new(g.reader(partition)),
        }
    }

    /// Rows still retained in the input store (backlog metric).
    pub fn retained_rows(&self) -> usize {
        match self {
            InputSpec::Ordered(t) => t.retained_rows(),
            InputSpec::LogBroker(t) => t.retained_rows(),
            InputSpec::Grouped(g) => g.source.retained_rows(),
        }
    }
}

/// The shared substrate bundle a processor (and its tests/figures) runs on:
/// one simulated cluster.
#[derive(Clone)]
pub struct ClusterEnv {
    pub clock: Clock,
    pub accounting: Arc<WriteAccounting>,
    pub store: Arc<DynTableStore>,
    pub cypress: Arc<Cypress>,
    pub net: Arc<RpcNet>,
    pub metrics: Arc<MetricsHub>,
}

impl ClusterEnv {
    /// Build a fresh simulated cluster.
    pub fn new(clock: Clock, seed: u64) -> ClusterEnv {
        let accounting = WriteAccounting::new();
        ClusterEnv {
            store: DynTableStore::new(accounting.clone()),
            cypress: Cypress::new(clock.clone(), accounting.clone()),
            net: RpcNet::new(clock.clone(), Prng::seeded(seed)),
            metrics: MetricsHub::new(),
            accounting,
            clock,
        }
    }

    pub fn client(&self) -> Client {
        Client {
            store: self.store.clone(),
            cypress: self.cypress.clone(),
            clock: self.clock.clone(),
        }
    }
}

/// Errors surfaced while assembling a processor.
#[derive(Debug, thiserror::Error)]
pub enum LaunchError {
    #[error("config: mapper_count {cfg} != input partition count {input}")]
    PartitionMismatch { cfg: usize, input: usize },
    #[error("state table setup failed: {0}")]
    Setup(String),
}

/// A running streaming processor: the user-facing handle.
pub struct StreamingProcessor {
    pub cfg: ProcessorConfig,
    pub env: ClusterEnv,
    pub input: InputSpec,
    supervisor: Arc<Supervisor>,
    processor_guid: Guid,
}

impl StreamingProcessor {
    /// Set up state tables and discovery, then launch the supervised
    /// worker fleet.
    pub fn launch(
        cfg: ProcessorConfig,
        env: ClusterEnv,
        input: InputSpec,
        mapper_factory: MapperFactory,
        reducer_factory: ReducerFactory,
        user_config: Yson,
    ) -> Result<StreamingProcessor, LaunchError> {
        if cfg.mapper_count != input.partition_count() {
            return Err(LaunchError::PartitionMismatch {
                cfg: cfg.mapper_count,
                input: input.partition_count(),
            });
        }
        let processor_guid = Guid::generate();
        setup_state_tables(&cfg, &env).map_err(LaunchError::Setup)?;

        let mapper_group = DiscoveryGroup::open(env.cypress.clone(), &cfg.mapper_group())
            .map_err(|e| LaunchError::Setup(e.to_string()))?;
        let reducer_group = DiscoveryGroup::open(env.cypress.clone(), &cfg.reducer_group())
            .map_err(|e| LaunchError::Setup(e.to_string()))?;

        let user_config = Arc::new(user_config);
        let mut slots: Vec<(Role, usize, Spawner)> = Vec::new();

        for index in 0..cfg.mapper_count {
            let cfg = cfg.clone();
            let env = env.clone();
            let input = input.clone();
            let factory = mapper_factory.clone();
            let user_config = user_config.clone();
            let group = mapper_group.clone();
            let spawner: Spawner = Box::new(move || {
                let guid = Guid::generate();
                let spec = MapperSpec {
                    processor_guid,
                    state_table: cfg.mapper_state_table.clone(),
                    index,
                    guid,
                    num_reducers: cfg.reducer_count,
                };
                let client = env.client();
                let user_mapper = factory(&user_config, &client, input.name_table(), &spec);
                let deps = MapperDeps {
                    client,
                    net: env.net.clone(),
                    metrics: env.metrics.clone(),
                    discovery: group.clone(),
                };
                WorkerHandle::Mapper(spawn_mapper(
                    cfg.clone(),
                    spec,
                    deps,
                    user_mapper,
                    input.reader(index),
                ))
            });
            slots.push((Role::Mapper, index, spawner));
        }

        for index in 0..cfg.reducer_count {
            let cfg = cfg.clone();
            let env = env.clone();
            let factory = reducer_factory.clone();
            let user_config = user_config.clone();
            let mapper_group = mapper_group.clone();
            let reducer_group = reducer_group.clone();
            let spawner: Spawner = Box::new(move || {
                let guid = Guid::generate();
                let spec = ReducerSpec {
                    processor_guid,
                    state_table: cfg.reducer_state_table.clone(),
                    index,
                    guid,
                    num_mappers: cfg.mapper_count,
                };
                let client = env.client();
                let user_reducer = factory(&user_config, &client, &spec);
                let deps = ReducerDeps {
                    client,
                    net: env.net.clone(),
                    metrics: env.metrics.clone(),
                    mapper_discovery: mapper_group.clone(),
                    reducer_discovery: reducer_group.clone(),
                };
                WorkerHandle::Reducer(spawn_reducer(cfg.clone(), spec, deps, user_reducer))
            });
            slots.push((Role::Reducer, index, spawner));
        }

        let supervisor = Supervisor::start(env.clock.clone(), cfg.restart_delay_ms, slots);
        Ok(StreamingProcessor {
            cfg,
            env,
            input,
            supervisor,
            processor_guid,
        })
    }

    pub fn processor_guid(&self) -> Guid {
        self.processor_guid
    }

    pub fn supervisor(&self) -> &Arc<Supervisor> {
        &self.supervisor
    }

    /// Total input payload bytes mappers have read so far.
    pub fn ingested_bytes(&self) -> u64 {
        self.env
            .metrics
            .get_counter(crate::metrics::hub::names::MAPPER_BYTES_READ)
    }

    /// Write-amplification report for this run.
    pub fn wa_report(&self, label: &str) -> WaReport {
        WaReport::new(label, self.ingested_bytes(), self.env.accounting.snapshot())
    }

    /// Stop all workers and the supervisor. Consumes the processor.
    pub fn stop(self) {
        self.supervisor.stop();
    }
}

/// Create the state tables (idempotent) and seed initial rows for every
/// worker index that has none yet.
fn setup_state_tables(cfg: &ProcessorConfig, env: &ClusterEnv) -> Result<(), String> {
    use crate::dyntable::store::StoreError;
    match env.store.create_table_scoped(
        &cfg.mapper_state_table,
        MapperState::schema(),
        WriteCategory::MapperMeta,
        cfg.scope_label.clone(),
    ) {
        Ok(_) | Err(StoreError::AlreadyExists(_)) => {}
        Err(e) => return Err(e.to_string()),
    }
    match env.store.create_table_scoped(
        &cfg.reducer_state_table,
        ReducerState::schema(),
        WriteCategory::ReducerMeta,
        cfg.scope_label.clone(),
    ) {
        Ok(_) | Err(StoreError::AlreadyExists(_)) => {}
        Err(e) => return Err(e.to_string()),
    }

    let mut txn = env.store.begin();
    for index in 0..cfg.mapper_count {
        let existing = txn
            .lookup(&cfg.mapper_state_table, &MapperState::key(index))
            .map_err(|e| e.to_string())?;
        if existing.is_none() {
            txn.write(
                &cfg.mapper_state_table,
                MapperState::initial().to_row(index),
            )
            .map_err(|e| e.to_string())?;
        }
    }
    for index in 0..cfg.reducer_count {
        let existing = txn
            .lookup(&cfg.reducer_state_table, &ReducerState::key(index))
            .map_err(|e| e.to_string())?;
        if existing.is_none() {
            txn.write(
                &cfg.reducer_state_table,
                ReducerState::initial(cfg.mapper_count).to_row(index),
            )
            .map_err(|e| e.to_string())?;
        }
    }
    txn.commit().map_err(|e| e.to_string())?;
    Ok(())
}
