//! Streaming-processor assembly: wire config + substrates + user code into
//! a supervised fleet of mappers and reducers (§4.5, §4.6), with live
//! elasticity: the reducer fleet can be resharded N → M while running
//! ([`StreamingProcessor::reshard`]), and the mapper fleet can grow when an
//! upstream dataflow stage reshards its handoff partitioning.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::api::{Client, MapperFactory, MapperSpec, ReducerFactory, ReducerSpec};
use crate::controller::{Role, Spawner, Supervisor, WorkerHandle};
use crate::coordinator::config::ProcessorConfig;
use crate::coordinator::mapper::{spawn_mapper, MapperDeps};
use crate::coordinator::reducer::{spawn_reducer, ReducerDeps};
use crate::coordinator::state::{MapperState, ReducerState};
use crate::cypress::{Cypress, DiscoveryGroup};
use crate::dyntable::DynTableStore;
use crate::metrics::{MetricsHub, WaReport};
use crate::queue::logbroker::LbTopic;
use crate::queue::ordered_table::OrderedTable;
use crate::queue::PartitionReader;
use crate::reshard::driver::{AutoscaleDriver, DriverConfig, DriverDeps};
use crate::reshard::plan::{reducer_slot, reducer_state_table, PlanPhase, ReshardPlan};
use crate::reshard::resharder::{self, ReshardContext, ReshardError, ReshardStats};
use crate::reshard::ReshardRuntime;
use crate::rows::NameTable;
use crate::rpc::RpcNet;
use crate::storage::{WriteAccounting, WriteCategory};
use crate::util;
use crate::util::yson::Yson;
use crate::util::{Clock, Guid, Prng};

/// The input stream feeding the processor (§4.2): one mapper per partition.
#[derive(Clone)]
pub enum InputSpec {
    Ordered(Arc<OrderedTable>),
    LogBroker(Arc<LbTopic>),
    /// §6 multi-partition mappers: several source partitions per mapper,
    /// made deterministic by the order log (see [`crate::multipart`]).
    Grouped(Arc<crate::multipart::GroupedInput>),
    /// Unified backfill ([`crate::coldtier`]): drain a bounded historical
    /// range from cold chunks, then cut over to live tailing at the
    /// per-partition fence. Same mapper loop, same checkpoints — the
    /// reader is the only thing that knows history from head.
    BoundedRange(Arc<crate::coldtier::ColdInput>),
}

impl InputSpec {
    pub fn partition_count(&self) -> usize {
        match self {
            InputSpec::Ordered(t) => t.tablet_count(),
            InputSpec::LogBroker(t) => t.partition_count(),
            InputSpec::Grouped(g) => g.mapper_count(),
            InputSpec::BoundedRange(c) => c.partition_count(),
        }
    }

    pub fn name_table(&self) -> Arc<NameTable> {
        match self {
            InputSpec::Ordered(t) => t.name_table(),
            InputSpec::LogBroker(t) => t.name_table(),
            InputSpec::Grouped(g) => g.source.name_table(),
            InputSpec::BoundedRange(c) => c.name_table(),
        }
    }

    pub fn reader(&self, partition: usize) -> Box<dyn PartitionReader> {
        match self {
            InputSpec::Ordered(t) => Box::new(t.reader(partition)),
            InputSpec::LogBroker(t) => Box::new(t.reader(partition)),
            InputSpec::Grouped(g) => Box::new(g.reader(partition)),
            InputSpec::BoundedRange(c) => Box::new(c.reader(partition)),
        }
    }

    /// Rows still retained in the input store (backlog metric).
    pub fn retained_rows(&self) -> usize {
        match self {
            InputSpec::Ordered(t) => t.retained_rows(),
            InputSpec::LogBroker(t) => t.retained_rows(),
            InputSpec::Grouped(g) => g.source.retained_rows(),
            InputSpec::BoundedRange(c) => c.retained_rows(),
        }
    }
}

/// The shared substrate bundle a processor (and its tests/figures) runs on:
/// one simulated cluster.
#[derive(Clone)]
pub struct ClusterEnv {
    pub clock: Clock,
    pub accounting: Arc<WriteAccounting>,
    pub store: Arc<DynTableStore>,
    pub cypress: Arc<Cypress>,
    pub net: Arc<RpcNet>,
    pub metrics: Arc<MetricsHub>,
}

impl ClusterEnv {
    /// Build a fresh simulated cluster.
    pub fn new(clock: Clock, seed: u64) -> ClusterEnv {
        let accounting = WriteAccounting::new();
        ClusterEnv {
            store: DynTableStore::new(accounting.clone()),
            cypress: Cypress::new(clock.clone(), accounting.clone()),
            net: RpcNet::new(clock.clone(), Prng::seeded(seed)),
            metrics: MetricsHub::new(),
            accounting,
            clock,
        }
    }

    pub fn client(&self) -> Client {
        Client {
            store: self.store.clone(),
            cypress: self.cypress.clone(),
            clock: self.clock.clone(),
        }
    }
}

/// Errors surfaced while assembling a processor.
#[derive(Debug, thiserror::Error)]
pub enum LaunchError {
    #[error("config: mapper_count {cfg} != input partition count {input}")]
    PartitionMismatch { cfg: usize, input: usize },
    #[error("backfill input: {fences} cutover fences for {partitions} partitions")]
    FenceMismatch { fences: usize, partitions: usize },
    #[error("state table setup failed: {0}")]
    Setup(String),
}

/// A running streaming processor: the user-facing handle.
pub struct StreamingProcessor {
    pub cfg: ProcessorConfig,
    pub env: ClusterEnv,
    pub input: InputSpec,
    supervisor: Arc<Supervisor>,
    processor_guid: Guid,
    reshard_runtime: Arc<ReshardRuntime>,
    spawn_mapper_slot: Arc<dyn Fn(usize) -> WorkerHandle + Send + Sync>,
    spawn_reducer_slot: Arc<dyn Fn(i64, usize) -> WorkerHandle + Send + Sync>,
    /// Live mapper-slot count (grows on upstream re-wiring).
    mapper_count: Arc<AtomicUsize>,
    /// The resident autoscale loop, when started ([`StreamingProcessor::
    /// start_autoscaler`]); stopped with the processor.
    autoscaler: std::sync::Mutex<Option<AutoscaleDriver>>,
}

impl StreamingProcessor {
    /// Set up state tables and discovery, then launch the supervised
    /// worker fleet.
    pub fn launch(
        cfg: ProcessorConfig,
        env: ClusterEnv,
        input: InputSpec,
        mapper_factory: MapperFactory,
        reducer_factory: ReducerFactory,
        user_config: Yson,
    ) -> Result<StreamingProcessor, LaunchError> {
        Self::launch_with_runtime(cfg.clone(), env.clone(), input, mapper_factory, reducer_factory, user_config, {
            ReshardRuntime::new(
                cfg.reshard_plan_table.clone(),
                env.accounting.clone(),
                cfg.scope_label.clone(),
            )
        })
    }

    /// Like [`StreamingProcessor::launch`] but with a caller-provided
    /// reshard runtime (custom residual exporter/importer).
    pub fn launch_with_runtime(
        cfg: ProcessorConfig,
        env: ClusterEnv,
        input: InputSpec,
        mapper_factory: MapperFactory,
        reducer_factory: ReducerFactory,
        user_config: Yson,
        reshard_runtime: Arc<ReshardRuntime>,
    ) -> Result<StreamingProcessor, LaunchError> {
        if cfg.mapper_count != input.partition_count() {
            return Err(LaunchError::PartitionMismatch {
                cfg: cfg.mapper_count,
                input: input.partition_count(),
            });
        }
        if let InputSpec::BoundedRange(c) = &input {
            // One cutover fence per partition, or the backfill/live split
            // is ill-defined for the fenceless partitions.
            if c.fences().len() != c.partition_count() {
                return Err(LaunchError::FenceMismatch {
                    fences: c.fences().len(),
                    partitions: c.partition_count(),
                });
            }
        }
        let processor_guid = Guid::generate();
        setup_state_tables(&cfg, &env).map_err(LaunchError::Setup)?;

        let mapper_group = DiscoveryGroup::open(env.cypress.clone(), &cfg.mapper_group())
            .map_err(|e| LaunchError::Setup(e.to_string()))?;
        let reducer_group = DiscoveryGroup::open(env.cypress.clone(), &cfg.reducer_group())
            .map_err(|e| LaunchError::Setup(e.to_string()))?;

        let user_config = Arc::new(user_config);
        let mapper_count = Arc::new(AtomicUsize::new(cfg.mapper_count));

        let spawn_mapper_slot: Arc<dyn Fn(usize) -> WorkerHandle + Send + Sync> = {
            let cfg = cfg.clone();
            let env = env.clone();
            let input = input.clone();
            let factory = mapper_factory.clone();
            let user_config = user_config.clone();
            let group = mapper_group.clone();
            Arc::new(move |index: usize| {
                let guid = Guid::generate();
                let spec = MapperSpec {
                    processor_guid,
                    state_table: cfg.mapper_state_table.clone(),
                    index,
                    guid,
                    num_reducers: cfg.reducer_count,
                };
                let deps = MapperDeps {
                    client: env.client(),
                    net: env.net.clone(),
                    metrics: env.metrics.clone(),
                    discovery: group.clone(),
                    factory: factory.clone(),
                    user_config: user_config.clone(),
                    input_name_table: input.name_table(),
                };
                WorkerHandle::Mapper(spawn_mapper(cfg.clone(), spec, deps, input.reader(index)))
            })
        };

        let spawn_reducer_slot: Arc<dyn Fn(i64, usize) -> WorkerHandle + Send + Sync> = {
            let cfg = cfg.clone();
            let env = env.clone();
            let factory = reducer_factory.clone();
            let user_config = user_config.clone();
            let mapper_group = mapper_group.clone();
            let reducer_group = reducer_group.clone();
            let runtime = reshard_runtime.clone();
            let mapper_count = mapper_count.clone();
            Arc::new(move |epoch: i64, index: usize| {
                let guid = Guid::generate();
                let spec = ReducerSpec {
                    processor_guid,
                    state_table: reducer_state_table(&cfg.reducer_state_table, epoch),
                    index,
                    guid,
                    num_mappers: mapper_count.load(Ordering::SeqCst),
                    epoch,
                };
                let client = env.client();
                let user_reducer = factory(&user_config, &client, &spec);
                let deps = ReducerDeps {
                    client,
                    net: env.net.clone(),
                    metrics: env.metrics.clone(),
                    mapper_discovery: mapper_group.clone(),
                    reducer_discovery: reducer_group.clone(),
                    reshard: runtime.clone(),
                };
                WorkerHandle::Reducer(spawn_reducer(cfg.clone(), spec, deps, user_reducer))
            })
        };

        let mut slots: Vec<(Role, usize, Spawner)> = Vec::new();
        for index in 0..cfg.mapper_count {
            let spawn = spawn_mapper_slot.clone();
            slots.push((Role::Mapper, index, Box::new(move || spawn(index))));
        }
        for index in 0..cfg.reducer_count {
            let spawn = spawn_reducer_slot.clone();
            slots.push((
                Role::Reducer,
                reducer_slot(0, index),
                Box::new(move || spawn(0, index)),
            ));
        }

        let supervisor = Supervisor::start(env.clock.clone(), cfg.restart_delay_ms, slots);
        Ok(StreamingProcessor {
            cfg,
            env,
            input,
            supervisor,
            processor_guid,
            reshard_runtime,
            spawn_mapper_slot,
            spawn_reducer_slot,
            mapper_count,
            autoscaler: std::sync::Mutex::new(None),
        })
    }

    pub fn processor_guid(&self) -> Guid {
        self.processor_guid
    }

    pub fn supervisor(&self) -> &Arc<Supervisor> {
        &self.supervisor
    }

    pub fn reshard_runtime(&self) -> &Arc<ReshardRuntime> {
        &self.reshard_runtime
    }

    /// The live reshard plan (None before setup / on store outage).
    pub fn current_plan(&self) -> Option<ReshardPlan> {
        ReshardPlan::fetch(&self.env.store, &self.cfg.reshard_plan_table)
    }

    /// Reducer count of the epoch currently being routed to (the target
    /// fleet while a migration is in flight).
    pub fn current_reducer_count(&self) -> usize {
        match self.current_plan() {
            Some(p) if p.phase == PlanPhase::Migrating => p.next_partitions,
            Some(p) => p.partitions,
            None => self.cfg.reducer_count,
        }
    }

    fn reshard_ctx(&self) -> ReshardContext {
        (self.reshard_ctx_factory())()
    }

    /// A factory the resident driver can hold without borrowing the
    /// processor: each call snapshots the *current* mapper count (dataflow
    /// re-wiring changes it mid-life).
    pub(crate) fn reshard_ctx_factory(&self) -> Arc<dyn Fn() -> ReshardContext + Send + Sync> {
        let store = self.env.store.clone();
        let runtime = self.reshard_runtime.clone();
        let reducer_state_base = self.cfg.reducer_state_table.clone();
        let mapper_count = self.mapper_count.clone();
        let supervisor = self.supervisor.clone();
        let spawn_reducer = self.spawn_reducer_slot.clone();
        let metrics = self.env.metrics.clone();
        let scope = self.cfg.scope_label.clone();
        let state_category = self.cfg.consistency.state_write_category();
        Arc::new(move || ReshardContext {
            store: store.clone(),
            runtime: runtime.clone(),
            reducer_state_base: reducer_state_base.clone(),
            num_mappers: mapper_count.load(Ordering::SeqCst),
            supervisor: supervisor.clone(),
            spawn_reducer: spawn_reducer.clone(),
            metrics: metrics.clone(),
            scope: scope.clone(),
            state_category,
        })
    }

    /// Start the resident autoscale loop: every `tick_period_ms` it fuses
    /// the fleet's lag signals with the input backlog, and executes its
    /// own proposals through the same begin/finish/resume path as manual
    /// resharding. A plan left `Migrating` (crashed driver, interrupted
    /// manual call) is resumed before any new proposal — starting the
    /// driver is therefore also the crash-recovery action. Replaces a
    /// previously started driver. Stopped automatically by
    /// [`StreamingProcessor::stop`].
    pub fn start_autoscaler(&self, cfg: DriverConfig) {
        let deps = DriverDeps {
            clock: self.env.clock.clone(),
            store: self.env.store.clone(),
            plan_table: self.cfg.reshard_plan_table.clone(),
            metrics: self.env.metrics.clone(),
            input: self.input.clone(),
            ctx: self.reshard_ctx_factory(),
            pre_begin: None,
            post_stable: None,
        };
        let driver = AutoscaleDriver::start(cfg, deps);
        if let Some(old) = util::lock(&self.autoscaler).replace(driver) {
            old.stop();
        }
    }

    /// Stop the resident autoscale loop, if one is running. A migration
    /// it was mid-way through stays `Migrating` in the plan row and is
    /// picked up by the next driver start (or a manual
    /// [`StreamingProcessor::resume_reshard`]).
    pub fn stop_autoscaler(&self) {
        if let Some(driver) = util::lock(&self.autoscaler).take() {
            driver.stop();
        }
    }

    /// Is a resident autoscale loop currently attached?
    pub fn autoscaler_running(&self) -> bool {
        util::lock(&self.autoscaler).is_some()
    }

    /// Start a live reshard towards `new_count` reducers. Returns the
    /// in-flight plan; the migration proceeds in the background (workers
    /// carry it) until [`StreamingProcessor::finish_reshard`].
    pub fn begin_reshard(&self, new_count: usize) -> Result<ReshardPlan, ReshardError> {
        resharder::begin(&self.reshard_ctx(), new_count)
    }

    /// Wait for the in-flight migration to drain and finalize it.
    pub fn finish_reshard(&self, wall_timeout_ms: u64) -> Result<ReshardStats, ReshardError> {
        resharder::finalize(&self.reshard_ctx(), wall_timeout_ms)
    }

    /// Convenience: begin + finish in one call.
    pub fn reshard(
        &self,
        new_count: usize,
        wall_timeout_ms: u64,
    ) -> Result<ReshardStats, ReshardError> {
        self.begin_reshard(new_count)?;
        self.finish_reshard(wall_timeout_ms)
    }

    /// Resume an interrupted migration (driver crash / timeout).
    pub fn resume_reshard(&self, wall_timeout_ms: u64) -> Result<ReshardStats, ReshardError> {
        resharder::resume(&self.reshard_ctx(), wall_timeout_ms)
    }

    /// Grow the mapper fleet to `new_count` (used by dataflow re-wiring
    /// when an upstream stage reshards its handoff partitioning; the input
    /// spec must already expose the new partitions). Previously retired
    /// slots below `new_count` are revived (their state-row `retired` flag
    /// cleared *before* the worker respawns, so reducers re-include the
    /// index in their drain gates no later than it can serve rows again).
    pub fn grow_mappers(&self, new_count: usize) {
        let old = self.mapper_count.load(Ordering::SeqCst);
        for index in 0..new_count.min(old) {
            if self.supervisor.has_slot(Role::Mapper, index)
                && !self.supervisor.is_active(Role::Mapper, index)
            {
                self.set_mapper_retired_flag(index, false);
                self.supervisor.revive(Role::Mapper, index);
            }
        }
        if new_count <= old {
            return;
        }
        assert!(
            new_count <= self.input.partition_count(),
            "grow_mappers({new_count}) exceeds input partition count {}",
            self.input.partition_count()
        );
        for index in old..new_count {
            let spawn = self.spawn_mapper_slot.clone();
            self.supervisor
                .add_slot(Role::Mapper, index, Box::new(move || spawn(index)));
        }
        self.mapper_count.store(new_count, Ordering::SeqCst);
    }

    /// Current mapper-slot count.
    pub fn mapper_count(&self) -> usize {
        self.mapper_count.load(Ordering::SeqCst)
    }

    /// Retire one mapper slot (downstream shrink re-wiring: its upstream
    /// handoff tablet went quiet and drained). Kills the worker, disables
    /// its respawn, then CAS-marks its state row `retired` so reducer
    /// drain gates drop the index — without the flag, the dead index would
    /// block every later reshard of this stage's reducers (shrink
    /// hygiene).
    pub fn retire_mapper(&self, index: usize) {
        self.supervisor.retire(Role::Mapper, index);
        self.set_mapper_retired_flag(index, true);
    }

    /// CAS the `retired` column of one mapper state row. The retired
    /// instance is already dead (or, on revival, not yet respawned), so
    /// contention is limited to its last in-flight trim commit — a short
    /// retry absorbs it. A *missing* row (a grown mapper killed before
    /// its lazy startup write) is created retired: leaving no row would
    /// leave the index looking live to reducer drain gates forever —
    /// exactly the deadlock the flag exists to prevent.
    fn set_mapper_retired_flag(&self, index: usize, retired: bool) {
        for _ in 0..64 {
            let mut txn = self.env.store.begin();
            let state = match txn.lookup(&self.cfg.mapper_state_table, &MapperState::key(index)) {
                Ok(Some(row)) => MapperState::from_row(&row),
                Ok(None) if retired => Some(MapperState::initial()),
                Ok(None) => return, // nothing to clear
                Err(_) => {
                    self.env.clock.sleep_ms(2);
                    continue;
                }
            };
            let Some(mut state) = state else { return };
            if state.retired == retired {
                return;
            }
            state.retired = retired;
            if txn
                .write(&self.cfg.mapper_state_table, state.to_row(index))
                .is_ok()
                && txn.commit().is_ok()
            {
                return;
            }
            self.env.clock.sleep_ms(2);
        }
    }

    /// Fleet event-time watermark: min over live mappers' persisted
    /// watermarks (None when event time is disabled, unobserved, or any
    /// live mapper has not reported yet). See [`crate::eventtime`].
    pub fn fleet_watermark(&self) -> Option<i64> {
        self.cfg.event_time.as_ref()?;
        crate::eventtime::WatermarkTracker::new(
            self.env.store.clone(),
            self.cfg.mapper_state_table.clone(),
        )
        .fleet_watermark()
    }

    /// Declare the input closed for event time: asserts no further rows
    /// will ever be appended to this processor's input and every event
    /// time already appended is `< close_ts_ms`
    /// ([`crate::eventtime::EVENT_TIME_CLOSED`] is the conventional +∞).
    /// Mappers lift their watermarks to the close timestamp once they
    /// drain, which lets windowed reducers final-fire everything.
    pub fn close_event_time(&self, close_ts_ms: i64) -> Result<(), String> {
        if self.cfg.event_time.is_none() {
            return Err("close_event_time: event time is not enabled".into());
        }
        crate::eventtime::close_source(&self.env.store, &self.cfg.mapper_state_table, close_ts_ms)
    }

    /// Total input payload bytes mappers have read so far.
    pub fn ingested_bytes(&self) -> u64 {
        self.env
            .metrics
            .get_counter(crate::metrics::hub::names::MAPPER_BYTES_READ)
    }

    /// Write-amplification report for this run.
    pub fn wa_report(&self, label: &str) -> WaReport {
        WaReport::new(label, self.ingested_bytes(), self.env.accounting.snapshot())
    }

    /// Stop the resident autoscaler (if any), all workers, and the
    /// supervisor, without consuming the handle — what `Arc`-shared
    /// owners (topology autoscalers) call.
    pub fn shutdown(&self) {
        self.stop_autoscaler();
        self.supervisor.stop();
    }

    /// Stop all workers and the supervisor. Consumes the processor.
    pub fn stop(self) {
        self.shutdown();
    }
}

/// Create the state + plan tables (idempotent) and seed initial rows for
/// every worker index (and the plan) that has none yet.
fn setup_state_tables(cfg: &ProcessorConfig, env: &ClusterEnv) -> Result<(), String> {
    use crate::dyntable::store::StoreError;
    match env.store.create_table_scoped(
        &cfg.mapper_state_table,
        MapperState::schema(),
        WriteCategory::MapperMeta,
        cfg.scope_label.clone(),
    ) {
        Ok(_) | Err(StoreError::AlreadyExists(_)) => {}
        Err(e) => return Err(e.to_string()),
    }
    // Approximate-tier stages write this table rarely (anchors and
    // lifecycle rows only); its bytes land on the `anchor_state` frontier
    // line instead of `reducer_meta`.
    match env.store.create_table_scoped(
        &cfg.reducer_state_table,
        ReducerState::schema(),
        cfg.consistency.state_write_category(),
        cfg.scope_label.clone(),
    ) {
        Ok(_) | Err(StoreError::AlreadyExists(_)) => {}
        Err(e) => return Err(e.to_string()),
    }
    match env.store.create_table_scoped(
        &cfg.reshard_plan_table,
        ReshardPlan::schema(),
        WriteCategory::Reshard,
        cfg.scope_label.clone(),
    ) {
        Ok(_) | Err(StoreError::AlreadyExists(_)) => {}
        Err(e) => return Err(e.to_string()),
    }
    if cfg.event_time.is_some() {
        crate::eventtime::watermark::ensure_close_table(
            &env.store,
            &cfg.mapper_state_table,
            cfg.scope_label.clone(),
        )?;
    }
    if let Some(cold) = &cfg.cold_tier {
        // Compact-on-trim writes manifest + payload rows inside the trim
        // CAS; the tables must exist before the first mapper commit.
        crate::coldtier::ColdStore::from_config(env.store.clone(), cold)
            .ensure_tables(cfg.scope_label.clone())
            .map_err(|e| e.to_string())?;
    }

    let mut txn = env.store.begin();
    for index in 0..cfg.mapper_count {
        let existing = txn
            .lookup(&cfg.mapper_state_table, &MapperState::key(index))
            .map_err(|e| e.to_string())?;
        if existing.is_none() {
            txn.write(
                &cfg.mapper_state_table,
                MapperState::initial().to_row(index),
            )
            .map_err(|e| e.to_string())?;
        }
    }
    for index in 0..cfg.reducer_count {
        let existing = txn
            .lookup(&cfg.reducer_state_table, &ReducerState::key(index))
            .map_err(|e| e.to_string())?;
        if existing.is_none() {
            txn.write(
                &cfg.reducer_state_table,
                ReducerState::initial(cfg.mapper_count).to_row(index),
            )
            .map_err(|e| e.to_string())?;
        }
    }
    let plan_existing = txn
        .lookup(&cfg.reshard_plan_table, &ReshardPlan::key())
        .map_err(|e| e.to_string())?;
    if plan_existing.is_none() {
        txn.write(
            &cfg.reshard_plan_table,
            ReshardPlan::initial(cfg.reducer_count).to_row(),
        )
        .map_err(|e| e.to_string())?;
    }
    txn.commit().map_err(|e| e.to_string())?;
    Ok(())
}
