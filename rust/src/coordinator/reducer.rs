//! The reducer worker (§4.4): pull rows from every mapper, run the user's
//! Reduce, commit effects + meta-state atomically (exactly-once).
//!
//! The main procedure is factored into three phases — **fetch**,
//! **process**, **commit** — matching the §6 pipelining proposal ("a
//! single cycle of the reducer's main procedure can be subdivided into
//! three consecutive stages: fetch, process … and commit"). The serial
//! loop here runs them back-to-back; [`crate::pipelined`] overlaps
//! fetch(n+1) with process/commit(n).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::api::{Client, Reducer, ReducerSpec};
use crate::consistency::{AnchorScheduler, Consistency};
use crate::coordinator::config::ProcessorConfig;
use crate::coordinator::state::{MapperState, ReducerState};
use crate::cypress::{DiscoveryGroup, MemberInfo, SessionId};
use crate::dyntable::{Transaction, TxnError};
use crate::metrics::hub::names;
use crate::metrics::MetricsHub;
use crate::obs::{self, SpanOutcome, TxnSpan, WorkerId};
use crate::reshard::migration::{ExportCtx, ImportCtx, ReshardRuntime};
use crate::reshard::plan::{PlanPhase, ReshardPlan};
use crate::rows::{codec, UnversionedRowset, Value};
use crate::rpc::{ReqGetRows, Request, Response, RpcNet, RspGetRows};
use crate::storage::accounting::CATEGORY_COUNT;
use crate::util::Guid;

/// Dependencies handed to a reducer instance at spawn.
pub struct ReducerDeps {
    pub client: Client,
    pub net: Arc<RpcNet>,
    pub metrics: Arc<MetricsHub>,
    /// Where mappers register (to resolve addresses, §4.4.2 step 3).
    pub mapper_discovery: DiscoveryGroup,
    /// Where this reducer registers itself.
    pub reducer_discovery: DiscoveryGroup,
    /// The processor's shared reshard runtime: plan table, migration
    /// handoffs, residual exporter/importer.
    pub reshard: Arc<ReshardRuntime>,
}

/// Control handle for one running reducer instance.
pub struct ReducerHandle {
    pub index: usize,
    pub guid: Guid,
    pub address: String,
    kill: Arc<AtomicBool>,
    pause: Arc<AtomicBool>,
    join: std::thread::JoinHandle<()>,
}

impl ReducerHandle {
    pub fn set_paused(&self, paused: bool) {
        self.pause.store(paused, Ordering::SeqCst);
    }

    pub fn kill(&self) {
        self.kill.store(true, Ordering::SeqCst);
    }

    pub fn is_finished(&self) -> bool {
        self.join.is_finished()
    }

    pub fn join(self) {
        let _ = self.join.join();
    }
}

/// One mapper's contribution to a reducer cycle.
pub(crate) struct FetchResult {
    pub mapper_index: usize,
    pub rsp: RspGetRows,
}

/// Outcome of the process+commit phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum CommitOutcome {
    /// State advanced; effects applied exactly once.
    Committed { rows: i64, bytes: usize },
    /// A twin changed the state under us (§4.4.2 step 7).
    SplitBrain,
    /// OCC conflict at commit time.
    Conflict,
    /// Nothing to process this cycle.
    Nothing,
    /// Transient error (store down, decode failure); retry next cycle.
    TransientError,
}

/// Spawn a reducer instance running the serial main procedure (§4.4.2),
/// or the §6 pipelined variant when `cfg` asks for it.
pub fn spawn_reducer(
    cfg: ProcessorConfig,
    spec: ReducerSpec,
    deps: ReducerDeps,
    mut user_reducer: Box<dyn Reducer>,
) -> ReducerHandle {
    let kill = Arc::new(AtomicBool::new(false));
    let pause = Arc::new(AtomicBool::new(false));
    let address = format!("reducer-{}/{}", spec.index, spec.guid);
    let index = spec.index;
    let guid = spec.guid;

    let join = std::thread::Builder::new()
        .name(format!("reducer-{}", spec.index))
        .spawn({
            let kill = kill.clone();
            let pause = pause.clone();
            let address = address.clone();
            move || {
                let rt = ReducerRt {
                    cfg,
                    spec,
                    deps,
                    address,
                };
                // Approximate tiers run the serial loop: their commit
                // acknowledgement lives in this incarnation's memory, and
                // the pipelined overlap's resync-on-miss would discard it.
                if rt.cfg.pipelined_reducer && rt.cfg.consistency.is_exactly_once() {
                    crate::pipelined::run_reducer_pipelined(&rt, user_reducer.as_mut(), &kill, &pause);
                } else {
                    run_reducer_serial(&rt, user_reducer.as_mut(), &kill, &pause);
                }
            }
        })
        // protolint: allow(panic, "thread spawn fails only on OS resource exhaustion at worker startup; there is no protocol state yet to corrupt")
        .expect("spawn reducer thread");

    ReducerHandle {
        index,
        guid,
        address,
        kill,
        pause,
        join,
    }
}

/// Everything a reducer loop needs (shared by serial and pipelined).
pub(crate) struct ReducerRt {
    pub cfg: ProcessorConfig,
    pub spec: ReducerSpec,
    pub deps: ReducerDeps,
    pub address: String,
}

impl ReducerRt {
    /// Join the reducer discovery group, waiting out a live predecessor.
    /// Keys are epoch-qualified so a reshard's new fleet can register
    /// beside the draining old one.
    pub(crate) fn join_discovery(&self, kill: &AtomicBool) -> Option<SessionId> {
        let clock = &self.deps.client.clock;
        let session = self
            .deps
            .client
            .cypress
            .open_session(self.cfg.session_ttl_ms);
        loop {
            if kill.load(Ordering::SeqCst) {
                return None;
            }
            match self.deps.reducer_discovery.join(
                session,
                &format!("e{}-reducer-{}", self.spec.epoch, self.spec.index),
                &self.address,
                self.spec.index as i64,
                self.spec.guid,
            ) {
                Ok(()) => return Some(session),
                Err(_) => clock.sleep_ms(self.cfg.backoff_ms),
            }
        }
    }

    /// Plain (non-transactional) read of the reshard plan.
    pub(crate) fn fetch_plan(&self) -> Option<ReshardPlan> {
        ReshardPlan::fetch(&self.deps.client.store, &self.deps.reshard.plan_table)
    }

    pub(crate) fn heartbeat_if_due(&self, session: SessionId, last: &mut u64) {
        let now = self.deps.client.clock.now_ms();
        if now.saturating_sub(*last) >= self.cfg.heartbeat_period_ms {
            let _ = self.deps.client.cypress.heartbeat(session);
            *last = now;
        }
    }

    /// Step 2: fetch (or lazily create) the persistent state. A reducer
    /// born by a reshard (epoch > 0) starts un-bootstrapped: it must
    /// import its migration tablet before serving.
    pub(crate) fn fetch_state(&self) -> Option<ReducerState> {
        let key = ReducerState::key(self.spec.index);
        match self
            .deps
            .client
            .store
            .lookup(&self.spec.state_table, &key)
        {
            Ok(Some(row)) => ReducerState::from_row(&row),
            Ok(None) => {
                // Create the row CAS-on-absence: the transactional lookup
                // records the absent key (version 0) in the read set, so a
                // twin that created the row first makes this commit conflict
                // instead of being silently reset to the initial state.
                let mut txn = self.deps.client.begin();
                let Ok(None) = txn.lookup(&self.spec.state_table, &key) else {
                    return None; // raced a twin (or store error): refetch
                };
                let init = if self.spec.epoch > 0 {
                    ReducerState::initial_migrating(self.spec.num_mappers)
                } else {
                    ReducerState::initial(self.spec.num_mappers)
                };
                if txn
                    .write(&self.spec.state_table, init.to_row(self.spec.index))
                    .is_ok()
                    && txn.commit().is_ok()
                {
                    Some(init)
                } else {
                    None
                }
            }
            Err(_) => None,
        }
    }

    /// Step 3: one parallel GetRows per mapper index.
    pub(crate) fn fetch_cycle(&self, state: &ReducerState, cycle: u64) -> Vec<FetchResult> {
        let members = match self.deps.mapper_discovery.list() {
            Ok(m) => m,
            Err(_) => return Vec::new(),
        };
        fetch_from_mappers(
            &self.cfg,
            &self.spec,
            &self.deps.net,
            &self.address,
            &members,
            state,
            cycle,
        )
    }

    /// Step 4: the tentative new state + total fetched rows. The committed
    /// vector grows on demand — a resharded intermediate stage can gain
    /// mapper indexes mid-life (downstream re-wiring), and a fresh index
    /// simply starts from -1.
    pub(crate) fn tentative_state(
        &self,
        state: &ReducerState,
        fetches: &[FetchResult],
    ) -> (ReducerState, i64) {
        let mut new_state = state.clone();
        let mut total = 0;
        for f in fetches {
            if f.rsp.row_count > 0 {
                if new_state.committed_row_indices.len() <= f.mapper_index {
                    new_state.committed_row_indices.resize(f.mapper_index + 1, -1);
                }
                new_state.committed_row_indices[f.mapper_index] = f.rsp.last_shuffle_row_index;
                total += f.rsp.row_count;
            }
        }
        (new_state, total)
    }

    /// Record a flight-recorder span for one commit-spine attempt.
    /// Called strictly *after* the transaction's outcome is known — the
    /// recorder never joins the CAS read set, so recording cannot
    /// change any commit result. Call sites gate on
    /// `recorder().enabled()` so the disabled path stays one atomic
    /// load per transaction.
    pub(crate) fn record_span(
        &self,
        scope: &str,
        trace_id: u64,
        read_set: usize,
        outcome: SpanOutcome,
        bytes_by_category: [u64; CATEGORY_COUNT],
        start_ms: u64,
    ) {
        self.deps.metrics.recorder().record(TxnSpan {
            txn_id: 0,
            trace_id,
            worker: WorkerId::reducer(self.spec.index, &self.spec.guid.to_string()),
            scope: scope.to_string(),
            read_set,
            outcome,
            bytes_by_category,
            start_ms,
            end_ms: self.deps.client.clock.now_ms(),
        });
    }

    /// Steps 5–8: decode, combine, run the user Reduce, validate the state
    /// within the transaction and commit atomically.
    ///
    /// `persist` gates step 8 only ([`crate::consistency`]): an
    /// approximate tier's non-anchor commit applies the user effects and
    /// the fences but leaves the durable state row untouched — the
    /// fetched-row acknowledgement lives in the incarnation's memory (its
    /// bounded-drift exposure). The state row still joins the read set in
    /// step 7, so a rival incarnation's anchor serializes against this
    /// commit exactly as under exactly-once.
    pub(crate) fn process_and_commit(
        &self,
        user_reducer: &mut dyn Reducer,
        state: &ReducerState,
        new_state: &ReducerState,
        fetches: &[FetchResult],
        persist: bool,
    ) -> CommitOutcome {
        let client = &self.deps.client;
        let state_table = &self.spec.state_table;
        let state_key = ReducerState::key(self.spec.index);

        // Step 5: deserialize and combine into one batch. Attachments are
        // Arc'd, so the decode is zero-copy: string cells are views into
        // the attachment buffers, and the combine below moves rows without
        // touching payload bytes.
        let mut parts = Vec::new();
        let mut total_rows = 0i64;
        for f in fetches {
            if f.rsp.row_count > 0 {
                match codec::decode_rowset_shared(&f.rsp.attachment) {
                    Ok(rs) => {
                        total_rows += rs.len() as i64;
                        parts.push(rs);
                    }
                    Err(_) => return CommitOutcome::TransientError,
                }
            }
        }
        let Some(combined) = UnversionedRowset::concat_owned(parts) else {
            return CommitOutcome::Nothing;
        };
        let combined_bytes = combined.byte_size();
        let batch_ts = max_ts_of(&combined);

        // Flight recorder: one span per transaction attempt from here on
        // (a txn exists past this point). The trace id hashes the
        // shuffle row ranges this attempt covers, so the mapper trim
        // that later retires these rows carries a joinable id.
        let obs_on = self.deps.metrics.recorder().enabled();
        let (span_start, span_trace) = if obs_on {
            let ranges: Vec<(usize, i64, i64)> = fetches
                .iter()
                .filter(|f| f.rsp.row_count > 0)
                .map(|f| {
                    (
                        f.mapper_index,
                        f.rsp.last_shuffle_row_index - f.rsp.row_count,
                        f.rsp.last_shuffle_row_index,
                    )
                })
                .collect();
            (client.clock.now_ms(), obs::trace_id(&ranges))
        } else {
            (0, 0)
        };

        // Step 6: user Reduce, taking over its transaction if it opened
        // one.
        let mut txn = match user_reducer.reduce(combined) {
            Some(t) => t,
            None => client.begin(),
        };

        // Steps 7 + 7b, group-committed: the split-brain state CAS and the
        // reshard plan fence are *one* batched transactional read
        // ([`Transaction::lookup_many`]) — one pass under the store lock
        // instead of a round trip per row. The recorded versions and the
        // conflict semantics are identical to the former per-row lookups.
        let meta = match txn.lookup_many(&[
            (state_table.as_str(), state_key.clone()),
            (self.deps.reshard.plan_table.as_str(), ReshardPlan::key()),
        ]) {
            Ok(rows) => rows,
            Err(_) => {
                let rs = txn.read_set_len();
                txn.abort();
                if obs_on {
                    self.record_span(
                        "reduce",
                        span_trace,
                        rs,
                        SpanOutcome::Error,
                        [0; CATEGORY_COUNT],
                        span_start,
                    );
                }
                return CommitOutcome::TransientError;
            }
        };

        // Step 7: split-brain check inside the transaction.
        let in_txn = meta[0].as_ref().and_then(ReducerState::from_row);
        if in_txn.as_ref() != Some(state) {
            self.deps.metrics.add(names::REDUCER_SPLIT_BRAIN, 1);
            let rs = txn.read_set_len();
            txn.abort();
            if obs_on {
                self.record_span(
                    "reduce",
                    span_trace,
                    rs,
                    SpanOutcome::Abdicated,
                    [0; CATEGORY_COUNT],
                    span_start,
                );
            }
            return CommitOutcome::SplitBrain;
        }

        // Step 7b: reshard fencing, also inside the transaction. The plan
        // row joins the read set of *every* commit (so a reshard starting
        // or finalizing mid-commit conflicts us into a retry), and while a
        // migration is in flight an old-epoch reducer additionally
        // validates each contributing mapper's cutover: a row at or past
        // it belongs to the new epoch — it can only have been served by a
        // stale twin that had not adopted yet — and committing it here
        // would double it against the new fleet. Adoption writes the
        // mapper state row this fence reads, so the two serialize. The
        // cutover rows of every contributing mapper are validated in a
        // second single-pass batch (they must *not* join the read set
        // outside a migration, so they cannot ride the first one).
        let plan = meta[1].as_ref().and_then(ReshardPlan::from_row);
        let Some(plan) = plan else {
            let rs = txn.read_set_len();
            txn.abort();
            if obs_on {
                self.record_span(
                    "reduce",
                    span_trace,
                    rs,
                    SpanOutcome::Error,
                    [0; CATEGORY_COUNT],
                    span_start,
                );
            }
            return CommitOutcome::TransientError;
        };
        let fence_ok = match plan.phase {
            PlanPhase::Stable => plan.epoch == self.spec.epoch,
            PlanPhase::Migrating if self.spec.epoch == plan.next_epoch() => true,
            PlanPhase::Migrating if self.spec.epoch == plan.epoch => {
                let contributing: Vec<&FetchResult> =
                    fetches.iter().filter(|f| f.rsp.row_count > 0).collect();
                let reads: Vec<(&str, Vec<Value>)> = contributing
                    .iter()
                    .map(|f| {
                        (
                            self.cfg.mapper_state_table.as_str(),
                            MapperState::key(f.mapper_index),
                        )
                    })
                    .collect();
                match txn.lookup_many(&reads) {
                    Ok(rows) => contributing.iter().zip(&rows).all(|(f, row)| {
                        match row.as_ref().and_then(MapperState::from_row) {
                            Some(ms) => {
                                ms.epoch <= self.spec.epoch
                                    || f.rsp.last_shuffle_row_index < ms.cutover_index
                            }
                            None => true,
                        }
                    }),
                    Err(_) => false,
                }
            }
            PlanPhase::Migrating => false, // zombie of an already-drained epoch
        };
        if !fence_ok {
            self.deps.metrics.add(names::RESHARD_COMMIT_FENCED, 1);
            let rs = txn.read_set_len();
            txn.abort();
            if obs_on {
                self.record_span(
                    "reduce",
                    span_trace,
                    rs,
                    SpanOutcome::Abdicated,
                    [0; CATEGORY_COUNT],
                    span_start,
                );
            }
            return CommitOutcome::TransientError;
        }

        // Step 8: write the new state; commit everything atomically.
        if persist {
            if txn
                .write(state_table, new_state.to_row(self.spec.index))
                .is_err()
            {
                return CommitOutcome::TransientError;
            }
        }
        let read_set = txn.read_set_len();
        match txn.commit() {
            Ok(res) => {
                if let Some(ts) = batch_ts {
                    let now = client.clock.now_ms();
                    self.deps.metrics.record_latency(
                        &names::reducer_commit_latency(self.spec.index),
                        now,
                        (now as i64 - ts).max(0) as f64,
                    );
                }
                if obs_on {
                    self.record_span(
                        "reduce",
                        span_trace,
                        read_set,
                        SpanOutcome::Committed,
                        res.bytes_by_category,
                        span_start,
                    );
                }
                CommitOutcome::Committed {
                    rows: total_rows,
                    bytes: combined_bytes,
                }
            }
            Err(TxnError::Conflict { table, key, .. }) => {
                self.deps.metrics.add(names::REDUCER_COMMIT_CONFLICTS, 1);
                if obs_on {
                    self.record_span(
                        "reduce",
                        span_trace,
                        read_set,
                        SpanOutcome::Conflicted {
                            losing_row: format!("{table}/{key:?}"),
                        },
                        [0; CATEGORY_COUNT],
                        span_start,
                    );
                }
                CommitOutcome::Conflict
            }
            Err(_) => {
                if obs_on {
                    self.record_span(
                        "reduce",
                        span_trace,
                        read_set,
                        SpanOutcome::Error,
                        [0; CATEGORY_COUNT],
                        span_start,
                    );
                }
                CommitOutcome::TransientError
            }
        }
    }

    /// Is this reducer's epoch fully drained on every *live* mapper?
    /// Requires a `drained` response (empty, flag set) from every known
    /// mapper index in this cycle's fetch results. "Known" is the max of
    /// the spec, the live discovery listing, and `min_mappers` — the
    /// caller's high-water mark of indexes ever fetched from, so a
    /// grown-fleet mapper whose discovery session lapsed (crash + TTL
    /// expiry) cannot silently drop out of the retirement gate while it
    /// may still hold undrained rows.
    ///
    /// Indexes whose mapper state row carries the `retired` flag are
    /// excluded: a decommissioned slot (e.g. a downstream fleet shrunk
    /// after an upstream reshard) was only retired once its partition
    /// drained for good, so it can hold no rows for any epoch — and it
    /// will never answer a fetch again, so gating on the historical
    /// high-water mark would deadlock every later reshard of this stage.
    /// Returns the retired index set on success so the retirement
    /// transaction can re-validate it (a racing revival must conflict).
    pub(crate) fn ready_to_retire(
        &self,
        fetches: &[FetchResult],
        min_mappers: usize,
    ) -> Option<Vec<usize>> {
        let members = self.deps.mapper_discovery.list().ok()?;
        let n = members
            .iter()
            .map(|m| m.index + 1)
            .fold(self.spec.num_mappers.max(min_mappers) as i64, i64::max)
            .max(0) as usize;
        if n == 0 {
            return None;
        }
        let mut dead = Vec::new();
        let mut drained = vec![false; n];
        for index in 0..n {
            let state = self
                .deps
                .client
                .store
                .lookup(&self.cfg.mapper_state_table, &MapperState::key(index))
                .ok()?
                .as_ref()
                .and_then(MapperState::from_row);
            if state.is_some_and(|s| s.retired) {
                dead.push(index);
                drained[index] = true;
            }
        }
        for f in fetches {
            if f.rsp.drained && f.rsp.row_count == 0 && f.mapper_index < n {
                drained[f.mapper_index] = true;
            }
        }
        drained.iter().all(|&d| d).then_some(dead)
    }

    /// The retirement transaction: CAS this reducer's state row to
    /// retired and `append_ordered` its residual state into the migration
    /// handoff table, atomically. `dead_mappers` is the retired index set
    /// the drain gate observed — each row joins the read set, so a mapper
    /// slot revived between the gate and this commit conflicts us into a
    /// re-check instead of retiring against rows that may reappear.
    /// Returns true when this instance won the retirement (it must then
    /// exit).
    pub(crate) fn try_retire(
        &self,
        state: &ReducerState,
        plan: &ReshardPlan,
        dead_mappers: &[usize],
    ) -> bool {
        if plan.phase != PlanPhase::Migrating || plan.epoch != self.spec.epoch {
            return false;
        }
        let mig = self
            .deps
            .reshard
            .migration_for(plan.next_epoch(), plan.next_partitions);
        let mut txn = self.deps.client.begin();
        // The migration we observed must still be the live one.
        match txn.lookup(&self.deps.reshard.plan_table, &ReshardPlan::key()) {
            Ok(Some(row)) if ReshardPlan::from_row(&row).as_ref() == Some(plan) => {}
            _ => return false,
        }
        // Every mapper the drain gate skipped must still be retired.
        for &index in dead_mappers {
            match txn.lookup(&self.cfg.mapper_state_table, &MapperState::key(index)) {
                Ok(Some(row))
                    if MapperState::from_row(&row).is_some_and(|s| s.retired) => {}
                _ => return false,
            }
        }
        // CAS base: our state must be exactly what we drained against.
        match txn.lookup(&self.spec.state_table, &ReducerState::key(self.spec.index)) {
            Ok(Some(row)) if ReducerState::from_row(&row).as_ref() == Some(state) => {}
            _ => return false,
        }
        let mut retired = state.clone();
        retired.retired = true;
        if txn
            .write(&self.spec.state_table, retired.to_row(self.spec.index))
            .is_err()
        {
            return false;
        }
        let ctx = ExportCtx {
            old_index: self.spec.index,
            old_partitions: plan.partitions,
            new_partitions: plan.next_partitions,
            new_epoch: plan.next_epoch(),
            state: state.clone(),
        };
        let exports = match self.deps.reshard.exporter.export(&ctx, &mut txn) {
            Ok(e) => e,
            Err(_) => return false,
        };
        for (tablet, rows) in exports {
            if txn.append_ordered(mig.clone(), tablet, rows).is_err() {
                return false;
            }
        }
        let obs_on = self.deps.metrics.recorder().enabled();
        let span_start = if obs_on {
            self.deps.client.clock.now_ms()
        } else {
            0
        };
        let read_set = txn.read_set_len();
        match txn.commit() {
            Ok(res) => {
                self.deps.metrics.add(names::RESHARD_RETIRED, 1);
                if obs_on {
                    self.record_span(
                        "retire",
                        0,
                        read_set,
                        SpanOutcome::Committed,
                        res.bytes_by_category,
                        span_start,
                    );
                }
                true
            }
            Err(e) => {
                if obs_on {
                    let outcome = match e {
                        TxnError::Conflict { table, key, .. } => SpanOutcome::Conflicted {
                            losing_row: format!("{table}/{key:?}"),
                        },
                        _ => SpanOutcome::Error,
                    };
                    self.record_span(
                        "retire",
                        0,
                        read_set,
                        outcome,
                        [0; CATEGORY_COUNT],
                        span_start,
                    );
                }
                false
            }
        }
    }

    /// The bootstrap transaction of a resharded-in reducer: once our
    /// epoch is the plan's authoritative one (⇒ the migration that bred
    /// us finalized ⇒ every exporter committed), consume our migration
    /// tablet and CAS-mark ourselves bootstrapped. This stays true when a
    /// *further* migration is already draining us away (`Migrating` with
    /// `plan.epoch == ours`) — a late bootstrapper must still import and
    /// serve, or its buckets could never drain. Returns true when this
    /// instance performed the import.
    pub(crate) fn try_bootstrap(&self, state: &ReducerState) -> bool {
        let Some(plan) = self.fetch_plan() else {
            return false;
        };
        if plan.epoch != self.spec.epoch {
            return false; // the migration breeding us has not finalized yet
        }
        let mig = self.deps.reshard.migration_for(self.spec.epoch, plan.partitions);
        if self.spec.index >= mig.tablet_count() {
            return false;
        }
        let end = mig.end_index(self.spec.index);
        let rows = match mig.read_tablet(self.spec.index, 0, end) {
            Ok(r) => r,
            Err(_) => return false,
        };
        let mut txn = self.deps.client.begin();
        match txn.lookup(&self.spec.state_table, &ReducerState::key(self.spec.index)) {
            Ok(Some(row)) if ReducerState::from_row(&row).as_ref() == Some(state) => {}
            _ => return false, // a twin already imported; refetch next cycle
        }
        let ctx = ImportCtx {
            new_index: self.spec.index,
            new_partitions: plan.partitions,
            epoch: self.spec.epoch,
        };
        if self.deps.reshard.importer.import(&ctx, &rows, &mut txn).is_err() {
            return false;
        }
        let mut s = state.clone();
        s.bootstrapped = true;
        if txn
            .write(&self.spec.state_table, s.to_row(self.spec.index))
            .is_err()
        {
            return false;
        }
        let obs_on = self.deps.metrics.recorder().enabled();
        let span_start = if obs_on {
            self.deps.client.clock.now_ms()
        } else {
            0
        };
        let read_set = txn.read_set_len();
        // The tablet range the bootstrap consumed, keyed by our index.
        let span_trace = if obs_on {
            obs::trace_id(&[(self.spec.index, 0, end)])
        } else {
            0
        };
        match txn.commit() {
            Ok(res) => {
                self.deps.metrics.add(names::RESHARD_BOOTSTRAPPED, 1);
                if obs_on {
                    self.record_span(
                        "bootstrap",
                        span_trace,
                        read_set,
                        SpanOutcome::Committed,
                        res.bytes_by_category,
                        span_start,
                    );
                }
                true
            }
            Err(e) => {
                if obs_on {
                    let outcome = match e {
                        TxnError::Conflict { table, key, .. } => SpanOutcome::Conflicted {
                            losing_row: format!("{table}/{key:?}"),
                        },
                        _ => SpanOutcome::Error,
                    };
                    self.record_span(
                        "bootstrap",
                        span_trace,
                        read_set,
                        outcome,
                        [0; CATEGORY_COUNT],
                        span_start,
                    );
                }
                false
            }
        }
    }

    /// Commit a time-driven (row-less) transaction from
    /// [`Reducer::tick`] under the full exactly-once protocol: the
    /// split-brain CAS (step 7), the reshard plan fence (step 7b — with
    /// no fetched rows the per-mapper cutover checks are vacuous), and a
    /// rewrite of the state row so racing twins serialize on its version
    /// exactly like a normal commit. Exactly-once passes the same state
    /// for `state` and `new_state` (a rewrite of the unchanged row); an
    /// approximate tier passes its working state as `new_state`, making
    /// every tick commit an anchor — the tick's user effects (e.g. window
    /// fires) then can never outrun the durable row-index frontier.
    pub(crate) fn commit_tick(
        &self,
        state: &ReducerState,
        new_state: &ReducerState,
        mut txn: Transaction,
    ) -> CommitOutcome {
        let state_table = &self.spec.state_table;
        let state_key = ReducerState::key(self.spec.index);
        let obs_on = self.deps.metrics.recorder().enabled();
        let span_start = if obs_on {
            self.deps.client.clock.now_ms()
        } else {
            0
        };

        // Same batched steps-7+7b read as `process_and_commit`: state CAS
        // and plan fence join the read set in one locked pass.
        let meta = match txn.lookup_many(&[
            (state_table.as_str(), state_key.clone()),
            (self.deps.reshard.plan_table.as_str(), ReshardPlan::key()),
        ]) {
            Ok(rows) => rows,
            Err(_) => {
                let rs = txn.read_set_len();
                txn.abort();
                if obs_on {
                    self.record_span(
                        "tick",
                        0,
                        rs,
                        SpanOutcome::Error,
                        [0; CATEGORY_COUNT],
                        span_start,
                    );
                }
                return CommitOutcome::TransientError;
            }
        };
        let in_txn = meta[0].as_ref().and_then(ReducerState::from_row);
        if in_txn.as_ref() != Some(state) {
            self.deps.metrics.add(names::REDUCER_SPLIT_BRAIN, 1);
            let rs = txn.read_set_len();
            txn.abort();
            if obs_on {
                self.record_span(
                    "tick",
                    0,
                    rs,
                    SpanOutcome::Abdicated,
                    [0; CATEGORY_COUNT],
                    span_start,
                );
            }
            return CommitOutcome::SplitBrain;
        }
        let Some(plan) = meta[1].as_ref().and_then(ReshardPlan::from_row) else {
            let rs = txn.read_set_len();
            txn.abort();
            if obs_on {
                self.record_span(
                    "tick",
                    0,
                    rs,
                    SpanOutcome::Error,
                    [0; CATEGORY_COUNT],
                    span_start,
                );
            }
            return CommitOutcome::TransientError;
        };
        let fence_ok = match plan.phase {
            PlanPhase::Stable => plan.epoch == self.spec.epoch,
            PlanPhase::Migrating => {
                self.spec.epoch == plan.next_epoch() || self.spec.epoch == plan.epoch
            }
        };
        if !fence_ok {
            self.deps.metrics.add(names::RESHARD_COMMIT_FENCED, 1);
            let rs = txn.read_set_len();
            txn.abort();
            if obs_on {
                self.record_span(
                    "tick",
                    0,
                    rs,
                    SpanOutcome::Abdicated,
                    [0; CATEGORY_COUNT],
                    span_start,
                );
            }
            return CommitOutcome::TransientError;
        }
        if txn
            .write(state_table, new_state.to_row(self.spec.index))
            .is_err()
        {
            return CommitOutcome::TransientError;
        }
        let read_set = txn.read_set_len();
        match txn.commit() {
            Ok(res) => {
                self.deps.metrics.add(names::REDUCER_COMMITS, 1);
                if obs_on {
                    self.record_span(
                        "tick",
                        0,
                        read_set,
                        SpanOutcome::Committed,
                        res.bytes_by_category,
                        span_start,
                    );
                }
                CommitOutcome::Committed { rows: 0, bytes: 0 }
            }
            Err(TxnError::Conflict { table, key, .. }) => {
                self.deps.metrics.add(names::REDUCER_COMMIT_CONFLICTS, 1);
                if obs_on {
                    self.record_span(
                        "tick",
                        0,
                        read_set,
                        SpanOutcome::Conflicted {
                            losing_row: format!("{table}/{key:?}"),
                        },
                        [0; CATEGORY_COUNT],
                        span_start,
                    );
                }
                CommitOutcome::Conflict
            }
            Err(_) => {
                if obs_on {
                    self.record_span(
                        "tick",
                        0,
                        read_set,
                        SpanOutcome::Error,
                        [0; CATEGORY_COUNT],
                        span_start,
                    );
                }
                CommitOutcome::TransientError
            }
        }
    }

    /// Record post-commit metrics; returns the new `last_commit_ms`.
    pub(crate) fn record_commit(&self, rows: i64, bytes: usize, last_commit_ms: u64) -> u64 {
        let now = self.deps.client.clock.now_ms();
        let dt_s = ((now - last_commit_ms).max(1)) as f64 / 1000.0;
        self.deps
            .metrics
            .series(&names::reducer_throughput(self.spec.index))
            .record(now, bytes as f64 / dt_s);
        self.deps.metrics.add(names::REDUCER_ROWS, rows as u64);
        self.deps.metrics.add(names::REDUCER_BYTES, bytes as u64);
        self.deps.metrics.add(names::REDUCER_COMMITS, 1);
        now
    }
}

/// Newest producer/mapper timestamp in a combined batch (commit-latency
/// metric); looks for a `ts` or `write_ts_ms` column.
fn max_ts_of(rs: &UnversionedRowset) -> Option<i64> {
    let col = rs
        .name_table()
        .id("write_ts_ms")
        .or_else(|| rs.name_table().id("ts"))?;
    rs.rows()
        .iter()
        .filter_map(|r| r.get(col).and_then(|v| v.as_i64()))
        .max()
}

/// The serial main procedure (§4.4.2 steps 1–8), for every consistency
/// tier ([`crate::consistency`]).
///
/// Exactly-once re-adopts the durable state row each cycle and persists
/// on every commit — the seed behavior, unchanged. Approximate tiers keep
/// an in-memory *working* state driving fetch offsets (acknowledgement
/// reaches mappers through the normal fetch protocol), remember the
/// durable row they last observed or wrote (the commit CAS base), and:
///
/// * persist only at scheduler-chosen anchors (`BoundedError`) or never
///   in steady state (`AtMostOnce`);
/// * recover from the last anchor on restart — the first `fetch_state` of
///   an incarnation adopts the durable row, replaying (`BoundedError`) or
///   discarding (`AtMostOnce`) the unanchored window;
/// * **abdicate** — exit the loop — when the durable row moves under a
///   live incarnation or a commit trips the split-brain CAS: a rival
///   incarnation anchored past us. The supervisor respawns incumbents but
///   never `duplicate` twins, so split-brain contention collapses to a
///   single instance within about one anchor window, instead of both
///   twins committing the same bucket-head rows indefinitely.
fn run_reducer_serial(
    rt: &ReducerRt,
    user_reducer: &mut dyn Reducer,
    kill: &AtomicBool,
    pause: &AtomicBool,
) {
    let clock = rt.deps.client.clock.clone();
    let Some(session) = rt.join_discovery(kill) else {
        return;
    };
    let policy = rt.cfg.consistency;
    let mut anchors = AnchorScheduler::new(policy);
    // (working, base): the in-memory committed frontier and the durable
    // row it grew from. `None` until the incarnation's first adoption.
    // Updated only on successful commits — a failed attempt must leave
    // the frontier at its last committed value or unacknowledged rows
    // would be popped by the next fetch.
    let mut resident: Option<(ReducerState, ReducerState)> = None;
    // At-most-once: the first non-empty fetch round of an incarnation is
    // the predecessor's in-flight window — adopted, never processed.
    let mut discarded_inflight = !matches!(policy, Consistency::AtMostOnce);
    let mut last_commit_ms = clock.now_ms();
    let mut last_heartbeat_ms = clock.now_ms();
    let mut last_cycle_committed = true;
    let mut cycle: u64 = 0;
    // Highest mapper index (+1) this instance has ever fetched from —
    // floors the retirement gate against discovery-listing gaps.
    let mut max_mapper_seen = rt.spec.num_mappers;

    while !kill.load(Ordering::SeqCst) {
        if pause.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
            continue;
        }
        rt.heartbeat_if_due(session, &mut last_heartbeat_ms);
        cycle += 1;

        // Step 1: back-off unless the previous cycle committed.
        if !last_cycle_committed {
            clock.sleep_ms(rt.cfg.backoff_ms);
        }
        last_cycle_committed = false;

        // Step 2.
        let Some(durable) = rt.fetch_state() else {
            continue;
        };
        if durable.retired {
            return; // this epoch was resharded away; the slot is done
        }
        if !durable.bootstrapped {
            // Born by a reshard: import the migration tablet before
            // serving the key range.
            rt.try_bootstrap(&durable);
            clock.sleep_ms(rt.cfg.backoff_ms);
            continue;
        }
        let (state, base) = match resident.take() {
            Some((w, b)) if b == durable => (w, b),
            Some(_) if policy.is_approximate() => {
                // The durable row moved under a live incarnation: a rival
                // anchored past us, and our unanchored in-memory frontier
                // lost. Resyncing would keep both twins committing the
                // same bucket-head rows between anchors — abdicate
                // instead; the supervisor restarts incumbents (never
                // twins), so exactly one instance survives.
                rt.deps.metrics.add(names::REDUCER_ABDICATIONS, 1);
                if rt.deps.metrics.recorder().enabled() {
                    let now = clock.now_ms();
                    rt.record_span(
                        "abdicate",
                        0,
                        0,
                        SpanOutcome::Abdicated,
                        [0; CATEGORY_COUNT],
                        now,
                    );
                }
                return;
            }
            // First adoption of this incarnation (for approximate tiers:
            // the recovery-from-anchor path), or exactly-once re-adopting
            // the durable row as it always has.
            _ => (durable.clone(), durable),
        };
        resident = Some((state.clone(), base.clone()));

        // Steps 3–4.
        let mut fetches = rt.fetch_cycle(&state, cycle);
        for f in &fetches {
            max_mapper_seen = max_mapper_seen.max(f.mapper_index + 1);
        }
        let (mut new_state, total_rows) = rt.tentative_state(&state, &fetches);
        if total_rows == 0 {
            // A drained old-epoch reducer retires: final transaction flips
            // its state to retired and exports its residual rows. The CAS
            // base (= the anchor, for approximate tiers) is what it drains
            // and exports against — rows past the anchor are the tier's
            // declared drift.
            if let Some(plan) = rt.fetch_plan() {
                if plan.phase == PlanPhase::Migrating && plan.epoch == rt.spec.epoch {
                    if let Some(dead) = rt.ready_to_retire(&fetches, max_mapper_seen) {
                        if rt.try_retire(&base, &plan, &dead) {
                            return;
                        }
                    }
                }
            }
            // Time-driven work on a quiet stream (e.g. final-firing
            // event-time windows): the user hook may hand back a
            // transaction, committed under the full exactly-once protocol.
            // The rewrite carries the working state, so for approximate
            // tiers every tick commit is an anchor.
            if let Some(txn) = user_reducer.tick() {
                match rt.commit_tick(&base, &state, txn) {
                    CommitOutcome::Committed { .. } => {
                        last_cycle_committed = true;
                        anchors.note_commit(true, 0);
                        resident = Some((state.clone(), state));
                    }
                    CommitOutcome::SplitBrain if policy.is_approximate() => {
                        rt.deps.metrics.add(names::REDUCER_ABDICATIONS, 1);
                        return;
                    }
                    _ => {}
                }
            }
            continue;
        }

        // At-most-once: adopt the first non-empty round's frontier without
        // processing it. The predecessor's in-flight window (rows served
        // but unacknowledged when it died) is dropped, never duplicated —
        // the tier's defining trade.
        if !discarded_inflight {
            discarded_inflight = true;
            rt.deps.metrics.add(names::REDUCER_DISCARD_ROUNDS, 1);
            resident = Some((new_state, base));
            last_cycle_committed = true; // fresh rows next cycle; no backoff
            continue;
        }

        // Group-commit coalescing: while the stream is backed up — the
        // previous round filled its `fetch_count` budget for some mapper,
        // so arrival rate is not the limiter — pull further rounds against
        // the *tentative* state (reads are side-effect-free; nothing is
        // acknowledged until the commit below) and fold them into one
        // atomic commit. One state CAS + plan fence + one `ReducerMeta`
        // journal record then covers every coalesced round. Later fetch
        // results for a mapper overwrite its tentative index, so the
        // committed state is exactly the last round's frontier.
        let full = |fs: &[FetchResult]| {
            fs.iter()
                .any(|f| f.rsp.row_count >= rt.cfg.fetch_count as i64)
        };
        let mut round_full = full(&fetches);
        let mut rounds = 1;
        while round_full && rounds < rt.cfg.commit_coalesce_max {
            let more = rt.fetch_cycle(&new_state, cycle);
            let (next_state, more_rows) = rt.tentative_state(&new_state, &more);
            if more_rows == 0 {
                break;
            }
            round_full = full(&more);
            new_state = next_state;
            fetches.extend(more);
            rounds += 1;
            rt.deps.metrics.add(names::REDUCER_COALESCED_ROUNDS, 1);
        }

        // Steps 5–8. The anchor scheduler decides whether this commit
        // carries the state write (always, under exactly-once).
        let batch_rows: i64 = fetches.iter().map(|f| f.rsp.row_count.max(0)).sum();
        let persist = anchors.should_persist(batch_rows.max(0) as u64);
        match rt.process_and_commit(user_reducer, &base, &new_state, &fetches, persist) {
            CommitOutcome::Committed { rows, bytes } => {
                anchors.note_commit(persist, rows.max(0) as u64);
                if policy.is_approximate() {
                    rt.deps.metrics.add(
                        if persist {
                            names::REDUCER_ANCHOR_COMMITS
                        } else {
                            names::REDUCER_SKIPPED_PERSISTS
                        },
                        1,
                    );
                }
                let next_base = if persist { new_state.clone() } else { base };
                resident = Some((new_state, next_base));
                last_cycle_committed = true;
                last_commit_ms = rt.record_commit(rows, bytes, last_commit_ms);
            }
            CommitOutcome::SplitBrain if policy.is_approximate() => {
                // A rival anchored between our step-2 read and the commit:
                // same abdication rule as the fetch-time detection above.
                rt.deps.metrics.add(names::REDUCER_ABDICATIONS, 1);
                return;
            }
            CommitOutcome::SplitBrain
            | CommitOutcome::Conflict
            | CommitOutcome::Nothing
            | CommitOutcome::TransientError => {}
        }
    }
}

/// Step 3's fan-out: one `GetRows` per mapper index, issued in parallel.
/// "If a mapper … returned an error or was missing in discovery and wasn't
/// polled, its entry is left unchanged." Split-brain twins both appear in
/// discovery under one index; we rotate between them across cycles so a
/// dead twin cannot starve the index forever.
pub(crate) fn fetch_from_mappers(
    cfg: &ProcessorConfig,
    spec: &ReducerSpec,
    net: &Arc<RpcNet>,
    reducer_address: &str,
    members: &[MemberInfo],
    state: &ReducerState,
    cycle: u64,
) -> Vec<FetchResult> {
    // Group members by mapper index. The index space can outgrow the spec
    // (downstream re-wiring after an upstream reshard), so size by what
    // discovery actually shows.
    let num_mappers = members
        .iter()
        .map(|m| m.index + 1)
        .fold(spec.num_mappers as i64, i64::max)
        .max(0) as usize;
    let mut by_index: Vec<Vec<&MemberInfo>> = vec![Vec::new(); num_mappers];
    for m in members {
        if (0..num_mappers as i64).contains(&m.index) {
            by_index[m.index as usize].push(m);
        }
    }

    let mut results: Vec<Option<FetchResult>> = Vec::with_capacity(num_mappers);
    for _ in 0..num_mappers {
        results.push(None);
    }

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (mapper_index, candidates) in by_index.iter().enumerate() {
            if candidates.is_empty() {
                continue;
            }
            // Only one request per mapper index per cycle (§4.4.2 step 3).
            let target = candidates[(cycle as usize) % candidates.len()];
            let committed = state
                .committed_row_indices
                .get(mapper_index)
                .copied()
                .unwrap_or(-1);
            let req = Request::GetRows(ReqGetRows {
                count: cfg.fetch_count as i64,
                reducer_index: spec.index as i64,
                epoch: spec.epoch,
                committed_row_index: committed,
                mapper_id: target.guid.to_string(),
            });
            let net = net.clone();
            let addr = target.address.clone();
            let src = reducer_address.to_string();
            handles.push((
                mapper_index,
                scope.spawn(move || net.call(&src, &addr, req)),
            ));
        }
        for (mapper_index, h) in handles {
            if let Ok(Ok(Response::GetRows(rsp))) = h.join().map_err(|_| ()) {
                results[mapper_index] = Some(FetchResult { mapper_index, rsp });
            }
        }
    });

    results.into_iter().flatten().collect()
}
