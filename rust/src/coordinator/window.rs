//! The mapper's in-memory row window (§4.3.1).
//!
//! "A queue of WindowEntry objects, which hold information about batches of
//! read and mapped rows. These entries are indexed sequentially within the
//! lifetime of the instance … Each window entry also stores a *bucket
//! pointer count*, which tallies the number of buckets for which this entry
//! holds the first row in their queue."
//!
//! This queue **is** the paper's write-amplification win: mapped rows live
//! here, in memory, until every designated reducer has committed them —
//! they are never persisted (unless the §6 spill feature evicts them).
//!
//! Row payloads are shared, not owned: string cells are
//! [`crate::rows::ByteStr`] views, so buffering a mapped batch here and
//! cloning rows out of it are refcount bumps, never payload copies.
//! (Serving and spilling still *encode*, which performs the one bulk copy
//! into the attachment/record buffer.) `total_bytes` tracks the *logical*
//! payload footprint used by the memory semaphore — a retained cell can
//! pin a larger shared backing buffer; long-lived sinks detach
//! ([`crate::rows::UnversionedRow::detached`]).

use std::collections::VecDeque;

use crate::queue::ContinuationToken;
use crate::rows::UnversionedRowset;
use crate::util::slab::Slab;

/// One mapped batch held in the window.
#[derive(Debug, Clone)]
pub struct WindowEntry {
    /// Absolute entry index within the mapper instance's lifetime.
    pub entry_index: u64,
    /// The mapped rows (output of the user's Map).
    pub rowset: UnversionedRowset,
    /// Input-numbering range [begin, end) this entry was mapped from.
    pub input_begin: i64,
    pub input_end: i64,
    /// Shuffle-numbering range [begin, end): `rowset.rows()[i]` has shuffle
    /// index `shuffle_begin + i`.
    pub shuffle_begin: i64,
    pub shuffle_end: i64,
    /// Continuation token *after* reading the input batch.
    pub continuation_token: ContinuationToken,
    /// Number of buckets whose first queued row lies in this entry.
    pub bucket_ptr_count: usize,
    /// Cached payload size (drives the memory semaphore).
    pub byte_size: usize,
    /// Simulated timestamp when the batch was read (metrics).
    pub read_ts_ms: u64,
    /// Smallest event time among this entry's mapped rows (`None` when
    /// event time is disabled or no row carried one). The mapper's
    /// watermark can never pass a retained entry's minimum — retained
    /// means some routed row was not yet committed by its reducer.
    pub min_event_ts: Option<i64>,
}

impl WindowEntry {
    /// Row with the given shuffle index, if it lies in this entry.
    pub fn row_at_shuffle_index(&self, shuffle_index: i64) -> Option<&crate::rows::UnversionedRow> {
        if shuffle_index < self.shuffle_begin || shuffle_index >= self.shuffle_end {
            return None;
        }
        self.rowset.rows().get((shuffle_index - self.shuffle_begin) as usize)
    }
}

/// Result of a front-trim: the state reached by consuming everything up to
/// and including the last popped entry (feeds `LocalMapperState`, §4.3.5).
#[derive(Debug, Clone, PartialEq)]
pub struct TrimOutcome {
    pub entries_popped: usize,
    pub bytes_freed: usize,
    /// After-the-end indexes + token of the last popped entry.
    pub input_unread_row_index: i64,
    pub shuffle_unread_row_index: i64,
    pub continuation_token: ContinuationToken,
}

/// FIFO of window entries with absolute indexing.
///
/// Entries live in a [`Slab`] and FIFO order is a deque of slot keys:
/// push/trim churn at batch rate forever, and the slab recycles freed
/// slots so a steady-state window settles into a fixed pool instead of
/// round-tripping every entry through the allocator.
#[derive(Debug, Default)]
pub struct WindowQueue {
    slab: Slab<WindowEntry>,
    /// Slab keys in FIFO order; `order[i]` holds absolute entry index
    /// `first_entry_index + i`.
    order: VecDeque<usize>,
    first_entry_index: u64,
    total_bytes: usize,
}

impl WindowQueue {
    pub fn new() -> WindowQueue {
        WindowQueue::default()
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Entry at FIFO offset `i` (0 = front). Offsets in `[0, len)` are
    /// always backed by an occupied slot.
    fn at(&self, i: usize) -> &WindowEntry {
        self.slab.get(self.order[i]).expect("window order key is live")
    }

    pub fn total_bytes(&self) -> usize {
        self.total_bytes
    }

    /// Slots ever allocated in the entry pool — plateaus at the window's
    /// peak depth under steady-state churn (diagnostic).
    pub fn entry_pool_capacity(&self) -> usize {
        self.slab.capacity()
    }

    pub fn first_entry_index(&self) -> u64 {
        self.first_entry_index
    }

    /// Index the next pushed entry will get.
    pub fn next_entry_index(&self) -> u64 {
        self.first_entry_index + self.order.len() as u64
    }

    /// Push a new entry (must carry `next_entry_index`).
    pub fn push(&mut self, entry: WindowEntry) {
        assert_eq!(
            entry.entry_index,
            self.next_entry_index(),
            "window entries must be pushed in order"
        );
        self.total_bytes += entry.byte_size;
        let key = self.slab.insert(entry);
        self.order.push_back(key);
    }

    /// Entry by absolute index.
    pub fn get(&self, entry_index: u64) -> Option<&WindowEntry> {
        let offset = entry_index.checked_sub(self.first_entry_index)? as usize;
        let key = *self.order.get(offset)?;
        self.slab.get(key)
    }

    pub fn get_mut(&mut self, entry_index: u64) -> Option<&mut WindowEntry> {
        let offset = entry_index.checked_sub(self.first_entry_index)? as usize;
        let key = *self.order.get(offset)?;
        self.slab.get_mut(key)
    }

    /// Entry containing the given shuffle index (binary search — entries
    /// have increasing, contiguous-per-entry shuffle ranges, but there may
    /// be gaps where Map produced zero rows).
    pub fn entry_for_shuffle_index(&self, shuffle_index: i64) -> Option<&WindowEntry> {
        // partition_point over FIFO order, resolving keys through the slab.
        let mut lo = 0;
        let mut hi = self.order.len();
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if self.at(mid).shuffle_end <= shuffle_index {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        if lo == self.order.len() {
            return None;
        }
        Some(self.at(lo)).filter(|e| e.shuffle_begin <= shuffle_index && shuffle_index < e.shuffle_end)
    }

    /// Absolute entry index containing a shuffle index.
    pub fn entry_index_for_shuffle_index(&self, shuffle_index: i64) -> Option<u64> {
        self.entry_for_shuffle_index(shuffle_index).map(|e| e.entry_index)
    }

    /// `TrimWindowEntries` (§4.3.5): pop entries with zero bucket-pointer
    /// count from the front; returns the advanced unread state if anything
    /// was popped.
    pub fn trim_front(&mut self) -> Option<TrimOutcome> {
        let mut popped = 0;
        let mut freed = 0;
        let mut last: Option<(i64, i64, ContinuationToken)> = None;
        while let Some(&key) = self.order.front() {
            if self.slab.get(key).expect("window order key is live").bucket_ptr_count != 0 {
                break;
            }
            self.order.pop_front();
            let e = self.slab.remove(key).unwrap();
            self.first_entry_index += 1;
            popped += 1;
            freed += e.byte_size;
            last = Some((e.input_end, e.shuffle_end, e.continuation_token));
        }
        self.total_bytes -= freed;
        last.map(
            |(input_unread_row_index, shuffle_unread_row_index, continuation_token)| TrimOutcome {
                entries_popped: popped,
                bytes_freed: freed,
                input_unread_row_index,
                shuffle_unread_row_index,
                continuation_token,
            },
        )
    }

    /// Smallest `min_event_ts` across retained entries — the buffered
    /// event-time low water the mapper's watermark is clamped by.
    pub fn min_event_ts(&self) -> Option<i64> {
        self.iter().filter_map(|e| e.min_event_ts).min()
    }

    /// Drop everything (split-brain reset, §4.3.3 step 3). The slab keeps
    /// its slot pool for the rebuilt window.
    pub fn clear(&mut self) {
        self.slab.clear();
        self.order.clear();
        self.total_bytes = 0;
        // first_entry_index keeps increasing monotonically so stale
        // BucketRow references can never alias a future entry.
        self.first_entry_index = self.first_entry_index.wrapping_add(1 << 32);
    }

    pub fn iter(&self) -> impl Iterator<Item = &WindowEntry> {
        self.order
            .iter()
            .map(move |&k| self.slab.get(k).expect("window order key is live"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::row;
    use crate::rows::{NameTable, RowsetBuilder};

    fn entry(q: &WindowQueue, in_range: (i64, i64), sh_range: (i64, i64), nrows: usize) -> WindowEntry {
        let nt = NameTable::new(&["v"]);
        let mut b = RowsetBuilder::new(nt);
        for i in 0..nrows {
            b.push(row![sh_range.0 + i as i64]);
        }
        let rowset = b.build();
        let byte_size = rowset.byte_size();
        WindowEntry {
            entry_index: q.next_entry_index(),
            rowset,
            input_begin: in_range.0,
            input_end: in_range.1,
            shuffle_begin: sh_range.0,
            shuffle_end: sh_range.1,
            continuation_token: ContinuationToken(format!("tok{}", in_range.1)),
            bucket_ptr_count: 0,
            byte_size,
            read_ts_ms: 0,
            min_event_ts: Some(sh_range.0),
        }
    }

    #[test]
    fn push_and_absolute_indexing() {
        let mut q = WindowQueue::new();
        q.push(entry(&q, (0, 10), (0, 8), 8));
        q.push(entry(&q, (10, 20), (8, 20), 12));
        assert_eq!(q.len(), 2);
        assert_eq!(q.get(0).unwrap().input_begin, 0);
        assert_eq!(q.get(1).unwrap().shuffle_begin, 8);
        assert!(q.get(2).is_none());
        assert!(q.total_bytes() > 0);
    }

    #[test]
    #[should_panic(expected = "in order")]
    fn out_of_order_push_rejected() {
        let mut q = WindowQueue::new();
        let mut e = entry(&q, (0, 1), (0, 1), 1);
        e.entry_index = 5;
        q.push(e);
    }

    #[test]
    fn shuffle_index_lookup_with_gaps() {
        let mut q = WindowQueue::new();
        q.push(entry(&q, (0, 10), (0, 5), 5));
        // An entry whose Map produced zero rows: empty shuffle range.
        q.push(entry(&q, (10, 20), (5, 5), 0));
        q.push(entry(&q, (20, 30), (5, 9), 4));
        assert_eq!(q.entry_index_for_shuffle_index(0), Some(0));
        assert_eq!(q.entry_index_for_shuffle_index(4), Some(0));
        assert_eq!(q.entry_index_for_shuffle_index(5), Some(2));
        assert_eq!(q.entry_index_for_shuffle_index(8), Some(2));
        assert_eq!(q.entry_index_for_shuffle_index(9), None);
        let e = q.entry_for_shuffle_index(6).unwrap();
        assert_eq!(e.row_at_shuffle_index(6).unwrap(), &row![6i64]);
        assert!(e.row_at_shuffle_index(100).is_none());
    }

    #[test]
    fn trim_front_respects_pointer_counts() {
        let mut q = WindowQueue::new();
        q.push(entry(&q, (0, 10), (0, 5), 5));
        q.push(entry(&q, (10, 20), (5, 9), 4));
        q.push(entry(&q, (20, 30), (9, 12), 3));
        q.get_mut(1).unwrap().bucket_ptr_count = 1;

        let out = q.trim_front().unwrap();
        assert_eq!(out.entries_popped, 1);
        assert_eq!(out.input_unread_row_index, 10);
        assert_eq!(out.shuffle_unread_row_index, 5);
        assert_eq!(out.continuation_token.0, "tok10");
        assert_eq!(q.len(), 2);
        assert_eq!(q.first_entry_index(), 1);

        // Entry 1 still pinned: nothing more to trim.
        assert_eq!(q.trim_front(), None);

        // Unpin and trim the rest.
        q.get_mut(1).unwrap().bucket_ptr_count = 0;
        let out = q.trim_front().unwrap();
        assert_eq!(out.entries_popped, 2);
        assert_eq!(out.input_unread_row_index, 30);
        assert_eq!(out.shuffle_unread_row_index, 12);
        assert!(q.is_empty());
        assert_eq!(q.total_bytes(), 0);
    }

    #[test]
    fn byte_accounting_tracks_trim() {
        let mut q = WindowQueue::new();
        q.push(entry(&q, (0, 1), (0, 3), 3));
        let b1 = q.total_bytes();
        q.push(entry(&q, (1, 2), (3, 6), 3));
        assert!(q.total_bytes() > b1);
        q.trim_front().unwrap();
        assert_eq!(q.total_bytes(), 0);
    }

    #[test]
    fn min_event_ts_tracks_retained_entries() {
        let mut q = WindowQueue::new();
        assert_eq!(q.min_event_ts(), None);
        let mut a = entry(&q, (0, 1), (0, 3), 3);
        a.min_event_ts = Some(10);
        q.push(a);
        let mut b = entry(&q, (1, 2), (3, 6), 3);
        b.min_event_ts = Some(5); // out-of-order event time
        q.push(b);
        assert_eq!(q.min_event_ts(), Some(5));
        q.trim_front().unwrap(); // both unpinned: everything pops
        assert_eq!(q.min_event_ts(), None);
        // Entries without event time are transparent to the minimum.
        let mut e = entry(&q, (2, 3), (6, 7), 1);
        e.min_event_ts = None;
        q.push(e);
        assert_eq!(q.min_event_ts(), None);
    }

    #[test]
    fn steady_state_churn_reuses_slab_slots() {
        let mut q = WindowQueue::new();
        // Push/trim at depth 4 for many rounds: the slab pool must stop
        // growing once the window depth is reached.
        let mut next_in = 0i64;
        let mut next_sh = 0i64;
        // Push one pinned entry (pinned so trims pop exactly the front we
        // unpin, one per round).
        let mut push = |q: &mut WindowQueue| {
            let mut e = entry(q, (next_in, next_in + 1), (next_sh, next_sh + 2), 2);
            e.bucket_ptr_count = 1;
            let idx = e.entry_index;
            q.push(e);
            assert!(q.get(idx).is_some());
            next_in += 1;
            next_sh += 2;
        };
        for _ in 0..4 {
            push(&mut q);
        }
        let plateau = 4;
        for round in 0..50 {
            let first = q.first_entry_index();
            q.get_mut(first).unwrap().bucket_ptr_count = 0;
            let out = q.trim_front().unwrap();
            assert_eq!(out.entries_popped, 1);
            push(&mut q);
            // Depth returns to 4 and absolute indexing still works.
            assert_eq!(q.len(), plateau);
            let first = q.first_entry_index();
            assert_eq!(q.get(first).unwrap().input_begin, round as i64 + 1);
        }
        // The pool never grew past the window's depth: 50 rounds of churn
        // ran entirely on recycled slots.
        assert_eq!(q.entry_pool_capacity(), plateau);
        // Every entry still resolvable by shuffle index after heavy churn.
        let first = q.first_entry_index();
        let front_sh = q.get(first).unwrap().shuffle_begin;
        assert_eq!(q.entry_index_for_shuffle_index(front_sh), Some(first));
    }

    #[test]
    fn clear_advances_indices() {
        let mut q = WindowQueue::new();
        q.push(entry(&q, (0, 1), (0, 1), 1));
        let before = q.first_entry_index();
        q.clear();
        assert!(q.is_empty());
        assert!(q.first_entry_index() > before);
        assert_eq!(q.total_bytes(), 0);
    }
}
