//! The mapper worker (§4.3): input ingestion, in-memory window, GetRows
//! service, trimming, split-brain defence — and, for elastic resharding,
//! per-epoch bucket sets with a CAS-adopted cutover.
//!
//! A mapper routes every mapped row to exactly one `(epoch, reducer)`
//! bucket. While a reshard is in flight it keeps **two** bucket sets: the
//! old epoch's (rows with shuffle index in `[prev_cutover, cutover)`,
//! partitioned over the old reducer count) and the new epoch's (rows at or
//! above `cutover`, partitioned over the new count). The cutover is chosen
//! in the adoption transaction as
//! `max(rows this instance already routed, 1 + max shuffle index any old
//! reducer has committed from this mapper)` — the latter read *inside* the
//! transaction, so an old-fleet commit racing the adoption serializes
//! against it. Together with the reducer-side commit fencing this makes
//! "routed old" and "routed new" disjoint even under split-brain twins
//! and crash-recovery re-maps.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::api::{partitioning, Client, Mapper, MapperFactory, MapperSpec};
use crate::coordinator::bucket::{BucketRow, BucketState};
use crate::coordinator::config::ProcessorConfig;
use crate::coordinator::state::{MapperState, ReducerState};
use crate::coordinator::window::{WindowEntry, WindowQueue};
use crate::cypress::DiscoveryGroup;
use crate::dyntable::TxnError;
use crate::eventtime::{fetch_close, WatermarkTracker, NO_WATERMARK};
use crate::metrics::hub::names;
use crate::metrics::MetricsHub;
use crate::obs::{self, SpanOutcome, TxnSpan, WorkerId};
use crate::queue::{PartitionReader, INPUT_COL_WRITE_TS};
use crate::reshard::plan::{reducer_state_table, PlanPhase, ReshardPlan};
use crate::rows::{codec, NameTable, Value};
use crate::rpc::{ReqGetRows, Request, Response, RpcNet, RpcService, RspGetRows};
use crate::spill::{pick_straggler_buckets, SpillQueue};
use crate::storage::accounting::CATEGORY_COUNT;
use crate::storage::{Journal, WriteCategory};
use crate::util;
use crate::util::yson::Yson;
use crate::util::Guid;

/// One epoch's bucket set: the routing surface one reducer fleet pulls
/// from.
pub(crate) struct EpochBuckets {
    pub epoch: i64,
    pub partitions: usize,
    pub buckets: Vec<BucketState>,
    pub spilled: Vec<SpillQueue>,
}

/// Event-time tracking of one mapper instance (present iff
/// `ProcessorConfig::event_time` is set). See [`crate::eventtime`].
pub(crate) struct EventTimeState {
    /// Configured column name of the event time in mapped rows.
    col_name: String,
    /// Resolved column id (known after the first mapped batch).
    col: Option<usize>,
    /// Max event time ever ingested by this instance (the frontier).
    frontier: i64,
    /// Source-close timestamp, once observed in the close table.
    closed_at: Option<i64>,
    /// The last input read returned empty *after* the close marker was
    /// observed — given the close contract (marker written after the
    /// final append), the partition is fully consumed.
    exhausted_after_close: bool,
    /// Upstream fleet watermark fetched on the trim cadence — the value
    /// the next caught-up observation locks in. Only meaningful when the
    /// stage consumes an event-timed handoff.
    pending_upstream_cap: Option<i64>,
    /// The upstream cap that was current *before* the most recent empty
    /// read. An empty read proves every row appended before it has been
    /// ingested; any row appended after it was still buffered upstream at
    /// that moment, so (by the emit contract) its event time is at or
    /// above this cap — the local watermark must never exceed it.
    caught_up_cap: Option<i64>,
}

impl EventTimeState {
    fn new(col_name: String) -> EventTimeState {
        EventTimeState {
            col_name,
            col: None,
            frontier: NO_WATERMARK,
            closed_at: None,
            exhausted_after_close: false,
            pending_upstream_cap: None,
            caught_up_cap: None,
        }
    }
}

/// Mutable mapper internals shared between the ingestion thread and the
/// GetRows RPC handler (§4.3.1's "internal state").
pub(crate) struct MapperInner {
    pub window: WindowQueue,
    /// Bucket sets in ascending epoch order; the last is the routing
    /// target for fresh rows. At most two during a migration.
    pub epochs: Vec<EpochBuckets>,
    /// LocalMapperState: lower bound advanced by TrimWindowEntries (epoch
    /// fields mirror the adoption state).
    pub local_state: MapperState,
    /// PersistedMapperState: last state this instance committed/observed.
    pub persisted_state: MapperState,
    /// Output name table, known after the first mapped batch.
    pub out_name_table: Option<Arc<NameTable>>,
    /// Shuffle index one past the last row this instance has mapped —
    /// feeds the drain signal (an old epoch is only drained once the
    /// instance has mapped everything below the cutover).
    pub mapped_end: i64,
    /// Event-time tracking (None = disabled).
    pub event: Option<EventTimeState>,
    /// Builds the spill journal of one `(epoch, reducer)` queue.
    spill_journal: Arc<dyn Fn(i64, usize) -> Arc<Journal> + Send + Sync>,
}

impl MapperInner {
    fn new(
        spill_journal: Arc<dyn Fn(i64, usize) -> Arc<Journal> + Send + Sync>,
        event_col: Option<String>,
    ) -> MapperInner {
        MapperInner {
            window: WindowQueue::new(),
            epochs: Vec::new(),
            local_state: MapperState::initial(),
            persisted_state: MapperState::initial(),
            out_name_table: None,
            mapped_end: 0,
            event: event_col.map(EventTimeState::new),
            spill_journal,
        }
    }

    fn make_set(&self, epoch: i64, partitions: usize) -> EpochBuckets {
        EpochBuckets {
            epoch,
            partitions,
            buckets: (0..partitions).map(|_| BucketState::new()).collect(),
            spilled: (0..partitions)
                .map(|r| SpillQueue::new((self.spill_journal)(epoch, r)))
                .collect(),
        }
    }

    /// Replace every bucket set (init / split-brain reset).
    fn install_epochs(&mut self, sets: &[(i64, usize)]) {
        let fresh: Vec<EpochBuckets> = sets.iter().map(|&(e, p)| self.make_set(e, p)).collect();
        self.epochs = fresh;
    }

    /// Add the new epoch's set at adoption (no-op if present).
    fn ensure_epoch(&mut self, epoch: i64, partitions: usize) {
        if !self.epochs.iter().any(|s| s.epoch == epoch) {
            let set = self.make_set(epoch, partitions);
            self.epochs.push(set);
            self.epochs.sort_by_key(|s| s.epoch);
        }
    }

    /// Drop bucket sets of epochs below `epoch` once the plan finalized
    /// past them, releasing any window pins they still hold.
    fn drop_epochs_below(&mut self, epoch: i64) {
        let (drop, keep): (Vec<EpochBuckets>, Vec<EpochBuckets>) =
            std::mem::take(&mut self.epochs)
                .into_iter()
                .partition(|s| s.epoch < epoch);
        self.epochs = keep;
        for set in drop {
            for b in &set.buckets {
                if let Some(e) = b.first_entry_index() {
                    if let Some(entry) = self.window.get_mut(e) {
                        entry.bucket_ptr_count -= 1;
                    }
                }
            }
        }
        self.trim_window_entries();
    }

    fn set_pos(&self, epoch: i64) -> Option<usize> {
        self.epochs.iter().position(|s| s.epoch == epoch)
    }

    /// Split-brain reset: "the internal state is dropped" (§4.3.3 step 3).
    fn reset(&mut self, fresh: MapperState, sets: &[(i64, usize)]) {
        self.window.clear();
        self.install_epochs(sets);
        self.mapped_end = fresh.shuffle_unread_row_index;
        self.local_state = fresh.clone();
        self.persisted_state = fresh;
        if let Some(ev) = &mut self.event {
            // Conservative: re-establish "input fully consumed" with a
            // fresh empty read after the reset. The frontier stays — it is
            // a monotone fact about what was ever ingested.
            ev.exhausted_after_close = false;
        }
    }

    /// `TrimWindowEntries` (§4.3.5): advance past fully-acknowledged
    /// entries and fold the result into LocalMapperState (position fields
    /// only — the epoch/cutover fields track adoption, not trimming).
    fn trim_window_entries(&mut self) -> usize {
        match self.window.trim_front() {
            Some(outcome) => {
                self.local_state = MapperState {
                    input_unread_row_index: outcome.input_unread_row_index,
                    shuffle_unread_row_index: outcome.shuffle_unread_row_index,
                    continuation_token: outcome.continuation_token.clone(),
                    ..self.local_state.clone()
                };
                outcome.entries_popped
            }
            None => 0,
        }
    }
}

/// Everything the RPC service and ingestion loop share.
pub(crate) struct MapperShared {
    pub cfg: ProcessorConfig,
    pub index: usize,
    pub guid: Guid,
    pub address: String,
    pub client: Client,
    pub metrics: Arc<MetricsHub>,
    pub inner: Mutex<MapperInner>,
    /// Signalled whenever window memory is freed (step 8's semaphore).
    pub mem_freed: Condvar,
    pub pause: Arc<AtomicBool>,
    pub kill: Arc<AtomicBool>,
}

impl MapperShared {
    fn record_window_gauge(&self, bytes: usize) {
        self.metrics
            .series(&names::mapper_window_bytes(self.index))
            .record(self.client.clock.now_ms(), bytes as f64);
    }

    /// Record a flight-recorder span for one commit-spine attempt.
    /// Strictly post-outcome — the recorder never joins the CAS read
    /// set. Call sites gate on `recorder().enabled()` so the disabled
    /// path costs one atomic load per transaction.
    fn record_span(
        &self,
        scope: &str,
        trace_id: u64,
        read_set: usize,
        outcome: SpanOutcome,
        bytes_by_category: [u64; CATEGORY_COUNT],
        start_ms: u64,
    ) {
        self.metrics.recorder().record(TxnSpan {
            txn_id: 0,
            trace_id,
            worker: WorkerId::mapper(self.index, &self.guid.to_string()),
            scope: scope.to_string(),
            read_set,
            outcome,
            bytes_by_category,
            start_ms,
            end_ms: self.client.clock.now_ms(),
        });
    }
}

/// The GetRows RPC endpoint (§4.3.4).
pub(crate) struct MapperService {
    shared: Arc<MapperShared>,
}

impl MapperService {
    /// Steps 1–4 of the GetRows procedure, epoch-routed.
    fn get_rows(&self, req: ReqGetRows) -> Result<RspGetRows, String> {
        let sh = &self.shared;
        // Step 1: stale-discovery defence.
        if req.mapper_id != sh.guid.to_string() {
            return Err(format!(
                "mapper id mismatch: request for {} but this is {}",
                req.mapper_id, sh.guid
            ));
        }
        let reducer = req.reducer_index as usize;
        let mut inner = util::lock(&sh.inner);
        let Some(pos) = inner.set_pos(req.epoch) else {
            // An epoch this instance does not route for. Older than our
            // newest set ⇒ it was finalized away (everything it could own
            // is committed) — report it drained so a zombie retires.
            // Newer (or we are not initialized yet) ⇒ plain empty.
            let newest = inner.epochs.last().map(|s| s.epoch);
            return Ok(if newest.is_some_and(|n| req.epoch < n) {
                RspGetRows::empty_drained()
            } else {
                RspGetRows::empty()
            });
        };
        if reducer >= inner.epochs[pos].partitions {
            return Err(format!(
                "reducer index {reducer} out of range for epoch {}",
                req.epoch
            ));
        }

        // Step 2: pop acknowledged rows and maintain bucket pointers.
        let ack = {
            let set = &mut inner.epochs[pos];
            set.spilled[reducer].ack(req.committed_row_index);
            set.buckets[reducer].ack(req.committed_row_index)
        };
        if ack.old_head_entry != ack.new_head_entry {
            if let Some(old) = ack.old_head_entry {
                if let Some(e) = inner.window.get_mut(old) {
                    e.bucket_ptr_count -= 1;
                }
            }
            if let Some(new) = ack.new_head_entry {
                if let Some(e) = inner.window.get_mut(new) {
                    e.bucket_ptr_count += 1;
                }
            }
        }

        // Step 3: trimming. TrimWindowEntries is cheap and runs inline;
        // TrimInputRows is transactional and runs on its own cadence in
        // the ingestion thread (§4.3.5's two-method split).
        if inner.trim_window_entries() > 0 {
            let bytes = inner.window.total_bytes();
            drop(inner);
            sh.record_window_gauge(bytes);
            sh.mem_freed.notify_all();
            inner = util::lock(&sh.inner);
        }

        // Step 4: serve up to `count` rows *without* removing them.
        // Encoded straight from window references — no per-row clones
        // (§Perf optimization 2).
        let want = req.count.max(0) as usize;
        let mut last_shuffle = -1i64;
        let spilled_rows: Vec<(i64, crate::rows::UnversionedRow)> =
            inner.epochs[pos].spilled[reducer].peek(want);
        if let Some((s, _)) = spilled_rows.last() {
            last_shuffle = *s;
        }
        let remaining = want - spilled_rows.len();
        let picks: Vec<BucketRow> = inner.epochs[pos].buckets[reducer]
            .peek(remaining)
            .copied()
            .collect();
        if let Some(r) = picks.last() {
            last_shuffle = r.shuffle_index;
        }

        // Drain signal: this epoch is older than the routing epoch, the
        // instance has mapped everything below the cutover, and nothing is
        // queued or spilled for (epoch, reducer).
        let drained = pos + 1 < inner.epochs.len()
            && spilled_rows.is_empty()
            && picks.is_empty()
            && inner.epochs[pos].buckets[reducer].is_empty()
            && inner.mapped_end >= inner.local_state.cutover_index;

        if spilled_rows.is_empty() && picks.is_empty() {
            return Ok(RspGetRows {
                drained,
                ..RspGetRows::empty()
            });
        }
        let nt = inner
            .out_name_table
            .clone()
            // protolint: allow(panic, "spilled/picked rows exist only after at least one map_batch stored the output name table; reaching this with None means in-process memory corruption, not drift")
            .expect("rows served before any batch was mapped");
        let mut refs: Vec<&crate::rows::UnversionedRow> =
            Vec::with_capacity(spilled_rows.len() + picks.len());
        refs.extend(spilled_rows.iter().map(|(_, r)| r));
        for r in &picks {
            let entry = inner
                .window
                .get(r.entry_index)
                // protolint: allow(panic, "TrimWindowEntries never trims an entry with live bucket pointers (bucket_ptr_count > 0 pins it); a dangling index is a window-queue accounting bug, caught loudly")
                .expect("bucket row references trimmed entry");
            refs.push(
                entry
                    .row_at_shuffle_index(r.shuffle_index)
                    // protolint: allow(panic, "bucket rows are built from the entry's own shuffle range at push time; an out-of-range index is in-process corruption, not input drift")
                    .expect("shuffle index outside its entry"),
            );
        }
        let row_count = refs.len() as i64;
        // One exactly-sized encode plus one bulk Vec→Arc copy; after that
        // every downstream holder (transport, reducer decode, retries)
        // bumps a refcount instead of copying the payload.
        let attachment = codec::encode_rowset_refs(&nt, &refs);
        Ok(RspGetRows {
            row_count,
            last_shuffle_row_index: last_shuffle,
            attachment: attachment.into(),
            drained: false,
        })
    }
}

impl RpcService for MapperService {
    fn handle(&self, req: Request) -> Result<Response, String> {
        // A paused worker models a hung process: no responses at all.
        if self.shared.pause.load(Ordering::SeqCst) {
            return Err("mapper unresponsive (paused)".into());
        }
        match req {
            Request::Ping => Ok(Response::Pong),
            Request::GetRows(r) => self.get_rows(r).map(Response::GetRows),
        }
    }
}

/// Dependencies handed to a mapper instance at spawn. The factory (plus
/// its config node and the input schema) stays available so the worker can
/// rebuild its user mapper against a new reducer count when it adopts a
/// reshard epoch.
pub struct MapperDeps {
    pub client: Client,
    pub net: Arc<RpcNet>,
    pub metrics: Arc<MetricsHub>,
    pub discovery: DiscoveryGroup,
    pub factory: MapperFactory,
    pub user_config: Arc<Yson>,
    pub input_name_table: Arc<NameTable>,
}

/// Control handle for one running mapper instance.
pub struct MapperHandle {
    pub index: usize,
    pub guid: Guid,
    pub address: String,
    kill: Arc<AtomicBool>,
    pause: Arc<AtomicBool>,
    join: std::thread::JoinHandle<()>,
}

impl MapperHandle {
    /// Simulate a hang (§5.2 drills): ingestion stops, RPCs error, the
    /// discovery session stops heartbeating.
    pub fn set_paused(&self, paused: bool) {
        self.pause.store(paused, Ordering::SeqCst);
    }

    /// Crash the worker. The thread exits; nothing is cleaned up except
    /// the RPC registration (a dead process's sockets close; its discovery
    /// entry lingers until TTL expiry).
    pub fn kill(&self) {
        self.kill.store(true, Ordering::SeqCst);
    }

    pub fn is_finished(&self) -> bool {
        self.join.is_finished()
    }

    pub fn join(self) {
        let _ = self.join.join();
    }
}

/// Spawn a mapper instance: ingestion thread + RPC registration +
/// discovery membership. The user mapper is built inside the worker (from
/// `deps.factory`) once the authoritative reducer count is known from the
/// reshard plan; `reader` is the partition reader for this mapper's
/// partition.
pub fn spawn_mapper(
    cfg: ProcessorConfig,
    spec: MapperSpec,
    deps: MapperDeps,
    mut reader: Box<dyn PartitionReader>,
) -> MapperHandle {
    let kill = Arc::new(AtomicBool::new(false));
    let pause = Arc::new(AtomicBool::new(false));
    let address = format!("mapper-{}/{}", spec.index, spec.guid);
    let accounting = deps.client.store.accounting();
    let mapper_index = spec.index;
    let scope_label = cfg.scope_label.clone();

    let event_col = cfg.event_time.as_ref().map(|e| e.column.clone());
    let shared = Arc::new(MapperShared {
        cfg: cfg.clone(),
        index: spec.index,
        guid: spec.guid,
        address: address.clone(),
        client: deps.client.clone(),
        metrics: deps.metrics.clone(),
        inner: Mutex::new(MapperInner::new(
            Arc::new(move |epoch, r| {
                Journal::new_scoped(
                    format!("spill/m{mapper_index}/e{epoch}/r{r}"),
                    WriteCategory::Spill,
                    accounting.clone(),
                    scope_label.clone(),
                )
            }),
            event_col,
        )),
        mem_freed: Condvar::new(),
        pause: pause.clone(),
        kill: kill.clone(),
    });

    deps.net.register(
        &address,
        Arc::new(MapperService {
            shared: shared.clone(),
        }),
    );

    let join = std::thread::Builder::new()
        .name(format!("mapper-{}", spec.index))
        .spawn({
            let shared = shared.clone();
            let net = deps.net.clone();
            move || {
                run_ingestion(&shared, &spec, &deps, reader.as_mut());
                net.unregister(&shared.address);
            }
        })
        // protolint: allow(panic, "thread spawn fails only on OS resource exhaustion at worker startup; there is no protocol state yet to corrupt")
        .expect("spawn mapper thread");

    MapperHandle {
        index: shared.index,
        guid: shared.guid,
        address,
        kill,
        pause,
        join,
    }
}

/// The user mapper instances the worker routes through: one per live
/// partition map. Rebuilt from the factory at adoption; the old-count
/// instance sticks around while the old epoch drains so crash-recovery
/// re-maps can partition sub-cutover rows exactly as the original life
/// did.
struct UserMappers {
    current: Box<dyn Mapper>,
    current_count: usize,
    old: Option<(Box<dyn Mapper>, usize)>,
}

impl UserMappers {
    fn adopt(&mut self, fresh: Box<dyn Mapper>, count: usize) {
        let prev = std::mem::replace(&mut self.current, fresh);
        self.old = Some((prev, self.current_count));
        self.current_count = count;
    }
}

/// Fetch + parse the reshard plan (None on store error / missing row).
fn fetch_plan(sh: &MapperShared) -> Option<ReshardPlan> {
    ReshardPlan::fetch(&sh.client.store, &sh.cfg.reshard_plan_table)
}

/// The `(epoch, partitions)` bucket sets implied by a state/plan pair.
fn epoch_sets(state: &MapperState, plan: &ReshardPlan) -> Vec<(i64, usize)> {
    if plan.phase == PlanPhase::Migrating && state.epoch == plan.next_epoch() {
        // Adopted; the old fleet still drains.
        vec![
            (plan.epoch, plan.partitions),
            (state.epoch, plan.next_partitions),
        ]
    } else {
        // Not (yet) adopted, stable, or a state/plan skew the adoption
        // poll will repair: route only the state's own epoch, at the
        // plan's count for it.
        vec![(state.epoch, plan.partitions)]
    }
}

/// Build one user mapper against a specific reducer count.
fn build_user_mapper(spec: &MapperSpec, deps: &MapperDeps, count: usize) -> Box<dyn Mapper> {
    let mut s = spec.clone();
    s.num_reducers = count;
    (deps.factory)(
        &deps.user_config,
        &deps.client,
        deps.input_name_table.clone(),
        &s,
    )
}

/// Build the user-mapper pair matching the bucket sets.
fn build_user_mappers(
    sets: &[(i64, usize)],
    spec: &MapperSpec,
    deps: &MapperDeps,
) -> UserMappers {
    // protolint: allow(panic, "epoch_sets() returns at least one element by construction (both branches build a non-empty vec)")
    let (_, current_count) = *sets.last().expect("at least one epoch set");
    UserMappers {
        current: build_user_mapper(spec, deps, current_count),
        current_count,
        old: (sets.len() > 1).then(|| {
            let (_, old_count) = sets[0];
            (build_user_mapper(spec, deps, old_count), old_count)
        }),
    }
}

/// The input ingestion procedure (§4.3.3) plus the TrimInputRows and
/// plan-poll cadences.
fn run_ingestion(
    sh: &Arc<MapperShared>,
    spec: &MapperSpec,
    deps: &MapperDeps,
    reader: &mut dyn PartitionReader,
) {
    let clock = sh.client.clock.clone();
    let cfg = &sh.cfg;
    let state_table = &spec.state_table;
    let state_key = MapperState::key(sh.index);
    let discovery = &deps.discovery;

    // Join discovery, waiting out a live predecessor if needed.
    let session = sh.client.cypress.open_session(cfg.session_ttl_ms);
    loop {
        if sh.kill.load(Ordering::SeqCst) {
            return;
        }
        match discovery.join(session, &sh.guid.to_string(), &sh.address, sh.index as i64, sh.guid) {
            Ok(()) => break,
            Err(_) => clock.sleep_ms(cfg.backoff_ms),
        }
    }

    // Initial state fetch (§4.3.3: "Initially, it fetches its corresponding
    // row from the state table"), creating the row if this is a fresh
    // processor.
    let mut cur = loop {
        if sh.kill.load(Ordering::SeqCst) {
            return;
        }
        match sh.client.store.lookup(state_table, &state_key) {
            Ok(Some(row)) => match MapperState::from_row(&row) {
                Some(s) => break s,
                None => {
                    clock.sleep_ms(cfg.backoff_ms);
                }
            },
            Ok(None) => {
                // Create the row CAS-on-absence: the transactional lookup
                // records the absent key (version 0) in the read set, so a
                // twin that created the row first makes this commit conflict
                // instead of being silently reset to the initial state.
                let mut txn = sh.client.begin();
                if let Ok(None) = txn.lookup(state_table, &state_key) {
                    let init = MapperState::initial();
                    if txn.write(state_table, init.to_row(sh.index)).is_ok()
                        && txn.commit().is_ok()
                    {
                        break init;
                    }
                }
                clock.sleep_ms(cfg.backoff_ms);
            }
            Err(_) => clock.sleep_ms(cfg.backoff_ms),
        }
    };
    // Initial plan fetch (the processor seeds it at launch).
    let plan = loop {
        if sh.kill.load(Ordering::SeqCst) {
            return;
        }
        match fetch_plan(sh) {
            Some(p) => break p,
            None => clock.sleep_ms(cfg.backoff_ms),
        }
    };
    let sets = epoch_sets(&cur, &plan);
    let mut mappers = build_user_mappers(&sets, spec, deps);
    {
        let mut inner = util::lock(&sh.inner);
        inner.install_epochs(&sets);
        inner.mapped_end = cur.shuffle_unread_row_index;
        inner.local_state = cur.clone();
        inner.persisted_state = cur.clone();
    }

    let lag_name = names::mapper_read_lag(sh.index);
    let mut last_trim_ms = clock.now_ms();
    let mut last_plan_ms = clock.now_ms();
    let mut last_heartbeat_ms = clock.now_ms();
    let mut last_batch_empty = false;

    // The continuous ingestion cycle (§4.3.3 steps 1–8).
    while !sh.kill.load(Ordering::SeqCst) {
        if sh.pause.load(Ordering::SeqCst) {
            // A hung worker: no reads, no heartbeats, no trims.
            std::thread::sleep(Duration::from_millis(1));
            continue;
        }
        heartbeat_if_due(sh, session, &mut last_heartbeat_ms);

        // Step 1: back-off if the previous iteration appended nothing.
        if last_batch_empty {
            clock.sleep_ms(cfg.backoff_ms);
        }
        last_batch_empty = true;

        // Step 2: next batch from the partition reader.
        let batch = match reader.read(
            cur.input_unread_row_index,
            cur.input_unread_row_index + cfg.read_batch_rows as i64,
            &cur.continuation_token,
        ) {
            Ok(b) => b,
            Err(_) => continue, // partition outage: retry after backoff
        };

        // Step 3: split-brain check against the remote persistent state.
        let remote = match sh.client.store.lookup(state_table, &state_key) {
            Ok(Some(row)) => match MapperState::from_row(&row) {
                Some(s) => s,
                None => continue,
            },
            _ => continue, // state backend error: skip to next iteration
        };
        let persisted = util::lock(&sh.inner).persisted_state.clone();
        if remote != persisted {
            // "we are in a split-brain situation and the mapper waits out a
            // configurable delay, after which the internal state is dropped
            // and the whole input ingestion procedure is restarted."
            // A twin's epoch adoption takes this same path: the fresh state
            // carries the agreed cutover and the bucket sets are rebuilt
            // from it.
            sh.metrics.add(names::MAPPER_SPLIT_BRAIN, 1);
            if sh.metrics.recorder().enabled() {
                sh.record_span(
                    "ingest",
                    0,
                    0,
                    SpanOutcome::Abdicated,
                    [0; CATEGORY_COUNT],
                    clock.now_ms(),
                );
            }
            clock.sleep_ms(cfg.split_brain_delay_ms);
            let fresh = match sh.client.store.lookup(state_table, &state_key) {
                Ok(Some(row)) => match MapperState::from_row(&row) {
                    Some(s) => s,
                    // Decode/schema drift on the remote row must not reset
                    // this mapper to the initial state — that would rewind
                    // shuffle_unread_row_index to 0 and re-emit every row.
                    // Keep the stale internal state and retry; step 3
                    // re-detects the mismatch next cycle.
                    None => continue,
                },
                _ => continue,
            };
            // The reset needs a *real* plan: fabricating one could drop
            // the old epoch's bucket set mid-migration (rows silently
            // treated as committed). On a transient failure keep the
            // stale internal state and retry — step 3 will re-detect the
            // mismatch next cycle.
            let Some(fresh_plan) = fetch_plan(sh) else {
                continue;
            };
            let sets = epoch_sets(&fresh, &fresh_plan);
            mappers = build_user_mappers(&sets, spec, deps);
            util::lock(&sh.inner).reset(fresh.clone(), &sets);
            cur = fresh;
            sh.record_window_gauge(0);
            continue;
        }

        // Step 4: empty batch → next iteration (with backoff). An empty
        // read after the source-close marker was observed means the
        // partition is fully consumed (the marker is written after the
        // final append), unlocking the watermark's lift to the close
        // timestamp once the window drains.
        if batch.rowset.is_empty() {
            {
                let mut inner = util::lock(&sh.inner);
                if let Some(ev) = &mut inner.event {
                    if ev.closed_at.is_some() {
                        ev.exhausted_after_close = true;
                    }
                    // Caught up: everything appended before this read is
                    // ingested, so the upstream cap fetched *before* it
                    // now bounds every not-yet-read row. Locked caps only
                    // ever improve (the upstream fleet value is monotone).
                    if let Some(pending) = ev.pending_upstream_cap {
                        ev.caught_up_cap =
                            Some(ev.caught_up_cap.map_or(pending, |c: i64| c.max(pending)));
                    }
                }
            }
            maybe_trim_input(sh, reader, &mut last_trim_ms);
            maybe_poll_plan(sh, spec, deps, &mut cur, &mut mappers, &mut last_plan_ms);
            continue;
        }
        last_batch_empty = false;

        let n_in = batch.rowset.len() as i64;
        let input_bytes = batch.rowset.byte_size();

        // Read-lag metric: now − newest producer write timestamp.
        if let Some(last_row) = batch.rowset.rows().last() {
            if let Some(ts) = last_row.get(INPUT_COL_WRITE_TS).and_then(|v| v.as_i64()) {
                let lag = clock.now_ms() as i64 - ts;
                sh.metrics
                    .record_latency(&lag_name, clock.now_ms(), lag.max(0) as f64);
            }
        }

        // Step 5: run the user Map. Fresh ingestion runs only the current
        // map; a crash-recovery re-map of rows below the cutover also
        // needs the *old-count* partition assignment. A hash-publishing
        // mapper gets it for free: `owner(h, n)` holds for any partition
        // count, so the old assignment is derived from the current map's
        // hash column — no second Map call and no input clone. Otherwise
        // the batch is re-mapped under the old count (Map output rows must
        // not depend on the partition count — the §4.6 determinism
        // contract, extended).
        let may_straddle_old =
            mappers.old.is_some() && cur.shuffle_unread_row_index < cur.cutover_index;
        let needs_old_remap = may_straddle_old && !mappers.current.publishes_key_hashes();
        let input_for_old = if needs_old_remap {
            Some(batch.rowset.clone())
        } else {
            None
        };
        let mapped = mappers.current.map(batch.rowset);
        if let Err(e) = mapped.validate(mappers.current_count) {
            // protolint: allow(panic, "user Map contract violation: continuing with malformed output could break the determinism contract exactly-once rests on; fail loudly before any state is touched")
            panic!("user Map produced invalid output: {e}");
        }
        let n_out = mapped.rowset.len() as i64;
        let old_partitions: Option<Vec<usize>> = if may_straddle_old {
            // protolint: allow(panic, "guarded by may_straddle_old, which requires mappers.old.is_some() two statements up")
            let (old_mapper, old_count) = mappers.old.as_mut().expect("checked");
            match (&mapped.key_hashes, input_for_old) {
                (Some(hashes), _) => Some(
                    hashes
                        .iter()
                        .map(|&h| partitioning::owner(h, *old_count))
                        .collect(),
                ),
                (None, Some(input)) => {
                    let mapped_old = old_mapper.map(input);
                    if let Err(e) = mapped_old.validate(*old_count) {
                        // protolint: allow(panic, "user Map contract violation on the old-epoch re-map; same determinism-contract reasoning as the current-epoch check above")
                        panic!("user Map produced invalid output (old epoch): {e}");
                    }
                    assert_eq!(
                        mapped_old.partition_indexes.len(),
                        n_out as usize,
                        "Map output row count must not depend on the partition count"
                    );
                    Some(mapped_old.partition_indexes)
                }
                (None, None) => {
                    // protolint: allow(panic, "unreachable by construction: input_for_old is Some whenever the current map does not publish hashes; reaching here means the user Mapper lied about publishes_key_hashes()")
                    panic!("mapper declared publishes_key_hashes() but returned no hash column")
                }
            }
        } else {
            None
        };

        sh.metrics.add(names::MAPPER_ROWS_READ, n_in as u64);
        sh.metrics.add(names::MAPPER_ROWS_MAPPED, n_out as u64);
        sh.metrics.add(names::MAPPER_BYTES_READ, input_bytes as u64);

        // Step 6: push into the window and distribute to the epoch bucket
        // sets: rows at or above the cutover to the current map, rows in
        // [prev_cutover, cutover) to the draining old map, anything lower
        // was committed before the last finalized reshard and gets no
        // bucket at all (the entry trims as soon as live rows ack).
        {
            let mut inner = util::lock(&sh.inner);
            if inner.out_name_table.is_none() && n_out > 0 {
                inner.out_name_table = Some(mapped.rowset.name_table().clone());
            }
            // Event-time bookkeeping: the entry's min pins the watermark
            // while any of its rows is unacked; the max advances the
            // ingest frontier.
            let mut min_event_ts = None;
            if let Some(ev) = &mut inner.event {
                ev.exhausted_after_close = false;
                if ev.col.is_none() {
                    ev.col = mapped.rowset.name_table().id(&ev.col_name);
                }
                if let Some(col) = ev.col {
                    for r in mapped.rowset.rows() {
                        if let Some(ts) = r.get(col).and_then(Value::as_i64) {
                            min_event_ts =
                                Some(min_event_ts.map_or(ts, |m: i64| m.min(ts)));
                            if ts > ev.frontier {
                                ev.frontier = ts;
                            }
                        }
                    }
                }
            }
            let entry_index = inner.window.next_entry_index();
            let byte_size = mapped.rowset.byte_size();
            let entry = WindowEntry {
                entry_index,
                rowset: mapped.rowset,
                input_begin: cur.input_unread_row_index,
                input_end: cur.input_unread_row_index + n_in,
                shuffle_begin: cur.shuffle_unread_row_index,
                shuffle_end: cur.shuffle_unread_row_index + n_out,
                continuation_token: batch.next_token.clone(),
                bucket_ptr_count: 0,
                byte_size,
                read_ts_ms: clock.now_ms(),
                min_event_ts,
            };
            inner.window.push(entry);
            let newest_pos = inner.epochs.len() - 1;
            for (i, &reducer) in mapped.partition_indexes.iter().enumerate() {
                let shuffle_index = cur.shuffle_unread_row_index + i as i64;
                let (pos, target) = if shuffle_index >= cur.cutover_index {
                    (newest_pos, reducer)
                } else if shuffle_index >= cur.prev_cutover_index && newest_pos > 0 {
                    (
                        newest_pos - 1,
                        old_partitions.as_ref().map_or(reducer, |o| o[i]),
                    )
                } else {
                    continue; // committed before the last finalized reshard
                };
                let became_head = inner.epochs[pos].buckets[target].push(BucketRow {
                    shuffle_index,
                    entry_index,
                });
                if became_head {
                    if let Some(e) = inner.window.get_mut(entry_index) {
                        e.bucket_ptr_count += 1;
                    }
                }
            }
            inner.mapped_end = cur.shuffle_unread_row_index + n_out;
            // An entry no bucket points into (all rows filtered, or zero
            // output) is immediately trimmable; fold it into local state.
            inner.trim_window_entries();
            sh.record_window_gauge(inner.window.total_bytes());
        }

        // Step 7: advance the cursor.
        cur.input_unread_row_index += n_in;
        cur.shuffle_unread_row_index += n_out;
        cur.continuation_token = batch.next_token;

        // §6 straggler spill (feature-gated).
        if cfg.spill.enabled {
            try_spill(sh);
        }

        // TrimInputRows cadence (§4.3.5: "regularly with a
        // configuration-defined period") and the reshard-plan poll.
        maybe_trim_input(sh, reader, &mut last_trim_ms);
        maybe_poll_plan(sh, spec, deps, &mut cur, &mut mappers, &mut last_plan_ms);

        // Step 8: memory semaphore.
        {
            let mut inner = util::lock(&sh.inner);
            while inner.window.total_bytes() > cfg.memory_limit_bytes
                && !sh.kill.load(Ordering::SeqCst)
                && !sh.pause.load(Ordering::SeqCst)
            {
                if cfg.spill.enabled {
                    drop(inner);
                    try_spill(sh);
                    inner = util::lock(&sh.inner);
                    if inner.window.total_bytes() <= cfg.memory_limit_bytes {
                        break;
                    }
                }
                inner = util::cond_wait_timeout(&sh.mem_freed, inner, Duration::from_millis(2));
                drop(inner);
                heartbeat_if_due(sh, session, &mut last_heartbeat_ms);
                maybe_trim_input(sh, reader, &mut last_trim_ms);
                inner = util::lock(&sh.inner);
            }
        }
    }
}

fn heartbeat_if_due(sh: &MapperShared, session: crate::cypress::SessionId, last: &mut u64) {
    let now = sh.client.clock.now_ms();
    if now.saturating_sub(*last) >= sh.cfg.heartbeat_period_ms {
        let _ = sh.client.cypress.heartbeat(session);
        *last = now;
    }
}

/// Poll the reshard plan on the trim cadence: adopt a newly announced
/// epoch (CAS), or drop drained old bucket sets once the plan finalized.
fn maybe_poll_plan(
    sh: &Arc<MapperShared>,
    spec: &MapperSpec,
    deps: &MapperDeps,
    cur: &mut MapperState,
    mappers: &mut UserMappers,
    last_plan_ms: &mut u64,
) {
    let now = sh.client.clock.now_ms();
    if now.saturating_sub(*last_plan_ms) < sh.cfg.trim_period_ms {
        return;
    }
    *last_plan_ms = now;
    let Some(plan) = fetch_plan(sh) else { return };

    match plan.phase {
        PlanPhase::Migrating if plan.next_epoch() > cur.epoch => {
            // Live adoption: rows routed so far stay old, rows from here
            // on route new — the in-memory position is the base cutover.
            if let Some(adopted) =
                try_adopt(sh, spec, &plan, plan.next_epoch(), cur.shuffle_unread_row_index)
            {
                {
                    let mut inner = util::lock(&sh.inner);
                    inner.persisted_state = adopted.clone();
                    inner.local_state = inner
                        .local_state
                        .adopted(adopted.epoch, adopted.cutover_index);
                    inner.ensure_epoch(adopted.epoch, plan.next_partitions);
                }
                *cur = cur.adopted(adopted.epoch, adopted.cutover_index);
                let fresh = build_user_mapper(spec, deps, plan.next_partitions);
                mappers.adopt(fresh, plan.next_partitions);
            }
        }
        PlanPhase::Stable if plan.epoch > cur.epoch => {
            // Slept through an entire migration (defensive: the finalize
            // gate makes this unreachable, since every old reducer needed
            // our drain flag, which needed adoption). Adopt from the
            // *persisted* floor and hard-reset, so everything above the
            // trim point re-maps under the new partition map and nothing
            // this instance routed under the dead map can leak out.
            let persisted = util::lock(&sh.inner).persisted_state.clone();
            if let Some(adopted) =
                try_adopt(sh, spec, &plan, plan.epoch, persisted.shuffle_unread_row_index)
            {
                let sets = epoch_sets(&adopted, &plan);
                *mappers = build_user_mappers(&sets, spec, deps);
                util::lock(&sh.inner).reset(adopted.clone(), &sets);
                *cur = adopted;
                sh.record_window_gauge(0);
            }
        }
        PlanPhase::Stable if plan.epoch == cur.epoch => {
            let mut inner = util::lock(&sh.inner);
            if inner.epochs.len() > 1 {
                inner.drop_epochs_below(cur.epoch);
                mappers.old = None;
                let bytes = inner.window.total_bytes();
                drop(inner);
                sh.record_window_gauge(bytes);
                sh.mem_freed.notify_all();
            }
        }
        _ => {}
    }
}

/// The adoption transaction: CAS the mapper state row to the new epoch
/// with a cutover no old-fleet commit can ever have exceeded —
/// `max(base_cutover, 1 + max committed shuffle index across the old
/// fleet)`, the latter read *inside* the transaction. An old-fleet commit
/// racing this adoption reads this mapper's state row in its own fencing
/// pass, so the two serialize: one retries with a consistent view.
/// Returns the adopted persisted state on success.
fn try_adopt(
    sh: &Arc<MapperShared>,
    spec: &MapperSpec,
    plan: &ReshardPlan,
    new_epoch: i64,
    base_cutover: i64,
) -> Option<MapperState> {
    let persisted = util::lock(&sh.inner).persisted_state.clone();
    let old_state_table = reducer_state_table(&sh.cfg.reducer_state_table, plan.epoch);

    let mut txn = sh.client.begin();
    // CAS base: the persisted mapper state must be what we believe it is.
    match txn.lookup(&spec.state_table, &MapperState::key(sh.index)) {
        Ok(Some(row)) if MapperState::from_row(&row).as_ref() == Some(&persisted) => {}
        _ => return None,
    }
    let mut cutover = base_cutover;
    for r in 0..plan.partitions {
        let committed = match txn.lookup(&old_state_table, &ReducerState::key(r)) {
            Ok(row) => row
                .as_ref()
                .and_then(ReducerState::from_row)
                .and_then(|s| s.committed_row_indices.get(sh.index).copied())
                .unwrap_or(-1),
            Err(_) => return None,
        };
        cutover = cutover.max(committed + 1);
    }
    let adopted = persisted.adopted(new_epoch, cutover);
    txn.write(&spec.state_table, adopted.to_row(sh.index)).ok()?;
    let obs_on = sh.metrics.recorder().enabled();
    let span_start = if obs_on { sh.client.clock.now_ms() } else { 0 };
    let read_set = txn.read_set_len();
    match txn.commit() {
        Ok(res) => {
            sh.metrics.add(names::RESHARD_ADOPTIONS, 1);
            if obs_on {
                sh.record_span(
                    "adopt",
                    0,
                    read_set,
                    SpanOutcome::Committed,
                    res.bytes_by_category,
                    span_start,
                );
            }
            Some(adopted)
        }
        // Conflict: a twin adopted or the old fleet raced; re-polled.
        // Other errors: transient store failure; retried next poll.
        Err(e) => {
            if obs_on {
                let outcome = match e {
                    TxnError::Conflict { table, key, .. } => SpanOutcome::Conflicted {
                        losing_row: format!("{table}/{key:?}"),
                    },
                    _ => SpanOutcome::Error,
                };
                sh.record_span(
                    "adopt",
                    0,
                    read_set,
                    outcome,
                    [0; CATEGORY_COUNT],
                    span_start,
                );
            }
            None
        }
    }
}

/// Smallest event time over rows this instance still buffers (window
/// entries + spill queues) — the value the watermark can never pass.
/// Both sources keep the minimum cached (per window entry; per spill
/// record at push time), so this is an O(entries + spilled) integer scan
/// with no decoding.
fn buffered_event_min(inner: &MapperInner) -> Option<i64> {
    let mut min = inner.window.min_event_ts();
    for set in &inner.epochs {
        for q in &set.spilled {
            if let Some(ts) = q.min_event_ts() {
                min = Some(min.map_or(ts, |m: i64| m.min(ts)));
            }
        }
    }
    min
}

/// Recompute the event-time watermark into `local_state.watermark_ms`
/// (clamped monotone). When `upstream_required` (this stage consumes an
/// event-timed handoff), the data-derived candidate is additionally
/// bounded by the *locked* upstream cap — the upstream fleet watermark
/// that was current before the most recent caught-up (empty) read. Every
/// row ingested before that read is covered by the buffered/frontier
/// terms; every row appended after it was still buffered upstream at that
/// moment, so the [`crate::dataflow::EmitReducer`] event-time contract
/// puts its event time at or above the cap. Without a locked cap the
/// watermark holds entirely.
fn update_event_watermark(inner: &mut MapperInner, upstream_required: bool) {
    let (frontier, closed_at, exhausted, caught_up_cap) = match &inner.event {
        Some(ev) => (
            ev.frontier,
            ev.closed_at,
            ev.exhausted_after_close,
            ev.caught_up_cap,
        ),
        None => return,
    };
    let data = match buffered_event_min(inner) {
        Some(m) => m,
        None => {
            // Nothing buffered: everything ingested so far is committed,
            // so the watermark is the frontier (exclusive). After a close
            // + a post-close empty read, the partition is complete and
            // the watermark lifts to the close timestamp.
            let base = if frontier == NO_WATERMARK {
                NO_WATERMARK
            } else {
                frontier.saturating_add(1)
            };
            match closed_at {
                Some(c) if exhausted => base.max(c),
                _ => base,
            }
        }
    };
    let candidate = if upstream_required {
        match caught_up_cap {
            Some(cap) => data.min(cap),
            None => NO_WATERMARK,
        }
    } else {
        data
    };
    if candidate != NO_WATERMARK && candidate > inner.local_state.watermark_ms {
        inner.local_state.watermark_ms = candidate;
    }
}

/// Event-time housekeeping, on the trim cadence: poll the close marker,
/// refresh the pending upstream cap (the next empty read locks it in),
/// recompute the local watermark and record the gauge. No-op when event
/// time is disabled.
fn maybe_update_event_time(sh: &Arc<MapperShared>) {
    if sh.cfg.event_time.is_none() {
        return;
    }
    // Both reads happen outside the window lock (plain store reads).
    let closed = fetch_close(&sh.client.store, &sh.cfg.mapper_state_table);
    let upstream_required = sh.cfg.upstream_watermark_table.is_some();
    let upstream = sh.cfg.upstream_watermark_table.as_ref().and_then(|t| {
        WatermarkTracker::new(sh.client.store.clone(), t.clone()).fleet_watermark()
    });
    let wm = {
        let mut inner = util::lock(&sh.inner);
        if let Some(ev) = inner.event.as_mut() {
            if let Some(c) = closed {
                if ev.closed_at < Some(c) {
                    ev.closed_at = Some(c);
                }
            }
            if let Some(u) = upstream {
                ev.pending_upstream_cap =
                    Some(ev.pending_upstream_cap.map_or(u, |p: i64| p.max(u)));
            }
        }
        update_event_watermark(&mut inner, upstream_required);
        inner.local_state.watermark_ms
    };
    if wm != NO_WATERMARK {
        sh.metrics
            .series(&names::mapper_watermark(sh.index))
            .record(sh.client.clock.now_ms(), wm as f64);
    }
}

/// `TrimInputRows` (§4.3.5): transactional CAS of the persistent state to
/// LocalMapperState, then trim the input partition. Also the watermark's
/// persistence point: the `watermark_ms` column rides the same CAS, so
/// event time adds **no** new write path.
fn maybe_trim_input(sh: &Arc<MapperShared>, reader: &mut dyn PartitionReader, last_trim_ms: &mut u64) {
    let now = sh.client.clock.now_ms();
    if now.saturating_sub(*last_trim_ms) < sh.cfg.trim_period_ms {
        return;
    }
    *last_trim_ms = now;
    maybe_update_event_time(sh);

    let (local, persisted) = {
        let inner = util::lock(&sh.inner);
        (inner.local_state.clone(), inner.persisted_state.clone())
    };
    if local.input_unread_row_index <= persisted.input_unread_row_index
        && local.watermark_ms <= persisted.watermark_ms
    {
        return; // nothing new to persist
    }

    // Flight recorder: the trim commit's trace id hashes the input
    // segment this CAS makes trimmable — the same `[persisted, local)`
    // range the cold chunk below compacts, so the ingest, the trim and
    // any later backfill read of that chunk share one trace id.
    let obs_on = sh.metrics.recorder().enabled();
    let (span_start, span_trace) = if obs_on {
        (
            now,
            obs::trace_id(&[(
                sh.index,
                persisted.input_unread_row_index,
                local.input_unread_row_index,
            )]),
        )
    } else {
        (0, 0)
    };

    let state_table = &sh.cfg.mapper_state_table;
    let key = MapperState::key(sh.index);
    let mut txn = sh.client.begin();
    let committed = match txn.lookup(state_table, &key) {
        Ok(Some(row)) => match MapperState::from_row(&row) {
            Some(s) => s,
            None => return,
        },
        _ => return,
    };
    // "If it is equal to the state stored in PersistedMapperState and
    // LocalMapperState is further along than the committed state, the
    // method tries to update the remote state…"
    if committed != persisted {
        if obs_on {
            sh.record_span(
                "trim",
                span_trace,
                txn.read_set_len(),
                SpanOutcome::Abdicated,
                [0; CATEGORY_COUNT],
                span_start,
            );
        }
        return; // split brain — the ingestion loop will handle it
    }
    if txn.write(state_table, local.to_row(sh.index)).is_err() {
        return;
    }
    // Compact-on-trim ([`crate::coldtier`]): the segment this commit will
    // make trimmable — `[persisted.input_unread_row_index,
    // local.input_unread_row_index)` — is re-read and compacted into one
    // immutable cold chunk *inside the trim CAS*. Commit semantics do all
    // the correctness work: a split-brain twin's chunk aborts with its
    // losing CAS; a crash after commit but before the `trim` call below
    // re-trims later without re-compacting (the manifest row exists, and
    // `compact_into` is idempotent on it); the chunk chain is continuous
    // by induction because each chunk covers exactly one committed state
    // advance (chunk id = begin row index).
    if let Some(cold_cfg) = &sh.cfg.cold_tier {
        if local.input_unread_row_index > persisted.input_unread_row_index {
            let begin = persisted.input_unread_row_index;
            let end = local.input_unread_row_index;
            match reader.read(begin, end, &persisted.continuation_token) {
                Ok(batch) if batch.rowset.len() as i64 == end - begin => {
                    let cold =
                        crate::coldtier::ColdStore::from_config(sh.client.store.clone(), cold_cfg);
                    let ts_col = crate::queue::INPUT_COL_WRITE_TS;
                    if cold
                        .compact_into(
                            &mut txn,
                            sh.index,
                            crate::coldtier::KIND_SEGMENT,
                            begin,
                            begin,
                            &batch.rowset,
                            Some(ts_col),
                            None,
                        )
                        .is_err()
                    {
                        return; // store blip: keep the segment, retry next period
                    }
                }
                // Short or failed re-read (e.g. a twin already trimmed the
                // segment after winning the CAS we are about to lose):
                // don't commit a hole into the chunk chain — the CAS check
                // has the committed row in its read set, so if we *are*
                // the winner this is a transient store fault and the next
                // period retries with the segment still retained.
                _ => return,
            }
        }
    }
    let read_set = txn.read_set_len();
    match txn.commit() {
        Ok(res) => {
            if obs_on {
                sh.record_span(
                    "trim",
                    span_trace,
                    read_set,
                    SpanOutcome::Committed,
                    res.bytes_by_category,
                    span_start,
                );
            }
            {
                let mut inner = util::lock(&sh.inner);
                inner.persisted_state = local.clone();
            }
            // "…and calls Trim on the partition reader."
            let _ = reader.trim(local.input_unread_row_index, &local.continuation_token);
        }
        Err(TxnError::Conflict { table, key, .. }) => {
            // Raced a twin; the ingestion loop handles the reset.
            if obs_on {
                sh.record_span(
                    "trim",
                    span_trace,
                    read_set,
                    SpanOutcome::Conflicted {
                        losing_row: format!("{table}/{key:?}"),
                    },
                    [0; CATEGORY_COUNT],
                    span_start,
                );
            }
        }
        Err(_) => {
            // Transient store failure; retried next period.
            if obs_on {
                sh.record_span(
                    "trim",
                    span_trace,
                    read_set,
                    SpanOutcome::Error,
                    [0; CATEGORY_COUNT],
                    span_start,
                );
            }
        }
    }
}

/// §6 spill: detach straggler buckets' rows from the window. Operates on
/// the *active* (newest) epoch's buckets — a draining epoch's buckets are
/// short-lived by construction and are never spilled.
fn try_spill(sh: &Arc<MapperShared>) {
    let mut inner = util::lock(&sh.inner);
    let Some(pos) = inner.epochs.len().checked_sub(1) else {
        return;
    };
    let heads: Vec<Option<u64>> = inner.epochs[pos]
        .buckets
        .iter()
        .map(|b| b.first_entry_index())
        .collect();
    let front = inner.window.first_entry_index();
    let victims = pick_straggler_buckets(
        inner.window.total_bytes(),
        sh.cfg.memory_limit_bytes,
        sh.cfg.spill.trigger_fraction,
        sh.cfg.spill.straggler_quorum,
        &heads,
        front,
    );
    if victims.is_empty() {
        return;
    }
    let mut spilled_rows = 0u64;
    for b in victims {
        // Detach the bucket's whole queue: every queued row moves to the
        // persisted spill queue, the window loses the pin.
        let rows: Vec<BucketRow> = inner.epochs[pos].buckets[b]
            .peek(usize::MAX)
            .copied()
            .collect();
        let old_head = inner.epochs[pos].buckets[b].first_entry_index();
        let event_col = inner.event.as_ref().and_then(|ev| ev.col);
        let detached: Vec<(i64, Option<i64>, crate::rows::UnversionedRow)> = rows
            .iter()
            .map(|r| {
                let row = inner
                    .window
                    .get(r.entry_index)
                    .and_then(|e| e.row_at_shuffle_index(r.shuffle_index))
                    // protolint: allow(panic, "a bucket head pins its window entry (bucket_ptr_count), so queued rows are resident by construction; a miss is in-process queue corruption")
                    .expect("spill source row must be resident")
                    .clone();
                // Cache the event time with the record so the watermark
                // query never decodes spilled rows.
                let event_ts = event_col.and_then(|c| row.get(c).and_then(Value::as_i64));
                (r.shuffle_index, event_ts, row)
            })
            .collect();
        // The whole detached run becomes one spill record batch: one
        // encode pass and one journal operation instead of per-row ones.
        let batch: Vec<(i64, Option<i64>, &crate::rows::UnversionedRow)> =
            detached.iter().map(|(s, ts, r)| (*s, *ts, r)).collect();
        inner.epochs[pos].spilled[b].push_batch(&batch);
        spilled_rows += batch.len() as u64;
        inner.epochs[pos].buckets[b].ack(i64::MAX); // drain the in-memory queue
        if let Some(old) = old_head {
            if let Some(e) = inner.window.get_mut(old) {
                e.bucket_ptr_count -= 1;
            }
        }
    }
    inner.trim_window_entries();
    let bytes = inner.window.total_bytes();
    drop(inner);
    sh.metrics.add(names::SPILL_ROWS, spilled_rows);
    sh.record_window_gauge(bytes);
    sh.mem_freed.notify_all();
}
