//! The mapper worker (§4.3): input ingestion, in-memory window, GetRows
//! service, trimming, split-brain defence.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::api::{Client, Mapper, MapperSpec};
use crate::coordinator::bucket::{BucketRow, BucketState};
use crate::coordinator::config::ProcessorConfig;
use crate::coordinator::state::MapperState;
use crate::coordinator::window::{WindowEntry, WindowQueue};
use crate::cypress::DiscoveryGroup;
use crate::dyntable::TxnError;
use crate::metrics::hub::names;
use crate::metrics::MetricsHub;
use crate::queue::{PartitionReader, INPUT_COL_WRITE_TS};
use crate::rows::{codec, NameTable};
use crate::rpc::{ReqGetRows, Request, Response, RpcNet, RpcService, RspGetRows};
use crate::spill::{pick_straggler_buckets, SpillQueue};
use crate::storage::{Journal, WriteCategory};
use crate::util::Guid;

/// Mutable mapper internals shared between the ingestion thread and the
/// GetRows RPC handler (§4.3.1's "internal state").
pub(crate) struct MapperInner {
    pub window: WindowQueue,
    pub buckets: Vec<BucketState>,
    pub spilled: Vec<SpillQueue>,
    /// LocalMapperState: lower bound advanced by TrimWindowEntries.
    pub local_state: MapperState,
    /// PersistedMapperState: last state this instance committed/observed.
    pub persisted_state: MapperState,
    /// Output name table, known after the first mapped batch.
    pub out_name_table: Option<Arc<NameTable>>,
}

impl MapperInner {
    fn new(num_reducers: usize, spill_journal: impl Fn(usize) -> Arc<Journal>) -> MapperInner {
        MapperInner {
            window: WindowQueue::new(),
            buckets: (0..num_reducers).map(|_| BucketState::new()).collect(),
            spilled: (0..num_reducers)
                .map(|r| SpillQueue::new(spill_journal(r)))
                .collect(),
            local_state: MapperState::initial(),
            persisted_state: MapperState::initial(),
            out_name_table: None,
        }
    }

    /// Split-brain reset: "the internal state is dropped" (§4.3.3 step 3).
    fn reset(&mut self, fresh: MapperState) {
        self.window.clear();
        for b in &mut self.buckets {
            b.clear();
        }
        for s in &mut self.spilled {
            s.clear();
        }
        self.local_state = fresh.clone();
        self.persisted_state = fresh;
    }

    /// `TrimWindowEntries` (§4.3.5): advance past fully-acknowledged
    /// entries and fold the result into LocalMapperState.
    fn trim_window_entries(&mut self) -> usize {
        match self.window.trim_front() {
            Some(outcome) => {
                self.local_state = MapperState {
                    input_unread_row_index: outcome.input_unread_row_index,
                    shuffle_unread_row_index: outcome.shuffle_unread_row_index,
                    continuation_token: outcome.continuation_token.clone(),
                };
                outcome.entries_popped
            }
            None => 0,
        }
    }
}

/// Everything the RPC service and ingestion loop share.
pub(crate) struct MapperShared {
    pub cfg: ProcessorConfig,
    pub index: usize,
    pub guid: Guid,
    pub address: String,
    pub client: Client,
    pub metrics: Arc<MetricsHub>,
    pub inner: Mutex<MapperInner>,
    /// Signalled whenever window memory is freed (step 8's semaphore).
    pub mem_freed: Condvar,
    pub pause: Arc<AtomicBool>,
    pub kill: Arc<AtomicBool>,
}

impl MapperShared {
    fn record_window_gauge(&self, bytes: usize) {
        self.metrics
            .series(&names::mapper_window_bytes(self.index))
            .record(self.client.clock.now_ms(), bytes as f64);
    }
}

/// The GetRows RPC endpoint (§4.3.4).
pub(crate) struct MapperService {
    shared: Arc<MapperShared>,
}

impl MapperService {
    /// Steps 1–4 of the GetRows procedure.
    fn get_rows(&self, req: ReqGetRows) -> Result<RspGetRows, String> {
        let sh = &self.shared;
        // Step 1: stale-discovery defence.
        if req.mapper_id != sh.guid.to_string() {
            return Err(format!(
                "mapper id mismatch: request for {} but this is {}",
                req.mapper_id, sh.guid
            ));
        }
        let reducer = req.reducer_index as usize;
        let mut inner = sh.inner.lock().unwrap();
        if reducer >= inner.buckets.len() {
            return Err(format!("reducer index {reducer} out of range"));
        }

        // Step 2: pop acknowledged rows and maintain bucket pointers.
        inner.spilled[reducer].ack(req.committed_row_index);
        let ack = inner.buckets[reducer].ack(req.committed_row_index);
        if ack.old_head_entry != ack.new_head_entry {
            if let Some(old) = ack.old_head_entry {
                if let Some(e) = inner.window.get_mut(old) {
                    e.bucket_ptr_count -= 1;
                }
            }
            if let Some(new) = ack.new_head_entry {
                if let Some(e) = inner.window.get_mut(new) {
                    e.bucket_ptr_count += 1;
                }
            }
        }

        // Step 3: trimming. TrimWindowEntries is cheap and runs inline;
        // TrimInputRows is transactional and runs on its own cadence in
        // the ingestion thread (§4.3.5's two-method split).
        if inner.trim_window_entries() > 0 {
            let bytes = inner.window.total_bytes();
            drop(inner);
            sh.record_window_gauge(bytes);
            sh.mem_freed.notify_all();
            inner = sh.inner.lock().unwrap();
        }

        // Step 4: serve up to `count` rows *without* removing them.
        // Encoded straight from window references — no per-row clones
        // (§Perf optimization 2).
        let want = req.count.max(0) as usize;
        let mut last_shuffle = -1i64;
        let spilled_rows: Vec<(i64, crate::rows::UnversionedRow)> =
            inner.spilled[reducer].peek(want);
        if let Some((s, _)) = spilled_rows.last() {
            last_shuffle = *s;
        }
        let remaining = want - spilled_rows.len();
        let picks: Vec<BucketRow> = inner.buckets[reducer].peek(remaining).copied().collect();
        if let Some(r) = picks.last() {
            last_shuffle = r.shuffle_index;
        }

        if spilled_rows.is_empty() && picks.is_empty() {
            return Ok(RspGetRows::empty());
        }
        let nt = inner
            .out_name_table
            .clone()
            .expect("rows served before any batch was mapped");
        let mut refs: Vec<&crate::rows::UnversionedRow> =
            Vec::with_capacity(spilled_rows.len() + picks.len());
        refs.extend(spilled_rows.iter().map(|(_, r)| r));
        for r in &picks {
            let entry = inner
                .window
                .get(r.entry_index)
                .expect("bucket row references trimmed entry");
            refs.push(
                entry
                    .row_at_shuffle_index(r.shuffle_index)
                    .expect("shuffle index outside its entry"),
            );
        }
        let row_count = refs.len() as i64;
        // One exactly-sized encode plus one bulk Vec→Arc copy; after that
        // every downstream holder (transport, reducer decode, retries)
        // bumps a refcount instead of copying the payload.
        let attachment = codec::encode_rowset_refs(&nt, &refs);
        Ok(RspGetRows {
            row_count,
            last_shuffle_row_index: last_shuffle,
            attachment: attachment.into(),
        })
    }
}

impl RpcService for MapperService {
    fn handle(&self, req: Request) -> Result<Response, String> {
        // A paused worker models a hung process: no responses at all.
        if self.shared.pause.load(Ordering::SeqCst) {
            return Err("mapper unresponsive (paused)".into());
        }
        match req {
            Request::Ping => Ok(Response::Pong),
            Request::GetRows(r) => self.get_rows(r).map(Response::GetRows),
        }
    }
}

/// Dependencies handed to a mapper instance at spawn.
pub struct MapperDeps {
    pub client: Client,
    pub net: Arc<RpcNet>,
    pub metrics: Arc<MetricsHub>,
    pub discovery: DiscoveryGroup,
}

/// Control handle for one running mapper instance.
pub struct MapperHandle {
    pub index: usize,
    pub guid: Guid,
    pub address: String,
    kill: Arc<AtomicBool>,
    pause: Arc<AtomicBool>,
    join: std::thread::JoinHandle<()>,
}

impl MapperHandle {
    /// Simulate a hang (§5.2 drills): ingestion stops, RPCs error, the
    /// discovery session stops heartbeating.
    pub fn set_paused(&self, paused: bool) {
        self.pause.store(paused, Ordering::SeqCst);
    }

    /// Crash the worker. The thread exits; nothing is cleaned up except
    /// the RPC registration (a dead process's sockets close; its discovery
    /// entry lingers until TTL expiry).
    pub fn kill(&self) {
        self.kill.store(true, Ordering::SeqCst);
    }

    pub fn is_finished(&self) -> bool {
        self.join.is_finished()
    }

    pub fn join(self) {
        let _ = self.join.join();
    }
}

/// Spawn a mapper instance: ingestion thread + RPC registration +
/// discovery membership. `user_mapper` is the product of the user's
/// factory; `reader` is the partition reader for this mapper's partition.
pub fn spawn_mapper(
    cfg: ProcessorConfig,
    spec: MapperSpec,
    deps: MapperDeps,
    mut user_mapper: Box<dyn Mapper>,
    mut reader: Box<dyn PartitionReader>,
) -> MapperHandle {
    let kill = Arc::new(AtomicBool::new(false));
    let pause = Arc::new(AtomicBool::new(false));
    let address = format!("mapper-{}/{}", spec.index, spec.guid);
    let accounting = deps.client.store.accounting();
    let num_reducers = spec.num_reducers;
    let mapper_index = spec.index;

    let shared = Arc::new(MapperShared {
        cfg: cfg.clone(),
        index: spec.index,
        guid: spec.guid,
        address: address.clone(),
        client: deps.client.clone(),
        metrics: deps.metrics.clone(),
        inner: Mutex::new(MapperInner::new(num_reducers, |r| {
            Journal::new_scoped(
                format!("spill/m{mapper_index}/r{r}"),
                WriteCategory::Spill,
                accounting.clone(),
                cfg.scope_label.clone(),
            )
        })),
        mem_freed: Condvar::new(),
        pause: pause.clone(),
        kill: kill.clone(),
    });

    deps.net.register(
        &address,
        Arc::new(MapperService {
            shared: shared.clone(),
        }),
    );

    let join = std::thread::Builder::new()
        .name(format!("mapper-{}", spec.index))
        .spawn({
            let shared = shared.clone();
            let net = deps.net.clone();
            let discovery = deps.discovery.clone();
            move || {
                run_ingestion(&shared, &spec, &discovery, user_mapper.as_mut(), reader.as_mut());
                net.unregister(&shared.address);
            }
        })
        .expect("spawn mapper thread");

    MapperHandle {
        index: shared.index,
        guid: shared.guid,
        address,
        kill,
        pause,
        join,
    }
}

/// The input ingestion procedure (§4.3.3) plus the TrimInputRows cadence.
fn run_ingestion(
    sh: &Arc<MapperShared>,
    spec: &MapperSpec,
    discovery: &DiscoveryGroup,
    user_mapper: &mut dyn Mapper,
    reader: &mut dyn PartitionReader,
) {
    let clock = sh.client.clock.clone();
    let cfg = &sh.cfg;
    let state_table = &spec.state_table;
    let state_key = MapperState::key(sh.index);

    // Join discovery, waiting out a live predecessor if needed.
    let session = sh.client.cypress.open_session(cfg.session_ttl_ms);
    loop {
        if sh.kill.load(Ordering::SeqCst) {
            return;
        }
        match discovery.join(session, &sh.guid.to_string(), &sh.address, sh.index as i64, sh.guid) {
            Ok(()) => break,
            Err(_) => clock.sleep_ms(cfg.backoff_ms),
        }
    }

    // Initial state fetch (§4.3.3: "Initially, it fetches its corresponding
    // row from the state table"), creating the row if this is a fresh
    // processor.
    let mut cur = loop {
        if sh.kill.load(Ordering::SeqCst) {
            return;
        }
        match sh.client.store.lookup(state_table, &state_key) {
            Ok(Some(row)) => match MapperState::from_row(&row) {
                Some(s) => break s,
                None => {
                    clock.sleep_ms(cfg.backoff_ms);
                }
            },
            Ok(None) => {
                let mut txn = sh.client.begin();
                let init = MapperState::initial();
                if txn.write(state_table, init.to_row(sh.index)).is_ok() && txn.commit().is_ok() {
                    break init;
                }
                clock.sleep_ms(cfg.backoff_ms);
            }
            Err(_) => clock.sleep_ms(cfg.backoff_ms),
        }
    };
    {
        let mut inner = sh.inner.lock().unwrap();
        inner.local_state = cur.clone();
        inner.persisted_state = cur.clone();
    }

    let lag_series = sh.metrics.series(&names::mapper_read_lag(sh.index));
    let mut last_trim_ms = clock.now_ms();
    let mut last_heartbeat_ms = clock.now_ms();
    let mut last_batch_empty = false;

    // The continuous ingestion cycle (§4.3.3 steps 1–8).
    while !sh.kill.load(Ordering::SeqCst) {
        if sh.pause.load(Ordering::SeqCst) {
            // A hung worker: no reads, no heartbeats, no trims.
            std::thread::sleep(Duration::from_millis(1));
            continue;
        }
        heartbeat_if_due(sh, session, &mut last_heartbeat_ms);

        // Step 1: back-off if the previous iteration appended nothing.
        if last_batch_empty {
            clock.sleep_ms(cfg.backoff_ms);
        }
        last_batch_empty = true;

        // Step 2: next batch from the partition reader.
        let batch = match reader.read(
            cur.input_unread_row_index,
            cur.input_unread_row_index + cfg.read_batch_rows as i64,
            &cur.continuation_token,
        ) {
            Ok(b) => b,
            Err(_) => continue, // partition outage: retry after backoff
        };

        // Step 3: split-brain check against the remote persistent state.
        let remote = match sh.client.store.lookup(state_table, &state_key) {
            Ok(Some(row)) => match MapperState::from_row(&row) {
                Some(s) => s,
                None => continue,
            },
            _ => continue, // state backend error: skip to next iteration
        };
        let persisted = sh.inner.lock().unwrap().persisted_state.clone();
        if remote != persisted {
            // "we are in a split-brain situation and the mapper waits out a
            // configurable delay, after which the internal state is dropped
            // and the whole input ingestion procedure is restarted."
            sh.metrics.add(names::MAPPER_SPLIT_BRAIN, 1);
            clock.sleep_ms(cfg.split_brain_delay_ms);
            let fresh = match sh.client.store.lookup(state_table, &state_key) {
                Ok(Some(row)) => MapperState::from_row(&row).unwrap_or_else(MapperState::initial),
                _ => continue,
            };
            sh.inner.lock().unwrap().reset(fresh.clone());
            cur = fresh;
            sh.record_window_gauge(0);
            continue;
        }

        // Step 4: empty batch → next iteration (with backoff).
        if batch.rowset.is_empty() {
            maybe_trim_input(sh, reader, &mut last_trim_ms);
            continue;
        }
        last_batch_empty = false;

        let n_in = batch.rowset.len() as i64;
        let input_bytes = batch.rowset.byte_size();

        // Read-lag metric: now − newest producer write timestamp.
        if let Some(last_row) = batch.rowset.rows().last() {
            if let Some(ts) = last_row.get(INPUT_COL_WRITE_TS).and_then(|v| v.as_i64()) {
                let lag = clock.now_ms() as i64 - ts;
                lag_series.record(clock.now_ms(), lag.max(0) as f64);
            }
        }

        // Step 5: run the user Map and build the window entry.
        let mapped = user_mapper.map(batch.rowset);
        if let Err(e) = mapped.validate(sh.cfg.reducer_count) {
            panic!("user Map produced invalid output: {e}");
        }
        let n_out = mapped.rowset.len() as i64;

        sh.metrics.add(names::MAPPER_ROWS_READ, n_in as u64);
        sh.metrics.add(names::MAPPER_ROWS_MAPPED, n_out as u64);
        sh.metrics.add(names::MAPPER_BYTES_READ, input_bytes as u64);

        // Step 6: push into the window and distribute to buckets.
        {
            let mut inner = sh.inner.lock().unwrap();
            if inner.out_name_table.is_none() && n_out > 0 {
                inner.out_name_table = Some(mapped.rowset.name_table().clone());
            }
            let entry_index = inner.window.next_entry_index();
            let byte_size = mapped.rowset.byte_size();
            let entry = WindowEntry {
                entry_index,
                rowset: mapped.rowset,
                input_begin: cur.input_unread_row_index,
                input_end: cur.input_unread_row_index + n_in,
                shuffle_begin: cur.shuffle_unread_row_index,
                shuffle_end: cur.shuffle_unread_row_index + n_out,
                continuation_token: batch.next_token.clone(),
                bucket_ptr_count: 0,
                byte_size,
                read_ts_ms: clock.now_ms(),
            };
            inner.window.push(entry);
            for (i, &reducer) in mapped.partition_indexes.iter().enumerate() {
                let shuffle_index = cur.shuffle_unread_row_index + i as i64;
                let became_head = inner.buckets[reducer].push(BucketRow {
                    shuffle_index,
                    entry_index,
                });
                if became_head {
                    inner
                        .window
                        .get_mut(entry_index)
                        .unwrap()
                        .bucket_ptr_count += 1;
                }
            }
            // An entry no bucket points into (all rows filtered, or zero
            // output) is immediately trimmable; fold it into local state.
            inner.trim_window_entries();
            sh.record_window_gauge(inner.window.total_bytes());
        }

        // Step 7: advance the cursor.
        cur.input_unread_row_index += n_in;
        cur.shuffle_unread_row_index += n_out;
        cur.continuation_token = batch.next_token;

        // §6 straggler spill (feature-gated).
        if cfg.spill.enabled {
            try_spill(sh);
        }

        // TrimInputRows cadence (§4.3.5: "regularly with a
        // configuration-defined period").
        maybe_trim_input(sh, reader, &mut last_trim_ms);

        // Step 8: memory semaphore.
        {
            let mut inner = sh.inner.lock().unwrap();
            while inner.window.total_bytes() > cfg.memory_limit_bytes
                && !sh.kill.load(Ordering::SeqCst)
                && !sh.pause.load(Ordering::SeqCst)
            {
                if cfg.spill.enabled {
                    drop(inner);
                    try_spill(sh);
                    inner = sh.inner.lock().unwrap();
                    if inner.window.total_bytes() <= cfg.memory_limit_bytes {
                        break;
                    }
                }
                let (guard, _timeout) = sh
                    .mem_freed
                    .wait_timeout(inner, Duration::from_millis(2))
                    .unwrap();
                inner = guard;
                drop(inner);
                heartbeat_if_due(sh, session, &mut last_heartbeat_ms);
                maybe_trim_input(sh, reader, &mut last_trim_ms);
                inner = sh.inner.lock().unwrap();
            }
        }
    }
}

fn heartbeat_if_due(sh: &MapperShared, session: crate::cypress::SessionId, last: &mut u64) {
    let now = sh.client.clock.now_ms();
    if now.saturating_sub(*last) >= sh.cfg.heartbeat_period_ms {
        let _ = sh.client.cypress.heartbeat(session);
        *last = now;
    }
}

/// `TrimInputRows` (§4.3.5): transactional CAS of the persistent state to
/// LocalMapperState, then trim the input partition.
fn maybe_trim_input(sh: &Arc<MapperShared>, reader: &mut dyn PartitionReader, last_trim_ms: &mut u64) {
    let now = sh.client.clock.now_ms();
    if now.saturating_sub(*last_trim_ms) < sh.cfg.trim_period_ms {
        return;
    }
    *last_trim_ms = now;

    let (local, persisted) = {
        let inner = sh.inner.lock().unwrap();
        (inner.local_state.clone(), inner.persisted_state.clone())
    };
    if local.input_unread_row_index <= persisted.input_unread_row_index {
        return; // nothing new to persist
    }

    let state_table = &sh.cfg.mapper_state_table;
    let key = MapperState::key(sh.index);
    let mut txn = sh.client.begin();
    let committed = match txn.lookup(state_table, &key) {
        Ok(Some(row)) => match MapperState::from_row(&row) {
            Some(s) => s,
            None => return,
        },
        _ => return,
    };
    // "If it is equal to the state stored in PersistedMapperState and
    // LocalMapperState is further along than the committed state, the
    // method tries to update the remote state…"
    if committed != persisted {
        return; // split brain — the ingestion loop will handle it
    }
    if txn.write(state_table, local.to_row(sh.index)).is_err() {
        return;
    }
    match txn.commit() {
        Ok(_) => {
            {
                let mut inner = sh.inner.lock().unwrap();
                inner.persisted_state = local.clone();
            }
            // "…and calls Trim on the partition reader."
            let _ = reader.trim(local.input_unread_row_index, &local.continuation_token);
        }
        Err(TxnError::Conflict { .. }) => { /* raced a twin; loop handles it */ }
        Err(_) => { /* transient store failure; retried next period */ }
    }
}

/// §6 spill: detach straggler buckets' rows from the window.
fn try_spill(sh: &Arc<MapperShared>) {
    let mut inner = sh.inner.lock().unwrap();
    let heads: Vec<Option<u64>> = inner.buckets.iter().map(|b| b.first_entry_index()).collect();
    let front = inner.window.first_entry_index();
    let victims = pick_straggler_buckets(
        inner.window.total_bytes(),
        sh.cfg.memory_limit_bytes,
        sh.cfg.spill.trigger_fraction,
        sh.cfg.spill.straggler_quorum,
        &heads,
        front,
    );
    if victims.is_empty() {
        return;
    }
    let mut spilled_rows = 0u64;
    for b in victims {
        // Detach the bucket's whole queue: every queued row moves to the
        // persisted spill queue, the window loses the pin.
        let rows: Vec<BucketRow> = inner.buckets[b].peek(usize::MAX).copied().collect();
        let old_head = inner.buckets[b].first_entry_index();
        for r in &rows {
            let row = inner
                .window
                .get(r.entry_index)
                .and_then(|e| e.row_at_shuffle_index(r.shuffle_index))
                .expect("spill source row must be resident")
                .clone();
            inner.spilled[b].push(r.shuffle_index, &row);
            spilled_rows += 1;
        }
        inner.buckets[b].ack(i64::MAX); // drain the in-memory queue
        if let Some(old) = old_head {
            if let Some(e) = inner.window.get_mut(old) {
                e.bucket_ptr_count -= 1;
            }
        }
    }
    inner.trim_window_entries();
    let bytes = inner.window.total_bytes();
    drop(inner);
    sh.metrics.add(names::SPILL_ROWS, spilled_rows);
    sh.record_window_gauge(bytes);
    sh.mem_freed.notify_all();
}
