//! Lag- and backlog-driven autoscaling policy.
//!
//! A pure decision loop: feed it periodic [`LoadSignal`] observations and
//! it proposes partition-count changes with hysteresis, so transient
//! spikes and the post-reshard catch-up dip do not thrash the fleet. The
//! resident driver ([`crate::reshard::driver`]) gathers the signals from
//! [`crate::metrics::MetricsHub`] and executes proposals through
//! [`crate::coordinator::StreamingProcessor::begin_reshard`] /
//! `finish_reshard`; manual callers (figure drivers, operator loops) can
//! still tick it by hand.
//!
//! Signal fusion: retained-row backlog alone under-reports overload when
//! trims stall (a wedged trim keeps the backlog *constant* while consumers
//! fall behind), so the policy fuses three signals:
//!
//! * **backlog per reducer** — rows retained in the stage's input;
//! * **read lag** — worst per-mapper `read_lag_ms` mean over the recent
//!   window (how stale the rows being ingested are);
//! * **commit latency** — worst per-reducer `commit_latency_ms` mean over
//!   the recent window (how long a row waits producer→commit).
//!
//! The stage is *overloaded* when **any** signal crosses its high
//! watermark (scale up fast), and *over-provisioned* only when **all**
//! signals sit below their low watermarks (scale down conservatively). A
//! missing lag signal (no samples in the window — e.g. a fully drained
//! input) counts as "below": an idle stage must still be able to shrink.
//!
//! Policy shape (Muppet-style load-watermark scaling):
//! * scale **up** (double, capped) when overloaded for `hysteresis_ticks`
//!   consecutive observations;
//! * scale **down** (halve, floored) when over-provisioned just as long;
//! * after an **executed** proposal, hold off for `cooldown_ms` — a
//!   migration must drain before its effect is measurable.
//!
//! Propose vs. acknowledge: [`Autoscaler::observe`] never arms the
//! cooldown itself. The driver calls [`Autoscaler::acknowledge`] once the
//! reshard actually *began*; a proposal that was rejected (e.g. a
//! migration already in flight, a store outage) leaves the cooldown
//! unarmed so the very next observation can re-propose, instead of the
//! lost proposal silencing the policy for a full cooldown.

/// One fused observation of a stage's load.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LoadSignal {
    /// Rows retained in the stage's input.
    pub backlog_rows: usize,
    /// Worst per-mapper read-lag mean (ms) over the recent window; `None`
    /// when no mapper recorded a sample in the window.
    pub read_lag_ms: Option<f64>,
    /// Worst per-reducer commit-latency mean (ms) over the recent window;
    /// `None` when no reducer committed in the window.
    pub commit_latency_ms: Option<f64>,
}

impl LoadSignal {
    /// A backlog-only observation (manual ticking, unit tests).
    pub fn backlog(rows: usize) -> LoadSignal {
        LoadSignal {
            backlog_rows: rows,
            ..LoadSignal::default()
        }
    }
}

/// Tunables of the policy loop.
#[derive(Debug, Clone)]
pub struct AutoscalerConfig {
    /// Backlog rows per reducer above which the stage is overloaded.
    pub backlog_high_per_reducer: f64,
    /// Backlog rows per reducer below which the stage is over-provisioned.
    pub backlog_low_per_reducer: f64,
    /// Read lag (ms) above which the stage is overloaded regardless of
    /// backlog (the trim-stall case).
    pub lag_high_ms: f64,
    /// Read lag (ms) the stage must sit below before a scale-down.
    pub lag_low_ms: f64,
    /// Commit latency (ms) above which the stage is overloaded.
    pub latency_high_ms: f64,
    /// Commit latency (ms) the stage must sit below before a scale-down.
    pub latency_low_ms: f64,
    /// Consecutive out-of-band observations required before proposing.
    pub hysteresis_ticks: u32,
    /// Minimum simulated time between *executed* proposals (armed by
    /// [`Autoscaler::acknowledge`], not by proposing).
    pub cooldown_ms: u64,
    pub min_reducers: usize,
    pub max_reducers: usize,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            backlog_high_per_reducer: 2_000.0,
            backlog_low_per_reducer: 200.0,
            lag_high_ms: 30_000.0,
            lag_low_ms: 5_000.0,
            latency_high_ms: 20_000.0,
            latency_low_ms: 5_000.0,
            hysteresis_ticks: 3,
            cooldown_ms: 5_000,
            min_reducers: 1,
            max_reducers: 64,
        }
    }
}

/// A proposed partition-count change.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScaleDecision {
    pub from: usize,
    pub to: usize,
}

/// The stateful policy loop.
#[derive(Debug)]
pub struct Autoscaler {
    cfg: AutoscalerConfig,
    above_streak: u32,
    below_streak: u32,
    /// Time of the last *acknowledged* (actually begun) reshard.
    last_executed_ms: Option<u64>,
}

impl Autoscaler {
    pub fn new(cfg: AutoscalerConfig) -> Autoscaler {
        Autoscaler {
            cfg,
            above_streak: 0,
            below_streak: 0,
            last_executed_ms: None,
        }
    }

    pub fn config(&self) -> &AutoscalerConfig {
        &self.cfg
    }

    /// Is any high watermark crossed?
    fn overloaded(&self, s: &LoadSignal, current: usize) -> bool {
        let per_reducer = s.backlog_rows as f64 / current as f64;
        per_reducer > self.cfg.backlog_high_per_reducer
            || s.read_lag_ms.is_some_and(|l| l > self.cfg.lag_high_ms)
            || s.commit_latency_ms
                .is_some_and(|l| l > self.cfg.latency_high_ms)
    }

    /// Are *all* signals below their low watermarks? Missing lag signals
    /// count as below (an idle stage must be able to shrink).
    fn underloaded(&self, s: &LoadSignal, current: usize) -> bool {
        let per_reducer = s.backlog_rows as f64 / current as f64;
        per_reducer < self.cfg.backlog_low_per_reducer
            && s.read_lag_ms.map_or(true, |l| l < self.cfg.lag_low_ms)
            && s.commit_latency_ms
                .map_or(true, |l| l < self.cfg.latency_low_ms)
    }

    /// Feed one fused observation; returns a proposal when the watermark
    /// streak and cooldown both allow one. Proposing does **not** arm the
    /// cooldown — the caller reports execution via
    /// [`Autoscaler::acknowledge`]; an unexecuted proposal may be
    /// re-proposed on the next observation (the streak is kept).
    pub fn observe(
        &mut self,
        now_ms: u64,
        signal: &LoadSignal,
        current_reducers: usize,
    ) -> Option<ScaleDecision> {
        // During the cooldown the stage is mid-migration (or just out of
        // one): its signals say nothing about the new fleet yet, so these
        // observations must not count toward a streak — otherwise the
        // first tick past the cooldown would fire on pre-drain data,
        // exactly the thrash the cooldown exists to prevent.
        if let Some(last) = self.last_executed_ms {
            if now_ms.saturating_sub(last) < self.cfg.cooldown_ms {
                self.above_streak = 0;
                self.below_streak = 0;
                return None;
            }
        }

        let current = current_reducers.max(1);
        if self.overloaded(signal, current) {
            self.above_streak += 1;
            self.below_streak = 0;
        } else if self.underloaded(signal, current) {
            self.below_streak += 1;
            self.above_streak = 0;
        } else {
            self.above_streak = 0;
            self.below_streak = 0;
        }

        let target = if self.above_streak >= self.cfg.hysteresis_ticks {
            (current * 2).min(self.cfg.max_reducers)
        } else if self.below_streak >= self.cfg.hysteresis_ticks {
            (current / 2).max(self.cfg.min_reducers)
        } else {
            return None;
        };
        if target == current {
            return None;
        }
        Some(ScaleDecision {
            from: current,
            to: target,
        })
    }

    /// The driver reports that a proposed reshard actually *began*: arm
    /// the cooldown and reset the streaks. Never called for rejected
    /// proposals — their streak survives, so the retry is immediate once
    /// the blocker (an in-flight migration, a store outage) clears.
    pub fn acknowledge(&mut self, now_ms: u64) {
        self.above_streak = 0;
        self.below_streak = 0;
        self.last_executed_ms = Some(now_ms);
    }

    /// Backlog-only convenience wrapper around [`Autoscaler::observe`]
    /// (manual ticking; the figure demo and older call sites). Same
    /// propose/acknowledge contract.
    pub fn tick(
        &mut self,
        now_ms: u64,
        backlog_rows: usize,
        current_reducers: usize,
    ) -> Option<ScaleDecision> {
        self.observe(now_ms, &LoadSignal::backlog(backlog_rows), current_reducers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscalerConfig {
        AutoscalerConfig {
            backlog_high_per_reducer: 100.0,
            backlog_low_per_reducer: 10.0,
            lag_high_ms: 1_000.0,
            lag_low_ms: 100.0,
            latency_high_ms: 1_000.0,
            latency_low_ms: 100.0,
            hysteresis_ticks: 3,
            cooldown_ms: 1_000,
            min_reducers: 2,
            max_reducers: 16,
        }
    }

    #[test]
    fn scale_up_needs_full_streak() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.tick(0, 1_000, 4), None);
        assert_eq!(a.tick(100, 1_000, 4), None);
        assert_eq!(
            a.tick(200, 1_000, 4),
            Some(ScaleDecision { from: 4, to: 8 }),
            "third consecutive high observation proposes a doubling"
        );
    }

    #[test]
    fn streak_resets_on_in_band_observation() {
        let mut a = Autoscaler::new(cfg());
        a.tick(0, 1_000, 4);
        a.tick(100, 1_000, 4);
        assert_eq!(a.tick(200, 200, 4), None, "50/reducer is in band");
        assert_eq!(a.tick(300, 1_000, 4), None, "streak restarted");
    }

    #[test]
    fn scale_down_halves_with_floor() {
        let mut a = Autoscaler::new(cfg());
        for t in 0..2 {
            assert_eq!(a.tick(t * 100, 0, 8), None);
        }
        assert_eq!(a.tick(300, 0, 8), Some(ScaleDecision { from: 8, to: 4 }));
        // Floor: 2 never halves to 1 with min_reducers = 2.
        let mut b = Autoscaler::new(cfg());
        for t in 0..10 {
            let d = b.tick(t * 2_000, 0, 2);
            assert_eq!(d, None, "already at the floor");
        }
    }

    #[test]
    fn cooldown_arms_on_acknowledge_only() {
        let mut a = Autoscaler::new(cfg());
        for t in 0..2 {
            assert_eq!(a.tick(t * 100, 10_000, 4), None);
        }
        let d = a.tick(200, 10_000, 4).expect("streak complete");
        assert_eq!(d, ScaleDecision { from: 4, to: 8 });
        // The proposal was NOT executed (say, a migration was already in
        // flight): no cooldown — the streak survives and the very next
        // high observation re-proposes.
        assert_eq!(
            a.tick(300, 10_000, 4),
            Some(ScaleDecision { from: 4, to: 8 }),
            "rejected proposal must be retried, not swallowed by a cooldown"
        );
        // Now the driver executes it and acknowledges.
        a.acknowledge(400);
        for t in 4..13 {
            assert_eq!(a.tick(t * 100, 10_000, 8), None, "cooldown holds");
        }
        // Past the cooldown the streak (rebuilt) may propose again.
        let mut fired = None;
        for t in 15..40 {
            if let Some(d) = a.tick(t * 100, 10_000, 8) {
                fired = Some(d);
                break;
            }
        }
        assert_eq!(fired, Some(ScaleDecision { from: 8, to: 16 }));
    }

    #[test]
    fn cap_at_max_reducers() {
        let mut a = Autoscaler::new(cfg());
        for t in 0..10 {
            if let Some(d) = a.tick(t * 2_000, 100_000, 16) {
                panic!("proposed past the cap: {d:?}");
            }
        }
    }

    #[test]
    fn lag_alone_scales_up_despite_small_backlog() {
        // The trim-stall case: backlog looks tame (trims wedged, retained
        // rows constant) but read lag climbs — the fused policy must still
        // scale up.
        let mut a = Autoscaler::new(cfg());
        let stalled = LoadSignal {
            backlog_rows: 40, // 10/reducer: between the watermarks
            read_lag_ms: Some(5_000.0),
            commit_latency_ms: None,
        };
        assert_eq!(a.observe(0, &stalled, 4), None);
        assert_eq!(a.observe(100, &stalled, 4), None);
        assert_eq!(
            a.observe(200, &stalled, 4),
            Some(ScaleDecision { from: 4, to: 8 }),
            "high read lag must trigger a scale-up on its own"
        );
    }

    #[test]
    fn commit_latency_alone_scales_up() {
        let mut a = Autoscaler::new(cfg());
        let slow = LoadSignal {
            backlog_rows: 0,
            read_lag_ms: None,
            commit_latency_ms: Some(9_999.0),
        };
        a.observe(0, &slow, 2);
        a.observe(100, &slow, 2);
        assert_eq!(
            a.observe(200, &slow, 2),
            Some(ScaleDecision { from: 2, to: 4 })
        );
    }

    #[test]
    fn shrink_requires_all_signals_low() {
        let mut a = Autoscaler::new(cfg());
        // Backlog is near zero but commit latency is still high: no shrink.
        let mixed = LoadSignal {
            backlog_rows: 0,
            read_lag_ms: None,
            commit_latency_ms: Some(500.0),
        };
        for t in 0..10 {
            assert_eq!(a.observe(t * 100, &mixed, 8), None, "latency in band blocks shrink");
        }
        // All signals quiet (lag None = drained input counts as below).
        let quiet = LoadSignal {
            backlog_rows: 0,
            read_lag_ms: None,
            commit_latency_ms: Some(50.0),
        };
        let mut fired = None;
        for t in 10..20 {
            if let Some(d) = a.observe(t * 100, &quiet, 8) {
                fired = Some(d);
                break;
            }
        }
        assert_eq!(fired, Some(ScaleDecision { from: 8, to: 4 }));
    }
}
