//! Backlog-driven autoscaling policy.
//!
//! A pure decision loop: feed it periodic backlog observations (rows
//! retained in the stage's input — the same number
//! [`crate::coordinator::InputSpec::retained_rows`] and the per-stage
//! backlog metrics report) and it proposes partition-count changes with
//! hysteresis, so transient spikes and the post-reshard catch-up dip do
//! not thrash the fleet. The caller (figure drivers, the elastic workload
//! scenario, an operator loop) executes proposals via
//! [`crate::coordinator::StreamingProcessor::reshard`].
//!
//! Policy shape (Muppet-style load-watermark scaling):
//! * scale **up** (double, capped) when backlog per reducer stays above
//!   the high watermark for `hysteresis_ticks` consecutive observations;
//! * scale **down** (halve, floored) when it stays below the low
//!   watermark just as long;
//! * after any proposal, hold off for `cooldown_ms` — a migration must
//!   drain before its effect is measurable.

/// Tunables of the policy loop.
#[derive(Debug, Clone)]
pub struct AutoscalerConfig {
    /// Backlog rows per reducer above which the stage is overloaded.
    pub backlog_high_per_reducer: f64,
    /// Backlog rows per reducer below which the stage is over-provisioned.
    pub backlog_low_per_reducer: f64,
    /// Consecutive out-of-band observations required before proposing.
    pub hysteresis_ticks: u32,
    /// Minimum simulated time between proposals.
    pub cooldown_ms: u64,
    pub min_reducers: usize,
    pub max_reducers: usize,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            backlog_high_per_reducer: 2_000.0,
            backlog_low_per_reducer: 200.0,
            hysteresis_ticks: 3,
            cooldown_ms: 5_000,
            min_reducers: 1,
            max_reducers: 64,
        }
    }
}

/// A proposed partition-count change.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScaleDecision {
    pub from: usize,
    pub to: usize,
}

/// The stateful policy loop.
#[derive(Debug)]
pub struct Autoscaler {
    cfg: AutoscalerConfig,
    above_streak: u32,
    below_streak: u32,
    last_proposal_ms: Option<u64>,
}

impl Autoscaler {
    pub fn new(cfg: AutoscalerConfig) -> Autoscaler {
        Autoscaler {
            cfg,
            above_streak: 0,
            below_streak: 0,
            last_proposal_ms: None,
        }
    }

    pub fn config(&self) -> &AutoscalerConfig {
        &self.cfg
    }

    /// Feed one observation; returns a proposal when the watermark streak
    /// and cooldown both allow one. The caller decides whether to execute
    /// it (and keeps ticking either way).
    pub fn tick(
        &mut self,
        now_ms: u64,
        backlog_rows: usize,
        current_reducers: usize,
    ) -> Option<ScaleDecision> {
        // During the cooldown the stage is mid-migration (or just out of
        // one): its backlog says nothing about the new fleet yet, so
        // these observations must not count toward a streak — otherwise
        // the first tick past the cooldown would fire on pre-drain data,
        // exactly the thrash the cooldown exists to prevent.
        if let Some(last) = self.last_proposal_ms {
            if now_ms.saturating_sub(last) < self.cfg.cooldown_ms {
                self.above_streak = 0;
                self.below_streak = 0;
                return None;
            }
        }

        let current = current_reducers.max(1);
        let per_reducer = backlog_rows as f64 / current as f64;

        if per_reducer > self.cfg.backlog_high_per_reducer {
            self.above_streak += 1;
            self.below_streak = 0;
        } else if per_reducer < self.cfg.backlog_low_per_reducer {
            self.below_streak += 1;
            self.above_streak = 0;
        } else {
            self.above_streak = 0;
            self.below_streak = 0;
        }

        let target = if self.above_streak >= self.cfg.hysteresis_ticks {
            (current * 2).min(self.cfg.max_reducers)
        } else if self.below_streak >= self.cfg.hysteresis_ticks {
            (current / 2).max(self.cfg.min_reducers)
        } else {
            return None;
        };
        if target == current {
            return None;
        }
        self.above_streak = 0;
        self.below_streak = 0;
        self.last_proposal_ms = Some(now_ms);
        Some(ScaleDecision {
            from: current,
            to: target,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscalerConfig {
        AutoscalerConfig {
            backlog_high_per_reducer: 100.0,
            backlog_low_per_reducer: 10.0,
            hysteresis_ticks: 3,
            cooldown_ms: 1_000,
            min_reducers: 2,
            max_reducers: 16,
        }
    }

    #[test]
    fn scale_up_needs_full_streak() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.tick(0, 1_000, 4), None);
        assert_eq!(a.tick(100, 1_000, 4), None);
        assert_eq!(
            a.tick(200, 1_000, 4),
            Some(ScaleDecision { from: 4, to: 8 }),
            "third consecutive high observation proposes a doubling"
        );
    }

    #[test]
    fn streak_resets_on_in_band_observation() {
        let mut a = Autoscaler::new(cfg());
        a.tick(0, 1_000, 4);
        a.tick(100, 1_000, 4);
        assert_eq!(a.tick(200, 200, 4), None, "50/reducer is in band");
        assert_eq!(a.tick(300, 1_000, 4), None, "streak restarted");
    }

    #[test]
    fn scale_down_halves_with_floor() {
        let mut a = Autoscaler::new(cfg());
        for t in 0..2 {
            assert_eq!(a.tick(t * 100, 0, 8), None);
        }
        assert_eq!(a.tick(300, 0, 8), Some(ScaleDecision { from: 8, to: 4 }));
        // Floor: 2 never halves to 1 with min_reducers = 2.
        let mut b = Autoscaler::new(cfg());
        for t in 0..10 {
            let d = b.tick(t * 2_000, 0, 2);
            assert_eq!(d, None, "already at the floor");
        }
    }

    #[test]
    fn cooldown_suppresses_back_to_back_proposals() {
        let mut a = Autoscaler::new(cfg());
        for t in 0..3 {
            a.tick(t * 100, 10_000, 4);
        }
        // Proposal fired at t=200. Keep observing high backlog within the
        // cooldown window: silence.
        for t in 3..10 {
            assert_eq!(a.tick(t * 100, 10_000, 8), None);
        }
        // Past the cooldown the streak (rebuilt) may propose again.
        let mut fired = None;
        for t in 13..30 {
            if let Some(d) = a.tick(t * 100, 10_000, 8) {
                fired = Some(d);
                break;
            }
        }
        assert_eq!(fired, Some(ScaleDecision { from: 8, to: 16 }));
    }

    #[test]
    fn cap_at_max_reducers() {
        let mut a = Autoscaler::new(cfg());
        for t in 0..10 {
            if let Some(d) = a.tick(t * 2_000, 100_000, 16) {
                panic!("proposed past the cap: {d:?}");
            }
        }
    }
}
