//! The reshard plan: a small state machine persisted in the stage's
//! dyntable meta-state.
//!
//! One row (key `stage = 0`) in the processor's plan table holds the
//! current partition map and, while a reshard is in flight, the target
//! map:
//!
//! ```text
//!   Stable(epoch e, N partitions)
//!       │ Resharder::begin — CAS
//!       ▼
//!   Migrating(epoch e → e+1, N → M)
//!       │ every mapper CAS-adopts a cutover; every epoch-e reducer
//!       │ drains, exports residual state, CAS-retires
//!       │ Resharder::finalize — CAS, validates all retirements
//!       ▼
//!   Stable(epoch e+1, M partitions)
//! ```
//!
//! Everything reads the plan through ordinary lookups and validates it
//! inside commit transactions — the migration rides the existing
//! split-brain CAS, no new consensus mechanism. Plan bytes are accounted
//! as [`crate::storage::WriteCategory::Reshard`].

use crate::rows::{ColumnSchema, ColumnType, TableSchema, UnversionedRow, Value};

/// Phase of the plan state machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanPhase {
    /// One partition map, `epoch`/`partitions`, is authoritative.
    Stable,
    /// Epoch `epoch` (with `partitions` reducers) is being drained in
    /// favour of epoch `epoch + 1` (with `next_partitions` reducers).
    Migrating,
}

/// The persisted plan row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReshardPlan {
    pub phase: PlanPhase,
    /// Current authoritative epoch (the *old* epoch while migrating).
    pub epoch: i64,
    /// Reducer count of `epoch`.
    pub partitions: usize,
    /// Reducer count of `epoch + 1` while migrating; meaningless (0) when
    /// stable.
    pub next_partitions: usize,
}

impl ReshardPlan {
    /// The plan a freshly launched processor persists.
    pub fn initial(partitions: usize) -> ReshardPlan {
        ReshardPlan {
            phase: PlanPhase::Stable,
            epoch: 0,
            partitions,
            next_partitions: 0,
        }
    }

    /// Epoch mappers must adopt and new reducers belong to, while
    /// migrating.
    pub fn next_epoch(&self) -> i64 {
        self.epoch + 1
    }

    /// Begin a migration towards `new_partitions` (pure transition; the
    /// caller CASes it in).
    pub fn begin_migration(&self, new_partitions: usize) -> Option<ReshardPlan> {
        if self.phase != PlanPhase::Stable
            || new_partitions == 0
            || new_partitions == self.partitions
        {
            return None;
        }
        Some(ReshardPlan {
            phase: PlanPhase::Migrating,
            epoch: self.epoch,
            partitions: self.partitions,
            next_partitions: new_partitions,
        })
    }

    /// Finalize the in-flight migration (pure transition).
    pub fn finalized(&self) -> Option<ReshardPlan> {
        if self.phase != PlanPhase::Migrating {
            return None;
        }
        Some(ReshardPlan {
            phase: PlanPhase::Stable,
            epoch: self.epoch + 1,
            partitions: self.next_partitions,
            next_partitions: 0,
        })
    }

    pub fn schema() -> TableSchema {
        TableSchema::new(vec![
            ColumnSchema::key("stage", ColumnType::Int64),
            ColumnSchema::value("phase", ColumnType::Str),
            ColumnSchema::value("epoch", ColumnType::Int64),
            ColumnSchema::value("partitions", ColumnType::Int64),
            ColumnSchema::value("next_partitions", ColumnType::Int64),
        ])
    }

    pub fn to_row(&self) -> UnversionedRow {
        UnversionedRow::new(vec![
            Value::Int64(0),
            Value::from(match self.phase {
                PlanPhase::Stable => "stable",
                PlanPhase::Migrating => "migrating",
            }),
            Value::Int64(self.epoch),
            Value::Int64(self.partitions as i64),
            Value::Int64(self.next_partitions as i64),
        ])
    }

    pub fn from_row(row: &UnversionedRow) -> Option<ReshardPlan> {
        let phase = match row.get(1)?.as_str()? {
            "stable" => PlanPhase::Stable,
            "migrating" => PlanPhase::Migrating,
            _ => return None,
        };
        Some(ReshardPlan {
            phase,
            epoch: row.get(2)?.as_i64()?,
            partitions: row.get(3)?.as_i64()? as usize,
            next_partitions: row.get(4)?.as_i64()? as usize,
        })
    }

    /// The plan table's single row key.
    pub fn key() -> Vec<Value> {
        vec![Value::Int64(0)]
    }

    /// Plain (non-transactional) fetch from a store: `None` on a store
    /// error, a missing row, or a corrupt row. The one shared poll every
    /// worker and driver uses; transactional validation goes through
    /// `txn.lookup` + [`ReshardPlan::from_row`] instead.
    pub fn fetch(
        store: &crate::dyntable::DynTableStore,
        plan_table: &str,
    ) -> Option<ReshardPlan> {
        match store.lookup(plan_table, &Self::key()) {
            Ok(Some(row)) => Self::from_row(&row),
            _ => None,
        }
    }
}

/// Per-epoch reducer state table path: epoch 0 keeps the configured path
/// (backwards compatible), later epochs get their own table so the CAS
/// domains of concurrent fleets never collide.
pub fn reducer_state_table(base: &str, epoch: i64) -> String {
    if epoch == 0 {
        base.to_string()
    } else {
        format!("{base}/e{epoch}")
    }
}

/// Migration handoff table path for the fleet bootstrapping epoch `epoch`.
pub fn migration_table(plan_table: &str, epoch: i64) -> String {
    format!("{plan_table}/migration/e{epoch}")
}

/// Supervisor slot index of reducer `index` in `epoch` — epochs get
/// disjoint slot ranges so a reshard can add its fleet next to the old one
/// under one supervisor.
pub fn reducer_slot(epoch: i64, index: usize) -> usize {
    epoch as usize * EPOCH_SLOT_STRIDE + index
}

/// Reducer slot stride between epochs (bounds a single epoch's fleet).
pub const EPOCH_SLOT_STRIDE: usize = 10_000;

/// A mapper's view of the partition maps it routes for: the pure model of
/// "which epoch and which reducer owns a shuffle row". The miniprop suite
/// checks this function is total and exclusive over (shuffle index, key).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EpochRouting {
    /// Current epoch and its reducer count.
    pub epoch: i64,
    pub partitions: usize,
    /// Previous epoch's reducer count while its fleet still drains
    /// (`None` once the plan went stable past it).
    pub old_partitions: Option<usize>,
    /// Shuffle index where `epoch`'s map took over.
    pub cutover: i64,
    /// Shuffle index where the previous epoch's map took over; rows below
    /// it were committed before that epoch retired.
    pub prev_cutover: i64,
}

/// Where one shuffle row goes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteTarget {
    /// Owned by reducer `.1` of epoch `.0`.
    Epoch(i64, usize),
    /// Below every live epoch's range: committed before the last finalized
    /// reshard, never re-routed.
    Committed,
}

impl EpochRouting {
    /// Routing for a processor that never resharded.
    pub fn stable(epoch: i64, partitions: usize, cutover: i64, prev_cutover: i64) -> EpochRouting {
        EpochRouting {
            epoch,
            partitions,
            old_partitions: None,
            cutover,
            prev_cutover,
        }
    }

    /// Route one shuffle row given its key hash. Total: every
    /// (shuffle index, hash) has exactly one target.
    pub fn route(&self, shuffle_index: i64, key_hash: u64) -> RouteTarget {
        if shuffle_index >= self.cutover {
            return RouteTarget::Epoch(
                self.epoch,
                crate::api::partitioning::owner(key_hash, self.partitions),
            );
        }
        match self.old_partitions {
            Some(old) if shuffle_index >= self.prev_cutover => {
                RouteTarget::Epoch(self.epoch - 1, crate::api::partitioning::owner(key_hash, old))
            }
            // Either below the previous cutover (committed before the
            // previous reshard finalized) or the old fleet is fully
            // retired (plan stable ⇒ everything below cutover committed).
            _ => RouteTarget::Committed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_roundtrip_and_transitions() {
        let p = ReshardPlan::initial(4);
        assert_eq!(ReshardPlan::from_row(&p.to_row()), Some(p.clone()));
        assert_eq!(p.phase, PlanPhase::Stable);

        let m = p.begin_migration(8).unwrap();
        assert_eq!(m.phase, PlanPhase::Migrating);
        assert_eq!(m.partitions, 4);
        assert_eq!(m.next_partitions, 8);
        assert_eq!(m.next_epoch(), 1);
        assert_eq!(ReshardPlan::from_row(&m.to_row()), Some(m.clone()));
        ReshardPlan::schema().validate(&m.to_row()).unwrap();

        let f = m.finalized().unwrap();
        assert_eq!(f, ReshardPlan {
            phase: PlanPhase::Stable,
            epoch: 1,
            partitions: 8,
            next_partitions: 0,
        });

        // Illegal transitions are rejected.
        assert!(p.begin_migration(4).is_none(), "no-op resize");
        assert!(p.begin_migration(0).is_none());
        assert!(m.begin_migration(2).is_none(), "already migrating");
        assert!(p.finalized().is_none(), "nothing to finalize");
    }

    #[test]
    fn state_table_paths_per_epoch() {
        assert_eq!(reducer_state_table("//sys/p/reducer_state", 0), "//sys/p/reducer_state");
        assert_eq!(
            reducer_state_table("//sys/p/reducer_state", 2),
            "//sys/p/reducer_state/e2"
        );
        assert_eq!(migration_table("//sys/p/reshard_plan", 1), "//sys/p/reshard_plan/migration/e1");
        assert_eq!(reducer_slot(0, 3), 3);
        assert_eq!(reducer_slot(2, 3), 2 * EPOCH_SLOT_STRIDE + 3);
    }

    #[test]
    fn routing_is_total_and_exclusive() {
        // Migrating 4 → 8 with cutover at 100 over [40, ∞).
        let r = EpochRouting {
            epoch: 1,
            partitions: 8,
            old_partitions: Some(4),
            cutover: 100,
            prev_cutover: 40,
        };
        assert_eq!(r.route(39, 7), RouteTarget::Committed);
        assert!(matches!(r.route(40, 7), RouteTarget::Epoch(0, o) if o < 4));
        assert!(matches!(r.route(99, 7), RouteTarget::Epoch(0, _)));
        assert!(matches!(r.route(100, 7), RouteTarget::Epoch(1, o) if o < 8));

        // After the old fleet retires, sub-cutover rows are committed.
        let s = EpochRouting::stable(1, 8, 100, 40);
        assert_eq!(s.route(99, 7), RouteTarget::Committed);
        assert!(matches!(s.route(100, 7), RouteTarget::Epoch(1, _)));
    }
}
