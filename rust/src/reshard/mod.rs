//! Elastic resharding: live partition-count changes with exactly-once
//! state migration, plus the backlog-driven autoscaler that proposes them.
//!
//! The paper's processor bakes its reducer count in for life; a production
//! system serving heavy traffic must resize while running. This subsystem
//! changes a live stage's reducer partition count N → M without stopping
//! ingestion and without breaking exactly-once or batch-invariant output.
//! A reshard epoch is itself a small state machine persisted in the
//! stage's dyntable meta-state ([`plan::ReshardPlan`]):
//!
//! 1. **Begin** — the driver CASes the plan `Stable(e,N)` →
//!    `Migrating(e→e+1, N→M)` and spawns the epoch-e+1 fleet beside the
//!    old one ([`resharder::begin`]).
//! 2. **Cutover** — each mapper observes the plan (discovery-by-lookup on
//!    its trim cadence), CAS-adopts a per-mapper *cutover shuffle index*
//!    into its own state row, and from then on dual-routes: rows below the
//!    cutover stay in the old epoch's bucket set, rows at or above it go
//!    to the new epoch's buckets under the new partition map. Because the
//!    cutover rides the mapper-state CAS, split-brain twins always agree
//!    on where the map changed — and the reducer-side commit validation
//!    (plan + mapper state in the commit read set) makes a stale twin's
//!    mis-routed serve unable to commit.
//! 3. **Drain & retire** — each old reducer keeps its normal
//!    fetch/process/commit cycle until every mapper reports its (epoch,
//!    reducer) bucket drained, then commits a final transaction that (a)
//!    CAS-bumps its state row to retired and (b) `append_ordered`s its
//!    residual grouped state into the migration handoff table
//!    ([`migration`]) — exactly like a dataflow inter-stage handoff,
//!    accounted as [`crate::storage::WriteCategory::Reshard`] so the WA
//!    cost of rescaling is measured honestly.
//! 4. **Bootstrap** — new reducers consume their migration tablet inside
//!    a transaction that CAS-marks them bootstrapped, then serve their
//!    key range.
//! 5. **Finalize** — once every old reducer retired, the driver CASes the
//!    plan `Stable(e+1, M)` with all retirements in the read set
//!    ([`resharder::finalize`]); mappers then drop the old bucket sets.
//!
//! On top sits the policy half: the [`autoscaler`] is a pure watermark
//! loop fusing backlog with read-lag / commit-latency signals, and the
//! [`driver`] is the *resident* incarnation — owned by the processor,
//! gathering its own signals from [`crate::metrics::MetricsHub`],
//! executing its own proposals, and resuming any migration a crashed
//! driver left behind (the plan row is the recovery point).
//! [`crate::dataflow`] re-wires adjacent stages when an intermediate
//! stage reshards (handoff tablets grow, downstream mapper fleets re-spec
//! against the new tablet count) and runs the same loop topology-wide
//! ([`crate::dataflow::TopologyAutoscaler`]).

pub mod autoscaler;
pub mod driver;
pub mod migration;
pub mod plan;
pub mod resharder;

pub use autoscaler::{Autoscaler, AutoscalerConfig, LoadSignal, ScaleDecision};
pub use driver::{gather_signal, AutoscaleDriver, DriverConfig, DriverDeps};
pub use migration::{
    ExportCtx, ImportCtx, MetaStateExporter, NoopImporter, ReshardRuntime, ResidualExporter,
    ResidualImporter,
};
pub use plan::{EpochRouting, PlanPhase, ReshardPlan, RouteTarget};
pub use resharder::{ReshardContext, ReshardError, ReshardStats};
