//! Residual-state migration: how a retiring reducer hands what it owns to
//! the new partition map, exactly once.
//!
//! A retiring reducer's final transaction (a) CAS-bumps its state row to
//! retired and (b) `append_ordered`s its residual rows into the epoch's
//! **migration handoff table** — an ordered table with one tablet per
//! *new* reducer, exactly like a dataflow inter-stage handoff. The append
//! rides the retirement CAS, so split-brain twins cannot double-export.
//! New reducers bootstrap by consuming their tablet inside a transaction
//! that CAS-marks their state row `bootstrapped` — so the import also
//! happens exactly once. All migration bytes are accounted as
//! [`WriteCategory::Reshard`].
//!
//! What counts as residual state is workload-defined through
//! [`ResidualExporter`]/[`ResidualImporter`]. The default pair exports the
//! retiring reducer's committed row-index vector as an audit record (the
//! shared-output workloads keep their grouped state in key-addressed
//! tables that survive any partition map) and imports it as a no-op;
//! stateful workloads plug in their own.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::coordinator::state::ReducerState;
use crate::dyntable::{Transaction, TxnError};
use crate::queue::ordered_table::OrderedTable;
use crate::rows::{NameTable, UnversionedRow, Value};
use crate::storage::{WriteAccounting, WriteCategory};
use crate::util::yson::Yson;

use super::plan::migration_table;
use crate::util;

/// Columns of a migration-handoff row: which old reducer exported it, a
/// workload-defined kind tag, and an opaque payload.
pub fn residual_name_table() -> Arc<NameTable> {
    NameTable::new(&["origin_index", "kind", "payload"])
}

/// Context handed to a [`ResidualExporter`].
pub struct ExportCtx {
    /// Index of the retiring reducer within the old partition map.
    pub old_index: usize,
    pub old_partitions: usize,
    pub new_partitions: usize,
    /// The epoch being bootstrapped (old epoch + 1).
    pub new_epoch: i64,
    /// The retiring reducer's final committed state.
    pub state: ReducerState,
}

/// Context handed to a [`ResidualImporter`].
pub struct ImportCtx {
    /// Index of the importing reducer within the new partition map.
    pub new_index: usize,
    pub new_partitions: usize,
    pub epoch: i64,
}

/// Selects the residual rows a retiring reducer must hand off, grouped by
/// destination tablet (= new owner). Runs inside the retirement
/// transaction: lookups join its read set, so the export is CAS-protected
/// like everything else.
pub trait ResidualExporter: Send + Sync {
    fn export(
        &self,
        ctx: &ExportCtx,
        txn: &mut Transaction,
    ) -> Result<Vec<(usize, Vec<UnversionedRow>)>, TxnError>;
}

/// Applies one tablet's residual rows before the new reducer serves its
/// key range. Runs inside the bootstrap transaction (which also CAS-marks
/// the reducer bootstrapped), so it applies exactly once.
pub trait ResidualImporter: Send + Sync {
    fn import(
        &self,
        ctx: &ImportCtx,
        rows: &[UnversionedRow],
        txn: &mut Transaction,
    ) -> Result<(), TxnError>;
}

/// Default exporter: one audit row carrying the retiring reducer's
/// committed row-index vector, owned by `old_index % new_partitions`. It
/// keeps the migration path (and its WA accounting) exercised even for
/// workloads whose grouped state lives in shared key-addressed tables.
pub struct MetaStateExporter;

impl ResidualExporter for MetaStateExporter {
    fn export(
        &self,
        ctx: &ExportCtx,
        _txn: &mut Transaction,
    ) -> Result<Vec<(usize, Vec<UnversionedRow>)>, TxnError> {
        let payload = Yson::List(
            ctx.state
                .committed_row_indices
                .iter()
                .map(|v| Yson::Int(*v))
                .collect(),
        )
        .to_string();
        let row = UnversionedRow::new(vec![
            Value::Int64(ctx.old_index as i64),
            Value::from("committed_row_indices"),
            Value::from(payload.as_str()),
        ]);
        Ok(vec![(ctx.old_index % ctx.new_partitions, vec![row])])
    }
}

/// Default importer: the audit rows need no application.
pub struct NoopImporter;

impl ResidualImporter for NoopImporter {
    fn import(
        &self,
        _ctx: &ImportCtx,
        _rows: &[UnversionedRow],
        _txn: &mut Transaction,
    ) -> Result<(), TxnError> {
        Ok(())
    }
}

/// Shared reshard runtime of one streaming processor: the plan-table path
/// every worker polls, the exporter/importer pair, and the per-epoch
/// migration handoff tables (created lazily by whoever needs one first —
/// the same `Arc` is handed to every caller, so retiring appends and
/// bootstrap reads meet on one table).
pub struct ReshardRuntime {
    pub plan_table: String,
    pub exporter: Arc<dyn ResidualExporter>,
    pub importer: Arc<dyn ResidualImporter>,
    accounting: Arc<WriteAccounting>,
    scope: Option<String>,
    migrations: Mutex<HashMap<i64, Arc<OrderedTable>>>,
}

impl ReshardRuntime {
    pub fn new(
        plan_table: impl Into<String>,
        accounting: Arc<WriteAccounting>,
        scope: Option<String>,
    ) -> Arc<ReshardRuntime> {
        Arc::new(ReshardRuntime {
            plan_table: plan_table.into(),
            exporter: Arc::new(MetaStateExporter),
            importer: Arc::new(NoopImporter),
            accounting,
            scope,
            migrations: Mutex::new(HashMap::new()),
        })
    }

    /// Constructor with a custom exporter/importer pair (stateful
    /// workloads). Build this *before* launch and hand it to
    /// [`crate::coordinator::StreamingProcessor::launch_with_runtime`] —
    /// the runtime's identity is the sharing contract (retiring appends
    /// and bootstrap reads must meet on one `Arc`), so swapping migrators
    /// on a runtime that workers already hold is not offered.
    pub fn new_with_migrators(
        plan_table: impl Into<String>,
        accounting: Arc<WriteAccounting>,
        scope: Option<String>,
        exporter: Arc<dyn ResidualExporter>,
        importer: Arc<dyn ResidualImporter>,
    ) -> Arc<ReshardRuntime> {
        Arc::new(ReshardRuntime {
            plan_table: plan_table.into(),
            exporter,
            importer,
            accounting,
            scope,
            migrations: Mutex::new(HashMap::new()),
        })
    }

    /// The migration handoff table for the fleet bootstrapping `epoch`,
    /// with one tablet per new reducer. Idempotent get-or-create.
    pub fn migration_for(&self, epoch: i64, new_partitions: usize) -> Arc<OrderedTable> {
        let mut g = util::lock(&self.migrations);
        g.entry(epoch)
            .or_insert_with(|| {
                OrderedTable::new_scoped(
                    &migration_table(&self.plan_table, epoch),
                    residual_name_table(),
                    new_partitions,
                    self.accounting.clone(),
                    WriteCategory::Reshard,
                    self.scope.clone(),
                )
            })
            .clone()
    }

    /// Total rows ever appended to migration handoff tables (stats).
    pub fn migrated_rows(&self) -> i64 {
        let g = util::lock(&self.migrations);
        g.values()
            .map(|t| (0..t.tablet_count()).map(|i| t.end_index(i)).sum::<i64>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dyntable::DynTableStore;

    #[test]
    fn migration_table_is_shared_and_sized() {
        let acc = WriteAccounting::new();
        let rt = ReshardRuntime::new("//sys/p/reshard_plan", acc, None);
        let a = rt.migration_for(1, 8);
        let b = rt.migration_for(1, 8);
        assert!(Arc::ptr_eq(&a, &b), "one table per epoch");
        assert_eq!(a.tablet_count(), 8);
        assert_eq!(a.name(), "//sys/p/reshard_plan/migration/e1");
        assert_eq!(rt.migrated_rows(), 0);
    }

    #[test]
    fn default_exporter_emits_one_audit_row_to_stable_owner() {
        let acc = WriteAccounting::new();
        let store = DynTableStore::new(acc);
        let mut txn = store.begin();
        let ctx = ExportCtx {
            old_index: 5,
            old_partitions: 8,
            new_partitions: 4,
            new_epoch: 1,
            state: ReducerState {
                committed_row_indices: vec![10, -1, 7],
                retired: false,
                bootstrapped: true,
            },
        };
        let out = MetaStateExporter.export(&ctx, &mut txn).unwrap();
        assert_eq!(out.len(), 1);
        let (tablet, rows) = &out[0];
        assert_eq!(*tablet, 5 % 4);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get(0).unwrap().as_i64(), Some(5));
        assert_eq!(rows[0].get(1).unwrap().as_str(), Some("committed_row_indices"));
        assert!(rows[0].get(2).unwrap().as_str().unwrap().contains("10"));
        txn.abort();
    }

    #[test]
    fn residual_rows_are_accounted_as_reshard() {
        let acc = WriteAccounting::new();
        let rt = ReshardRuntime::new("//sys/p/plan", acc.clone(), Some("stage-x".into()));
        let mig = rt.migration_for(1, 2);
        mig.append(
            1,
            vec![UnversionedRow::new(vec![
                Value::Int64(0),
                Value::from("k"),
                Value::from("payload"),
            ])],
        )
        .unwrap();
        assert!(acc.bytes(WriteCategory::Reshard) > 0);
        assert_eq!(
            acc.scope_snapshot("stage-x").bytes_of(WriteCategory::Reshard),
            acc.bytes(WriteCategory::Reshard)
        );
        assert_eq!(rt.migrated_rows(), 1);
    }
}
