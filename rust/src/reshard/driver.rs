//! The resident autoscale driver: the policy half of elastic resharding,
//! running *inside* the processor instead of in the caller's hands.
//!
//! PR 3 built the mechanism (live N→M migrations) but left the policy
//! manual: callers ticked [`Autoscaler`] themselves, fed it only
//! retained-row backlog, and executed proposals by hand. This module
//! closes that loop (Muppet's load-watermark scaling and StreamShield's
//! resident resiliency controller are the shape targets):
//!
//! * **Resident** — [`AutoscaleDriver::start`] spawns a loop owned by the
//!   [`crate::coordinator::StreamingProcessor`] (started via
//!   `start_autoscaler`, stopped with the processor), so scaling needs no
//!   operator in the loop.
//! * **Signal-rich** — each tick fuses retained-row backlog with the
//!   fleet's `read_lag_ms` / `commit_latency_ms` series from
//!   [`MetricsHub`] ([`gather_signal`]); backlog alone under-reports
//!   overload when trims stall.
//! * **Self-healing** — the persisted plan row is the recovery point: a
//!   loop that starts (or restarts) over a plan left `Migrating` by a
//!   crashed driver resumes and finalizes that migration before making
//!   any new proposal.
//! * **Honest about rejection** — the cooldown arms only when a proposal's
//!   reshard actually *begins* ([`Autoscaler::acknowledge`]); a rejected
//!   proposal (migration already in flight, store outage) is retried on
//!   the next tick instead of being swallowed for a cooldown period.
//!
//! The driver executes through the same [`resharder`] entry points as the
//! manual path (`begin`/`finalize`/`resume`), so everything the workers
//! enforce — commit fencing, CAS retirement, bootstrap — is identical
//! whether a human or the driver asked for the resize.
//! [`crate::dataflow::TopologyAutoscaler`] runs the same loop body over
//! every stage of a running topology.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::InputSpec;
use crate::dyntable::DynTableStore;
use crate::metrics::hub::names;
use crate::metrics::MetricsHub;
use crate::util::Clock;

use super::autoscaler::{Autoscaler, AutoscalerConfig, LoadSignal, ScaleDecision};
use super::plan::{PlanPhase, ReshardPlan};
use super::resharder::{self, ReshardContext, ReshardError};
use crate::util;

/// Tunables of the resident loop.
#[derive(Debug, Clone)]
pub struct DriverConfig {
    /// The watermark policy the loop feeds.
    pub autoscaler: AutoscalerConfig,
    /// Observation cadence, simulated ms.
    pub tick_period_ms: u64,
    /// Lookback window for the lag/latency means, simulated ms. Series
    /// with no sample inside the window contribute `None` (treated as
    /// "not overloaded" — a drained input has no read lag).
    pub signal_window_ms: u64,
    /// Wall-clock budget for one migration to drain and finalize. The
    /// loop waits at most [`TICK_DRAIN_BUDGET_MS`] of it inside a single
    /// tick (so a topology sweep is never starved by one slow stage);
    /// the remainder is spent across subsequent ticks' resume branch —
    /// the plan stays `Migrating` in between and nothing is lost.
    pub reshard_timeout_ms: u64,
}

/// Longest a single tick blocks waiting for a migration to drain; slower
/// drains complete across later ticks via the resume branch.
pub const TICK_DRAIN_BUDGET_MS: u64 = 2_000;

impl Default for DriverConfig {
    fn default() -> Self {
        DriverConfig {
            autoscaler: AutoscalerConfig::default(),
            tick_period_ms: 500,
            signal_window_ms: 5_000,
            reshard_timeout_ms: 30_000,
        }
    }
}

/// Everything the loop needs from the processor it scales, detached from
/// the processor's lifetime so the thread owns no borrow of it.
pub struct DriverDeps {
    pub clock: Clock,
    pub store: Arc<DynTableStore>,
    /// The stage's reshard plan table (the single-row state machine).
    pub plan_table: String,
    /// The stage's metrics hub: lag signals in, autoscale counters out.
    pub metrics: Arc<MetricsHub>,
    /// The stage's input (backlog signal).
    pub input: InputSpec,
    /// Builds a fresh [`ReshardContext`] per use — the mapper count baked
    /// into a context can change under dataflow re-wiring.
    pub ctx: Arc<dyn Fn() -> ReshardContext + Send + Sync>,
    /// Called with the target partition count right before a migration
    /// begins (and again on resume — idempotent): a dataflow stage grows
    /// its handoff table here, so the incoming fleet owns a tablet before
    /// it ever serves. `None` for a stand-alone processor.
    pub pre_begin: Option<Arc<dyn Fn(usize) + Send + Sync>>,
    /// Called with the stable partition count after a migration
    /// finalizes (fresh or resumed): a dataflow stage re-wires its
    /// downstream mapper fleet here. `None` for a stand-alone processor.
    pub post_stable: Option<Arc<dyn Fn(usize) + Send + Sync>>,
}

/// Gather one fused observation from a stage's metrics hub + input.
pub fn gather_signal(
    metrics: &MetricsHub,
    backlog_rows: usize,
    now_ms: u64,
    window_ms: u64,
) -> LoadSignal {
    let from = now_ms.saturating_sub(window_ms);
    LoadSignal {
        backlog_rows,
        read_lag_ms: metrics.read_lag_signal(from),
        commit_latency_ms: metrics.commit_latency_signal(from),
    }
}

/// Stop-flag + join-handle pair shared by the resident loops
/// ([`AutoscaleDriver`], [`crate::dataflow::TopologyAutoscaler`]), so
/// their shutdown semantics can never drift apart. Dropping it does
/// *not* stop the thread; call [`LoopHandle::stop`].
pub(crate) struct LoopHandle {
    stop: Arc<AtomicBool>,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl LoopHandle {
    /// Spawn `body` on a named thread; `body` polls the passed stop flag.
    pub(crate) fn spawn(
        name: &'static str,
        body: impl FnOnce(&AtomicBool) + Send + 'static,
    ) -> LoopHandle {
        let stop = Arc::new(AtomicBool::new(false));
        let join = std::thread::Builder::new()
            .name(name.into())
            .spawn({
                let stop = stop.clone();
                move || body(&stop)
            })
            // protolint: allow(panic, "thread spawn fails only on OS resource exhaustion at driver startup; no protocol state exists yet")
            .unwrap_or_else(|e| panic!("spawn {name} thread: {e}"));
        LoopHandle {
            stop,
            join: Mutex::new(Some(join)),
        }
    }

    /// Signal the loop to exit and join it (idempotent).
    pub(crate) fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(join) = util::lock(&self.join).take() {
            let _ = join.join();
        }
    }
}

/// Handle to a running resident loop. Dropping it does *not* stop the
/// thread; call [`AutoscaleDriver::stop`] (the owning processor does, on
/// shutdown).
pub struct AutoscaleDriver {
    inner: LoopHandle,
}

impl AutoscaleDriver {
    /// Spawn the resident loop.
    pub fn start(cfg: DriverConfig, deps: DriverDeps) -> AutoscaleDriver {
        AutoscaleDriver {
            inner: LoopHandle::spawn("autoscale-driver", move |stop| {
                run_driver(&cfg, &deps, stop)
            }),
        }
    }

    /// Signal the loop to exit and join it. If a migration is mid-drain
    /// the loop abandons the wait at the next slice boundary; the plan row
    /// stays `Migrating` and is resumed by the next driver (or a manual
    /// `resume_reshard`).
    pub fn stop(&self) {
        self.inner.stop();
    }
}

/// One stage's worth of the resident loop body: resume-if-migrating,
/// otherwise observe and (maybe) execute. Shared verbatim by the
/// single-processor driver and the topology autoscaler so the two can
/// never drift. Returns the decision it executed, if any.
pub(crate) fn drive_stage_tick(
    cfg: &DriverConfig,
    deps: &DriverDeps,
    scaler: &mut Autoscaler,
    stop: &AtomicBool,
) -> Option<ScaleDecision> {
    let now = deps.clock.now_ms();
    let plan = ReshardPlan::fetch(&deps.store, &deps.plan_table)?;
    if plan.phase == PlanPhase::Migrating {
        // Crash-resume: someone (a dead driver, an interrupted manual
        // call) left a migration in flight. Finish it before proposing
        // anything — the plan row is the recovery point. The dead driver
        // may have died before the stage re-wiring too, so the pre-begin
        // hook runs again (idempotent).
        deps.metrics.add(names::AUTOSCALE_RESUMES, 1);
        if let Some(pre) = &deps.pre_begin {
            pre(plan.next_partitions);
        }
        if finish_migration(cfg, deps, stop) {
            scaler.acknowledge(deps.clock.now_ms());
            if let Some(post) = &deps.post_stable {
                post(plan.next_partitions);
            }
        }
        return None;
    }

    let signal = gather_signal(
        &deps.metrics,
        deps.input.retained_rows(),
        now,
        cfg.signal_window_ms,
    );
    let decision = scaler.observe(now, &signal, plan.partitions)?;
    deps.metrics.add(names::AUTOSCALE_PROPOSALS, 1);
    if let Some(pre) = &deps.pre_begin {
        pre(decision.to);
    }
    match resharder::begin(&(deps.ctx)(), decision.to) {
        Ok(_) => {
            // The reshard began: arm the cooldown and count the resize
            // now — even if the drain below outlives this tick's budget,
            // the migration is real and the resume branch finishes it.
            scaler.acknowledge(deps.clock.now_ms());
            deps.metrics.add(
                if decision.to > decision.from {
                    names::AUTOSCALE_GROWS
                } else {
                    names::AUTOSCALE_SHRINKS
                },
                1,
            );
            if finish_migration(cfg, deps, stop) {
                if let Some(post) = &deps.post_stable {
                    post(decision.to);
                }
            }
            Some(decision)
        }
        Err(_) => {
            // Rejected (plan raced to Migrating, store outage, …): no
            // cooldown — the streak survives and the next tick retries.
            deps.metrics.add(names::AUTOSCALE_REJECTED, 1);
            None
        }
    }
}

/// Wait for the in-flight migration to drain and finalize, in short
/// slices so a stop request interrupts promptly, bounded per call so one
/// slow stage cannot starve a topology sweep. True = finalized.
fn finish_migration(cfg: &DriverConfig, deps: &DriverDeps, stop: &AtomicBool) -> bool {
    let budget = cfg.reshard_timeout_ms.min(TICK_DRAIN_BUDGET_MS);
    let deadline = std::time::Instant::now() + std::time::Duration::from_millis(budget);
    while !stop.load(Ordering::SeqCst) && std::time::Instant::now() < deadline {
        match resharder::resume(&(deps.ctx)(), 250) {
            Ok(_) => return true,
            // Still draining (or a racing driver swapped the migration):
            // keep waiting out the budget.
            Err(ReshardError::Timeout { .. }) | Err(ReshardError::NotStable) => {}
            Err(_) => return false,
        }
    }
    false
}

fn run_driver(cfg: &DriverConfig, deps: &DriverDeps, stop: &AtomicBool) {
    let mut scaler = Autoscaler::new(cfg.autoscaler.clone());
    while !stop.load(Ordering::SeqCst) {
        drive_stage_tick(cfg, deps, &mut scaler, stop);
        deps.clock.sleep_ms(cfg.tick_period_ms);
    }
}
