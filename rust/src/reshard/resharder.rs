//! The reshard driver: executes one partition-count change N → M against
//! a running processor.
//!
//! [`begin`] CASes the plan from `Stable(e, N)` to `Migrating(e→e+1,
//! N→M)`, creates the new epoch's reducer state table and migration
//! handoff table, and adds the new fleet's supervision slots. From that
//! point the migration is carried by the workers themselves — mappers
//! adopt cutovers, old reducers drain and retire, new reducers bootstrap
//! — and [`finalize`] just waits for every old reducer's `retired` mark,
//! then CASes the plan to `Stable(e+1, M)` (validating all retirements in
//! the same transaction) and retires the old supervision slots.
//!
//! Crash-safety: the plan row *is* the recovery point. A driver that dies
//! mid-migration leaves `Migrating` persisted; re-running [`finalize`]
//! (or [`resume`]) picks the migration back up. Workers never depend on
//! the driver being alive.

use std::sync::Arc;

use crate::controller::{Role, Supervisor, WorkerHandle};
use crate::coordinator::state::ReducerState;
use crate::dyntable::{DynTableStore, TxnError};
use crate::metrics::hub::names;
use crate::metrics::MetricsHub;
use crate::obs::{SpanOutcome, TxnSpan, WorkerId};
use crate::storage::accounting::CATEGORY_COUNT;
use crate::storage::WriteCategory;

use super::migration::ReshardRuntime;
use super::plan::{reducer_slot, reducer_state_table, PlanPhase, ReshardPlan};

/// Everything the driver needs from the processor it reshapes.
pub struct ReshardContext {
    pub store: Arc<DynTableStore>,
    pub runtime: Arc<ReshardRuntime>,
    /// Base path of the reducer state tables (epoch suffixes are derived).
    pub reducer_state_base: String,
    /// Current mapper count (sizes new reducers' committed vectors).
    pub num_mappers: usize,
    pub supervisor: Arc<Supervisor>,
    /// Build + register a reducer worker for (epoch, index).
    pub spawn_reducer: Arc<dyn Fn(i64, usize) -> WorkerHandle + Send + Sync>,
    pub metrics: Arc<MetricsHub>,
    /// Accounting scope for the new epoch's state table.
    pub scope: Option<String>,
    /// Accounting category for the new epoch's state table — matches the
    /// stage's consistency tier (`reducer_meta` for exactly-once,
    /// `anchor_state` for approximate), so resharding an approximate
    /// stage keeps its frontier line intact across epochs.
    pub state_category: WriteCategory,
}

#[derive(Debug, thiserror::Error)]
pub enum ReshardError {
    #[error("plan is not stable (a migration is already in flight or was never finalized)")]
    NotStable,
    #[error("invalid target partition count {to} (current {from})")]
    InvalidTarget { from: usize, to: usize },
    #[error("plan transaction failed: {0}")]
    Txn(#[from] TxnError),
    #[error("store error: {0}")]
    Store(String),
    #[error(
        "migration to epoch {epoch} timed out: {retired} of {total} old reducers retired \
         (plan left Migrating; re-run finalize to resume)"
    )]
    Timeout {
        epoch: i64,
        retired: usize,
        total: usize,
    },
}

/// Outcome of a completed migration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReshardStats {
    pub from_partitions: usize,
    pub to_partitions: usize,
    /// The epoch the new fleet serves.
    pub epoch: i64,
    /// Rows ever handed through migration tables (cumulative).
    pub migrated_rows: i64,
}

/// Flight-recorder span for one driver plan transaction. The driver is
/// a singleton outside any worker fleet, so its spans carry the fixed
/// `resharder-0/driver` identity; it also runs on wall-clock (no sim
/// clock in scope), so span timestamps are zero and ordering comes from
/// the recorder's monotonic txn ids.
fn record_plan_span(
    ctx: &ReshardContext,
    scope: &str,
    read_set: usize,
    outcome: SpanOutcome,
    bytes_by_category: [u64; CATEGORY_COUNT],
) {
    ctx.metrics.recorder().record(TxnSpan {
        txn_id: 0,
        trace_id: 0,
        worker: WorkerId::resharder(0, "driver"),
        scope: scope.to_string(),
        read_set,
        outcome,
        bytes_by_category,
        start_ms: 0,
        end_ms: 0,
    });
}

/// Read the current plan (non-transactionally).
pub fn read_plan(ctx: &ReshardContext) -> Result<ReshardPlan, ReshardError> {
    let row = ctx
        .store
        .lookup(&ctx.runtime.plan_table, &ReshardPlan::key())
        .map_err(|e| ReshardError::Store(e.to_string()))?
        .ok_or_else(|| ReshardError::Store("plan row missing".into()))?;
    ReshardPlan::from_row(&row).ok_or_else(|| ReshardError::Store("plan row corrupt".into()))
}

/// Start a migration towards `new_partitions`. Returns the in-flight plan.
pub fn begin(ctx: &ReshardContext, new_partitions: usize) -> Result<ReshardPlan, ReshardError> {
    // CAS Stable → Migrating.
    let mut txn = ctx.store.begin();
    let row = txn
        .lookup(&ctx.runtime.plan_table, &ReshardPlan::key())?
        .ok_or_else(|| ReshardError::Store("plan row missing".into()))?;
    let plan = ReshardPlan::from_row(&row)
        .ok_or_else(|| ReshardError::Store("plan row corrupt".into()))?;
    if plan.phase != PlanPhase::Stable {
        return Err(ReshardError::NotStable);
    }
    let migrating = plan
        .begin_migration(new_partitions)
        .ok_or(ReshardError::InvalidTarget {
            from: plan.partitions,
            to: new_partitions,
        })?;
    txn.write(&ctx.runtime.plan_table, migrating.to_row())?;
    let obs_on = ctx.metrics.recorder().enabled();
    let read_set = txn.read_set_len();
    match txn.commit() {
        Ok(res) => {
            if obs_on {
                record_plan_span(
                    ctx,
                    "reshard_plan",
                    read_set,
                    SpanOutcome::Committed,
                    res.bytes_by_category,
                );
            }
        }
        Err(e) => {
            if obs_on {
                let outcome = match &e {
                    TxnError::Conflict { table, key, .. } => SpanOutcome::Conflicted {
                        losing_row: format!("{table}/{key:?}"),
                    },
                    _ => SpanOutcome::Error,
                };
                record_plan_span(ctx, "reshard_plan", read_set, outcome, [0; CATEGORY_COUNT]);
            }
            return Err(e.into());
        }
    }

    ensure_new_fleet(ctx, &migrating)?;
    ctx.metrics.add(names::RESHARD_MIGRATIONS, 1);
    Ok(migrating)
}

/// Idempotently materialize everything the incoming fleet needs: the
/// migration handoff table, the new epoch's seeded state table, and the
/// supervision slots. Called by [`begin`] right after the plan CAS and
/// again by [`resume`] — a driver that crashed anywhere between the CAS
/// and the last slot must leave a resumable migration, so every step here
/// tolerates already-done work.
fn ensure_new_fleet(ctx: &ReshardContext, migrating: &ReshardPlan) -> Result<(), ReshardError> {
    let epoch = migrating.next_epoch();
    let new_partitions = migrating.next_partitions;
    // The handoff the retiring fleet will export into.
    ctx.runtime.migration_for(epoch, new_partitions);

    // New epoch's state table, seeded un-bootstrapped.
    let table = reducer_state_table(&ctx.reducer_state_base, epoch);
    match ctx.store.create_table_scoped(
        &table,
        ReducerState::schema(),
        ctx.state_category,
        ctx.scope.clone(),
    ) {
        Ok(_) | Err(crate::dyntable::store::StoreError::AlreadyExists(_)) => {}
        Err(e) => return Err(ReshardError::Store(e.to_string())),
    }
    let mut seed = ctx.store.begin();
    for index in 0..new_partitions {
        if seed.lookup(&table, &ReducerState::key(index))?.is_none() {
            seed.write(
                &table,
                ReducerState::initial_migrating(ctx.num_mappers).to_row(index),
            )?;
        }
    }
    match seed.commit() {
        Ok(_) => {}
        // On the resume path the fleet may already be running and a
        // reducer's lazy fetch_state init can race this seed; its write
        // is the same initial row, so losing the CAS is success.
        Err(TxnError::Conflict { .. }) => {}
        Err(e) => return Err(e.into()),
    }

    // Grow the fleet: the new reducers run beside the draining old ones.
    for index in 0..new_partitions {
        let slot = reducer_slot(epoch, index);
        if !ctx.supervisor.has_slot(Role::Reducer, slot) {
            let spawn = ctx.spawn_reducer.clone();
            ctx.supervisor
                .add_slot(Role::Reducer, slot, Box::new(move || spawn(epoch, index)));
        }
    }
    Ok(())
}

/// How many old reducers have retired so far.
fn count_retired(ctx: &ReshardContext, plan: &ReshardPlan) -> Result<usize, ReshardError> {
    let table = reducer_state_table(&ctx.reducer_state_base, plan.epoch);
    let mut retired = 0;
    for index in 0..plan.partitions {
        let row = ctx
            .store
            .lookup(&table, &ReducerState::key(index))
            .map_err(|e| ReshardError::Store(e.to_string()))?;
        if row
            .as_ref()
            .and_then(ReducerState::from_row)
            .is_some_and(|s| s.retired)
        {
            retired += 1;
        }
    }
    Ok(retired)
}

/// Wait (wall-clock bounded) for every old reducer to retire, then CAS
/// the plan stable and retire the old supervision slots. Idempotent: safe
/// to re-run after a timeout or driver crash.
pub fn finalize(ctx: &ReshardContext, wall_timeout_ms: u64) -> Result<ReshardStats, ReshardError> {
    let plan = read_plan(ctx)?;
    if plan.phase == PlanPhase::Stable {
        // Already finalized (idempotent resume path).
        return Ok(ReshardStats {
            from_partitions: plan.partitions,
            to_partitions: plan.partitions,
            epoch: plan.epoch,
            migrated_rows: ctx.runtime.migrated_rows(),
        });
    }

    let deadline = std::time::Instant::now() + std::time::Duration::from_millis(wall_timeout_ms);
    loop {
        let retired = count_retired(ctx, &plan)?;
        if retired == plan.partitions {
            break;
        }
        if std::time::Instant::now() >= deadline {
            return Err(ReshardError::Timeout {
                epoch: plan.next_epoch(),
                retired,
                total: plan.partitions,
            });
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }

    // CAS Migrating → Stable, re-validating every retirement in the same
    // transaction (a racing finalizer or a resurrected zombie loses here).
    // Everything below derives from the *re-read* plan, never the one we
    // polled against — a racing finalize+begin pair could have advanced
    // the live migration to a different epoch in between, and validating
    // the old epoch's (all-retired) table against the new migration would
    // finalize a fleet that never drained.
    let mut txn = ctx.store.begin();
    let row = txn
        .lookup(&ctx.runtime.plan_table, &ReshardPlan::key())?
        .ok_or_else(|| ReshardError::Store("plan row missing".into()))?;
    let current = ReshardPlan::from_row(&row)
        .ok_or_else(|| ReshardError::Store("plan row corrupt".into()))?;
    if current.phase == PlanPhase::Stable {
        // A racing finalizer beat us to the CAS. If it finalized the very
        // migration we were waiting on, report its true origin count;
        // otherwise we only know the current state.
        let from = if current.epoch == plan.next_epoch() {
            plan.partitions
        } else {
            current.partitions
        };
        return Ok(ReshardStats {
            from_partitions: from,
            to_partitions: current.partitions,
            epoch: current.epoch,
            migrated_rows: ctx.runtime.migrated_rows(),
        });
    }
    if current != plan {
        // A different migration is in flight now; re-enter the wait.
        return Err(ReshardError::NotStable);
    }
    let old_table = reducer_state_table(&ctx.reducer_state_base, current.epoch);
    for index in 0..current.partitions {
        let state = txn
            .lookup(&old_table, &ReducerState::key(index))?
            .as_ref()
            .and_then(ReducerState::from_row);
        if !state.is_some_and(|s| s.retired) {
            return Err(ReshardError::NotStable);
        }
    }
    let finalized = current.finalized().ok_or(ReshardError::NotStable)?;
    txn.write(&ctx.runtime.plan_table, finalized.to_row())?;
    let obs_on = ctx.metrics.recorder().enabled();
    let read_set = txn.read_set_len();
    match txn.commit() {
        Ok(res) => {
            if obs_on {
                record_plan_span(
                    ctx,
                    "reshard_finalize",
                    read_set,
                    SpanOutcome::Committed,
                    res.bytes_by_category,
                );
            }
        }
        Err(e) => {
            if obs_on {
                let outcome = match &e {
                    TxnError::Conflict { table, key, .. } => SpanOutcome::Conflicted {
                        losing_row: format!("{table}/{key:?}"),
                    },
                    _ => SpanOutcome::Error,
                };
                record_plan_span(ctx, "reshard_finalize", read_set, outcome, [0; CATEGORY_COUNT]);
            }
            return Err(e.into());
        }
    }

    // Stop respawning the retired fleet.
    for index in 0..current.partitions {
        ctx.supervisor
            .retire(Role::Reducer, reducer_slot(current.epoch, index));
    }
    ctx.metrics.add(names::RESHARD_FINALIZED, 1);
    Ok(ReshardStats {
        from_partitions: current.partitions,
        to_partitions: finalized.partitions,
        epoch: finalized.epoch,
        migrated_rows: ctx.runtime.migrated_rows(),
    })
}

/// Resume an interrupted migration: if the plan is mid-flight, make sure
/// the new fleet's slots exist (a crashed driver may have died between the
/// plan CAS and the spawn), then finalize.
pub fn resume(ctx: &ReshardContext, wall_timeout_ms: u64) -> Result<ReshardStats, ReshardError> {
    let plan = read_plan(ctx)?;
    if plan.phase == PlanPhase::Migrating {
        // Re-materialize whatever begin() did not get to: migration
        // table, seeded state table, supervision slots — all idempotent.
        ensure_new_fleet(ctx, &plan)?;
    }
    finalize(ctx, wall_timeout_ms)
}
