//! Persistent-storage substrate with per-category byte accounting.
//!
//! The paper's headline claim is about **write amplification** — "the
//! phenomenon associated with the same data being written to storage
//! multiple times" (§1). To *measure* it, every simulated persistent write
//! in the repository flows through a [`journal::Journal`] tagged with a
//! [`accounting::WriteCategory`]; [`accounting::WriteAccounting`] keeps the
//! global tally from which `WA = persisted-system-bytes / ingested-bytes`
//! is computed (see `metrics::wa` and the `figure wa` harness).
//!
//! [`chunk_store::ChunkStore`] is the bulk store used by the
//! persistent-shuffle *baseline* (classic MapReduce-style shuffle, §2.1–2.2)
//! and by the §6 straggler-spill extension.

pub mod accounting;
pub mod journal;
pub mod chunk_store;

pub use accounting::{WriteAccounting, WriteCategory};
pub use chunk_store::{ChunkId, ChunkStore};
pub use journal::Journal;
