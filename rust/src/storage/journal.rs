//! Append-only journal: the unit of simulated durable storage.
//!
//! Every substrate that "persists" something (dynamic-table commits,
//! ordered-table appends, chunk writes, cypress mutations) appends an
//! encoded record here. The journal keeps the payload in memory (this is a
//! simulation — durability is modeled, not provided) but *accounts* every
//! byte against its [`WriteCategory`], and can replay records for recovery
//! tests.

use std::sync::{Arc, Mutex};

use super::accounting::{WriteAccounting, WriteCategory};

/// An append-only record log with byte accounting.
#[derive(Debug)]
pub struct Journal {
    name: String,
    category: WriteCategory,
    accounting: Arc<WriteAccounting>,
    records: Mutex<Vec<Vec<u8>>>,
}

impl Journal {
    pub fn new(
        name: impl Into<String>,
        category: WriteCategory,
        accounting: Arc<WriteAccounting>,
    ) -> Arc<Journal> {
        Arc::new(Journal {
            name: name.into(),
            category,
            accounting,
            records: Mutex::new(Vec::new()),
        })
    }

    /// Append a record; returns its sequence number.
    pub fn append(&self, record: Vec<u8>) -> u64 {
        self.accounting.record(self.category, record.len() as u64);
        let mut g = self.records.lock().unwrap();
        g.push(record);
        (g.len() - 1) as u64
    }

    /// Append with an explicit accounted size (when the logical record is
    /// larger than the stored index entry, e.g. chunk metadata).
    pub fn append_accounted(&self, record: Vec<u8>, accounted_bytes: u64) -> u64 {
        self.accounting.record(self.category, accounted_bytes);
        let mut g = self.records.lock().unwrap();
        g.push(record);
        (g.len() - 1) as u64
    }

    pub fn len(&self) -> u64 {
        self.records.lock().unwrap().len() as u64
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read back a record (recovery / tests).
    pub fn read(&self, seqno: u64) -> Option<Vec<u8>> {
        self.records.lock().unwrap().get(seqno as usize).cloned()
    }

    /// Replay all records in order.
    pub fn replay(&self, mut f: impl FnMut(u64, &[u8])) {
        let g = self.records.lock().unwrap();
        for (i, r) in g.iter().enumerate() {
            f(i as u64, r);
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn category(&self) -> WriteCategory {
        self.category
    }

    /// Total payload bytes appended so far.
    pub fn total_bytes(&self) -> u64 {
        self.records
            .lock()
            .unwrap()
            .iter()
            .map(|r| r.len() as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_accounts_bytes() {
        let acc = WriteAccounting::new();
        let j = Journal::new("m0", WriteCategory::MapperMeta, acc.clone());
        let s0 = j.append(vec![1, 2, 3]);
        let s1 = j.append(vec![4, 5]);
        assert_eq!((s0, s1), (0, 1));
        assert_eq!(acc.bytes(WriteCategory::MapperMeta), 5);
        assert_eq!(j.total_bytes(), 5);
        assert_eq!(j.len(), 2);
    }

    #[test]
    fn read_and_replay() {
        let acc = WriteAccounting::new();
        let j = Journal::new("j", WriteCategory::ReducerMeta, acc);
        j.append(b"abc".to_vec());
        j.append(b"de".to_vec());
        assert_eq!(j.read(0), Some(b"abc".to_vec()));
        assert_eq!(j.read(9), None);
        let mut seen = Vec::new();
        j.replay(|i, r| seen.push((i, r.len())));
        assert_eq!(seen, vec![(0, 3), (1, 2)]);
    }

    #[test]
    fn append_accounted_overrides_size() {
        let acc = WriteAccounting::new();
        let j = Journal::new("chunks", WriteCategory::ShufflePersist, acc.clone());
        j.append_accounted(vec![0; 4], 1_000);
        assert_eq!(acc.bytes(WriteCategory::ShufflePersist), 1_000);
        assert_eq!(j.total_bytes(), 4);
    }

    #[test]
    fn concurrent_appends_all_land() {
        let acc = WriteAccounting::new();
        let j = Journal::new("c", WriteCategory::Spill, acc.clone());
        std::thread::scope(|s| {
            for t in 0..4 {
                let j = j.clone();
                s.spawn(move || {
                    for i in 0..250 {
                        j.append(vec![t as u8, i as u8]);
                    }
                });
            }
        });
        assert_eq!(j.len(), 1000);
        assert_eq!(acc.bytes(WriteCategory::Spill), 2000);
    }
}
