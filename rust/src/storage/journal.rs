//! Append-only journal: the unit of simulated durable storage.
//!
//! Every substrate that "persists" something (dynamic-table commits,
//! ordered-table appends, chunk writes, cypress mutations) appends an
//! encoded record here. The journal keeps the payload in memory (this is a
//! simulation — durability is modeled, not provided) but *accounts* every
//! byte against its [`WriteCategory`], and can replay records for recovery
//! tests.
//!
//! Append cost model (§Perf): a `Vec<u8>` record is **moved** in (no
//! copy — the high-rate ingest paths), an already-shared `Arc<[u8]>`
//! record is stored by refcount (the spill path, which shares one buffer
//! between its queue and the journal). Reads promote an owned record to
//! shared storage on first access (one copy, cold recovery/test path),
//! after which every read is a refcount bump. [`Journal::total_bytes`] is
//! a running atomic counter maintained on append — O(1), never re-summed
//! under the record lock (the old O(n) lock-held re-scan skewed the
//! write-amplification bench at scale).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::accounting::{ScopeHandle, WriteAccounting, WriteCategory};
use crate::util;

/// One journal record: owned when appended as `Vec` (move, no copy),
/// shared when appended as / promoted to `Arc<[u8]>`.
#[derive(Debug)]
pub enum Record {
    Owned(Vec<u8>),
    Shared(Arc<[u8]>),
}

impl Record {
    fn len(&self) -> usize {
        match self {
            Record::Owned(v) => v.len(),
            Record::Shared(a) => a.len(),
        }
    }

    fn bytes(&self) -> &[u8] {
        match self {
            Record::Owned(v) => v,
            Record::Shared(a) => a,
        }
    }

    /// Shared handle to this record, promoting `Owned` storage in place
    /// (one copy on first read, refcount bumps thereafter).
    fn share(&mut self) -> Arc<[u8]> {
        match self {
            Record::Shared(a) => a.clone(),
            Record::Owned(v) => {
                let a: Arc<[u8]> = std::mem::take(v).into();
                *self = Record::Shared(a.clone());
                a
            }
        }
    }
}

impl From<Vec<u8>> for Record {
    fn from(v: Vec<u8>) -> Record {
        Record::Owned(v)
    }
}

impl From<Arc<[u8]>> for Record {
    fn from(a: Arc<[u8]>) -> Record {
        Record::Shared(a)
    }
}

/// An append-only record log with byte accounting.
#[derive(Debug)]
pub struct Journal {
    name: String,
    category: WriteCategory,
    /// Accounting scope (dataflow stage) the bytes are attributed to, on
    /// top of the global per-category counters. Resolved once at
    /// construction; recording through it is lock-free.
    scope: Option<ScopeHandle>,
    accounting: Arc<WriteAccounting>,
    records: Mutex<Vec<Record>>,
    /// Running sum of record payload lengths, maintained on append.
    total_bytes: AtomicU64,
}

impl Journal {
    pub fn new(
        name: impl Into<String>,
        category: WriteCategory,
        accounting: Arc<WriteAccounting>,
    ) -> Arc<Journal> {
        Self::new_scoped(name, category, accounting, None)
    }

    /// Like [`Journal::new`] but attributing every appended byte to a
    /// named accounting scope as well (per-stage WA reports).
    pub fn new_scoped(
        name: impl Into<String>,
        category: WriteCategory,
        accounting: Arc<WriteAccounting>,
        scope: Option<String>,
    ) -> Arc<Journal> {
        let scope = scope.map(|s| accounting.scope_handle(&s));
        Arc::new(Journal {
            name: name.into(),
            category,
            scope,
            accounting,
            records: Mutex::new(Vec::new()),
            total_bytes: AtomicU64::new(0),
        })
    }

    #[inline]
    fn account(&self, bytes: u64) {
        self.accounting.record(self.category, bytes);
        if let Some(scope) = &self.scope {
            scope.record(self.category, bytes);
        }
    }

    /// Append a record; returns its sequence number. `Vec<u8>` is moved in
    /// without copying; `Arc<[u8]>` is stored by refcount.
    pub fn append(&self, record: impl Into<Record>) -> u64 {
        let record: Record = record.into();
        self.account(record.len() as u64);
        let mut g = util::lock(&self.records);
        // Incremented under the record lock so the counter never runs
        // ahead of (or behind) what read()/replay() can observe.
        self.total_bytes
            .fetch_add(record.len() as u64, Ordering::Relaxed);
        g.push(record);
        (g.len() - 1) as u64
    }

    /// Append with an explicit accounted size (when the logical record is
    /// larger than the stored index entry, e.g. chunk metadata).
    pub fn append_accounted(&self, record: impl Into<Record>, accounted_bytes: u64) -> u64 {
        let record: Record = record.into();
        self.account(accounted_bytes);
        let mut g = util::lock(&self.records);
        self.total_bytes
            .fetch_add(record.len() as u64, Ordering::Relaxed);
        g.push(record);
        (g.len() - 1) as u64
    }

    pub fn len(&self) -> u64 {
        util::lock(&self.records).len() as u64
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read back a record (recovery / tests). Shares the stored buffer,
    /// promoting owned storage on first access.
    pub fn read(&self, seqno: u64) -> Option<Arc<[u8]>> {
        let mut g = util::lock(&self.records);
        g.get_mut(seqno as usize).map(Record::share)
    }

    /// Replay all records in order.
    pub fn replay(&self, mut f: impl FnMut(u64, &[u8])) {
        let g = util::lock(&self.records);
        for (i, r) in g.iter().enumerate() {
            f(i as u64, r.bytes());
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn category(&self) -> WriteCategory {
        self.category
    }

    /// Total payload bytes appended so far — O(1), lock-free.
    pub fn total_bytes(&self) -> u64 {
        self.total_bytes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_accounts_bytes() {
        let acc = WriteAccounting::new();
        let j = Journal::new("m0", WriteCategory::MapperMeta, acc.clone());
        let s0 = j.append(vec![1, 2, 3]);
        let s1 = j.append(vec![4, 5]);
        assert_eq!((s0, s1), (0, 1));
        assert_eq!(acc.bytes(WriteCategory::MapperMeta), 5);
        assert_eq!(j.total_bytes(), 5);
        assert_eq!(j.len(), 2);
    }

    #[test]
    fn read_and_replay() {
        let acc = WriteAccounting::new();
        let j = Journal::new("j", WriteCategory::ReducerMeta, acc);
        j.append(b"abc".to_vec());
        j.append(b"de".to_vec());
        assert_eq!(j.read(0).as_deref(), Some(&b"abc"[..]));
        assert!(j.read(9).is_none());
        let mut seen = Vec::new();
        j.replay(|i, r| seen.push((i, r.len())));
        assert_eq!(seen, vec![(0, 3), (1, 2)]);
    }

    #[test]
    fn shared_append_does_not_copy() {
        let acc = WriteAccounting::new();
        let j = Journal::new("s", WriteCategory::Spill, acc);
        let rec: Arc<[u8]> = vec![7, 8, 9].into();
        j.append(rec.clone());
        let back = j.read(0).unwrap();
        assert!(Arc::ptr_eq(&rec, &back));
    }

    #[test]
    fn owned_read_promotes_once_then_shares() {
        let acc = WriteAccounting::new();
        let j = Journal::new("o", WriteCategory::SourceIngest, acc);
        j.append(vec![1, 2, 3]);
        let a = j.read(0).unwrap();
        let b = j.read(0).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "promotion must happen exactly once");
        assert_eq!(a.as_ref(), &[1, 2, 3]);
    }

    #[test]
    fn append_accounted_overrides_size() {
        let acc = WriteAccounting::new();
        let j = Journal::new("chunks", WriteCategory::ShufflePersist, acc.clone());
        j.append_accounted(vec![0; 4], 1_000);
        assert_eq!(acc.bytes(WriteCategory::ShufflePersist), 1_000);
        assert_eq!(j.total_bytes(), 4);
    }

    #[test]
    fn scoped_journal_attributes_bytes() {
        let acc = WriteAccounting::new();
        let j = Journal::new_scoped(
            "handoff",
            WriteCategory::InterStage,
            acc.clone(),
            Some("topo/stage-0".into()),
        );
        j.append(vec![0u8; 10]);
        assert_eq!(acc.bytes(WriteCategory::InterStage), 10);
        assert_eq!(
            acc.scope_snapshot("topo/stage-0").bytes_of(WriteCategory::InterStage),
            10
        );
    }

    #[test]
    fn concurrent_appends_all_land() {
        let acc = WriteAccounting::new();
        let j = Journal::new("c", WriteCategory::Spill, acc.clone());
        std::thread::scope(|s| {
            for t in 0..4 {
                let j = j.clone();
                s.spawn(move || {
                    for i in 0..250 {
                        j.append(vec![t as u8, i as u8]);
                    }
                });
            }
        });
        assert_eq!(j.len(), 1000);
        assert_eq!(acc.bytes(WriteCategory::Spill), 2000);
        assert_eq!(j.total_bytes(), 2000);
    }
}
