//! Global write-byte accounting — the write-amplification meter.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// What a persisted byte was written *for*. The WA factor of the streaming
/// processor counts only the categories the processor itself is responsible
/// for (see [`WriteCategory::counts_toward_wa`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WriteCategory {
    /// Producer appends into the input queues. This is the *input* of the
    /// system, not something the processor wrote — the WA denominator.
    SourceIngest,
    /// Mapper persistent meta-state updates (§4.3.2: three small columns).
    MapperMeta,
    /// Reducer persistent meta-state updates (§4.4.1).
    ReducerMeta,
    /// Rows written by the *user's* Reduce function to its output table.
    /// Useful output, reported separately from system overhead.
    UserOutput,
    /// Full shuffle payload persisted by the classic-MapReduce baseline
    /// (§2.1–2.2) — the thing the paper's design eliminates.
    ShufflePersist,
    /// Straggler spill writes (§6 future-work feature).
    Spill,
    /// Cypress / discovery metadata writes.
    CypressMeta,
}

pub const ALL_CATEGORIES: [WriteCategory; 7] = [
    WriteCategory::SourceIngest,
    WriteCategory::MapperMeta,
    WriteCategory::ReducerMeta,
    WriteCategory::UserOutput,
    WriteCategory::ShufflePersist,
    WriteCategory::Spill,
    WriteCategory::CypressMeta,
];

impl WriteCategory {
    fn index(self) -> usize {
        match self {
            WriteCategory::SourceIngest => 0,
            WriteCategory::MapperMeta => 1,
            WriteCategory::ReducerMeta => 2,
            WriteCategory::UserOutput => 3,
            WriteCategory::ShufflePersist => 4,
            WriteCategory::Spill => 5,
            WriteCategory::CypressMeta => 6,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WriteCategory::SourceIngest => "source_ingest",
            WriteCategory::MapperMeta => "mapper_meta",
            WriteCategory::ReducerMeta => "reducer_meta",
            WriteCategory::UserOutput => "user_output",
            WriteCategory::ShufflePersist => "shuffle_persist",
            WriteCategory::Spill => "spill",
            WriteCategory::CypressMeta => "cypress_meta",
        }
    }

    /// Does this category count toward the processor's write amplification?
    /// Input ingestion is the denominator; user output is useful work that
    /// every design pays identically, so the *system* WA excludes it (it is
    /// still reported).
    pub fn counts_toward_wa(self) -> bool {
        !matches!(
            self,
            WriteCategory::SourceIngest | WriteCategory::UserOutput
        )
    }
}

/// Lock-free per-category byte + op counters. One instance is shared by
/// every journal in a simulated cluster.
#[derive(Debug, Default)]
pub struct WriteAccounting {
    bytes: [AtomicU64; 7],
    ops: [AtomicU64; 7],
}

/// A point-in-time copy of the counters.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct AccountingSnapshot {
    pub bytes: [u64; 7],
    pub ops: [u64; 7],
}

impl WriteAccounting {
    pub fn new() -> Arc<WriteAccounting> {
        Arc::new(WriteAccounting::default())
    }

    #[inline]
    pub fn record(&self, cat: WriteCategory, bytes: u64) {
        let i = cat.index();
        self.bytes[i].fetch_add(bytes, Ordering::Relaxed);
        self.ops[i].fetch_add(1, Ordering::Relaxed);
    }

    pub fn bytes(&self, cat: WriteCategory) -> u64 {
        self.bytes[cat.index()].load(Ordering::Relaxed)
    }

    pub fn ops(&self, cat: WriteCategory) -> u64 {
        self.ops[cat.index()].load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> AccountingSnapshot {
        let mut s = AccountingSnapshot::default();
        for (i, (b, o)) in self.bytes.iter().zip(&self.ops).enumerate() {
            s.bytes[i] = b.load(Ordering::Relaxed);
            s.ops[i] = o.load(Ordering::Relaxed);
        }
        s
    }
}

impl AccountingSnapshot {
    pub fn bytes_of(&self, cat: WriteCategory) -> u64 {
        self.bytes[cat.index()]
    }

    pub fn ops_of(&self, cat: WriteCategory) -> u64 {
        self.ops[cat.index()]
    }

    /// Total persisted bytes attributable to the processor itself.
    pub fn system_bytes(&self) -> u64 {
        ALL_CATEGORIES
            .iter()
            .filter(|c| c.counts_toward_wa())
            .map(|c| self.bytes_of(*c))
            .sum()
    }

    /// Write-amplification factor relative to `ingested_bytes` of input
    /// payload actually processed.
    pub fn wa_factor(&self, ingested_bytes: u64) -> f64 {
        if ingested_bytes == 0 {
            return 0.0;
        }
        self.system_bytes() as f64 / ingested_bytes as f64
    }

    /// Difference against an earlier snapshot (per-window accounting).
    pub fn delta_since(&self, earlier: &AccountingSnapshot) -> AccountingSnapshot {
        let mut d = AccountingSnapshot::default();
        for i in 0..7 {
            d.bytes[i] = self.bytes[i] - earlier.bytes[i];
            d.ops[i] = self.ops[i] - earlier.ops[i];
        }
        d
    }
}

impl fmt::Display for AccountingSnapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for cat in ALL_CATEGORIES {
            if self.bytes_of(cat) > 0 || self.ops_of(cat) > 0 {
                writeln!(
                    f,
                    "  {:<16} {:>14} bytes {:>10} ops",
                    cat.name(),
                    self.bytes_of(cat),
                    self.ops_of(cat)
                )?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_read() {
        let a = WriteAccounting::new();
        a.record(WriteCategory::MapperMeta, 100);
        a.record(WriteCategory::MapperMeta, 50);
        a.record(WriteCategory::SourceIngest, 1000);
        assert_eq!(a.bytes(WriteCategory::MapperMeta), 150);
        assert_eq!(a.ops(WriteCategory::MapperMeta), 2);
        assert_eq!(a.bytes(WriteCategory::SourceIngest), 1000);
    }

    #[test]
    fn wa_excludes_source_and_user_output() {
        let a = WriteAccounting::new();
        a.record(WriteCategory::SourceIngest, 10_000);
        a.record(WriteCategory::UserOutput, 500);
        a.record(WriteCategory::MapperMeta, 100);
        a.record(WriteCategory::ReducerMeta, 100);
        a.record(WriteCategory::ShufflePersist, 20_000);
        let s = a.snapshot();
        assert_eq!(s.system_bytes(), 20_200);
        assert!((s.wa_factor(10_000) - 2.02).abs() < 1e-9);
    }

    #[test]
    fn wa_zero_denominator() {
        let s = AccountingSnapshot::default();
        assert_eq!(s.wa_factor(0), 0.0);
    }

    #[test]
    fn delta_since() {
        let a = WriteAccounting::new();
        a.record(WriteCategory::Spill, 10);
        let before = a.snapshot();
        a.record(WriteCategory::Spill, 25);
        let after = a.snapshot();
        let d = after.delta_since(&before);
        assert_eq!(d.bytes_of(WriteCategory::Spill), 25);
        assert_eq!(d.ops_of(WriteCategory::Spill), 1);
    }

    #[test]
    fn concurrent_recording() {
        let a = WriteAccounting::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let a = a.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        a.record(WriteCategory::ReducerMeta, 3);
                    }
                });
            }
        });
        assert_eq!(a.bytes(WriteCategory::ReducerMeta), 24_000);
        assert_eq!(a.ops(WriteCategory::ReducerMeta), 8_000);
    }

    #[test]
    fn display_skips_empty() {
        let a = WriteAccounting::new();
        a.record(WriteCategory::MapperMeta, 5);
        let text = a.snapshot().to_string();
        assert!(text.contains("mapper_meta"));
        assert!(!text.contains("spill"));
    }
}
