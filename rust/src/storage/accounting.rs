//! Global write-byte accounting — the write-amplification meter.
//!
//! Counters are kept twice: one global per-category array (lock-free, the
//! hot path every journal append hits) and an optional per-*scope* map for
//! multi-stage pipelines, where a scope is one stage of a
//! [`crate::dataflow`] topology and the per-stage WA report needs its own
//! numerator.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use crate::util;

/// What a persisted byte was written *for*. The WA factor of the streaming
/// processor counts only the categories the processor itself is responsible
/// for (see [`WriteCategory::counts_toward_wa`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WriteCategory {
    /// Producer appends into the input queues. This is the *input* of the
    /// system, not something the processor wrote — the WA denominator.
    SourceIngest,
    /// Mapper persistent meta-state updates (§4.3.2: three small columns).
    MapperMeta,
    /// Reducer persistent meta-state updates (§4.4.1).
    ReducerMeta,
    /// Rows written by the *user's* Reduce function to its output table.
    /// Useful output, reported separately from system overhead.
    UserOutput,
    /// Full shuffle payload persisted by the classic-MapReduce baseline
    /// (§2.1–2.2) — the thing the paper's design eliminates.
    ShufflePersist,
    /// Straggler spill writes (§6 future-work feature).
    Spill,
    /// Cypress / discovery metadata writes.
    CypressMeta,
    /// Inter-stage handoff rows: payload a dataflow stage's reducers
    /// persist into the ordered table feeding the next stage. Unlike
    /// [`WriteCategory::UserOutput`] this *is* system overhead the chained
    /// design pays per hop, so it counts toward WA.
    InterStage,
    /// Elastic-resharding migration bytes: plan-table state-machine
    /// updates and the residual state retiring reducers hand to the new
    /// partition map through the migration handoff table. Rescaling is a
    /// system activity, so its bytes count toward WA — `figure reshard`
    /// reports this line separately as the honest cost of elasticity.
    Reshard,
    /// Event-time bookkeeping of the [`crate::eventtime`] subsystem:
    /// open-window accumulator upserts, fired-watermark markers and
    /// source-close markers. Compact meta-state-sized records (never the
    /// row payload), but still system overhead final-fire windowing pays
    /// per batch — so it counts toward WA and `figure window` reports it
    /// as its own line against the per-batch-upsert `UserOutput` savings.
    EventTime,
    /// Anchor/lifecycle state rows of approximate-consistency stages
    /// ([`crate::consistency`]): the rare durable snapshots a
    /// `BoundedError` stage writes instead of per-commit `ReducerMeta`,
    /// plus the one-time bootstrap/retire rows an `AtMostOnce` stage still
    /// needs for reshard safety. System overhead — counts toward WA — and
    /// kept separate from `reducer_meta` so `figure consistency` can show
    /// the frontier as two lines on the same workload.
    AnchorState,
    /// Cold-tier chunk writes ([`crate::coldtier`]): trimmed ordered-table
    /// segments and fired-window history compacted into immutable columnar
    /// chunks (manifest + payload rows) inside the same transaction that
    /// performs the trim/fire. System overhead the cold tier pays to make
    /// backfill cheap — counts toward WA as its own line, and `figure
    /// backfill` asserts it never inflates the exactly-once hot-path lines.
    ColdTier,
}

/// Number of [`WriteCategory`] variants (array sizing).
pub const CATEGORY_COUNT: usize = 12;

pub const ALL_CATEGORIES: [WriteCategory; CATEGORY_COUNT] = [
    WriteCategory::SourceIngest,
    WriteCategory::MapperMeta,
    WriteCategory::ReducerMeta,
    WriteCategory::UserOutput,
    WriteCategory::ShufflePersist,
    WriteCategory::Spill,
    WriteCategory::CypressMeta,
    WriteCategory::InterStage,
    WriteCategory::Reshard,
    WriteCategory::EventTime,
    WriteCategory::AnchorState,
    WriteCategory::ColdTier,
];

impl WriteCategory {
    /// Dense array index of this category (`bytes_by_category`-style
    /// arrays in accounting snapshots and obs spans).
    pub fn index(self) -> usize {
        match self {
            WriteCategory::SourceIngest => 0,
            WriteCategory::MapperMeta => 1,
            WriteCategory::ReducerMeta => 2,
            WriteCategory::UserOutput => 3,
            WriteCategory::ShufflePersist => 4,
            WriteCategory::Spill => 5,
            WriteCategory::CypressMeta => 6,
            WriteCategory::InterStage => 7,
            WriteCategory::Reshard => 8,
            WriteCategory::EventTime => 9,
            WriteCategory::AnchorState => 10,
            WriteCategory::ColdTier => 11,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WriteCategory::SourceIngest => "source_ingest",
            WriteCategory::MapperMeta => "mapper_meta",
            WriteCategory::ReducerMeta => "reducer_meta",
            WriteCategory::UserOutput => "user_output",
            WriteCategory::ShufflePersist => "shuffle_persist",
            WriteCategory::Spill => "spill",
            WriteCategory::CypressMeta => "cypress_meta",
            WriteCategory::InterStage => "inter_stage",
            WriteCategory::Reshard => "reshard",
            WriteCategory::EventTime => "event_time",
            WriteCategory::AnchorState => "anchor_state",
            WriteCategory::ColdTier => "cold_tier",
        }
    }

    /// Does this category count toward the processor's write amplification?
    /// Input ingestion is the denominator; user output is useful work that
    /// every design pays identically, so the *system* WA excludes it (it is
    /// still reported).
    pub fn counts_toward_wa(self) -> bool {
        !matches!(
            self,
            WriteCategory::SourceIngest | WriteCategory::UserOutput
        )
    }
}

/// Lock-free per-category byte + op counters. One instance is shared by
/// every journal in a simulated cluster.
#[derive(Debug, Default)]
pub struct WriteAccounting {
    bytes: [AtomicU64; CATEGORY_COUNT],
    ops: [AtomicU64; CATEGORY_COUNT],
    /// Per-scope cells (dataflow stages). The map lock is taken only to
    /// resolve a [`ScopeHandle`] (once per journal/table construction) or
    /// to snapshot; recording through a handle is lock-free.
    scoped: Mutex<HashMap<String, Arc<ScopeCells>>>,
}

#[derive(Debug, Default)]
struct ScopeCells {
    bytes: [AtomicU64; CATEGORY_COUNT],
    ops: [AtomicU64; CATEGORY_COUNT],
}

/// Lock-free recording handle for one accounting scope, resolved once
/// (map lock + key allocation) via [`WriteAccounting::scope_handle`] and
/// then shared by that scope's journals and tables. Records **scope cells
/// only** — callers pair it with [`WriteAccounting::record`] for the
/// global tally.
#[derive(Debug, Clone)]
pub struct ScopeHandle {
    cells: Arc<ScopeCells>,
}

impl ScopeHandle {
    #[inline]
    pub fn record(&self, cat: WriteCategory, bytes: u64) {
        self.record_batch(cat, bytes, 1);
    }

    /// Record `ops` logical writes totalling `bytes` with two atomic adds
    /// instead of `2 * ops`. Snapshots are indistinguishable from `ops`
    /// individual [`ScopeHandle::record`] calls.
    #[inline]
    pub fn record_batch(&self, cat: WriteCategory, bytes: u64, ops: u64) {
        let i = cat.index();
        self.cells.bytes[i].fetch_add(bytes, Ordering::Relaxed);
        self.cells.ops[i].fetch_add(ops, Ordering::Relaxed);
    }
}

/// A point-in-time copy of the counters.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct AccountingSnapshot {
    pub bytes: [u64; CATEGORY_COUNT],
    pub ops: [u64; CATEGORY_COUNT],
}

impl WriteAccounting {
    pub fn new() -> Arc<WriteAccounting> {
        Arc::new(WriteAccounting::default())
    }

    #[inline]
    pub fn record(&self, cat: WriteCategory, bytes: u64) {
        self.record_batch(cat, bytes, 1);
    }

    /// Record `ops` logical writes totalling `bytes` with two atomic adds
    /// instead of `2 * ops` — the group-commit hot path sums a batch and
    /// records once. Counter state is indistinguishable from `ops`
    /// individual [`WriteAccounting::record`] calls.
    #[inline]
    pub fn record_batch(&self, cat: WriteCategory, bytes: u64, ops: u64) {
        let i = cat.index();
        self.bytes[i].fetch_add(bytes, Ordering::Relaxed);
        self.ops[i].fetch_add(ops, Ordering::Relaxed);
    }

    /// Get-or-create the lock-free recording handle for a scope.
    pub fn scope_handle(&self, scope: &str) -> ScopeHandle {
        let mut g = util::lock(&self.scoped);
        let cells = g
            .entry(scope.to_string())
            .or_insert_with(|| Arc::new(ScopeCells::default()))
            .clone();
        ScopeHandle { cells }
    }

    pub fn bytes(&self, cat: WriteCategory) -> u64 {
        self.bytes[cat.index()].load(Ordering::Relaxed)
    }

    pub fn ops(&self, cat: WriteCategory) -> u64 {
        self.ops[cat.index()].load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> AccountingSnapshot {
        let mut s = AccountingSnapshot::default();
        for (i, (b, o)) in self.bytes.iter().zip(&self.ops).enumerate() {
            s.bytes[i] = b.load(Ordering::Relaxed);
            s.ops[i] = o.load(Ordering::Relaxed);
        }
        s
    }

    /// Snapshot of one scope's counters (all-zero if the scope never
    /// recorded anything).
    pub fn scope_snapshot(&self, scope: &str) -> AccountingSnapshot {
        let cells = {
            let g = util::lock(&self.scoped);
            g.get(scope).cloned()
        };
        let mut s = AccountingSnapshot::default();
        if let Some(c) = cells {
            for i in 0..CATEGORY_COUNT {
                s.bytes[i] = c.bytes[i].load(Ordering::Relaxed);
                s.ops[i] = c.ops[i].load(Ordering::Relaxed);
            }
        }
        s
    }

}

impl AccountingSnapshot {
    pub fn bytes_of(&self, cat: WriteCategory) -> u64 {
        self.bytes[cat.index()]
    }

    pub fn ops_of(&self, cat: WriteCategory) -> u64 {
        self.ops[cat.index()]
    }

    /// Total persisted bytes attributable to the processor itself.
    pub fn system_bytes(&self) -> u64 {
        ALL_CATEGORIES
            .iter()
            .filter(|c| c.counts_toward_wa())
            .map(|c| self.bytes_of(*c))
            .sum()
    }

    /// Write-amplification factor relative to `ingested_bytes` of input
    /// payload actually processed.
    pub fn wa_factor(&self, ingested_bytes: u64) -> f64 {
        if ingested_bytes == 0 {
            return 0.0;
        }
        self.system_bytes() as f64 / ingested_bytes as f64
    }

    /// Difference against an earlier snapshot (per-window accounting).
    pub fn delta_since(&self, earlier: &AccountingSnapshot) -> AccountingSnapshot {
        let mut d = AccountingSnapshot::default();
        for i in 0..CATEGORY_COUNT {
            d.bytes[i] = self.bytes[i] - earlier.bytes[i];
            d.ops[i] = self.ops[i] - earlier.ops[i];
        }
        d
    }
}

impl fmt::Display for AccountingSnapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for cat in ALL_CATEGORIES {
            if self.bytes_of(cat) > 0 || self.ops_of(cat) > 0 {
                writeln!(
                    f,
                    "  {:<16} {:>14} bytes {:>10} ops",
                    cat.name(),
                    self.bytes_of(cat),
                    self.ops_of(cat)
                )?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_read() {
        let a = WriteAccounting::new();
        a.record(WriteCategory::MapperMeta, 100);
        a.record(WriteCategory::MapperMeta, 50);
        a.record(WriteCategory::SourceIngest, 1000);
        assert_eq!(a.bytes(WriteCategory::MapperMeta), 150);
        assert_eq!(a.ops(WriteCategory::MapperMeta), 2);
        assert_eq!(a.bytes(WriteCategory::SourceIngest), 1000);
    }

    #[test]
    fn wa_excludes_source_and_user_output() {
        let a = WriteAccounting::new();
        a.record(WriteCategory::SourceIngest, 10_000);
        a.record(WriteCategory::UserOutput, 500);
        a.record(WriteCategory::MapperMeta, 100);
        a.record(WriteCategory::ReducerMeta, 100);
        a.record(WriteCategory::ShufflePersist, 20_000);
        let s = a.snapshot();
        assert_eq!(s.system_bytes(), 20_200);
        assert!((s.wa_factor(10_000) - 2.02).abs() < 1e-9);
    }

    #[test]
    fn wa_zero_denominator() {
        let s = AccountingSnapshot::default();
        assert_eq!(s.wa_factor(0), 0.0);
    }

    #[test]
    fn delta_since() {
        let a = WriteAccounting::new();
        a.record(WriteCategory::Spill, 10);
        let before = a.snapshot();
        a.record(WriteCategory::Spill, 25);
        let after = a.snapshot();
        let d = after.delta_since(&before);
        assert_eq!(d.bytes_of(WriteCategory::Spill), 25);
        assert_eq!(d.ops_of(WriteCategory::Spill), 1);
    }

    #[test]
    fn concurrent_recording() {
        let a = WriteAccounting::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let a = a.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        a.record(WriteCategory::ReducerMeta, 3);
                    }
                });
            }
        });
        assert_eq!(a.bytes(WriteCategory::ReducerMeta), 24_000);
        assert_eq!(a.ops(WriteCategory::ReducerMeta), 8_000);
    }

    #[test]
    fn reshard_counts_toward_wa() {
        let a = WriteAccounting::new();
        a.record(WriteCategory::SourceIngest, 1_000);
        a.record(WriteCategory::Reshard, 250);
        let s = a.snapshot();
        assert_eq!(s.system_bytes(), 250);
        assert!((s.wa_factor(1_000) - 0.25).abs() < 1e-9);
        assert!(s.to_string().contains("reshard"));
    }

    #[test]
    fn event_time_counts_toward_wa() {
        let a = WriteAccounting::new();
        a.record(WriteCategory::SourceIngest, 1_000);
        a.record(WriteCategory::EventTime, 100);
        a.record(WriteCategory::UserOutput, 400);
        let s = a.snapshot();
        assert_eq!(s.system_bytes(), 100, "user output stays excluded");
        assert!((s.wa_factor(1_000) - 0.1).abs() < 1e-9);
        assert!(s.to_string().contains("event_time"));
    }

    #[test]
    fn anchor_state_counts_toward_wa() {
        let a = WriteAccounting::new();
        a.record(WriteCategory::SourceIngest, 1_000);
        a.record(WriteCategory::AnchorState, 80);
        a.record(WriteCategory::UserOutput, 400);
        let s = a.snapshot();
        assert_eq!(s.system_bytes(), 80, "user output stays excluded");
        assert!((s.wa_factor(1_000) - 0.08).abs() < 1e-9);
        assert!(s.to_string().contains("anchor_state"));
    }

    #[test]
    fn cold_tier_counts_toward_wa() {
        let a = WriteAccounting::new();
        a.record(WriteCategory::SourceIngest, 1_000);
        a.record(WriteCategory::ColdTier, 120);
        a.record(WriteCategory::UserOutput, 400);
        let s = a.snapshot();
        assert_eq!(s.system_bytes(), 120, "user output stays excluded");
        assert!((s.wa_factor(1_000) - 0.12).abs() < 1e-9);
        assert!(s.to_string().contains("cold_tier"));
    }

    #[test]
    fn inter_stage_counts_toward_wa() {
        let a = WriteAccounting::new();
        a.record(WriteCategory::SourceIngest, 1_000);
        a.record(WriteCategory::InterStage, 500);
        let s = a.snapshot();
        assert_eq!(s.system_bytes(), 500);
        assert!((s.wa_factor(1_000) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn scoped_recording_is_isolated_per_scope() {
        let a = WriteAccounting::new();
        a.scope_handle("stage-0").record(WriteCategory::MapperMeta, 100);
        a.scope_handle("stage-1").record(WriteCategory::MapperMeta, 40);
        let s0 = a.scope_snapshot("stage-0");
        assert_eq!(s0.bytes_of(WriteCategory::MapperMeta), 100);
        assert_eq!(s0.ops_of(WriteCategory::MapperMeta), 1);
        assert_eq!(
            a.scope_snapshot("stage-1").bytes_of(WriteCategory::MapperMeta),
            40
        );
        // Unknown scope: all-zero, not a panic.
        assert_eq!(a.scope_snapshot("nope"), AccountingSnapshot::default());
    }

    #[test]
    fn scope_handles_share_cells_and_skip_globals() {
        let a = WriteAccounting::new();
        let h1 = a.scope_handle("s");
        let h2 = a.scope_handle("s");
        h1.record(WriteCategory::InterStage, 5);
        h2.record(WriteCategory::InterStage, 7);
        assert_eq!(a.scope_snapshot("s").bytes_of(WriteCategory::InterStage), 12);
        assert_eq!(a.scope_snapshot("s").ops_of(WriteCategory::InterStage), 2);
        // A handle records scope cells only; journals pair it with the
        // global `record`.
        assert_eq!(a.bytes(WriteCategory::InterStage), 0);
    }

    #[test]
    fn record_batch_is_indistinguishable_from_singles() {
        let singles = WriteAccounting::new();
        for _ in 0..7 {
            singles.record(WriteCategory::ReducerMeta, 33);
        }
        singles.scope_handle("s").record(WriteCategory::EventTime, 5);
        singles.scope_handle("s").record(WriteCategory::EventTime, 6);

        let batched = WriteAccounting::new();
        batched.record_batch(WriteCategory::ReducerMeta, 7 * 33, 7);
        batched
            .scope_handle("s")
            .record_batch(WriteCategory::EventTime, 11, 2);

        assert_eq!(singles.snapshot(), batched.snapshot());
        assert_eq!(singles.scope_snapshot("s"), batched.scope_snapshot("s"));
        // Zero-op batches are legal and count bytes only (padding/framing).
        batched.record_batch(WriteCategory::Spill, 4, 0);
        assert_eq!(batched.bytes(WriteCategory::Spill), 4);
        assert_eq!(batched.ops(WriteCategory::Spill), 0);
    }

    #[test]
    fn display_skips_empty() {
        let a = WriteAccounting::new();
        a.record(WriteCategory::MapperMeta, 5);
        let text = a.snapshot().to_string();
        assert!(text.contains("mapper_meta"));
        assert!(!text.contains("spill"));
    }
}
