//! Bulk chunk store: where classic shuffles and spills put payload bytes.
//!
//! Classic MapReduce persists the full shuffle payload between phases
//! (§2.1); MapReduce Online still journals every pipelined batch (§2.2).
//! The baseline pipeline reproduces that behaviour through this store so
//! the WA comparison is apples-to-apples. The §6 spill extension also
//! writes here when a straggling reducer forces a mapper to evict rows.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::accounting::{WriteAccounting, WriteCategory};
use crate::util;

/// Opaque id of a stored chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ChunkId(pub u64);

/// Content store with accounted writes and delete (for trim-after-read).
/// Chunks are shared `Arc<[u8]>` buffers so readers decode them zero-copy
/// ([`crate::rows::codec::decode_rowset_shared`]).
#[derive(Debug)]
pub struct ChunkStore {
    accounting: Arc<WriteAccounting>,
    category: WriteCategory,
    next_id: AtomicU64,
    chunks: Mutex<HashMap<ChunkId, Arc<[u8]>>>,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum ChunkError {
    #[error("chunk {0:?} not found (trimmed or never written)")]
    NotFound(ChunkId),
}

impl ChunkStore {
    pub fn new(category: WriteCategory, accounting: Arc<WriteAccounting>) -> Arc<ChunkStore> {
        Arc::new(ChunkStore {
            accounting,
            category,
            next_id: AtomicU64::new(1),
            chunks: Mutex::new(HashMap::new()),
        })
    }

    /// Persist a chunk; every byte is accounted. Accepts an already-shared
    /// `Arc<[u8]>` (stored without copying) or a `Vec<u8>` (one bulk copy
    /// into shared storage — the price of zero-copy reads via
    /// [`Self::get`] + `decode_rowset_shared`).
    pub fn put(&self, data: impl Into<Arc<[u8]>>) -> ChunkId {
        let data: Arc<[u8]> = data.into();
        self.accounting.record(self.category, data.len() as u64);
        let id = ChunkId(self.next_id.fetch_add(1, Ordering::Relaxed));
        util::lock(&self.chunks).insert(id, data);
        id
    }

    pub fn get(&self, id: ChunkId) -> Result<Arc<[u8]>, ChunkError> {
        util::lock(&self.chunks)
            .get(&id)
            .cloned()
            .ok_or(ChunkError::NotFound(id))
    }

    /// Remove a chunk once its consumers are done (idempotent).
    pub fn delete(&self, id: ChunkId) {
        util::lock(&self.chunks).remove(&id);
    }

    /// Number of live (not yet deleted) chunks.
    pub fn live_count(&self) -> usize {
        util::lock(&self.chunks).len()
    }

    /// Bytes currently held live.
    pub fn live_bytes(&self) -> u64 {
        util::lock(&self.chunks)
            .values()
            .map(|c| c.len() as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_delete() {
        let acc = WriteAccounting::new();
        let s = ChunkStore::new(WriteCategory::ShufflePersist, acc.clone());
        let id = s.put(vec![9; 100]);
        assert_eq!(s.get(id).unwrap().len(), 100);
        assert_eq!(acc.bytes(WriteCategory::ShufflePersist), 100);
        assert_eq!(s.live_bytes(), 100);
        s.delete(id);
        assert_eq!(s.get(id), Err(ChunkError::NotFound(id)));
        assert_eq!(s.live_count(), 0);
        // accounting is monotone: deletes don't refund written bytes
        assert_eq!(acc.bytes(WriteCategory::ShufflePersist), 100);
    }

    #[test]
    fn delete_idempotent() {
        let acc = WriteAccounting::new();
        let s = ChunkStore::new(WriteCategory::Spill, acc);
        let id = s.put(vec![1]);
        s.delete(id);
        s.delete(id); // no panic
    }

    #[test]
    fn ids_unique_across_threads() {
        let acc = WriteAccounting::new();
        let s = ChunkStore::new(WriteCategory::Spill, acc);
        let ids = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let s = s.clone();
                    scope.spawn(move || (0..100).map(|_| s.put(vec![0])).collect::<Vec<_>>())
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().unwrap())
                .collect::<Vec<_>>()
        });
        let mut dedup = ids.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len());
    }
}
