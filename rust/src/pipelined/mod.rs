//! Pipelined reducer — the §6 future-work design, implemented.
//!
//! "A single cycle of the reducer's main procedure can be subdivided into
//! three consecutive stages: *fetch*, *process* (combine row batches and
//! run Reduce) and *commit*. Thus, we can perform stages within different
//! cycles concurrently, as long as executions of each individual stage are
//! well-ordered. This is a generalization of instruction pipelining
//! utilized in modern processors."
//!
//! The overlap implemented here: while process(n)+commit(n) run on a
//! scoped worker thread, the main thread *optimistically* fetches cycle
//! n+1 using the tentative state produced by fetch(n) — mappers keep
//! served-but-unacked rows anyway (§4.3.4 step 4), so an optimistic fetch
//! is always safe. If commit(n) fails (split brain, conflict), the
//! prefetched batch is discarded and the loop refetches from the real
//! state; exactly-once is untouched because *commit order* is unchanged —
//! only idle network time is reclaimed.
//!
//! Enabled with `pipelined_reducer = %true` in the processor config;
//! `rust/benches/ablation_pipelined.rs` measures the gain.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use crate::api::Reducer;
use crate::coordinator::reducer::{CommitOutcome, FetchResult, ReducerRt};
use crate::coordinator::state::ReducerState;

/// The pipelined main loop (same contract as the serial
/// `run_reducer_serial`).
pub(crate) fn run_reducer_pipelined(
    rt: &ReducerRt,
    user_reducer: &mut dyn Reducer,
    kill: &AtomicBool,
    pause: &AtomicBool,
) {
    let clock = rt.deps.client.clock.clone();
    let Some(session) = rt.join_discovery(kill) else {
        return;
    };
    let mut last_commit_ms = clock.now_ms();
    let mut last_heartbeat_ms = clock.now_ms();
    let mut cycle: u64 = 0;
    // Highest mapper index (+1) ever fetched from (retirement-gate floor).
    let mut max_mapper_seen = rt.spec.num_mappers;

    // The in-flight batch: (state it was fetched against, tentative new
    // state, fetched rows).
    let mut inflight: Option<(ReducerState, ReducerState, Vec<FetchResult>)> = None;

    while !kill.load(Ordering::SeqCst) {
        if pause.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
            inflight = None; // a hung worker loses its prefetch
            continue;
        }
        rt.heartbeat_if_due(session, &mut last_heartbeat_ms);
        cycle += 1;

        // Ensure we have a batch to process: fetch against the durable
        // state when the pipeline is empty. The reshard gates (retired
        // exit, bootstrap import, drain-and-retire) live on this refill
        // path — a reshard quiesces the pipeline anyway, so the overlap
        // machinery never runs mid-migration-boundary.
        let (state, new_state, fetches) = match inflight.take() {
            Some(x) => x,
            None => {
                let Some(state) = rt.fetch_state() else {
                    clock.sleep_ms(rt.cfg.backoff_ms);
                    continue;
                };
                if state.retired {
                    return; // this epoch was resharded away
                }
                if !state.bootstrapped {
                    rt.try_bootstrap(&state);
                    clock.sleep_ms(rt.cfg.backoff_ms);
                    continue;
                }
                let fetches = rt.fetch_cycle(&state, cycle);
                for f in &fetches {
                    max_mapper_seen = max_mapper_seen.max(f.mapper_index + 1);
                }
                let (new_state, total) = rt.tentative_state(&state, &fetches);
                if total == 0 {
                    if let Some(plan) = rt.fetch_plan() {
                        if plan.phase == crate::reshard::plan::PlanPhase::Migrating
                            && plan.epoch == rt.spec.epoch
                        {
                            if let Some(dead) = rt.ready_to_retire(&fetches, max_mapper_seen) {
                                if rt.try_retire(&state, &plan, &dead) {
                                    return;
                                }
                            }
                        }
                    }
                    // Time-driven work on a quiet stream (event-time
                    // final-fires): same hook as the serial loop; the
                    // pipeline is empty here, so no prefetch is at risk.
                    if let Some(txn) = user_reducer.tick() {
                        let _ = rt.commit_tick(&state, &state, txn);
                    }
                    clock.sleep_ms(rt.cfg.backoff_ms);
                    continue;
                }
                (state, new_state, fetches)
            }
        };

        // Overlap: commit the current batch on a scoped thread while this
        // thread prefetches the next one against the *tentative* state.
        let mut outcome = CommitOutcome::Nothing;
        let mut prefetch: Option<(ReducerState, ReducerState, Vec<FetchResult>)> = None;
        std::thread::scope(|scope| {
            // Pipelining is exactly-once-only (the spawn gate forces
            // approximate tiers onto the serial loop), so every commit
            // persists state.
            let commit = scope.spawn(|| {
                rt.process_and_commit(user_reducer, &state, &new_state, &fetches, true)
            });
            // Optimistic fetch(n+1) against new_state.
            let next_fetches = rt.fetch_cycle(&new_state, cycle + 1);
            let (next_state, next_total) = rt.tentative_state(&new_state, &next_fetches);
            if next_total > 0 {
                prefetch = Some((new_state.clone(), next_state, next_fetches));
            }
            outcome = commit.join().expect("commit stage panicked");
        });

        match outcome {
            CommitOutcome::Committed { rows, bytes } => {
                last_commit_ms = rt.record_commit(rows, bytes, last_commit_ms);
                // The durable state now equals `new_state`; the prefetch
                // that was built against it is valid.
                inflight = prefetch;
            }
            CommitOutcome::SplitBrain | CommitOutcome::Conflict => {
                // Commit lost: the prefetch is built on a state that never
                // became durable — discard and resync.
                inflight = None;
                clock.sleep_ms(rt.cfg.backoff_ms);
            }
            CommitOutcome::Nothing | CommitOutcome::TransientError => {
                inflight = None;
                clock.sleep_ms(rt.cfg.backoff_ms);
            }
        }
    }
}
