//! Watermarks: the event-time low water of a mapper fleet.
//!
//! **Definition.** A mapper's watermark `W` asserts: *every row this
//! mapper ever routed whose event time is `< W` has been committed by its
//! reducer.* The mapper derives it from what it can observe locally —
//! the minimum event time over rows still buffered (window entries and
//! spill queues pin exactly the not-yet-acked rows), falling back to the
//! ingest frontier (max event time ever ingested, exclusive) when nothing
//! is buffered — clamps it monotone, and persists it as the
//! `watermark_ms` column of its meta-state row on the existing
//! `TrimInputRows` CAS cadence. No new write path, no new consensus: the
//! watermark rides the same row that already carries the trim cursor.
//!
//! **Fleet watermark** = min over *live* (non-retired) mappers, computed
//! by [`WatermarkTracker`] from the mapper state table. Retired mappers
//! drop out of the min (they can never serve a row again); a live mapper
//! that has not reported yet holds the fleet at "no watermark" — firing
//! cannot outrun an unobserved partition. Because each mapper's column is
//! monotone and dropping a term can only raise a minimum, the fleet
//! watermark never regresses across kills, split-brain twins, or a
//! mid-stream reshard (the miniprop suite checks this).
//!
//! **Source close.** A drained source cannot be distinguished from a slow
//! one, so "the watermark reached +∞" is an explicit control decision:
//! the driver writes a close marker (one row in the `eventtime_close`
//! table beside the mapper state table) *after* the last append, and each
//! mapper lifts its watermark to the close timestamp once it has observed
//! the marker, read an empty batch after observing it, and flushed every
//! buffered row. [`EVENT_TIME_CLOSED`] is the conventional +∞ stand-in.

use std::sync::Arc;

use crate::coordinator::state::MapperState;
use crate::dyntable::DynTableStore;
use crate::rows::{ColumnSchema, ColumnType, TableSchema, UnversionedRow, Value};
use crate::storage::WriteCategory;

/// Sentinel for "no watermark observed yet" (also the column default when
/// event time is disabled). Smaller than every real event time.
pub const NO_WATERMARK: i64 = i64::MIN;

/// Conventional "+∞" close timestamp: strictly above any real event time
/// a workload emits, with headroom so `window_end + lateness` arithmetic
/// can never overflow.
pub const EVENT_TIME_CLOSED: i64 = i64::MAX / 4;

/// Path of the source-close control table, derived from the stage's
/// mapper state table path.
pub fn close_table_path(mapper_state_table: &str) -> String {
    format!("{mapper_state_table}/eventtime_close")
}

/// Schema of the close table: a single row (key 0) carrying the close
/// timestamp.
pub fn close_table_schema() -> TableSchema {
    TableSchema::new(vec![
        ColumnSchema::key("k", ColumnType::Int64),
        ColumnSchema::value("close_ts_ms", ColumnType::Int64),
    ])
}

/// Create the close table (idempotent). Called by processor setup when
/// event time is enabled.
pub fn ensure_close_table(
    store: &Arc<DynTableStore>,
    mapper_state_table: &str,
    scope: Option<String>,
) -> Result<(), String> {
    use crate::dyntable::store::StoreError;
    match store.create_table_scoped(
        &close_table_path(mapper_state_table),
        close_table_schema(),
        WriteCategory::EventTime,
        scope,
    ) {
        Ok(_) | Err(StoreError::AlreadyExists(_)) => Ok(()),
        Err(e) => Err(e.to_string()),
    }
}

/// Persist the close marker: asserts *no further rows will ever be
/// appended to this stage's input*, and that every event time already
/// appended is `< close_ts_ms`. Idempotent for the same timestamp; a
/// higher timestamp overwrites (re-opening is not supported). Retries
/// transient store errors a bounded number of times.
pub fn close_source(
    store: &Arc<DynTableStore>,
    mapper_state_table: &str,
    close_ts_ms: i64,
) -> Result<(), String> {
    let table = close_table_path(mapper_state_table);
    let mut last_err = String::from("close_source: retries exhausted");
    for _ in 0..64 {
        let mut txn = store.begin();
        match txn.lookup(&table, &[Value::Int64(0)]) {
            Ok(Some(row)) => {
                let existing = row.get(1).and_then(Value::as_i64).unwrap_or(NO_WATERMARK);
                if existing >= close_ts_ms {
                    return Ok(()); // already closed at or beyond this point
                }
            }
            Ok(None) => {}
            Err(e) => {
                last_err = e.to_string();
                continue;
            }
        }
        if let Err(e) = txn.write(
            &table,
            UnversionedRow::new(vec![Value::Int64(0), Value::Int64(close_ts_ms)]),
        ) {
            last_err = e.to_string();
            continue;
        }
        match txn.commit() {
            Ok(_) => return Ok(()),
            Err(e) => last_err = e.to_string(),
        }
    }
    Err(last_err)
}

/// Non-transactional read of the close marker (`None` = not closed, or
/// table missing / store outage — all safely "not closed").
pub fn fetch_close(store: &DynTableStore, mapper_state_table: &str) -> Option<i64> {
    store
        .lookup(&close_table_path(mapper_state_table), &[Value::Int64(0)])
        .ok()
        .flatten()
        .and_then(|row| row.get(1).and_then(Value::as_i64))
}

/// Computes the fleet watermark from a mapper state table. Stateless —
/// every call reads the live rows, so a consult after a crash or reshard
/// sees at least the value any earlier consult saw (per-mapper columns
/// are monotone, retired mappers only ever leave the min).
#[derive(Clone)]
pub struct WatermarkTracker {
    store: Arc<DynTableStore>,
    mapper_state_table: String,
}

impl WatermarkTracker {
    pub fn new(store: Arc<DynTableStore>, mapper_state_table: impl Into<String>) -> WatermarkTracker {
        WatermarkTracker {
            store,
            mapper_state_table: mapper_state_table.into(),
        }
    }

    pub fn mapper_state_table(&self) -> &str {
        &self.mapper_state_table
    }

    /// The fleet watermark: min over live (non-retired) mappers'
    /// `watermark_ms`. `None` when the table is unreadable, empty, or any
    /// live mapper has not reported a watermark yet — all of which must
    /// hold firing, never advance it.
    pub fn fleet_watermark(&self) -> Option<i64> {
        let rows = self.store.scan(&self.mapper_state_table).ok()?;
        let mut min: Option<i64> = None;
        let mut live = 0usize;
        for row in &rows {
            let Some(state) = MapperState::from_row(row) else {
                return None; // corrupt row: hold
            };
            if state.retired {
                continue;
            }
            live += 1;
            if state.watermark_ms == NO_WATERMARK {
                return None; // an unobserved live partition gates the fleet
            }
            min = Some(min.map_or(state.watermark_ms, |m: i64| m.min(state.watermark_ms)));
        }
        if live == 0 {
            return None; // nothing live: a fleet of zero reports nothing
        }
        min
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::WriteAccounting;

    const TABLE: &str = "//sys/p/mapper_state";

    fn store_with_states(states: &[(usize, i64, bool)]) -> Arc<DynTableStore> {
        let store = DynTableStore::new(WriteAccounting::new());
        store
            .create_table(TABLE, MapperState::schema(), WriteCategory::MapperMeta)
            .unwrap();
        let mut txn = store.begin();
        for &(index, wm, retired) in states {
            let mut s = MapperState::initial();
            s.watermark_ms = wm;
            s.retired = retired;
            txn.write(TABLE, s.to_row(index)).unwrap();
        }
        txn.commit().unwrap();
        store
    }

    #[test]
    fn fleet_watermark_is_min_over_live() {
        let store = store_with_states(&[(0, 100, false), (1, 70, false), (2, 250, false)]);
        let t = WatermarkTracker::new(store, TABLE);
        assert_eq!(t.fleet_watermark(), Some(70));
    }

    #[test]
    fn retired_mappers_drop_out_of_the_min() {
        let store = store_with_states(&[(0, 100, false), (1, 30, true), (2, 250, false)]);
        let t = WatermarkTracker::new(store.clone(), TABLE);
        assert_eq!(
            t.fleet_watermark(),
            Some(100),
            "a retired slot's stale low watermark must not hold the fleet"
        );
        // Retiring the minimum live mapper can only raise the fleet value.
        let mut txn = store.begin();
        let mut s = MapperState::initial();
        s.watermark_ms = 100;
        s.retired = true;
        txn.write(TABLE, s.to_row(0)).unwrap();
        txn.commit().unwrap();
        assert_eq!(t.fleet_watermark(), Some(250));
    }

    #[test]
    fn unreported_live_mapper_holds_the_fleet() {
        let store = store_with_states(&[(0, 100, false), (1, NO_WATERMARK, false)]);
        let t = WatermarkTracker::new(store, TABLE);
        assert_eq!(t.fleet_watermark(), None);
    }

    #[test]
    fn empty_or_missing_table_reports_nothing() {
        let store = store_with_states(&[]);
        assert_eq!(WatermarkTracker::new(store.clone(), TABLE).fleet_watermark(), None);
        assert_eq!(
            WatermarkTracker::new(store, "//no/such/table").fleet_watermark(),
            None
        );
    }

    #[test]
    fn all_retired_reports_nothing() {
        let store = store_with_states(&[(0, 10, true), (1, 20, true)]);
        assert_eq!(WatermarkTracker::new(store, TABLE).fleet_watermark(), None);
    }

    #[test]
    fn close_marker_roundtrip_and_idempotence() {
        let store = DynTableStore::new(WriteAccounting::new());
        ensure_close_table(&store, TABLE, None).unwrap();
        assert_eq!(fetch_close(&store, TABLE), None);
        close_source(&store, TABLE, 1_000).unwrap();
        assert_eq!(fetch_close(&store, TABLE), Some(1_000));
        // Re-closing at the same or a lower point is a no-op.
        close_source(&store, TABLE, 1_000).unwrap();
        close_source(&store, TABLE, 500).unwrap();
        assert_eq!(fetch_close(&store, TABLE), Some(1_000));
        close_source(&store, TABLE, EVENT_TIME_CLOSED).unwrap();
        assert_eq!(fetch_close(&store, TABLE), Some(EVENT_TIME_CLOSED));
    }

    #[test]
    fn fetch_close_on_missing_table_is_not_closed() {
        let store = DynTableStore::new(WriteAccounting::new());
        assert_eq!(fetch_close(&store, "//sys/none"), None);
    }

    #[test]
    fn close_bytes_are_accounted_as_event_time() {
        let acc = WriteAccounting::new();
        let store = DynTableStore::new(acc.clone());
        ensure_close_table(&store, TABLE, None).unwrap();
        close_source(&store, TABLE, 99).unwrap();
        assert!(acc.bytes(WriteCategory::EventTime) > 0);
    }
}
