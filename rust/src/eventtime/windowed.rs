//! Final-fire window reducers: tumbling event-time windows whose results
//! are written to the user output **exactly once**, when the fleet
//! watermark passes window end (+ allowed lateness).
//!
//! The write-amplification story: a per-batch-upsert reducer touches a
//! `(key)` output row once per batch that mentions the key — `UserOutput`
//! bytes scale with O(batches per key). A [`WindowedReducer`] instead
//! accumulates per-`(window, key)` state and emits each window's result
//! a single time — `UserOutput` becomes O(1) per window, the dominant WA
//! term gone. The open-window accumulators are compact
//! meta-state-sized records persisted in the commit transaction
//! (accounted as [`WriteCategory::EventTime`], reported honestly by
//! `figure window`), so a crashed or split-brain instance rehydrates from
//! the table instead of losing window contents.
//!
//! Exactly-once rides the existing row-index CAS, with **no new
//! mechanism**: accumulator upserts, fired-watermark markers, final
//! emissions, deletes and late-row side-channel appends all happen inside
//! the transaction the reducer main procedure commits together with its
//! meta-state row. A split-brain loser's folds and fires never land; a
//! winner's land atomically with the row-index advance, so a re-fetched
//! batch can never double-fold and a window can never double-fire.
//!
//! Why firing is safe: a mapper's watermark only passes a row once that
//! row was *committed* by its reducer (buffered rows pin the watermark —
//! see [`crate::eventtime::watermark`]). So when the fleet watermark
//! reaches `window_end + lateness`, every row of that window is already
//! folded into some reducer's persisted accumulator, and the fire emits a
//! complete result. Rows that arrive for an already-fired window (only
//! possible with out-of-order event times beyond the allowed lateness) go
//! to the **late side channel** — an ordered table appended within the
//! same transaction, so even lateness handling is exactly-once.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use crate::api::{partitioning, Client, Reducer, ReducerSpec};
use crate::consistency::{AnchorScheduler, Consistency};
use crate::dyntable::{DynTableStore, Transaction, TxnError};
use crate::metrics::hub::names;
use crate::metrics::MetricsHub;
use crate::queue::ordered_table::OrderedTable;
use crate::reshard::plan::{PlanPhase, ReshardPlan};
use crate::rows::{ColumnSchema, ColumnType, TableSchema, UnversionedRow, UnversionedRowset, Value};
use crate::storage::WriteCategory;
use crate::util::yson::Yson;

use super::watermark::{WatermarkTracker, NO_WATERMARK};

/// Tumbling-window geometry plus allowed lateness.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WindowSpec {
    /// Window length in event-time ms.
    pub size_ms: i64,
    /// How long past window end the watermark must travel before the
    /// window final-fires. Rows arriving later than that are late.
    pub allowed_lateness_ms: i64,
}

impl WindowSpec {
    pub fn tumbling(size_ms: i64) -> WindowSpec {
        assert!(size_ms > 0, "window size must be positive");
        WindowSpec {
            size_ms,
            allowed_lateness_ms: 0,
        }
    }

    pub fn with_lateness(self, allowed_lateness_ms: i64) -> WindowSpec {
        assert!(allowed_lateness_ms >= 0);
        WindowSpec {
            allowed_lateness_ms,
            ..self
        }
    }

    /// Start of the window containing `ts` (floor division, negative-safe).
    pub fn window_start(&self, ts: i64) -> i64 {
        ts.div_euclid(self.size_ms) * self.size_ms
    }

    /// Exclusive end of the window starting at `window_start`.
    pub fn window_end(&self, window_start: i64) -> i64 {
        window_start + self.size_ms
    }

    /// Is the window starting at `window_start` final under `watermark`?
    /// (Watermark semantics: all rows with event time `< watermark` are
    /// committed — so the window is complete once the watermark reaches
    /// `end + lateness`.)
    pub fn is_final(&self, window_start: i64, watermark: i64) -> bool {
        watermark
            >= self
                .window_end(window_start)
                .saturating_add(self.allowed_lateness_ms)
    }
}

/// User logic of a windowed stage: how rows map to (event time, key), how
/// they fold into a compact accumulator, and what the final fire writes.
///
/// Contracts (all required for the byte-identical-output guarantees):
/// * `key` must equal the routing key the stage's mapper hash-partitions
///   by — ownership of persisted window state is re-derived from it.
/// * `fold`/`merge` must be **batch-invariant** (commutative, associative
///   over row multisets), like every reducer in this system.
/// * `emit` must be deterministic in its inputs and write only
///   key-addressed rows (so firing order cannot matter).
pub trait WindowFold: Send + Sync {
    /// Event time of one row (`None` = row is dropped, deterministically).
    fn event_ts(&self, row: &UnversionedRow) -> Option<i64>;
    /// Grouping/routing key of one row (`None` = dropped).
    fn key(&self, row: &UnversionedRow) -> Option<String>;
    /// Fresh accumulator.
    fn zero(&self) -> Yson;
    /// Fold one row into an accumulator.
    fn fold(&self, acc: &mut Yson, row: &UnversionedRow);
    /// Merge another accumulator in (rehydration, reshard import).
    fn merge(&self, into: &mut Yson, other: &Yson);
    /// Write the final window result into the firing transaction. Called
    /// exactly once per (window, key) across the stage's whole lifetime.
    fn emit(
        &self,
        window_start: i64,
        window_end: i64,
        key: &str,
        acc: &Yson,
        txn: &mut Transaction,
    ) -> Result<(), TxnError>;
}

/// Per-epoch window-state table path (same convention as the reducer
/// state tables: epoch 0 keeps the base path).
pub fn window_state_table(base: &str, epoch: i64) -> String {
    if epoch == 0 {
        base.to_string()
    } else {
        format!("{base}/e{epoch}")
    }
}

/// Schema of a window-state table: `(window_start, win_key) → acc`.
/// Fired-watermark markers live in the same table under
/// `window_start == MARKER_WINDOW` with `win_key = "fired/<index>"`.
pub fn window_state_schema() -> TableSchema {
    TableSchema::new(vec![
        ColumnSchema::key("window_start", ColumnType::Int64),
        ColumnSchema::key("win_key", ColumnType::Str),
        ColumnSchema::value("acc", ColumnType::Str),
    ])
}

/// Name table of fired-history cold chunks: the window-state rows exactly
/// as the firing pass deleted them.
pub fn history_name_table() -> Arc<crate::rows::NameTable> {
    crate::rows::NameTable::new(&["window_start", "win_key", "acc"])
}

/// Reserved `window_start` of the per-reducer fired-watermark marker rows.
pub const MARKER_WINDOW: i64 = i64::MIN;

fn marker_key(index: usize) -> String {
    format!("fired/{index}")
}

/// Table key of reducer `index`'s fired-watermark marker row.
pub(crate) fn marker_row_key(index: usize) -> Vec<Value> {
    vec![
        Value::Int64(MARKER_WINDOW),
        Value::from(marker_key(index).as_str()),
    ]
}

/// The marker row itself (the single encoding every reader/writer —
/// reducer, exporter, importer — must share).
pub(crate) fn fired_marker_row(index: usize, fired_wm: i64) -> UnversionedRow {
    UnversionedRow::new(vec![
        Value::Int64(MARKER_WINDOW),
        Value::from(marker_key(index).as_str()),
        Value::from(Yson::Int(fired_wm).to_string().as_str()),
    ])
}

/// Read reducer `index`'s fired watermark through `txn` (`None` when the
/// marker is absent or unparsable).
pub(crate) fn lookup_fired_marker(
    txn: &mut Transaction,
    table: &str,
    index: usize,
) -> Result<Option<i64>, TxnError> {
    Ok(txn
        .lookup(table, &marker_row_key(index))?
        .and_then(|r| r.get(2).and_then(Value::as_str).map(str::to_string))
        .and_then(|s| Yson::parse(&s).ok())
        .and_then(|y| y.as_i64().ok()))
}

/// Install reducer `index`'s fired-watermark marker if `wm` advances it —
/// the bootstrap-from-cold path ([`crate::coldtier::ColdWindowBootstrap`])
/// restoring "these windows already fired" into a fresh epoch whose
/// migration handoff arrived empty.
pub fn restore_fired_marker(
    txn: &mut Transaction,
    table: &str,
    index: usize,
    wm: i64,
) -> Result<(), TxnError> {
    let existing = lookup_fired_marker(txn, table, index)?;
    if existing < Some(wm) {
        txn.write(table, fired_marker_row(index, wm))?;
    }
    Ok(())
}

/// Create a window-state table (idempotent).
pub fn ensure_window_state_table(
    store: &Arc<DynTableStore>,
    path: &str,
    scope: Option<String>,
) -> Result<(), String> {
    use crate::dyntable::store::StoreError;
    match store.create_table_scoped(path, window_state_schema(), WriteCategory::EventTime, scope) {
        Ok(_) | Err(StoreError::AlreadyExists(_)) => Ok(()),
        Err(e) => Err(e.to_string()),
    }
}

/// Everything a [`WindowedReducer`] (and the reshard migrators) need to
/// know about their stage, shared by the whole fleet.
pub struct WindowedDeps {
    pub spec: WindowSpec,
    pub fold: Arc<dyn WindowFold>,
    /// Base path of the per-epoch window-state tables.
    pub state_base: String,
    /// The stage's reshard plan table (resolves an epoch's fleet size).
    pub plan_table: String,
    /// The stage's mapper state table (fleet watermark source).
    pub mapper_state_table: String,
    /// Late side channel: rows whose window already final-fired. One
    /// tablet per reducer index (grown on demand).
    pub late: Arc<OrderedTable>,
    pub metrics: Arc<MetricsHub>,
    /// Write-accounting scope the window-state tables are attributed to
    /// (the stage's scope label in a topology; `None` standalone) — keeps
    /// the per-stage `event_time` WA line honest.
    pub scope: Option<String>,
    /// The stage's consistency tier. Under the approximate tiers the
    /// working accumulators live in memory and are persisted only at
    /// *anchors* (scheduler cadence, or when a window can fire — firing
    /// reads accumulators through the txn, so they must be in it); the
    /// durable table holds the last anchor, and a crash replays/loses at
    /// most the unanchored window. Exactly-once (the default) persists
    /// every batch — that code path is unchanged from the seed.
    pub consistency: Consistency,
    /// Cold tier (when enabled): each firing pass compacts the fired
    /// `(window, key, acc)` triples it is about to delete into one
    /// history chunk, written in the same transaction — the GC'd history
    /// becomes durable instead of gone, and the chunk id records the fire
    /// watermark for bootstrap-from-cold.
    pub cold: Option<Arc<crate::coldtier::ColdStore>>,
}

/// `CreateReducer` for a windowed final stage: every spawned instance
/// shares the stage's [`WindowedDeps`].
pub fn windowed_reducer_factory(deps: Arc<WindowedDeps>) -> crate::api::ReducerFactory {
    Arc::new(move |_cfg: &Yson, client: &Client, spec: &ReducerSpec| {
        Box::new(WindowedReducer::new(deps.clone(), client, spec)) as Box<dyn Reducer>
    })
}

/// Reusable fold-attempt buffers (the slot arena): cleared — capacity
/// retained — between attempts, so a steady-state reducer stops paying a
/// fresh allocation per batch for its per-(window, key) working set.
#[derive(Default)]
struct SlotArena {
    /// `(slot, row index)` tag per on-time row, stable-sorted by slot so
    /// each slot's rows form a contiguous run in arrival order.
    tags: Vec<((i64, String), usize)>,
    /// `(slot, accumulator)` per distinct slot, in slot order — the same
    /// `touched` set (and the same state-row write order) the old
    /// per-slot map produced.
    entries: Vec<((i64, String), Yson)>,
}

/// The final-fire adapter: implements [`Reducer`] over a [`WindowFold`].
pub struct WindowedReducer {
    deps: Arc<WindowedDeps>,
    client: Client,
    index: usize,
    epoch: i64,
    /// Fleet size of this reducer's epoch (lazily resolved from the plan;
    /// immutable once known).
    partitions: Option<usize>,
    tracker: WatermarkTracker,
    /// Monotone clamp over observed fleet watermarks.
    local_watermark: i64,
    arena: SlotArena,
    /// Approximate tiers only: the in-memory working accumulators. The
    /// durable table lags behind at the last anchor; this map is the
    /// truth folded between anchors. Always empty under exactly-once.
    resident: BTreeMap<(i64, String), Yson>,
    /// Anchor cadence for the approximate tiers (exactly-once: every
    /// batch persists, the scheduler is never consulted).
    anchors: AnchorScheduler,
}

impl WindowedReducer {
    pub fn new(deps: Arc<WindowedDeps>, client: &Client, spec: &ReducerSpec) -> WindowedReducer {
        let tracker = WatermarkTracker::new(client.store.clone(), deps.mapper_state_table.clone());
        // Best-effort here; a transient failure surfaces as retried txn
        // errors in the reducer loop.
        let _ = ensure_window_state_table(
            &client.store,
            &window_state_table(&deps.state_base, spec.epoch),
            deps.scope.clone(),
        );
        let policy = deps.consistency;
        WindowedReducer {
            deps,
            client: client.clone(),
            index: spec.index,
            epoch: spec.epoch,
            partitions: None,
            tracker,
            local_watermark: NO_WATERMARK,
            arena: SlotArena::default(),
            resident: BTreeMap::new(),
            anchors: AnchorScheduler::new(policy),
        }
    }

    fn state_table(&self) -> String {
        window_state_table(&self.deps.state_base, self.epoch)
    }

    /// This epoch's fleet size, from the plan row (an epoch's size never
    /// changes once announced, so the first resolution is cached).
    fn partitions(&mut self) -> Option<usize> {
        if self.partitions.is_some() {
            return self.partitions;
        }
        let plan = ReshardPlan::fetch(&self.client.store, &self.deps.plan_table)?;
        let p = if plan.epoch == self.epoch {
            Some(plan.partitions)
        } else if plan.phase == PlanPhase::Migrating && plan.next_epoch() == self.epoch {
            Some(plan.next_partitions)
        } else {
            None // zombie of a finalized-away epoch: never fires
        };
        self.partitions = p;
        p
    }

    fn refresh_watermark(&mut self) {
        if let Some(w) = self.tracker.fleet_watermark() {
            self.local_watermark = self.local_watermark.max(w);
        }
        if self.local_watermark != NO_WATERMARK {
            self.deps
                .metrics
                .series("eventtime/fleet_watermark_ms")
                .record(self.client.clock.now_ms(), self.local_watermark as f64);
        }
    }

    fn read_fired(&self, txn: &mut Transaction) -> Result<i64, TxnError> {
        Ok(lookup_fired_marker(txn, &self.state_table(), self.index)?.unwrap_or(NO_WATERMARK))
    }

    fn write_fired(&self, txn: &mut Transaction, fired_wm: i64) -> Result<(), TxnError> {
        // protolint: allow(cas_read_set, "helper: every caller opens the txn with read_fired, which puts this marker row in the read set")
        txn.write(&self.state_table(), fired_marker_row(self.index, fired_wm))
    }

    /// Fire every final window this reducer owns into `txn`. Candidates
    /// come from a table scan (cheap: open windows only) plus the
    /// accumulators touched by this very transaction; every candidate is
    /// re-read through the transaction, so the scan itself needs no
    /// consistency — but a *failed* scan must fail the attempt: silently
    /// firing only the touched subset would advance the fired marker past
    /// scan-missed windows and strand them forever. Returns the number of
    /// windows fired.
    fn fire_into(
        &mut self,
        txn: &mut Transaction,
        fired_wm: i64,
        touched: &[((i64, String), Yson)],
    ) -> Result<u64, TxnError> {
        let wm = self.local_watermark;
        if wm == NO_WATERMARK || wm <= fired_wm {
            // Nothing can be final beyond the last firing pass: rows for
            // windows final under `fired_wm` were routed late before they
            // could open state, and every fire deletes its state row — so
            // neither the table nor `touched` can hold a candidate. Skips
            // the per-batch table scan on the hot path.
            return Ok(0);
        }
        let Some(partitions) = self.partitions() else {
            return Ok(0); // ownership unresolvable: hold fire, lose nothing
        };
        let table = self.state_table();
        let mut candidates: BTreeSet<(i64, String)> = BTreeSet::new();
        let scanned = self
            .client
            .store
            .scan(&table)
            .map_err(|_| TxnError::Unavailable)?;
        for row in scanned {
            let (Some(w), Some(key)) = (
                row.get(0).and_then(Value::as_i64),
                row.get(1).and_then(Value::as_str),
            ) else {
                continue;
            };
            if w == MARKER_WINDOW
                || !self.deps.spec.is_final(w, wm)
                || partitioning::hash_partition(key, partitions) != self.index
            {
                continue;
            }
            candidates.insert((w, key.to_string()));
        }
        for ((w, key), _) in touched {
            if self.deps.spec.is_final(*w, wm) {
                candidates.insert((*w, key.clone()));
            }
        }

        let mut fired = 0u64;
        let mut history: Vec<UnversionedRow> = Vec::new();
        for (w, key) in &candidates {
            let row_key = vec![Value::Int64(*w), Value::from(key.as_str())];
            // Read through the transaction: validates against twins and
            // picks up this commit's own folds (read-your-writes).
            let Some(row) = txn.lookup(&table, &row_key)? else {
                continue; // already fired by a winner we'll conflict with
            };
            let acc = row
                .get(2)
                .and_then(Value::as_str)
                .and_then(|s| Yson::parse(s).ok())
                .unwrap_or_else(|| self.deps.fold.zero());
            self.deps
                .fold
                .emit(*w, self.deps.spec.window_end(*w), key, &acc, txn)?;
            if self.deps.cold.is_some() {
                history.push(row);
            }
            txn.delete(&table, row_key)?;
            fired += 1;
        }
        if fired > 0 && wm > fired_wm {
            // Compact-on-GC: the state rows this pass deletes ride the
            // same transaction into a cold history chunk whose chunk id
            // is the fire watermark (bootstrap-from-cold restores the
            // fired marker as the max history chunk id). A split-brain
            // loser's chunk aborts with the rest of its fires.
            if let Some(cold) = &self.deps.cold {
                let rowset = UnversionedRowset::new(history_name_table(), history);
                cold.compact_into(
                    txn,
                    self.index,
                    crate::coldtier::KIND_HISTORY,
                    wm,
                    0,
                    &rowset,
                    Some(0),
                    Some(1),
                )?;
            }
            self.write_fired(txn, wm)?;
        }
        if fired > 0 {
            // Advisory (pre-commit) counter; conflicts are rare and only
            // ever over-count.
            self.deps.metrics.add(names::EVENTTIME_WINDOWS_FIRED, fired);
            // Log-bucketed distribution of fires per transaction: the
            // obs export's view of fire burstiness (a watermark stall
            // shows up as a fat tail here before it shows up in lag).
            self.deps
                .metrics
                .histogram("eventtime/windows_fired_per_txn")
                .record(fired);
        }
        Ok(fired)
    }

    /// One attempt at the fold+fire transaction for a batch.
    fn attempt_reduce(&mut self, rows: &UnversionedRowset) -> Result<Transaction, TxnError> {
        let table = self.state_table();
        let mut txn = self.client.begin();
        let fired_wm = self.read_fired(&mut txn)?;

        // Pass 1 (no store access): classify every row as late or tag it
        // with its (window, key) slot, into the reusable arena.
        let mut arena = std::mem::take(&mut self.arena);
        arena.tags.clear();
        arena.entries.clear();
        let mut late: Vec<UnversionedRow> = Vec::new();
        let all_rows = rows.rows();
        for (i, row) in all_rows.iter().enumerate() {
            let (Some(ts), Some(key)) = (self.deps.fold.event_ts(row), self.deps.fold.key(row))
            else {
                continue; // malformed row: dropped deterministically
            };
            let w = self.deps.spec.window_start(ts);
            if fired_wm != NO_WATERMARK && self.deps.spec.is_final(w, fired_wm) {
                // This reducer already final-fired past this window: the
                // row is late and goes to the side channel, exactly once
                // (the append rides this same transaction).
                late.push(row.clone());
                continue;
            }
            arena.tags.push(((w, key), i));
        }
        // Stable sort: each slot's rows stay contiguous in arrival order,
        // so per-accumulator fold sequences are unchanged.
        arena.tags.sort_by(|a, b| a.0.cmp(&b.0));

        // Pass 2: one batched transactional read for every distinct slot —
        // the same read set (and thus the same commit-time CAS semantics)
        // as the former per-slot lookups, in a single pass.
        let mut reads: Vec<(&str, Vec<Value>)> = Vec::new();
        for (j, (slot, _)) in arena.tags.iter().enumerate() {
            if j == 0 || arena.tags[j - 1].0 != *slot {
                reads.push((
                    table.as_str(),
                    vec![Value::Int64(slot.0), Value::from(slot.1.as_str())],
                ));
            }
        }
        let existing = match txn.lookup_many(&reads) {
            Ok(rows) => rows,
            Err(e) => {
                self.arena = arena;
                return Err(e);
            }
        };

        // Pass 3: fold each slot's run of rows into its accumulator.
        let mut j = 0;
        while j < arena.tags.len() {
            let run_start = j;
            let mut acc = existing[arena.entries.len()]
                .as_ref()
                .and_then(|r| r.get(2).and_then(Value::as_str))
                .and_then(|s| Yson::parse(s).ok())
                .unwrap_or_else(|| self.deps.fold.zero());
            while j < arena.tags.len() && arena.tags[j].0 == arena.tags[run_start].0 {
                self.deps.fold.fold(&mut acc, &all_rows[arena.tags[j].1]);
                j += 1;
            }
            let slot = arena.tags[run_start].0.clone();
            arena.entries.push((slot, acc));
        }
        for ((w, key), acc) in &arena.entries {
            if let Err(e) = txn.write(
                &table,
                UnversionedRow::new(vec![
                    Value::Int64(*w),
                    Value::from(key.as_str()),
                    Value::from(acc.to_string().as_str()),
                ]),
            ) {
                self.arena = arena;
                return Err(e);
            }
        }

        self.refresh_watermark();
        let fire = self.fire_into(&mut txn, fired_wm, &arena.entries);
        self.arena = arena; // hand the buffers back for the next attempt
        fire?;

        if !late.is_empty() {
            self.deps
                .metrics
                .add(names::EVENTTIME_LATE_ROWS, late.len() as u64);
            self.deps.late.ensure_tablets(self.index + 1);
            txn.append_ordered(self.deps.late.clone(), self.index, late)?;
        }
        Ok(txn)
    }

    /// The durable fired-watermark marker, read *outside* any transaction
    /// — under the approximate tiers it is the authority on what already
    /// final-fired (ours or a twin's), consulted every batch.
    fn durable_fired(&self, table: &str) -> Result<i64, TxnError> {
        Ok(self
            .client
            .store
            .lookup(table, &marker_row_key(self.index))
            .map_err(|_| TxnError::Unavailable)?
            .and_then(|r| r.get(2).and_then(Value::as_str).map(str::to_string))
            .and_then(|s| Yson::parse(&s).ok())
            .and_then(|y| y.as_i64().ok())
            .unwrap_or(NO_WATERMARK))
    }

    /// Write the approximate tiers' working accumulators into `txn` so a
    /// fire in the same transaction sees them (read-your-writes). Returns
    /// the persisted entries in `fire_into`'s `touched` shape.
    fn persist_resident(
        &self,
        txn: &mut Transaction,
        table: &str,
        overlay: &[((i64, String), Yson)],
    ) -> Result<Vec<((i64, String), Yson)>, TxnError> {
        let mut entries: BTreeMap<(i64, String), Yson> = self.resident.clone();
        for (slot, acc) in overlay {
            entries.insert(slot.clone(), acc.clone());
        }
        let entries: Vec<((i64, String), Yson)> = entries.into_iter().collect();
        for ((w, key), acc) in &entries {
            txn.write(
                table,
                UnversionedRow::new(vec![
                    Value::Int64(*w),
                    Value::from(key.as_str()),
                    Value::from(acc.to_string().as_str()),
                ]),
            )?;
        }
        Ok(entries)
    }

    /// One attempt at a batch under an *approximate* tier: fold into the
    /// resident in-memory accumulators and carry window-state writes only
    /// on anchors. Recovery is from the last anchor — a fresh incarnation
    /// seeds each slot from the durable table, so a crash drifts by at
    /// most the unanchored window (what `figure consistency` measures).
    ///
    /// Retry safety: `resident` is only mutated by idempotent steps
    /// (eviction of durably-fired slots, seeding from the anchor) until
    /// the transaction is fully built; the folds land in a scratch vec
    /// and are applied to `resident` last, so the 500-attempt retry loop
    /// in [`Reducer::reduce`] never double-folds. A commit that fails
    /// *after* we returned the txn is the accepted optimistic case: the
    /// next anchor rewrites every resident slot, so folds are delayed,
    /// never lost.
    fn attempt_reduce_approx(&mut self, rows: &UnversionedRowset) -> Result<Transaction, TxnError> {
        let table = self.state_table();
        let spec = self.deps.spec;
        let fired_wm = self.durable_fired(&table)?;
        // Slots the durable marker retired were fired (by us, committed,
        // or by a twin): evict them; their stragglers route late below.
        if fired_wm != NO_WATERMARK {
            self.resident.retain(|(w, _), _| !spec.is_final(*w, fired_wm));
        }

        // Classify: late vs (window, key) slot — same rule as exactly-once.
        let mut late: Vec<UnversionedRow> = Vec::new();
        let mut tagged: Vec<((i64, String), usize)> = Vec::new();
        let all_rows = rows.rows();
        for (i, row) in all_rows.iter().enumerate() {
            let (Some(ts), Some(key)) = (self.deps.fold.event_ts(row), self.deps.fold.key(row))
            else {
                continue;
            };
            let w = spec.window_start(ts);
            if fired_wm != NO_WATERMARK && spec.is_final(w, fired_wm) {
                late.push(row.clone());
                continue;
            }
            tagged.push(((w, key), i));
        }
        tagged.sort_by(|a, b| a.0.cmp(&b.0));

        // Seed every slot this incarnation has never held from its last
        // anchor (idempotent, so a later error retries cleanly).
        for (slot, _) in &tagged {
            if self.resident.contains_key(slot) {
                continue;
            }
            let key = vec![Value::Int64(slot.0), Value::from(slot.1.as_str())];
            let acc = self
                .client
                .store
                .lookup(&table, &key)
                .map_err(|_| TxnError::Unavailable)?
                .and_then(|r| r.get(2).and_then(Value::as_str).and_then(|s| Yson::parse(s).ok()))
                .unwrap_or_else(|| self.deps.fold.zero());
            self.resident.insert(slot.clone(), acc);
        }

        // Fold into a scratch overlay (not `resident` — retry safety).
        let mut folded: Vec<((i64, String), Yson)> = Vec::new();
        let mut j = 0;
        while j < tagged.len() {
            let run_start = j;
            let slot = &tagged[run_start].0;
            // protolint: allow(panic, "every tagged slot was inserted into self.resident by the seeding loop directly above in this same function")
            let mut acc = self.resident.get(slot).cloned().expect("seeded above");
            while j < tagged.len() && tagged[j].0 == *slot {
                self.deps.fold.fold(&mut acc, &all_rows[tagged[j].1]);
                j += 1;
            }
            folded.push((slot.clone(), acc));
        }
        let batch_rows = tagged.len() as u64;

        self.refresh_watermark();
        // Anchor when the scheduler demands it, or when a *resident*
        // window is actually final — a fire emits through the txn, so the
        // accumulators must be persisted in it. (Durable leftovers from a
        // dead incarnation fire on the next anchor's table scan, or from
        // `tick` on a quiet stream.)
        let wm = self.local_watermark;
        let fire_possible = wm != NO_WATERMARK
            && wm > fired_wm
            && self.resident.keys().any(|(w, _)| spec.is_final(*w, wm));
        let anchor = self.anchors.should_persist(batch_rows) || fire_possible;

        let mut txn = self.client.begin();
        if anchor {
            let entries = self.persist_resident(&mut txn, &table, &folded)?;
            self.fire_into(&mut txn, fired_wm, &entries)?;
        }
        if !late.is_empty() {
            self.deps
                .metrics
                .add(names::EVENTTIME_LATE_ROWS, late.len() as u64);
            self.deps.late.ensure_tablets(self.index + 1);
            txn.append_ordered(self.deps.late.clone(), self.index, late)?;
        }

        // Success point: the txn is fully built — apply the folds.
        for (slot, acc) in folded {
            self.resident.insert(slot, acc);
        }
        self.anchors.note_commit(anchor, batch_rows);
        self.deps.metrics.add(
            if anchor {
                names::REDUCER_ANCHOR_COMMITS
            } else {
                names::REDUCER_SKIPPED_PERSISTS
            },
            1,
        );
        Ok(txn)
    }
}

impl Reducer for WindowedReducer {
    fn reduce(&mut self, rows: UnversionedRowset) -> Option<Transaction> {
        if rows.is_empty() {
            return None;
        }
        // Returning `None` for a non-empty batch would let the main
        // procedure advance the meta-state *without* our folds — silent
        // row loss. So a transient store failure is retried here, and a
        // persistent one crashes the worker (panic = simulated process
        // death): nothing committed, the supervisor restarts us, the
        // batch is re-fetched. Exactly-once is preserved either way; the
        // approximate tiers recover from their last anchor instead.
        for _ in 0..500 {
            let attempt = if self.deps.consistency.is_exactly_once() {
                self.attempt_reduce(&rows)
            } else {
                self.attempt_reduce_approx(&rows)
            };
            match attempt {
                Ok(txn) => return Some(txn),
                Err(_) => std::thread::sleep(std::time::Duration::from_millis(2)),
            }
        }
        // protolint: allow(panic, "deliberate crash-for-restart after exhausting retries: the supervisor respawns the worker and recovery re-reads persisted state; limping on without a store would stall the watermark silently")
        panic!(
            "windowed reducer {} (epoch {}): store kept failing; crashing for restart",
            self.index, self.epoch
        );
    }

    /// Empty-cycle hook: fire windows the advancing watermark finalized
    /// even though no new rows arrived (end-of-stream drain, quiet keys).
    fn tick(&mut self) -> Option<Transaction> {
        self.refresh_watermark();
        if self.local_watermark == NO_WATERMARK {
            return None;
        }
        self.partitions()?;
        let mut txn = self.client.begin();
        let fired_wm = self.read_fired(&mut txn).ok()?;
        if self.local_watermark <= fired_wm {
            // Everything final was already fired at this watermark; scans
            // can't produce new candidates. (Windows can still be *open*
            // above the watermark — they are not final yet.)
            txn.abort();
            return None;
        }
        // Approximate tiers: the working accumulators live in memory, and
        // a fire only sees them through the txn — persist them first.
        // (Tick commits always carry the meta row, so this *is* an anchor.)
        let mut touched: Vec<((i64, String), Yson)> = Vec::new();
        if self.deps.consistency.is_approximate() && !self.resident.is_empty() {
            let spec = self.deps.spec;
            if fired_wm != NO_WATERMARK {
                self.resident.retain(|(w, _), _| !spec.is_final(*w, fired_wm));
            }
            let table = self.state_table();
            match self.persist_resident(&mut txn, &table, &[]) {
                Ok(entries) => touched = entries,
                Err(_) => {
                    txn.abort();
                    return None; // transient: retried next cycle
                }
            }
        }
        match self.fire_into(&mut txn, fired_wm, &touched) {
            Ok(0) | Err(_) => {
                txn.abort();
                None // nothing to do (or transient failure: retried next cycle)
            }
            Ok(_) => Some(txn),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::processor::ClusterEnv;
    use crate::coordinator::state::MapperState;
    use crate::row;
    use crate::rows::{NameTable, RowsetBuilder};
    use crate::util::{Clock, Guid};

    const MAPPER_STATE: &str = "//sys/w/mapper_state";
    const PLAN: &str = "//sys/w/reshard_plan";
    const STATE_BASE: &str = "//sys/w/window_state";
    const OUT: &str = "//out/windowed_test";

    /// Toy fold: count rows per key; emit (window, key, count).
    struct CountFold;

    impl WindowFold for CountFold {
        fn event_ts(&self, row: &UnversionedRow) -> Option<i64> {
            row.get(2).and_then(Value::as_i64)
        }
        fn key(&self, row: &UnversionedRow) -> Option<String> {
            row.get(0).and_then(Value::as_str).map(str::to_string)
        }
        fn zero(&self) -> Yson {
            Yson::Int(0)
        }
        fn fold(&self, acc: &mut Yson, _row: &UnversionedRow) {
            *acc = Yson::Int(acc.as_i64().unwrap_or(0) + 1);
        }
        fn merge(&self, into: &mut Yson, other: &Yson) {
            *into = Yson::Int(into.as_i64().unwrap_or(0) + other.as_i64().unwrap_or(0));
        }
        fn emit(
            &self,
            window_start: i64,
            _window_end: i64,
            key: &str,
            acc: &Yson,
            txn: &mut Transaction,
        ) -> Result<(), TxnError> {
            txn.write(
                OUT,
                row![window_start, key, acc.as_i64().unwrap_or(0)],
            )
        }
    }

    struct TestRig {
        env: ClusterEnv,
        deps: Arc<WindowedDeps>,
    }

    fn rig(partitions: usize) -> TestRig {
        rig_tier(partitions, Consistency::ExactlyOnce)
    }

    fn rig_tier(partitions: usize, consistency: Consistency) -> TestRig {
        let env = ClusterEnv::new(Clock::realtime(), 11);
        env.store
            .create_table(MAPPER_STATE, MapperState::schema(), WriteCategory::MapperMeta)
            .unwrap();
        env.store
            .create_table(PLAN, ReshardPlan::schema(), WriteCategory::Reshard)
            .unwrap();
        env.store
            .create_table(
                OUT,
                TableSchema::new(vec![
                    ColumnSchema::key("window_start", ColumnType::Int64),
                    ColumnSchema::key("key", ColumnType::Str),
                    ColumnSchema::value("count", ColumnType::Int64),
                ]),
                WriteCategory::UserOutput,
            )
            .unwrap();
        let mut txn = env.store.begin();
        txn.write(PLAN, ReshardPlan::initial(partitions).to_row()).unwrap();
        txn.commit().unwrap();
        let late = OrderedTable::new_with_category(
            "//sys/w/late",
            NameTable::new(&["key", "payload", "ts"]),
            partitions,
            env.accounting.clone(),
            WriteCategory::UserOutput,
        );
        let deps = Arc::new(WindowedDeps {
            spec: WindowSpec::tumbling(100),
            fold: Arc::new(CountFold),
            state_base: STATE_BASE.into(),
            plan_table: PLAN.into(),
            mapper_state_table: MAPPER_STATE.into(),
            late,
            metrics: env.metrics.clone(),
            scope: None,
            consistency,
            cold: None,
        });
        TestRig { env, deps }
    }

    fn set_watermark(env: &ClusterEnv, index: usize, wm: i64) {
        let mut txn = env.store.begin();
        let mut s = MapperState::initial();
        s.watermark_ms = wm;
        txn.write(MAPPER_STATE, s.to_row(index)).unwrap();
        txn.commit().unwrap();
    }

    fn reducer(rig: &TestRig, index: usize) -> WindowedReducer {
        let spec = ReducerSpec {
            processor_guid: Guid::from_seed(1),
            state_table: "unused".into(),
            index,
            guid: Guid::from_seed(2),
            num_mappers: 1,
            epoch: 0,
        };
        WindowedReducer::new(rig.deps.clone(), &rig.env.client(), &spec)
    }

    fn batch(rows: &[(&str, i64)]) -> UnversionedRowset {
        let mut b = RowsetBuilder::new(NameTable::new(&["key", "payload", "ts"]));
        for (k, ts) in rows {
            b.push(row![*k, "x", *ts]);
        }
        b.build()
    }

    /// The key used throughout these tests must be owned by reducer 0
    /// under 1 partition (trivially true).
    #[test]
    fn accumulates_then_final_fires_exactly_once() {
        let rig = rig(1);
        let mut r = reducer(&rig, 0);

        // Watermark below window end: fold only, no fire.
        set_watermark(&rig.env, 0, 50);
        let txn = r.reduce(batch(&[("a", 10), ("a", 20), ("b", 30)])).unwrap();
        txn.commit().unwrap();
        assert_eq!(rig.env.store.scan(OUT).unwrap().len(), 0, "window still open");
        let state = rig.env.store.scan(&window_state_table(STATE_BASE, 0)).unwrap();
        assert_eq!(state.len(), 2, "two open (window,key) accumulators");

        // Another batch folds into the same accumulators.
        let txn = r.reduce(batch(&[("a", 40)])).unwrap();
        txn.commit().unwrap();

        // Watermark passes window end: tick final-fires.
        set_watermark(&rig.env, 0, 100);
        let txn = r.tick().expect("windows are final");
        txn.commit().unwrap();
        let out = rig.env.store.scan(OUT).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].get(1).unwrap().as_str(), Some("a"));
        assert_eq!(out[0].get(2).unwrap().as_i64(), Some(3));
        assert_eq!(out[1].get(1).unwrap().as_str(), Some("b"));
        assert_eq!(out[1].get(2).unwrap().as_i64(), Some(1));
        // Fired state deleted; only the marker row remains.
        let state = rig.env.store.scan(&window_state_table(STATE_BASE, 0)).unwrap();
        assert_eq!(state.len(), 1);
        assert_eq!(state[0].get(0).unwrap().as_i64(), Some(MARKER_WINDOW));
        // Nothing more to fire.
        assert!(r.tick().is_none());
    }

    #[test]
    fn fire_rides_the_commit_cas_split_brain_loser_fires_nothing() {
        let rig = rig(1);
        let mut a = reducer(&rig, 0);
        let mut b = reducer(&rig, 0); // split-brain twin

        set_watermark(&rig.env, 0, 10);
        a.reduce(batch(&[("a", 5)])).unwrap().commit().unwrap();
        set_watermark(&rig.env, 0, 200);

        let ta = a.tick().expect("final window");
        let tb = b.tick().expect("twin sees it too");
        ta.commit().unwrap();
        assert!(tb.commit().is_err(), "loser conflicts on the window row");
        let out = rig.env.store.scan(OUT).unwrap();
        assert_eq!(out.len(), 1, "fired exactly once");
        assert_eq!(out[0].get(2).unwrap().as_i64(), Some(1));
    }

    #[test]
    fn rows_and_fires_in_one_batch_when_watermark_already_passed() {
        let rig = rig(1);
        let mut r = reducer(&rig, 0);
        // Watermark already past the window when its first row arrives:
        // not late (never fired here) — fold and fire in the same commit.
        set_watermark(&rig.env, 0, 500);
        let txn = r.reduce(batch(&[("a", 10)])).unwrap();
        txn.commit().unwrap();
        let out = rig.env.store.scan(OUT).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].get(2).unwrap().as_i64(), Some(1));
        assert_eq!(rig.deps.late.retained_rows(), 0);
    }

    #[test]
    fn late_rows_go_to_the_side_channel_not_the_output() {
        let rig = rig(1);
        let mut r = reducer(&rig, 0);
        set_watermark(&rig.env, 0, 500);
        // Fire window [0,100) with one row.
        r.reduce(batch(&[("a", 10)])).unwrap().commit().unwrap();
        // A straggler for the fired window: late.
        let txn = r.reduce(batch(&[("a", 20)])).unwrap();
        txn.commit().unwrap();
        assert_eq!(rig.deps.late.end_index(0), 1, "late row appended");
        let out = rig.env.store.scan(OUT).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].get(2).unwrap().as_i64(), Some(1), "result not rewritten");
    }

    #[test]
    fn allowed_lateness_keeps_windows_open_longer() {
        let spec = WindowSpec::tumbling(100).with_lateness(50);
        assert_eq!(spec.window_start(0), 0);
        assert_eq!(spec.window_start(99), 0);
        assert_eq!(spec.window_start(100), 100);
        assert_eq!(spec.window_start(-1), -100);
        assert!(!spec.is_final(0, 100));
        assert!(!spec.is_final(0, 149));
        assert!(spec.is_final(0, 150));

        let rig = rig(1);
        // Same geometry in the rig but with lateness.
        let deps = Arc::new(WindowedDeps {
            spec,
            fold: rig.deps.fold.clone(),
            state_base: rig.deps.state_base.clone(),
            plan_table: rig.deps.plan_table.clone(),
            mapper_state_table: rig.deps.mapper_state_table.clone(),
            late: rig.deps.late.clone(),
            metrics: rig.deps.metrics.clone(),
            scope: None,
            consistency: rig.deps.consistency,
            cold: None,
        });
        let spec0 = ReducerSpec {
            processor_guid: Guid::from_seed(1),
            state_table: "unused".into(),
            index: 0,
            guid: Guid::from_seed(3),
            num_mappers: 1,
            epoch: 0,
        };
        let mut r = WindowedReducer::new(deps, &rig.env.client(), &spec0);
        set_watermark(&rig.env, 0, 120);
        r.reduce(batch(&[("a", 10)])).unwrap().commit().unwrap();
        assert!(r.tick().is_none(), "within lateness: window still open");
        set_watermark(&rig.env, 0, 150);
        r.tick().expect("now final").commit().unwrap();
        assert_eq!(rig.env.store.scan(OUT).unwrap().len(), 1);
    }

    #[test]
    fn crash_rehydrates_from_the_persisted_accumulators() {
        let rig = rig(1);
        {
            let mut r = reducer(&rig, 0);
            set_watermark(&rig.env, 0, 10);
            r.reduce(batch(&[("a", 5), ("a", 7)])).unwrap().commit().unwrap();
            // r dropped here = crash; its memory is gone.
        }
        let mut fresh = reducer(&rig, 0);
        set_watermark(&rig.env, 0, 10);
        fresh.reduce(batch(&[("a", 9)])).unwrap().commit().unwrap();
        set_watermark(&rig.env, 0, 999);
        fresh.tick().expect("final").commit().unwrap();
        let out = rig.env.store.scan(OUT).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(
            out[0].get(2).unwrap().as_i64(),
            Some(3),
            "pre-crash folds survived in the window-state table"
        );
    }

    #[test]
    fn window_state_bytes_are_accounted_as_event_time() {
        let rig = rig(1);
        let mut r = reducer(&rig, 0);
        set_watermark(&rig.env, 0, 10);
        r.reduce(batch(&[("a", 5)])).unwrap().commit().unwrap();
        assert!(rig.env.accounting.bytes(WriteCategory::EventTime) > 0);
        assert_eq!(rig.env.accounting.bytes(WriteCategory::UserOutput), 0);
    }

    #[test]
    fn state_table_paths_per_epoch() {
        assert_eq!(window_state_table("//b", 0), "//b");
        assert_eq!(window_state_table("//b", 3), "//b/e3");
    }

    #[test]
    fn bounded_error_skips_state_writes_between_anchors() {
        let rig = rig_tier(
            1,
            Consistency::BoundedError {
                divergence_budget: 1_000_000,
                anchor_every_batches: 3,
            },
        );
        let mut r = reducer(&rig, 0);
        set_watermark(&rig.env, 0, 50); // window [0,100) stays open

        let state_table = window_state_table(STATE_BASE, 0);
        let acc_at = |rig: &TestRig| -> Option<i64> {
            rig.env
                .store
                .scan(&state_table)
                .unwrap()
                .iter()
                .find(|row| row.get(0).and_then(Value::as_i64) != Some(MARKER_WINDOW))
                .and_then(|row| row.get(2).and_then(Value::as_str).map(str::to_string))
                .and_then(|s| Yson::parse(&s).ok())
                .and_then(|y| y.as_i64().ok())
        };

        // First commit of the incarnation anchors: durable acc = 1.
        r.reduce(batch(&[("a", 10)])).unwrap().commit().unwrap();
        assert_eq!(acc_at(&rig), Some(1));
        // The next two batches fold in memory only — durable stays at 1.
        r.reduce(batch(&[("a", 20)])).unwrap().commit().unwrap();
        assert_eq!(acc_at(&rig), Some(1), "non-anchor batch must not persist");
        r.reduce(batch(&[("a", 30)])).unwrap().commit().unwrap();
        assert_eq!(acc_at(&rig), Some(1));
        // Cadence of 3 skipped-or-not batches since the anchor: this one
        // anchors and the durable accumulator catches up to all 4 folds.
        r.reduce(batch(&[("a", 40)])).unwrap().commit().unwrap();
        assert_eq!(acc_at(&rig), Some(4), "cadence anchor persists the folds");
        assert_eq!(
            rig.env
                .metrics
                .get_counter(crate::metrics::hub::names::REDUCER_SKIPPED_PERSISTS),
            2
        );

        // Final fire still emits the complete (resident) count.
        set_watermark(&rig.env, 0, 200);
        r.tick().expect("final").commit().unwrap();
        let out = rig.env.store.scan(OUT).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].get(2).unwrap().as_i64(), Some(4));
    }

    #[test]
    fn bounded_error_crash_recovers_from_anchor_with_bounded_drift() {
        let rig = rig_tier(
            1,
            Consistency::BoundedError {
                divergence_budget: 1_000_000,
                anchor_every_batches: 1_000_000,
            },
        );
        {
            let mut r = reducer(&rig, 0);
            set_watermark(&rig.env, 0, 50);
            // Anchor (first commit) holds 1; two more folds stay resident.
            r.reduce(batch(&[("a", 10)])).unwrap().commit().unwrap();
            r.reduce(batch(&[("a", 20)])).unwrap().commit().unwrap();
            r.reduce(batch(&[("a", 30)])).unwrap().commit().unwrap();
            // r dropped = crash; the resident folds (rows 20, 30) are gone.
        }
        let mut fresh = reducer(&rig, 0);
        set_watermark(&rig.env, 0, 50);
        // The fresh incarnation seeds from the anchor (1) and folds on.
        fresh.reduce(batch(&[("a", 40)])).unwrap().commit().unwrap();
        set_watermark(&rig.env, 0, 999);
        fresh.tick().expect("final").commit().unwrap();
        let out = rig.env.store.scan(OUT).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(
            out[0].get(2).unwrap().as_i64(),
            Some(2),
            "recovered from the anchor: 4 rows in, 2 counted — the 2 lost \
             rows are exactly the unanchored exposure, never more"
        );
    }

    #[test]
    fn at_most_once_persists_nothing_until_a_fire() {
        let rig = rig_tier(1, Consistency::AtMostOnce);
        let mut r = reducer(&rig, 0);
        set_watermark(&rig.env, 0, 50);
        r.reduce(batch(&[("a", 10)])).unwrap().commit().unwrap();
        r.reduce(batch(&[("a", 20)])).unwrap().commit().unwrap();
        assert_eq!(
            rig.env.store.scan(&window_state_table(STATE_BASE, 0)).unwrap().len(),
            0,
            "at-most-once writes no steady-state window rows"
        );
        // Once the window is final the fire persists-and-emits in one txn
        // (the row at 250 opens a later, still-open window).
        set_watermark(&rig.env, 0, 200);
        r.reduce(batch(&[("b", 250)])).unwrap().commit().unwrap();
        let out = rig.env.store.scan(OUT).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].get(1).unwrap().as_str(), Some("a"));
        assert_eq!(out[0].get(2).unwrap().as_i64(), Some(2));
    }

    #[test]
    fn approximate_twin_fire_is_still_single_shot() {
        // The fire itself rides the commit CAS under every tier: a twin
        // racing the same final window conflicts and emits nothing.
        let rig = rig_tier(1, Consistency::bounded_error(1_000_000));
        let mut a = reducer(&rig, 0);
        let mut b = reducer(&rig, 0);
        set_watermark(&rig.env, 0, 10);
        a.reduce(batch(&[("a", 5)])).unwrap().commit().unwrap();
        b.reduce(batch(&[("a", 5)])).unwrap().commit().unwrap();
        set_watermark(&rig.env, 0, 200);
        let ta = a.tick().expect("final window");
        let tb = b.tick().expect("twin sees it too");
        ta.commit().unwrap();
        assert!(tb.commit().is_err(), "loser conflicts on the window row");
        let out = rig.env.store.scan(OUT).unwrap();
        assert_eq!(out.len(), 1, "fired exactly once despite the twin");
    }
}
