//! Event-time windowing: watermarks, final-fire window reducers, and
//! cross-reshard window-state migration.
//!
//! The paper's processor persists only meta-state, yet every shared-table
//! workload still re-commits per-batch *upserts* into the output dyntable
//! — a key touched by k batches is written k times, so `UserOutput` bytes
//! dominate the WA numerator. This subsystem turns that O(batches per
//! key) term into O(1) per window:
//!
//! * [`watermark`] — each mapper tracks a low-water event time over its
//!   routed rows and persists it as the `watermark_ms` column of its
//!   meta-state row (no new write path: it rides the `TrimInputRows`
//!   CAS). The **fleet watermark** is the min over live (non-retired)
//!   mappers, computed by [`WatermarkTracker`]; it never regresses across
//!   kills, twins, or reshards. "+∞" is an explicit *source close*
//!   marker written by the driver after the last append.
//! * [`windowed`] — [`WindowedReducer`] adapts a [`WindowFold`] into the
//!   reducer contract: tumbling windows + allowed lateness + a late-row
//!   side channel, with open-window accumulators persisted in the commit
//!   transaction (accounted [`crate::storage::WriteCategory::EventTime`])
//!   and each window's result emitted into `UserOutput` exactly once when
//!   the watermark passes window end — final-fire rides the existing
//!   row-index CAS, no new mechanism.
//! * [`migrate`] — [`WindowMigrators`] is the first real
//!   [`crate::reshard::ResidualExporter`]/`Importer` pair: retiring
//!   reducers serialize their open windows (and fired markers) into the
//!   migration handoff, new reducers rehydrate them keyed by the
//!   post-reshard partition map — windows survive N→M resizes with
//!   exactly-once final-fire.
//!
//! Topology propagation lives in [`crate::dataflow`]: an emitting stage's
//! watermark caps its downstream consumers (rows still buffered upstream
//! can never be overtaken), and
//! [`crate::dataflow::RunningTopology::close_event_time_cascade`] walks
//! the close marker down the chain so cascaded drain extends to
//! "watermark reached +∞".

pub mod migrate;
pub mod watermark;
pub mod windowed;

pub use migrate::{WindowMigrators, WindowResidualExporter, WindowResidualImporter};
pub use watermark::{
    close_source, close_table_path, fetch_close, WatermarkTracker, EVENT_TIME_CLOSED, NO_WATERMARK,
};
pub use windowed::{
    window_state_table, windowed_reducer_factory, WindowFold, WindowSpec, WindowedDeps,
    WindowedReducer,
};
